"""Async serving benchmark: rank-k factor update vs full refactor.

The straggler hot path: the server holds a live Cholesky factor and clients
with small local batches (n_k ≪ d) trickle in, each arrival immediately
followed by a ``solve()`` poll. Two ways to absorb an arrival:

  * **update**  — fold the arrival's (n_k, d) root into the cached factor,
    O(n_k·d²) (``engine.factor_update`` via ``AFLServer.submit``);
  * **refactor** — invalidate and re-factorize the d×d aggregate, O(d³)
    (the pre-PR-2 behavior: every submit cleared the cache).

Reported: median arrival→solve latency per straggler for both paths, the
speedup, and an async end-to-end run (`AsyncAFLServer`, submissions +
solves through the event loop) for the update path. The crossover the
numbers show (see ROADMAP): at d=512 small-batch updates edge out the
refactor; at d≥2048 they win clearly (2.4× at n_k=8) and the crossover
sits near n_k ≈ d/16 — past it the sweep loses and the server should (and
by default does) refactor instead.
"""

from __future__ import annotations

import asyncio
import dataclasses
import time

import numpy as np

from repro.fl import AsyncAFLServer
from repro.fl import AFLServer, make_report

from benchmarks.common import print_table


def _prime_server(d, c, gamma=1.0, **kw) -> AFLServer:
    """A server whose aggregate is already full-rank PD (2d warm samples)."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((2 * d, d))
    y = np.eye(c)[rng.integers(0, c, 2 * d)]
    srv = AFLServer(d, c, gamma=gamma, **kw)
    srv.submit(make_report(0, x, y, gamma))
    srv.solve()                                    # factor in cache
    return srv

def _arrivals(d, c, n_k, count, gamma=1.0, start_id=1):
    rng = np.random.default_rng(1)
    reps = []
    for i in range(count):
        x = rng.standard_normal((n_k, d))
        y = np.eye(c)[rng.integers(0, c, n_k)]
        reps.append(make_report(start_id + i, x, y, gamma))
    return reps


def _bench_arrival_solve(d, c, n_k, arrivals, repeat=2):
    """Median per-arrival (submit + solve) wall time, update vs refactor."""
    def run(strip_root):
        # budget pinned to n_k so BOTH sides of the crossover get measured
        # (the production default d//16 would refuse the losing updates)
        srv = _prime_server(d, c, update_rank_budget=n_k)
        times = []
        for rep in _arrivals(d, c, n_k, arrivals):
            if strip_root:
                rep = dataclasses.replace(rep, root=None)
            t0 = time.perf_counter()
            srv.submit(rep)
            srv.solve()
            times.append(time.perf_counter() - t0)
        return float(np.median(times))

    t_upd = min(run(strip_root=False) for _ in range(repeat))
    t_ref = min(run(strip_root=True) for _ in range(repeat))
    return t_upd, t_ref


def _bench_async_end_to_end(d, c, n_k, arrivals):
    """Arrival→solve latency through the event loop (queue + worker +
    deferred-refactor policy), update path."""
    reps = _arrivals(d, c, n_k, arrivals)

    async def scenario():
        # adopt the primed state so the loop starts with a live factor
        primed = _prime_server(d, c, update_rank_budget=n_k)
        async with AsyncAFLServer(d, c, gamma=1.0, server=primed) as srv:
            lat = []
            for rep in reps:
                t0 = time.perf_counter()
                await srv.submit(rep)
                await srv.join()
                await srv.solve()
                lat.append(time.perf_counter() - t0)
            return float(np.median(lat)), srv.updates, srv.deferred_refactors

    return asyncio.run(scenario())


def run(quick: bool = False) -> list[dict]:
    # (d, C, n_k, arrivals); full mode spans the paper's 512–6144 range
    cases = [(256, 20, 8, 6), (512, 50, 8, 6)] if quick else [
        (512, 50, 8, 8), (512, 50, 64, 8),
        (2048, 100, 8, 6), (2048, 100, 64, 6), (2048, 100, 256, 4),
        (6144, 100, 64, 3),
    ]
    rows, out = [], []
    for d, c, n_k, arrivals in cases:
        t_u, t_r = _bench_arrival_solve(d, c, n_k, arrivals)
        speed = t_r / max(t_u, 1e-12)
        rows.append([f"d={d} C={c} n_k={n_k}",
                     f"{1e3 * t_u:.1f}", f"{1e3 * t_r:.1f}", f"{speed:.1f}x"])
        out.append(dict(bench="arrival_solve", d=d, c=c, n_k=n_k,
                        arrivals=arrivals, update_s=t_u, refactor_s=t_r,
                        speedup=speed))
    print_table(
        "Straggler arrival→solve latency: rank-n_k factor update vs refactor",
        ["case", "update ms", "refactor ms", "speedup"], rows)

    rows2 = []
    for d, c, n_k, arrivals in ([cases[0]] if quick else [cases[2]]):
        t_async, n_upd, n_ref = _bench_async_end_to_end(d, c, n_k, arrivals)
        rows2.append([f"d={d} n_k={n_k} x{arrivals}",
                      f"{1e3 * t_async:.1f}", f"{n_upd}", f"{n_ref}"])
        out.append(dict(bench="async_end_to_end", d=d, c=c, n_k=n_k,
                        arrivals=arrivals, median_latency_s=t_async,
                        updates=n_upd, deferred_refactors=n_ref))
    print_table(
        "AsyncAFLServer end-to-end (queue + policy), update path",
        ["case", "median ms/arrival", "updates", "deferred refactors"], rows2)
    return out


if __name__ == "__main__":
    run()
