"""Beyond-paper: non-linear analytic heads (paper §5 future work).

AFL with kernel/activation feature maps φ before the Gram statistics: the
regression stays linear in φ-space, so exactness and partition invariance
hold verbatim while the head becomes non-linear in the inputs. Benchmarked
on (a) a linearly-inseparable XOR-style task and (b) the shared feature task.
"""

from __future__ import annotations

import numpy as np

from repro.config import FLConfig
from repro.core.features import relu_map, rff_map
from repro.data import synthetic as D
from repro.fl import afl

from benchmarks.common import feature_data, print_table


def _rings(n, seed=0):
    """Two concentric rings — rotation-invariant, linearly inseparable."""
    rng = np.random.default_rng(seed)
    r = np.where(rng.random(n) < 0.5, 1.0, 2.2)
    th = rng.uniform(0, 2 * np.pi, n)
    x = np.stack([r * np.cos(th), r * np.sin(th)], 1)
    x += rng.standard_normal((n, 2)) * 0.15
    return D.Dataset(x.astype(np.float32), (r > 1.5).astype(int), 2)


def run(quick: bool = False) -> list[dict]:
    n = 2000 if quick else 6000
    fl = FLConfig(num_clients=10 if quick else 40, partition="niid1", alpha=0.1)
    rows, out = [], []
    for name, ds in [("rings(2d)", _rings(n))]:
        train, test = D.train_test_split(ds, 0.25, seed=0)
        d_in = train.x.shape[1]
        lin = afl.run_afl(train, test, fl)
        rff = afl.run_afl(train, test, fl,
                          feature_map=rff_map(d_in, 512, lengthscale=0.7, seed=1))
        relu = afl.run_afl(train, test, fl,
                           feature_map=relu_map(d_in, 512, seed=1))
        rows.append([name, f"{lin.accuracy:.4f}", f"{rff.accuracy:.4f}",
                     f"{relu.accuracy:.4f}"])
        out.append(dict(task=name, linear=lin.accuracy, rff=rff.accuracy,
                        relu=relu.accuracy))
    # the standard feature task: φ should not hurt
    train, test = feature_data()
    d_in = train.x.shape[1]
    lin = afl.run_afl(train, test, fl)
    rff = afl.run_afl(train, test, fl,
                      feature_map=rff_map(d_in, 1024, lengthscale=8.0, seed=2))
    rows.append(["features(128d)", f"{lin.accuracy:.4f}",
                 f"{rff.accuracy:.4f}", "-"])
    out.append(dict(task="features", linear=lin.accuracy, rff=rff.accuracy))
    print_table("Beyond-paper — non-linear analytic heads (AFL, single round)",
                ["task", "linear", "RFF-512/1024", "ReLU-512"], rows)
    return out
