"""Beyond-paper: partial participation & stragglers (paper §5 limitation).

The paper flags waiting-for-all-clients as AFL's open operational problem.
The AA law dissolves it: the server's running aggregate is the *exact* joint
solution over whichever clients have reported. We simulate a straggler
timeline and report accuracy as arrivals accumulate, plus the same timeline
under SecAgg-style pairwise masking (bit-exact for AFL's sum-aggregation).
"""

from __future__ import annotations

import numpy as np

from repro.fl.afl import evaluate
from repro.fl.partition import make_partition
from repro.fl import AFLServer, make_report, masked_reports

from benchmarks.common import feature_data, print_table


def run(quick: bool = False) -> list[dict]:
    train, test = feature_data()
    k = 20 if quick else 50
    d, c = train.x.shape[1], train.num_classes
    y_onehot = np.eye(c)[train.y]
    parts = make_partition(train.y, k, "niid1", alpha=0.1, seed=0)
    reports = [make_report(i, train.x[idx], y_onehot[idx], 1.0)
               for i, idx in enumerate(parts)]
    rng = np.random.default_rng(1)
    arrival = rng.permutation(k)        # stragglers = late arrivals

    srv = AFLServer(d, c, gamma=1.0)
    rows, out = [], []
    checkpoints = [max(1, k // 10), k // 4, k // 2, 3 * k // 4, k]
    seen = 0
    for stop in checkpoints:
        while seen < stop:
            srv.submit(reports[arrival[seen]])
            seen += 1
        acc = evaluate(srv.solve(), test.x, test.y)
        rows.append([f"{stop}/{k}", f"{acc:.4f}"])
        out.append(dict(arrived=stop, accuracy=acc))
    print_table(
        "Beyond-paper — accuracy vs clients arrived (exact at every point; "
        "no rounds, no staleness)", ["arrived", "accuracy"], rows)

    # masked protocol: identical final aggregate
    srv_m = AFLServer(d, c, gamma=1.0)
    srv_m.submit_many(masked_reports(reports, seed=3))
    acc_m = evaluate(srv_m.solve(), test.x, test.y)
    dev = float(np.abs(srv_m.solve() - srv.solve()).max())
    print(f"secure (pairwise-masked) aggregation: acc={acc_m:.4f}, "
          f"max |ΔW| vs unmasked = {dev:.2e}")
    out.append(dict(masked_accuracy=acc_m, masked_deviation=dev))
    return out
