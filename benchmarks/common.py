"""Shared helpers for the per-table benchmark modules.

Offline substitution (see DESIGN.md §2): the paper's CIFAR/Tiny-ImageNet +
ImageNet-pretrained backbones are unavailable here, so the accuracy tables run
on a synthetic Gaussian-mixture feature task whose difficulty is tuned so the
paper's *qualitative* structure reproduces (gradient FL degrades with
heterogeneity; AFL is invariant and matches the joint solve exactly). The
exactness/invariance results (ΔW tables) are backbone-independent and
reproduce the paper's numbers in kind.
"""

from __future__ import annotations

import time
from typing import Callable

from repro.data import synthetic as D

# One moderately hard feature task shared by the accuracy tables.
FEATURES = dict(n=8_000, dim=128, num_classes=40, separation=0.45, seed=0)


def feature_data():
    ds = D.gaussian_mixture(**FEATURES)
    return D.train_test_split(ds, 0.25, seed=0)


def fmt_row(cells, widths):
    return " | ".join(str(c).ljust(w) for c, w in zip(cells, widths))


def print_table(title: str, header: list, rows: list) -> None:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(header)]
    print(f"\n== {title}")
    print(fmt_row(header, widths))
    print("-+-".join("-" * w for w in widths))
    for r in rows:
        print(fmt_row(r, widths))


def timed(fn: Callable):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0
