"""Elastic federation benchmark: what a migration actually costs.

Three operational moves introduced by the elastic rung, each timed wall-clock
so the runbook in README §"Operate it" can quote real numbers:

  * **reshard restore** — ``ShardedCoordinator.from_state(state,
    num_shards=n)``: cold-start a checkpoint onto a different shard count.
    The AA law makes this exact (merge = migration), so the only cost is the
    disjoint row-block split + device placement, O(d²) per shard.
  * **live grow/shrink** — ``coord.grow(n)`` / ``coord.shrink(n)`` on a
    serving coordinator: merge/fold of per-shard statistics plus solve-cache
    invalidation, no checkpoint round-trip.
  * **snapshot cycle** — ``SnapshotDaemon.snapshot_once`` (state pull +
    versioned directory write) and the matching ``restore`` back into a
    coordinator: the failover path's RPO tick and its recovery wall.

Each row reports the post-move solve parity against a single-server oracle
(``dw``) alongside the wall — the benchmark doubles as an exactness audit at
benchmark scale (d here ≫ the unit-test d=24).
"""

from __future__ import annotations

import time

import numpy as np

from repro.checkpoint import SnapshotDaemon
from repro.fl import AFLServer, ShardedCoordinator, make_report

from benchmarks.common import print_table

GAMMA = 1.0


def _population(d, c, n_clients, rows_each, seed=0):
    rng = np.random.default_rng(seed)
    n = n_clients * rows_each
    x = rng.standard_normal((n, d))
    y = np.eye(c)[rng.integers(0, c, n)]
    return [make_report(k, x[k * rows_each:(k + 1) * rows_each],
                        y[k * rows_each:(k + 1) * rows_each], GAMMA)
            for k in range(n_clients)]


def _dw(coord, oracle_w) -> float:
    return float(np.abs(np.asarray(coord.solve(), np.float64)
                        - oracle_w).max())


def run(quick: bool = False):
    d, c = (256, 20) if quick else (1024, 50)
    n_clients, rows_each = (16, 32) if quick else (64, 64)
    reps = _population(d, c, n_clients, rows_each)

    oracle = AFLServer(d, c, gamma=GAMMA)
    oracle.submit_many(reps)
    oracle_w = np.asarray(oracle.solve(), np.float64)
    state = oracle.state()

    rows = []

    # -- reshard restore: checkpoint → n shards, n sweeping the mesh sizes
    for n in (1, 2, 4, 8):
        t0 = time.perf_counter()
        coord = ShardedCoordinator.from_state(state, num_shards=n)
        restore_s = time.perf_counter() - t0
        rows.append({"bench": "reshard_restore", "d": d, "shards": n,
                     "restore_s": round(restore_s, 4),
                     "dw": _dw(coord, oracle_w)})

    # -- live resize on a serving coordinator (no checkpoint round-trip)
    coord = ShardedCoordinator(d, c, gamma=GAMMA, num_shards=2)
    coord.submit_many(reps)
    coord.solve()
    t0 = time.perf_counter()
    coord.grow(6)                       # 2 → 8
    grow_s = time.perf_counter() - t0
    dw_grow = _dw(coord, oracle_w)
    t0 = time.perf_counter()
    coord.shrink(6)                     # 8 → 2
    shrink_s = time.perf_counter() - t0
    rows.append({"bench": "live_resize", "d": d, "shards": 8,
                 "grow_s": round(grow_s, 4),
                 "shrink_s": round(shrink_s, 4),
                 "dw": max(dw_grow, _dw(coord, oracle_w))})

    # -- snapshot cycle: daemon pull+write, then cold-start restore
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        daemon = SnapshotDaemon(oracle, directory=tmp, interval=3600)
        t0 = time.perf_counter()
        daemon.snapshot_once()
        snap_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        restored = daemon.restore(cls=ShardedCoordinator, num_shards=4)
        restore_s = time.perf_counter() - t0
        rows.append({"bench": "snapshot_cycle", "d": d, "shards": 4,
                     "snapshot_s": round(snap_s, 4),
                     "restore_s": round(restore_s, 4),
                     "dw": _dw(restored, oracle_w)})

    print_table(
        f"Elastic federation — migration cost (d={d}, C={c}, "
        f"{n_clients} clients)",
        ["bench", "shards", "wall", "max|ΔW| vs oracle"],
        [[r["bench"], r["shards"],
          " ".join(f"{k[:-2]}={r[k]*1e3:.1f}ms"
                   for k in r if k.endswith("_s")),
          f"{r['dw']:.2e}"] for r in rows])
    return rows


if __name__ == "__main__":
    run(quick=True)
