"""Engine benchmark: repeated-solve throughput with cached factorization.

The serving scenario behind AFLServer's cache: clients trickle in and the
server is polled for the current joint weight after (or between) every
arrival. Without caching every poll pays the full d³ Cholesky; with the
cached factorization only polls that follow a NEW submission refactor, and
every other poll is a pair of d²·C triangular solves.

Also measures the multi-γ sweep: one eigendecomposition amortized over the
whole γ grid vs a fresh factorization per γ.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.engine import AnalyticEngine
from repro.fl import AFLServer, make_report

from benchmarks.common import print_table


def _bench_polls(d, c, k, polls, repeat=3):
    """Median wall time for ``polls`` straggler polls against a static
    aggregate: cached (AFLServer) vs uncached (fresh engine.solve each)."""
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((k, max(2 * d // k, 4), d))
    ys = np.eye(c)[rng.integers(0, c, xs.shape[:2])]
    srv = AFLServer(d, c, gamma=1.0)
    srv.submit_many(make_report(i, xs[i], ys[i], 1.0) for i in range(k))
    eng = srv.engine
    stats = srv._stats

    def run_cached():
        srv._factor_cache.clear()
        for _ in range(polls):
            srv.solve()

    def run_uncached():
        for _ in range(polls):
            eng.solve(stats)           # refactors every poll

    t_cached = min(_time(run_cached) for _ in range(repeat))
    t_uncached = min(_time(run_uncached) for _ in range(repeat))
    return t_cached, t_uncached


def _bench_multi_gamma(d, c, gammas, repeat=3):
    rng = np.random.default_rng(1)
    x = rng.standard_normal((4 * d, d))
    y = np.eye(c)[rng.integers(0, c, 4 * d)]
    eng = AnalyticEngine("numpy_f64", gamma=1.0)
    stats = eng.client_stats(x, y)

    def run_sweep():
        eng.solve_multi_gamma(stats, gammas)

    def run_loop():
        for g in gammas:
            eng.solve(stats, target_gamma=g)

    return (min(_time(run_sweep) for _ in range(repeat)),
            min(_time(run_loop) for _ in range(repeat)))


def _time(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(quick: bool = False) -> list[dict]:
    sizes = [(256, 50, 16, 50)] if quick else [
        (256, 50, 16, 50), (512, 100, 32, 50), (1024, 100, 32, 20),
    ]
    rows, out = [], []
    for d, c, k, polls in sizes:
        t_c, t_u = _bench_polls(d, c, k, polls)
        speed = t_u / max(t_c, 1e-12)
        rows.append([f"poll d={d} C={c} K={k} x{polls}",
                     f"{1e3 * t_c / polls:.2f}", f"{1e3 * t_u / polls:.2f}",
                     f"{speed:.1f}x"])
        out.append(dict(bench="cached_solve", d=d, c=c, k=k, polls=polls,
                        cached_s=t_c, uncached_s=t_u, speedup=speed))
    print_table(
        "AFLServer repeated solve: cached factorization vs refactor-per-poll",
        ["case", "cached ms/poll", "uncached ms/poll", "speedup"], rows)

    gammas = list(np.logspace(-3, 2, 6 if quick else 12))
    rows2 = []
    for d, c in ([(256, 50)] if quick else [(256, 50), (512, 100)]):
        t_sweep, t_loop = _bench_multi_gamma(d, c, gammas)
        rows2.append([f"γ-sweep d={d} C={c} |γ|={len(gammas)}",
                      f"{1e3 * t_sweep:.1f}", f"{1e3 * t_loop:.1f}",
                      f"{t_loop / max(t_sweep, 1e-12):.1f}x"])
        out.append(dict(bench="multi_gamma", d=d, c=c, n_gammas=len(gammas),
                        sweep_s=t_sweep, loop_s=t_loop))
    print_table("Multi-γ model sweep: one eigh vs per-γ factorization",
                ["case", "sweep ms", "loop ms", "speedup"], rows2)
    return out


if __name__ == "__main__":
    run()
