"""Environment truth for recorded benchmark numbers (SNIPPETS.md).

A benchmark number is only comparable run-over-run if the process
environment that produced it is pinned. This module bakes the flag set the
reference JAX-on-CPU setups use:

  * ``JAX_ENABLE_X64=1`` + ``JAX_DEFAULT_DTYPE_BITS=32`` — the double
    config: f64 is *allowed* (host-f64 statistics stay f64 on device, the
    1e-10 parity configuration) but nothing is *forced* to it (python
    scalars / fresh arrays still default to 32-bit).
  * ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — a fixed fake
    device count so mesh-shaped benches see the same topology everywhere
    (subprocess benches that need a specific count still override their own
    environment before importing jax).
  * ``--xla_step_marker_location=STEP_MARK_AT_TOP_LEVEL_WHILE_LOOP`` —
    step markers at the outer while loop, so profiles/cost analyses cut at
    the same boundary (the reference setups spell this ``=1``, the TPU
    runtime's numeric form; CPU jaxlib only parses the enum name).
  * ``TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD`` — silence the large-alloc
    warnings that would interleave with the printed tables. The tcmalloc
    ``LD_PRELOAD`` itself cannot be applied after process start — shell
    entry points (``tools/check.sh``) export it; here it is only recorded.

``apply()`` must run before the first ``import jax`` anywhere in the
process (env vars are read at import). Existing values are respected (a
caller that exports its own flags is presumed to mean them) and the
*effective* set is returned so the run can be recorded next to its numbers
in ``results/bench/BENCH_solve.json`` — that record is what makes an entry
auditable when a later run disagrees with it.
"""

from __future__ import annotations

import os
import platform
from typing import Dict

DEVICE_COUNT = 8        # the mesh width every sharded bench/test assumes

_ENV_TRUTH = {
    "JAX_ENABLE_X64": "1",
    "JAX_DEFAULT_DTYPE_BITS": "32",
    "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000",
}

_XLA_FLAGS = (
    f"--xla_force_host_platform_device_count={DEVICE_COUNT}",
    "--xla_step_marker_location=STEP_MARK_AT_TOP_LEVEL_WHILE_LOOP",
)


def apply(device_count: int | None = None) -> Dict[str, str]:
    """Set the env-truth flags (respecting existing values) and return the
    effective set. Call before the first jax import."""
    for key, val in _ENV_TRUTH.items():
        os.environ.setdefault(key, val)
    flags = list(_XLA_FLAGS)
    if device_count is not None:
        flags[0] = f"--xla_force_host_platform_device_count={device_count}"
    existing = os.environ.get("XLA_FLAGS", "")
    missing = [f for f in flags
               if f.split("=")[0] not in existing]
    if missing:
        os.environ["XLA_FLAGS"] = " ".join(
            ([existing] if existing else []) + missing)
    return snapshot()


def snapshot() -> Dict[str, str]:
    """The effective env-truth set of THIS process, for the bench record."""
    out = {k: os.environ.get(k, "") for k in _ENV_TRUTH}
    out["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "")
    out["LD_PRELOAD"] = os.environ.get("LD_PRELOAD", "")
    out["platform"] = platform.platform()
    out["cpu_count"] = str(os.cpu_count())
    return out
