"""Paper Figure 2: client-number invariance — accuracy vs K (100→1000).

Paper: FedAvg 56.57%→41.01% as K grows 100→1000; AFL identical throughout.
K=1000 here means N_k ≈ 6 < d=128 per client — the rank-deficient regime the
RI process exists for. The AFL column additionally runs through the
:class:`~repro.fl.api.ShardedCoordinator` (the K≥1000 backend: reports
round-robin into per-shard accumulators, one psum collective at solve time)
to show the sharded path lands on the same invariant accuracy.
"""

from __future__ import annotations

import numpy as np

from repro.config import FLConfig
from repro.fl import AFLClient, ShardedCoordinator, afl, baselines
from repro.fl.partition import make_partition

from benchmarks.common import feature_data, print_table


def afl_sharded(train, test, fl: FLConfig):
    """AFL end-to-end through the sharded coordinator; returns (accuracy,
    coordinator) so callers can inspect shard placement."""
    y_onehot = np.eye(train.num_classes)[train.y]
    parts = make_partition(train.y, fl.num_clients, fl.partition,
                           alpha=fl.alpha,
                           shards_per_client=fl.shards_per_client,
                           seed=fl.seed)
    coord = ShardedCoordinator(train.x.shape[1], train.num_classes,
                               gamma=fl.gamma)
    for cid, idx in enumerate(parts):
        coord.submit(AFLClient(cid, gamma=fl.gamma).local_stage(
            train.x[idx], y_onehot[idx]))
    return afl.evaluate(coord.solve(), test.x, test.y), coord


def run(quick: bool = False) -> list[dict]:
    train, test = feature_data()
    ks = [50, 200] if quick else [100, 500, 1000]
    rounds = 10 if quick else 20
    rows, out = [], []
    for k in ks:
        fl = FLConfig(num_clients=k, partition="niid1", alpha=0.1)
        fa = baselines.run_gradient_fl(train, test, fl, rounds=rounds)
        res = afl.run_afl(train, test, fl)
        acc_sh, coord = afl_sharded(train, test, fl)
        rows.append([k, f"{fa.accuracy:.4f}", f"{res.accuracy:.4f}",
                     f"{acc_sh:.4f}"])
        out.append(dict(clients=k, fedavg=fa.accuracy, afl=res.accuracy,
                        afl_sharded=acc_sh, shards=coord.num_shards))
    print_table("Figure 2 analogue — client-number invariance (NIID-1 a=0.1)",
                ["K", "FedAvg", "AFL", "AFL (sharded)"], rows)
    return out
