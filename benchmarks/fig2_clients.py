"""Paper Figure 2: client-number invariance — accuracy vs K (100→1000).

Paper: FedAvg 56.57%→41.01% as K grows 100→1000; AFL identical throughout.
K=1000 here means N_k ≈ 6 < d=128 per client — the rank-deficient regime the
RI process exists for.
"""

from __future__ import annotations

from repro.config import FLConfig
from repro.fl import afl, baselines

from benchmarks.common import feature_data, print_table


def run(quick: bool = False) -> list[dict]:
    train, test = feature_data()
    ks = [50, 200] if quick else [100, 500, 1000]
    rounds = 10 if quick else 20
    rows, out = [], []
    for k in ks:
        fl = FLConfig(num_clients=k, partition="niid1", alpha=0.1)
        fa = baselines.run_gradient_fl(train, test, fl, rounds=rounds)
        res = afl.run_afl(train, test, fl)
        rows.append([k, f"{fa.accuracy:.4f}", f"{res.accuracy:.4f}"])
        out.append(dict(clients=k, fedavg=fa.accuracy, afl=res.accuracy))
    print_table("Figure 2 analogue — client-number invariance (NIID-1 a=0.1)",
                ["K", "FedAvg", "AFL"], rows)
    return out
