"""Paper Figure 3 / §4.3: training efficiency — single-round AFL vs
multi-round gradient FL wall-clock on the same task.

Paper: FL methods need 500 rounds × 60–160 s ≈ 30k–80k s; AFL finishes in
236–350 s → ~150–200× speedup. Offline we measure the per-round cost of
FedAvg on the shared feature task, extrapolate to the paper's 500 rounds,
and measure AFL's one-shot cost directly.
"""

from __future__ import annotations

from repro.config import FLConfig
from repro.fl import afl, baselines

from benchmarks.common import feature_data, print_table

PAPER_ROUNDS = 500


def run(quick: bool = False) -> list[dict]:
    train, test = feature_data()
    num_clients = 20 if quick else 50
    measured_rounds = 5 if quick else 20
    fl = FLConfig(num_clients=num_clients, partition="niid1", alpha=0.1)
    fa = baselines.run_gradient_fl(train, test, fl, rounds=measured_rounds)
    per_round = fa.train_seconds / fa.rounds
    fa_total = per_round * PAPER_ROUNDS
    res = afl.run_afl(train, test, fl)
    speedup = fa_total / res.train_seconds
    rows = [
        ["FedAvg", f"{per_round*1e3:.1f} ms/round",
         f"{fa_total:.1f} s ({PAPER_ROUNDS} rounds)", f"{fa.accuracy:.4f}"],
        ["AFL", "single round", f"{res.train_seconds:.2f} s", f"{res.accuracy:.4f}"],
    ]
    print_table(
        f"Figure 3 analogue — wall clock (K={num_clients}); "
        f"AFL speedup ≈ {speedup:.0f}x (paper: 150–200x)",
        ["method", "per-round", "total", "best acc"], rows)
    return [dict(fedavg_per_round_s=per_round, fedavg_total_s=fa_total,
                 afl_s=res.train_seconds, speedup=speedup)]
