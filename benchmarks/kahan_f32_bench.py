"""Kahan-compensated f32 vs f64-on-device — through the AFLClient path.

The ROADMAP's f64 item left one half open: is compensated-f32 accumulation
(``kahan=True``) a viable cheap substitute for enabling x64 on device? This
benchmark answers it on the canonical client path — ``AFLClient.update``
folding many batches into engine SuffStats, ``report()`` emitting the wire
report — comparing three device configurations against the host numpy-f64
reference:

  * ``jax f32``         — plain f32 accumulation (the default device mode)
  * ``jax f32+kahan``   — compensated accumulation (2× adds, same dtype)
  * ``jax f64``         — x64 end-to-end (toggled for the run, restored
                          after, mirroring the scoped-x64 conformance test)

Reported per (d, batches): max relative error of the accumulated Gram and
moment vs the host-f64 reference, and the wall time of the whole local
stage. The accumulation uses offset features (μ=1) so plain-f32
cancellation drift actually shows at realistic batch counts.

  PYTHONPATH=src python -m benchmarks.kahan_f32_bench
"""

from __future__ import annotations

import time

import numpy as np


def _rel_err(a, b):
    scale = max(float(np.abs(b).max()), 1e-30)
    return float(np.abs(np.asarray(a, np.float64) - b).max() / scale)


def _local_stage(make_client, batches):
    client = make_client()
    t0 = time.perf_counter()
    for x, y in batches:
        client.update(x, y)
    report = client.report()          # materializes on host: device sync
    dt = time.perf_counter() - t0
    return report, dt


def _bench_case(dim, classes, n_batches, batch_rows, seed=0):
    import jax
    import jax.numpy as jnp

    from repro.fl.api import AFLClient

    rng = np.random.default_rng(seed)
    # offset features: Gram entries grow ~n·(1+ρ), the accumulation regime
    # where plain f32 loses digits batch over batch
    batches = [
        (rng.standard_normal((batch_rows, dim)).astype(np.float32) + 1.0,
         np.eye(classes, dtype=np.float32)[
             rng.integers(0, classes, batch_rows)])
        for _ in range(n_batches)
    ]

    ref, _ = _local_stage(lambda: AFLClient(0, gamma=1.0), batches)

    out = {"dim": dim, "classes": classes, "batches": n_batches,
           "rows_per_batch": batch_rows,
           "total_rows": n_batches * batch_rows, "variants": {}}

    def record(name, make_client):
        report, dt = _local_stage(make_client, batches)
        out["variants"][name] = {
            "gram_rel_err": _rel_err(report.gram, ref.gram),
            "moment_rel_err": _rel_err(report.moment, ref.moment),
            "seconds": dt,
        }

    record("jax_f32", lambda: AFLClient(0, gamma=1.0, backend="jax"))
    record("jax_f32_kahan",
           lambda: AFLClient(0, gamma=1.0, backend="jax", kahan=True))
    # f64-on-device: x64 is process-global — toggle it for this measurement
    # only and restore, exactly like the scoped-x64 conformance subprocess
    jax.config.update("jax_enable_x64", True)
    try:
        record("jax_f64", lambda: AFLClient(0, gamma=1.0, backend="jax",
                                            dtype=jnp.float64))
    finally:
        jax.config.update("jax_enable_x64", False)
    return out


def run(quick: bool = False):
    cases = ([(256, 16, 64, 256)] if quick
             else [(512, 32, 256, 256), (1024, 32, 256, 256)])
    rows = []
    for dim, classes, n_batches, batch_rows in cases:
        case = _bench_case(dim, classes, n_batches, batch_rows)
        rows.append(case)
        print(f"d={dim} n={case['total_rows']} rows "
              f"({n_batches}×{batch_rows}):")
        f64 = case["variants"]["jax_f64"]["seconds"]
        for name, v in case["variants"].items():
            print(f"  {name:14s} gram_rel_err={v['gram_rel_err']:.3e}  "
                  f"moment_rel_err={v['moment_rel_err']:.3e}  "
                  f"{v['seconds']:.3f}s ({v['seconds'] / f64:.2f}× f64)")
    return {
        "description": "Kahan-compensated f32 vs f64-on-device through "
                       "AFLClient.update/report (reference: host numpy_f64; "
                       "offset μ=1 features; CPU host — TPU cost still "
                       "unmeasured)",
        "cases": rows,
    }


if __name__ == "__main__":
    import json
    import pathlib

    out = run()
    path = pathlib.Path("results/bench/kahan_f32_bench.json")
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(out, indent=1))
    print(f"wrote {path}")
