"""Kernel microbenchmarks: Pallas (interpret-mode) vs jnp reference.

On CPU, interpret mode executes the kernel body in Python — the numbers are
correctness artifacts, not perf (the perf story is the §Roofline analysis).
What this bench adds over the tests: max-abs-error across a realistic shape
sweep, verifying the TPU tiling logic end to end.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from benchmarks.common import print_table


def run(quick: bool = False) -> list[dict]:
    key = jax.random.key(0)
    shapes = [(256, 128, 16), (512, 256, 100)] if quick else [
        (256, 128, 16), (512, 256, 100), (1024, 512, 128), (640, 384, 40),
    ]
    rows, out = [], []
    for n, d, c in shapes:
        kx, ky = jax.random.split(jax.random.fold_in(key, n))
        x = jax.random.normal(kx, (n, d), jnp.float32)
        y = jax.nn.one_hot(
            jax.random.randint(ky, (n,), 0, c), c, dtype=jnp.float32)
        g_k, q_k = ops.gram_update(x, y, interpret=True)
        g_r, q_r = ref.gram_ref(x, y)
        err = max(float(jnp.abs(g_k - g_r).max()), float(jnp.abs(q_k - q_r).max()))
        rows.append([f"gram {n}x{d} C={c}", f"{err:.2e}"])
        out.append(dict(kernel="gram", n=n, d=d, c=c, max_err=err))

    attn_shapes = [(1, 4, 2, 128, 64)] if quick else [
        (1, 4, 2, 128, 64), (2, 8, 2, 256, 64), (1, 4, 4, 512, 128),
    ]
    for b, h, hk, s, hd in attn_shapes:
        ks = jax.random.split(jax.random.fold_in(key, s), 3)
        q = jax.random.normal(ks[0], (b, h, s, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, hk, s, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, hk, s, hd), jnp.float32)
        o_k = ops.flash_attention(q, k, v, causal=True, interpret=True)
        o_r = ref.mha_ref(q, k, v, causal=True)
        err = float(jnp.abs(o_k - o_r).max())
        rows.append([f"flash b{b} h{h}/{hk} s{s} d{hd}", f"{err:.2e}"])
        out.append(dict(kernel="flash", b=b, h=h, s=s, hd=hd, max_err=err))
    print_table("Pallas kernels vs jnp oracle (interpret mode)",
                ["case", "max |err|"], rows)
    return out
