"""Kernel microbenchmarks: Pallas (interpret-mode) vs jnp reference.

On CPU, interpret mode executes the kernel body in Python — the numbers are
correctness artifacts, not perf (the perf story is the §Roofline analysis).
What this bench adds over the tests: max-abs-error across a realistic shape
sweep, verifying the TPU tiling logic end to end.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import AnalyticEngine
from repro.kernels import ops, ref

from benchmarks.common import print_table


def run(quick: bool = False) -> list[dict]:
    key = jax.random.key(0)
    shapes = [(256, 128, 16), (512, 256, 100)] if quick else [
        (256, 128, 16), (512, 256, 100), (1024, 512, 128), (640, 384, 40),
    ]
    # The gram sweep goes through the engine's kernel-backed jax backend —
    # the exact production update path — vs the pure-jnp oracle.
    eng_kernel = AnalyticEngine("jax", use_kernel=True)
    eng_host = AnalyticEngine("numpy_f64")
    rows, out = [], []
    for n, d, c in shapes:
        kx, ky = jax.random.split(jax.random.fold_in(key, n))
        x = jax.random.normal(kx, (n, d), jnp.float32)
        y = jax.nn.one_hot(
            jax.random.randint(ky, (n,), 0, c), c, dtype=jnp.float32)
        st_k = eng_kernel.update(eng_kernel.init(d, c), x, y)
        g_r, q_r = ref.gram_ref(x, y)
        err = max(float(jnp.abs(st_k.gram - g_r).max()),
                  float(jnp.abs(st_k.moment - q_r).max()))
        # engine cross-backend: host f64 accumulation of the same batch
        st_h = eng_host.update(eng_host.init(d, c), np.asarray(x), np.asarray(y))
        err_f64 = float(np.abs(np.asarray(st_k.gram) - st_h.gram).max())
        rows.append([f"gram {n}x{d} C={c}", f"{err:.2e}"])
        rows.append([f"  engine kernel vs numpy_f64", f"{err_f64:.2e}"])
        out.append(dict(kernel="gram", n=n, d=d, c=c, max_err=err,
                        engine_f64_err=err_f64))

    attn_shapes = [(1, 4, 2, 128, 64)] if quick else [
        (1, 4, 2, 128, 64), (2, 8, 2, 256, 64), (1, 4, 4, 512, 128),
    ]
    for b, h, hk, s, hd in attn_shapes:
        ks = jax.random.split(jax.random.fold_in(key, s), 3)
        q = jax.random.normal(ks[0], (b, h, s, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, hk, s, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, hk, s, hd), jnp.float32)
        o_k = ops.flash_attention(q, k, v, causal=True, interpret=True)
        o_r = ref.mha_ref(q, k, v, causal=True)
        err = float(jnp.abs(o_k - o_r).max())
        rows.append([f"flash b{b} h{h}/{hk} s{s} d{hd}", f"{err:.2e}"])
        out.append(dict(kernel="flash", b=b, h=h, s=s, hd=hd, max_err=err))
    print_table("Pallas kernels vs jnp oracle (interpret mode)",
                ["case", "max |err|"], rows)
    return out
