"""Load harness: saturation throughput + tail latency, http vs mux, TLS on/off.

A closed-loop driver (each worker issues its next request the moment the
previous one completes — no open-loop arrival fiction) measures the two
serving transports under the workload shapes a federation actually sees:

* ``load_upload`` — N concurrent uploaders, each submit a NEW client
  joining the federation. HTTP/1.1 is modeled the way distinct clients hit
  it: one fresh connection per arrival (TCP + optional TLS handshake each
  time) — there is no keep-alive across different machines. Mux rides ONE
  shared persistent connection for all workers. The ``upload_ratio`` row
  records mux-over-http saturation throughput — the PR's ≥2× acceptance
  bar — plus an honesty row for keep-alive HTTP (same-client polling, the
  shape keep-alive actually serves).
* ``load_mixed`` — weights polling with ETag revalidation, periodic
  ``submit_stream`` batches, and ``personalized_solve``, against a
  persistent per-worker connection (http) vs one shared mux socket.

Every row carries ``p50_s``/``p99_s``/``ops_per_s`` and lands in the
``tools/bench_gate.py`` trajectory via ``benchmarks/run.py`` (or this
file's own ``--smoke`` CLI, which records suite ``quick:load_harness``).
All measurements run the hardened path: bearer-token auth always on, TLS
per row.
"""

from __future__ import annotations

import asyncio
import tempfile
import threading
import time

import numpy as np

from repro.fl import (AFLServer, AsyncAFLServer, FederationService,
                      HttpTransport, MuxTransport, RemoteCoordinator,
                      generate_self_signed_cert, make_report, serve_http,
                      serve_mux, server_ssl_context)
from repro.fl.service import frame_reports, unpack_message

from benchmarks.common import print_table

GAMMA = 1.0
TOKEN = "load-harness-token"


def _population(d, c, n_clients, rows_each, seed=0, start_id=0):
    rng = np.random.default_rng(seed)
    n = n_clients * rows_each
    x = rng.standard_normal((n, d))
    y = np.eye(c)[rng.integers(0, c, n)]
    return [make_report(start_id + k, x[k * rows_each:(k + 1) * rows_each],
                        y[k * rows_each:(k + 1) * rows_each], GAMMA)
            for k in range(n_clients)]


def _percentiles(latencies):
    lat = np.sort(np.asarray(latencies))
    return (float(lat[int(0.50 * (len(lat) - 1))]),
            float(lat[int(0.99 * (len(lat) - 1))]))


class _Endpoint:
    """One served federation in a given (transport, tls) config, plus the
    matching client-side factories."""

    def __init__(self, transport, tls, d, c, cert=None, key=None,
                 server=None):
        self.transport, self.tls = transport, tls
        if server is None:
            server = AFLServer(d, c, gamma=GAMMA)
        self.service = FederationService(server, auth_token=TOKEN)
        ctx = server_ssl_context(cert, key) if tls else None
        if transport == "mux":
            self.server = serve_mux(self.service, ssl_context=ctx)
        else:
            self.server = serve_http(self.service, ssl_context=ctx)
        self.url = self.server.url
        self.cert = cert

    def fresh_transport(self, keep_alive=True):
        if self.transport == "mux":
            return MuxTransport(self.url, auth_token=TOKEN,
                                cafile=self.cert if self.tls else None)
        return HttpTransport(self.url, auth_token=TOKEN,
                             keep_alive=keep_alive,
                             cafile=self.cert if self.tls else None)

    def close(self):
        self.server.close()
        self.service.close()


# ---------------------------------------------------------------------------
# Upload saturation: N concurrent NEW clients joining
# ---------------------------------------------------------------------------


def _measure_upload(ep, payload_batches, mode):
    """Each worker submits its batch of pre-serialized reports. ``mode``:
    ``fresh`` opens a connection per submit (distinct-clients HTTP model),
    ``keepalive`` keeps one connection per worker, ``shared`` multiplexes
    every worker over ONE transport."""
    latencies: list = []
    lat_lock = threading.Lock()
    shared = ep.fresh_transport() if mode == "shared" else None
    errors: list = []

    def work(batch):
        local = []
        try:
            if mode == "keepalive":
                tr = ep.fresh_transport()
            for body in batch:
                t0 = time.perf_counter()
                if mode == "fresh":
                    tr = ep.fresh_transport(keep_alive=False)
                    try:
                        tr.request("submit", body, "default")
                    finally:
                        tr.close()
                elif mode == "keepalive":
                    tr.request("submit", body, "default")
                else:
                    shared.request("submit", body, "default")
                local.append(time.perf_counter() - t0)
            if mode == "keepalive":
                tr.close()
        except Exception as exc:                           # noqa: BLE001
            errors.append(repr(exc))
        with lat_lock:
            latencies.extend(local)

    threads = [threading.Thread(target=work, args=(b,))
               for b in payload_batches]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if shared is not None:
        shared.close()
    if errors:
        raise RuntimeError(f"upload workers failed: {errors[:3]}")
    p50, p99 = _percentiles(latencies)
    return p50, p99, len(latencies) / wall


# ---------------------------------------------------------------------------
# Ingest saturation: fire-and-forget streams into the async fold worker
# ---------------------------------------------------------------------------


def _stall_folds(ep):
    """Hold the coordinator's fold lock on its event loop so uploads pile
    up behind the worker. Returns a release callable. This is what turns
    the scenario into *saturation*: without it the mux wire (report parse +
    CRC) delivers slower than even the per-report fold drains, the queue
    never builds, and both configurations just measure the transport."""
    fed = ep.service._fed("default")
    coordinator = fed.coordinator
    release = threading.Event()
    held = threading.Event()

    async def hold():
        async with coordinator._lock:
            held.set()
            while not release.is_set():
                await asyncio.sleep(0.001)

    fut = asyncio.run_coroutine_threadsafe(hold(), fed._loop)
    held.wait()

    def _release():
        release.set()
        fut.result()

    return _release


def _measure_ingest(ep, batches, frame_size=16):
    """Closed-loop uploaders fire ``submit_stream`` frames over ONE shared
    mux connection into a queue-backed coordinator whose fold worker is
    stalled until every report is admitted; the drain clock then runs until
    the coordinator has FOLDED the lot (``describe.version`` reaches the
    total). ops/s is therefore pure apply throughput under a saturated
    queue — what the fold path can sustain once arrivals outpace it.
    Per-request frame latencies feed the p50/p99 columns."""
    shared = ep.fresh_transport()
    latencies: list = []
    lat_lock = threading.Lock()
    errors: list = []
    total = sum(len(b) for b in batches)
    release = _stall_folds(ep)

    def work(batch):
        local = []
        try:
            for i in range(0, len(batch), frame_size):
                body = frame_reports(batch[i:i + frame_size])
                t0 = time.perf_counter()
                shared.request("submit_stream", body, "default")
                local.append(time.perf_counter() - t0)
        except Exception as exc:                           # noqa: BLE001
            errors.append(repr(exc))
        with lat_lock:
            latencies.extend(local)

    threads = [threading.Thread(target=work, args=(b,)) for b in batches]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    t0 = time.perf_counter()
    release()
    info = {}
    while not errors:                          # drain to the folded tip
        info, _, _ = unpack_message(
            shared.request("describe", b"", "default"))
        if info["version"] >= total:
            break
        time.sleep(0.001)
    wall = time.perf_counter() - t0
    shared.close()
    if errors:
        raise RuntimeError(f"ingest workers failed: {errors[:3]}")
    p50, p99 = _percentiles(latencies)
    return p50, p99, total / wall, info


# ---------------------------------------------------------------------------
# Mixed read-mostly workload
# ---------------------------------------------------------------------------


def _measure_mixed(ep, ops_per_worker, workers, submit_batches):
    """Closed loop per worker: ETag-revalidating weights polls, a
    submit_stream batch every 4th op, personalized_solve every 4th+2."""
    latencies: list = []
    lat_lock = threading.Lock()
    errors: list = []
    shared = ep.fresh_transport() if ep.transport == "mux" else None

    def work(widx):
        local = []
        try:
            rc = RemoteCoordinator(shared if shared is not None
                                   else ep.fresh_transport())
            etag = None
            for i in range(ops_per_worker):
                t0 = time.perf_counter()
                if i % 4 == 0 and submit_batches[widx]:
                    rc.submit_many(submit_batches[widx].pop())
                elif i % 4 == 2:
                    rc.personalized_solve(0.25)
                else:
                    vw = rc.weights(0.25, if_etag=etag)
                    if not vw.not_modified:
                        etag = vw.etag
                local.append(time.perf_counter() - t0)
            if shared is None:
                rc.close()
        except Exception as exc:                           # noqa: BLE001
            errors.append(repr(exc))
        with lat_lock:
            latencies.extend(local)

    threads = [threading.Thread(target=work, args=(w,))
               for w in range(workers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if shared is not None:
        shared.close()
    if errors:
        raise RuntimeError(f"mixed workers failed: {errors[:3]}")
    p50, p99 = _percentiles(latencies)
    return p50, p99, len(latencies) / wall


# ---------------------------------------------------------------------------
# The bench
# ---------------------------------------------------------------------------


def run(quick: bool = False):
    # d stays small enough that the SERVICE (d² gram folds under the GIL)
    # doesn't become the bottleneck — this harness measures the transport;
    # engine_bench/solve_kernels_bench own the math-side numbers
    d, c = (64, 8) if quick else (96, 10)
    workers = 4 if quick else 16
    uploads_per_worker = 6 if quick else 24
    mixed_ops = 12 if quick else 40
    rows = []

    with tempfile.TemporaryDirectory() as td:
        cert, key = generate_self_signed_cert(td)

        # -- upload saturation, per transport × tls ------------------------
        throughput = {}
        for tls in (False, True):
            for transport, mode in (("http", "fresh"), ("mux", "shared")):
                ep = _Endpoint(transport, tls, d, c, cert, key)
                try:
                    batches = [
                        [r.to_bytes() for r in _population(
                            d, c, uploads_per_worker, 8, seed=w,
                            start_id=10_000 * (w + 1))]
                        for w in range(workers)]
                    p50, p99, rps = _measure_upload(ep, batches, mode)
                finally:
                    ep.close()
                throughput[(transport, tls)] = rps
                rows.append({"bench": "load_upload", "transport": transport,
                             "tls": tls, "mode": mode, "workers": workers,
                             "ops": workers * uploads_per_worker,
                             "p50_s": round(p50, 4), "p99_s": round(p99, 4),
                             "ops_per_s": round(rps, 1)})

        # honesty row: keep-alive HTTP (same-client polling shape — NOT the
        # distinct-uploaders model the ratio is defined over)
        ep = _Endpoint("http", True, d, c, cert, key)
        try:
            batches = [[r.to_bytes() for r in _population(
                d, c, uploads_per_worker, 8, seed=50 + w,
                start_id=900_000 + 10_000 * w)] for w in range(workers)]
            p50, p99, rps = _measure_upload(ep, batches, "keepalive")
        finally:
            ep.close()
        rows.append({"bench": "load_upload", "transport": "http-keepalive",
                     "tls": True, "mode": "keepalive", "workers": workers,
                     "ops": workers * uploads_per_worker,
                     "p50_s": round(p50, 4), "p99_s": round(p99, 4),
                     "ops_per_s": round(rps, 1)})

        # the acceptance-bar row: mux over fresh-connection HTTP/1.1
        rows.append({"bench": "upload_ratio",
                     "mux_over_http_plain": round(
                         throughput[("mux", False)]
                         / throughput[("http", False)], 2),
                     "mux_over_http_tls": round(
                         throughput[("mux", True)]
                         / throughput[("http", True)], 2)})

        # -- ingest saturation: batched fold vs per-report apply -----------
        # 16 uploaders even in --smoke: the reports are tiny and mux rides
        # one socket, so the scenario is cheap — and the batching win only
        # shows once arrivals actually pile up behind the fold worker.
        # d is deliberately SMALL here: batching amortizes the per-report
        # worker overhead (wakeup, lock, future bookkeeping), so the regime
        # under test is many small clients at high rate — at transport-bench
        # d the O(d²) per-report gram copy drowns the amortizable part, and
        # engine_bench owns that axis anyway.
        d_ing, c_ing = 32, 4
        ingest_workers = 16
        ingest_per_worker = 24 if quick else 64
        ingest_rps = {}
        for batch_max in (1, 32):
            srv = AsyncAFLServer(d_ing, c_ing, gamma=GAMMA,
                                 batch_max=batch_max)
            ep = _Endpoint("mux", False, d_ing, c_ing, cert, key,
                           server=srv)
            try:
                batches = [
                    [r.to_bytes() for r in _population(
                        d_ing, c_ing, ingest_per_worker, 2, seed=200 + w,
                        start_id=40_000 * (w + 1))]
                    for w in range(ingest_workers)]
                p50, p99, rps, info = _measure_ingest(ep, batches)
            finally:
                ep.close()
            ingest_rps[batch_max] = rps
            folded = info.get("ingest", {}).get("batches_folded", 0) or 1
            n_ops = ingest_workers * ingest_per_worker
            rows.append({"bench": "load_ingest", "transport": "mux",
                         "tls": False, "workers": ingest_workers,
                         "d": d_ing, "batch_max": batch_max, "ops": n_ops,
                         "batches_folded": folded,
                         "mean_batch": round(n_ops / folded, 1),
                         "p50_s": round(p50, 4), "p99_s": round(p99, 4),
                         "ops_per_s": round(rps, 1)})

        # the ingest acceptance-bar row: micro-batch fold over batch_max=1
        rows.append({"bench": "ingest_ratio",
                     "batched_over_per_report": round(
                         ingest_rps[32] / ingest_rps[1], 2)})

        # -- mixed workload, per transport × tls ---------------------------
        for tls in (False, True):
            for transport in ("http", "mux"):
                ep = _Endpoint(transport, tls, d, c, cert, key)
                try:
                    seed_rc = RemoteCoordinator(
                        ep.url, auth_token=TOKEN,
                        cafile=cert if tls else None)
                    seed_rc.submit_many(_population(d, c, 8, 8, seed=99))
                    batches = [
                        [_population(d, c, 2, 8, seed=100 + w * 10 + i,
                                     start_id=20_000 * (w + 1) + 100 * i)
                         for i in range(mixed_ops // 4 + 1)]
                        for w in range(workers)]
                    p50, p99, rps = _measure_mixed(ep, mixed_ops, workers,
                                                   batches)
                    seed_rc.close()
                finally:
                    ep.close()
                rows.append({"bench": "load_mixed", "transport": transport,
                             "tls": tls, "workers": workers,
                             "ops": workers * mixed_ops,
                             "p50_s": round(p50, 4), "p99_s": round(p99, 4),
                             "ops_per_s": round(rps, 1)})

    ratio = next(r for r in rows if r["bench"] == "upload_ratio")
    ingest_ratio = next(r for r in rows if r["bench"] == "ingest_ratio")
    print_table(
        f"Load harness — {workers} closed-loop workers (d={d}, C={c}), "
        f"auth on",
        ["bench", "transport", "tls", "p50", "p99", "ops/s"],
        [[r["bench"] + (f"[bm={r['batch_max']}]" if "batch_max" in r
                        else ""),
          r["transport"], "on" if r["tls"] else "off",
          f"{r['p50_s']*1e3:.1f}ms", f"{r['p99_s']*1e3:.1f}ms",
          r["ops_per_s"]]
         for r in rows if r["bench"] not in ("upload_ratio",
                                             "ingest_ratio")])
    print(f"concurrent-uploader throughput, mux over fresh-conn HTTP/1.1: "
          f"{ratio['mux_over_http_plain']}x plaintext, "
          f"{ratio['mux_over_http_tls']}x TLS "
          f"(acceptance bar: >=2x)")
    print(f"ingest apply throughput, micro-batch fold over per-report "
          f"apply: {ingest_ratio['batched_over_per_report']}x "
          f"(acceptance bar: >=2x)")
    return rows


def main() -> None:
    import argparse
    import json
    import pathlib

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale sizes; records suite quick:load_harness")
    args = ap.parse_args()

    from benchmarks import env_truth
    from benchmarks.run import _bench_metrics, record_trajectory

    env = env_truth.apply()
    outdir = pathlib.Path("results/bench")
    outdir.mkdir(parents=True, exist_ok=True)
    t0 = time.perf_counter()
    rows = run(quick=args.smoke)
    secs = time.perf_counter() - t0
    (outdir / "load_harness.json").write_text(json.dumps(rows, indent=1))
    pre = "quick" if args.smoke else "full"
    transport_rows = [r for r in rows
                      if r["bench"] not in ("load_ingest", "ingest_ratio")]
    ingest_rows = [r for r in rows
                   if r["bench"] in ("load_ingest", "ingest_ratio")]
    record_trajectory(outdir, pre + ":load_harness",
                      {"load_harness": secs}, [],
                      metrics=_bench_metrics("load_harness",
                                             transport_rows), env=env)
    # the ingest scenario gates under its own suite key, so a regression in
    # the fold path cannot hide behind transport-side noise (and vice versa)
    record_trajectory(outdir, pre + ":ingest", {"ingest": secs}, [],
                      metrics=_bench_metrics("ingest", ingest_rows),
                      env=env)
    print(f"[load_harness: {secs:.1f}s]")


if __name__ == "__main__":
    main()
