"""Read-replica benchmark: p50/p99 read latency, primary vs replica.

The replica exists for exactly one workload shape — solve once, download
millions — and this bench measures whether it actually buys anything: N
concurrent readers issue ``weights`` (ETag-revalidating after the first
download) and ``personalized_solve`` requests against (a) the primary,
which is simultaneously ingesting a stream of submits, and (b) a
:class:`~repro.fl.replication.WeightsReplica` following the primary's
ledger — which never contends with ingest because it reads from its own
cached factor.

Rows report per-target read p50/p99 wall seconds (``p50_s``/``p99_s``), so
the ``tools/bench_gate.py`` trajectory catches a regression on either path;
``dw`` audits that the replica's head is bit-for-bit the primary's.
"""

from __future__ import annotations

import tempfile
import threading
import time

import numpy as np

from repro.fl import (AFLServer, FederationService, RemoteCoordinator,
                      WeightsReplica, make_report, serve_http)

from benchmarks.common import print_table

GAMMA = 1.0


def _population(d, c, n_clients, rows_each, seed=0, start_id=0):
    rng = np.random.default_rng(seed)
    n = n_clients * rows_each
    x = rng.standard_normal((n, d))
    y = np.eye(c)[rng.integers(0, c, n)]
    return [make_report(start_id + k, x[k * rows_each:(k + 1) * rows_each],
                        y[k * rows_each:(k + 1) * rows_each], GAMMA)
            for k in range(n_clients)]


def _read_loop(url, reqs, latencies):
    """One reader: alternate cached-weights revalidation and a fresh
    personalized solve — the two read routes a deployment actually serves."""
    rc = RemoteCoordinator(url)
    etag = None
    try:
        for i in range(reqs):
            t0 = time.perf_counter()
            if i % 2 == 0:
                vw = rc.weights(0.25, if_etag=etag)
                if not vw.not_modified:
                    etag = vw.etag
            else:
                rc.personalized_solve(0.25)
            latencies.append(time.perf_counter() - t0)
    finally:
        rc.close()


def _measure(url, readers, reqs):
    latencies: list = []
    threads = [threading.Thread(target=_read_loop,
                                args=(url, reqs, latencies))
               for _ in range(readers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    lat = np.sort(np.asarray(latencies))
    return (float(lat[int(0.50 * (len(lat) - 1))]),
            float(lat[int(0.99 * (len(lat) - 1))]),
            len(lat) / wall)


def run(quick: bool = False):
    d, c = (128, 10) if quick else (512, 20)
    n_clients, rows_each = (16, 16) if quick else (48, 32)
    readers, reqs = (4, 20) if quick else (8, 50)
    reps = _population(d, c, n_clients, rows_each)
    # writer traffic during the measurement: a second population streaming
    # in while readers hammer the weights route
    writers = _population(d, c, n_clients, rows_each, seed=1,
                          start_id=n_clients)

    rows = []
    with tempfile.TemporaryDirectory() as tmp:
        primary = FederationService(AFLServer(d, c, gamma=GAMMA),
                                    ledger_dir=f"{tmp}/ledger")
        with primary, serve_http(primary) as http:
            rc = RemoteCoordinator(http.url)
            rc.submit_many(reps)
            primary_w = np.asarray(rc.solve(0.25), np.float64)

            # replica follows the ledger (same box here; the point is the
            # contention profile, not the network)
            replica = WeightsReplica(f"{tmp}/ledger",
                                     ctor_kw=dict(dim=d, num_classes=c,
                                                  gamma=GAMMA))
            rep_svc = FederationService(replica)
            with rep_svc, serve_http(rep_svc) as rep_http:
                # ingest load against the primary while both are measured:
                # the replica's reads must not care
                stop = threading.Event()

                def _ingest():
                    wrc = RemoteCoordinator(http.url)
                    i = 0
                    while not stop.is_set() and i < len(writers):
                        wrc.submit(writers[i])
                        i += 1
                        time.sleep(0.002)
                    wrc.close()

                ingest = threading.Thread(target=_ingest)
                ingest.start()
                try:
                    for target, url in (("primary", http.url),
                                        ("replica", rep_http.url)):
                        p50, p99, rps = _measure(url, readers, reqs)
                        rows.append({"bench": "replica_read", "d": d,
                                     "target": target, "readers": readers,
                                     "reqs": readers * reqs,
                                     "p50_s": round(p50, 4),
                                     "p99_s": round(p99, 4),
                                     "reads_per_s": round(rps, 1)})
                finally:
                    stop.set()
                    ingest.join()
                # exactness audit: the replica head at the primary's epoch
                replica.refresh()
                dw = float(np.abs(np.asarray(replica.solve(0.25),
                                             np.float64)
                                  - np.asarray(rc.solve(0.25),
                                               np.float64)).max())
                for row in rows:
                    row["dw"] = dw
            rc.close()

    print_table(
        f"Replica reads — {readers} readers × {reqs} reqs under ingest "
        f"(d={d}, C={c})",
        ["target", "p50", "p99", "reads/s", "max|ΔW| replica vs primary"],
        [[r["target"], f"{r['p50_s']*1e3:.1f}ms", f"{r['p99_s']*1e3:.1f}ms",
          r["reads_per_s"], f"{r['dw']:.2e}"] for r in rows])
    return rows


if __name__ == "__main__":
    run(quick=True)
