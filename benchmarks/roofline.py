"""§Roofline: three-term roofline table from the dry-run artifacts.

Reads ``results/dryrun/*.json`` (produced by ``repro.launch.dryrun``) and
prints, per (arch × shape × mesh):

  compute_s    = HLO_FLOPs_global   / (chips × 197e12)
  memory_s     = HLO_bytes_global   / (chips × 819e9)
  collective_s = coll_bytes_global  / (chips × 50e9)

plus the dominant term, MODEL_FLOPS = 2·N_active·D for forward-only analytic
steps (6·N·D for the gradient arm), and the MODEL/HLO FLOPs ratio (useful-
compute fraction — catches remat/redundancy waste). The §Roofline table in
EXPERIMENTS.md is generated from this module (single-pod rows).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib

from repro.config import INPUT_SHAPES
from repro.configs.registry import get_config
from repro.launch.dryrun import resolve_config

from benchmarks.common import print_table

RESULTS = pathlib.Path("results/dryrun")


def count_params(cfg) -> tuple[int, int]:
    """(total, active) parameter counts from the config arithmetic."""
    d, v = cfg.d_model, cfg.vocab_size
    hd = cfg.resolved_head_dim
    attn = d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
    n_mats = 3 if cfg.activation == "swiglu" else 2
    if cfg.moe is not None:
        ffn_total = cfg.moe.num_experts * n_mats * d * cfg.d_ff + d * cfg.moe.num_experts
        ffn_active = cfg.moe.top_k * n_mats * d * cfg.d_ff + d * cfg.moe.num_experts
    else:
        ffn_total = ffn_active = n_mats * d * cfg.d_ff
    if cfg.arch_type == "hybrid":
        ssm = cfg.ssm
        d_inner = ssm.expand * d
        mix = d * (2 * d_inner + 2 * ssm.d_state) + d_inner * d
        per_layer = mix
        n_attn = cfg.num_layers // cfg.shared_attn_every
        total = cfg.num_layers * per_layer + n_attn * (attn + ffn_total)
        active = total
    elif cfg.arch_type == "xlstm":
        d_inner = 2 * d
        per_layer = d * 2 * d_inner + d_inner * 3 * d_inner + d_inner * d
        total = active = cfg.num_layers * per_layer
    elif cfg.arch_type == "encdec":
        per_layer = attn + ffn_total
        total = (cfg.num_layers * (2 * attn + ffn_total)
                 + cfg.encoder_layers * per_layer)
        active = total
    else:
        total = cfg.num_layers * (attn + ffn_total)
        active = cfg.num_layers * (attn + ffn_active)
    embed = v * d * (1 if cfg.tie_embeddings else 2)
    return total + embed, active + embed


def model_flops(arch: str, shape_name: str, variant: str = "baseline") -> float:
    """2·N_active·D forward-only (analytic train / prefill / decode)."""
    shape = INPUT_SHAPES[shape_name]
    cfg = resolve_config(arch, shape, variant)
    if cfg is None:
        return 0.0
    _, active = count_params(cfg)
    # embedding lookup is not a matmul; exclude the embed table from N_active
    active -= cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    if shape.kind == "decode":
        tokens = shape.global_batch  # one new token per sequence
    else:
        tokens = shape.global_batch * shape.seq_len
    return 2.0 * active * tokens


def load(mesh: str = "single", variant: str = "baseline") -> list[dict]:
    recs = []
    for p in sorted(RESULTS.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("mesh") != mesh or r.get("variant", "baseline") != variant:
            continue
        recs.append(r)
    return recs


def rows_for(recs: list[dict]) -> tuple[list, list[dict]]:
    rows, out = [], []
    for r in recs:
        tag = f"{r['arch']} × {r['shape']}"
        if r.get("skipped"):
            rows.append([tag, "skip", "-", "-", "-", "-", "-"])
            continue
        if not r.get("ok"):
            rows.append([tag, "FAIL", "-", "-", "-", "-", "-"])
            continue
        rf = r["roofline"]
        mf = model_flops(r["arch"], r["shape"], r.get("variant", "baseline"))
        ratio = mf / rf["flops"] if rf["flops"] else 0.0
        rows.append([
            tag,
            f"{rf['compute_s']*1e3:.2f}",
            f"{rf['memory_s']*1e3:.2f}",
            f"{rf['collective_s']*1e3:.2f}",
            rf["dominant"],
            f"{ratio:.2f}",
            f"{r['memory']['peak_bytes_per_device']/2**30:.1f}",
        ])
        out.append(dict(arch=r["arch"], shape=r["shape"], **rf,
                        model_flops=mf, useful_ratio=ratio))
    return rows, out


def run(quick: bool = False) -> list[dict]:
    recs = load("single")
    if not recs:
        print("\n== Roofline: no dry-run artifacts found (run "
              "`python -m repro.launch.dryrun` first)")
        return []
    rows, out = rows_for(recs)
    print_table(
        "§Roofline — single-pod (16×16 = 256 chips), per-step seconds ×1e-3",
        ["arch × shape", "compute(ms)", "memory(ms)", "coll(ms)", "dominant",
         "useful", "peak GiB/dev*"], rows)
    print("* CPU stand-in peak; bf16 loop carries legalized to f32 inflate "
          "this vs the TPU target (see EXPERIMENTS.md §Dry-run).")
    return out
