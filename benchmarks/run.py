"""Benchmark driver: one module per paper table/figure + the roofline report.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only table1,...]

Writes results/bench/<name>.json per module and prints each table.
"""

from __future__ import annotations

import argparse
import importlib
import json
import pathlib
import time
import traceback

MODULES = [
    ("tableA1_dummy", "Table A.1 — AA-law exactness (dummy data)"),
    ("table1_noniid", "Table 1 — non-IID accuracy comparison"),
    ("table2_heterogeneity", "Table 2 — heterogeneity invariance"),
    ("fig2_clients", "Figure 2 — client-number invariance"),
    ("table3_ri_ablation", "Table 3 — RI / gamma ablation"),
    ("table4_backbones", "Table 4 — different backbones"),
    ("tableA2_local", "Table A.2 — FL vs local-only"),
    ("tableA3_oneshot", "Table A.3 — single-round competitors"),
    ("fig3_timing", "Figure 3 — training efficiency"),
    ("beyond_stragglers", "Beyond-paper — stragglers & secure aggregation"),
    ("beyond_nonlinear", "Beyond-paper — non-linear analytic heads"),
    ("kernels_micro", "Pallas kernel correctness sweep"),
    ("engine_bench", "Engine — cached-factorization solve throughput"),
    ("async_server_bench", "Async serving — rank-k update vs refactor"),
    ("kahan_f32_bench", "Kahan-compensated f32 vs f64-on-device (AFLClient)"),
    ("roofline", "§Roofline — dry-run derived"),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes (CI-scale)")
    ap.add_argument("--only", default="",
                    help="comma-separated module names")
    args = ap.parse_args()

    outdir = pathlib.Path("results/bench")
    outdir.mkdir(parents=True, exist_ok=True)
    only = {m for m in args.only.split(",") if m}
    failures = []
    t_start = time.perf_counter()
    for name, desc in MODULES:
        if only and name not in only:
            continue
        print(f"\n########## {desc}")
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            rows = mod.run(quick=args.quick)
            (outdir / f"{name}.json").write_text(json.dumps(rows, indent=1))
            print(f"[{name}: {time.perf_counter()-t0:.1f}s]")
        except Exception:
            failures.append(name)
            traceback.print_exc()
    print(f"\ntotal: {time.perf_counter()-t_start:.1f}s")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
