"""Benchmark driver: one module per paper table/figure + the roofline report.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only table1,...]

Writes results/bench/<name>.json per module and prints each table. Every run
also appends a consolidated entry — git SHA, suite, per-module wall seconds —
to results/bench/BENCH_solve.json, the run-over-run perf trajectory (one
entry per (sha, suite); re-running the same commit replaces its entry).
"""

from __future__ import annotations

import argparse
import importlib
import json
import pathlib
import subprocess
import time
import traceback

MODULES = [
    ("tableA1_dummy", "Table A.1 — AA-law exactness (dummy data)"),
    ("table1_noniid", "Table 1 — non-IID accuracy comparison"),
    ("table2_heterogeneity", "Table 2 — heterogeneity invariance"),
    ("fig2_clients", "Figure 2 — client-number invariance"),
    ("table3_ri_ablation", "Table 3 — RI / gamma ablation"),
    ("table4_backbones", "Table 4 — different backbones"),
    ("tableA2_local", "Table A.2 — FL vs local-only"),
    ("tableA3_oneshot", "Table A.3 — single-round competitors"),
    ("fig3_timing", "Figure 3 — training efficiency"),
    ("beyond_stragglers", "Beyond-paper — stragglers & secure aggregation"),
    ("beyond_nonlinear", "Beyond-paper — non-linear analytic heads"),
    ("kernels_micro", "Pallas kernel correctness sweep"),
    ("engine_bench", "Engine — cached-factorization solve throughput"),
    ("async_server_bench", "Async serving — rank-k update vs refactor"),
    ("kahan_f32_bench", "Kahan-compensated f32 vs f64-on-device (AFLClient)"),
    ("solve_kernels_bench",
     "Solve kernels — fused γ-sweep, batched factor, tiled d=6144"),
    ("elastic_bench",
     "Elastic federation — reshard/resize/snapshot migration cost"),
    ("replica_read_bench",
     "Replication — p50/p99 reads, primary-under-ingest vs replica"),
    ("load_harness",
     "Serving at traffic — http vs mux saturation, TLS on/off"),
    ("roofline", "§Roofline — dry-run derived"),
]


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, check=True).stdout.strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def _bench_metrics(name: str, rows) -> dict:
    """Flatten a module's result rows into gate-comparable wall metrics:
    every ``*_s`` field of every row that self-identifies with a ``bench``
    key, keyed ``module.bench[.d]`` (min wins on collisions — repeated
    cases of one bench compare at their best)."""
    metrics: dict = {}
    if not isinstance(rows, list):
        return metrics
    for row in rows:
        if not (isinstance(row, dict) and "bench" in row):
            continue
        key = f"{name}.{row['bench']}"
        if "d" in row:
            key += f".d{row['d']}"
        for field, val in row.items():
            if field.endswith("_s") and isinstance(val, (int, float)):
                mkey = f"{key}.{field}"
                metrics[mkey] = min(metrics.get(mkey, float("inf")),
                                    round(float(val), 4))
    return metrics


def record_trajectory(outdir: pathlib.Path, suite: str,
                      module_seconds: dict, failures: list,
                      metrics: dict | None = None,
                      env: dict | None = None) -> None:
    """Append this run to the BENCH_solve.json perf trajectory.

    Keyed by (git sha, suite): re-running the same commit replaces its
    entry, so the file stays one line of history per measured state instead
    of growing with every retry. Each entry carries the env-truth flag set
    and machine fingerprint that produced it (``benchmarks/env_truth.py``)
    plus the per-bench wall metrics ``tools/bench_gate.py`` compares.
    """
    path = outdir / "BENCH_solve.json"
    try:
        trajectory = json.loads(path.read_text())
        assert isinstance(trajectory, list)
    except (OSError, ValueError, AssertionError):
        trajectory = []
    sha = _git_sha()
    trajectory = [e for e in trajectory
                  if not (e.get("sha") == sha and e.get("suite") == suite)]
    trajectory.append({
        "sha": sha,
        "suite": suite,
        "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "env": env or {},
        "modules": {k: round(v, 3) for k, v in module_seconds.items()},
        "metrics": metrics or {},
        "failures": sorted(failures),
    })
    path.write_text(json.dumps(trajectory, indent=1))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes (CI-scale)")
    ap.add_argument("--only", default="",
                    help="comma-separated module names")
    args = ap.parse_args()

    # env truth BEFORE any bench module (and therefore jax) is imported:
    # recorded numbers are only comparable under a pinned flag set
    from benchmarks import env_truth
    env = env_truth.apply()

    outdir = pathlib.Path("results/bench")
    outdir.mkdir(parents=True, exist_ok=True)
    only = {m for m in args.only.split(",") if m}
    failures = []
    module_seconds = {}
    metrics = {}
    t_start = time.perf_counter()
    for name, desc in MODULES:
        if only and name not in only:
            continue
        print(f"\n########## {desc}")
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            rows = mod.run(quick=args.quick)
            (outdir / f"{name}.json").write_text(json.dumps(rows, indent=1))
            module_seconds[name] = time.perf_counter() - t0
            metrics.update(_bench_metrics(name, rows))
            print(f"[{name}: {module_seconds[name]:.1f}s]")
        except Exception:
            failures.append(name)
            traceback.print_exc()
    suite = ("quick" if args.quick else "full") + (
        f":{','.join(sorted(only))}" if only else "")
    record_trajectory(outdir, suite, module_seconds, failures,
                      metrics=metrics, env=env)
    print(f"\ntotal: {time.perf_counter()-t_start:.1f}s")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
