"""Solve-kernel benchmarks: fused γ-sweep, batched factor kernels, the
Woodbury sweep-handle crossover, and the tiled-Gram d=6144 sharded solve.

The headline numbers behind ISSUE 5's acceptance bar, recorded in
``results/bench/solve_kernels_bench.json``:

  * ``fused_sweep`` — the fused Pallas multi-γ kernel (interpret mode on
    this CPU host) vs the PR-3 per-γ host loop (fresh ``C + γI`` + LAPACK
    per γ) and vs the one-eigendecomposition host sweep, at d=2048 / 16 γs.
    Acceptance: fused ≥ 2× the per-γ host loop.
  * ``batched_factor`` — blocked-Cholesky + batched-substitution kernels vs
    a numpy loop over the same batch.
  * ``sweep_handle`` — repeated ``solve_multi_gamma`` on an evolving
    federation: Woodbury-updated eigendecomposition handle vs re-eigh per
    sweep, as pending rank grows (the d/8 budget guidance).
  * ``tiled_6144`` — the tiled-Gram ``ShardedCoordinator`` solving a
    d=6144 head on an 8-way (host-platform) mesh under x64, with per-shard
    parity vs the sync host path and resident-memory accounting. Runs in a
    subprocess because both x64 and the device count are process-global.
  * ``distributed_factor`` — ISSUE 6's tile-parallel distributed Cholesky
    vs gather-then-factor on an 8-way mesh at d∈{2048, 4096, 6144, 8192}
    (x64 subprocess per d). Records wall time, the peak per-device
    transient from the jaxpr (``peak_aval_bytes``) and the 1e-10 parity
    bar. The gather baseline only runs where its (d, d) per-device
    transient fits ``DEVICE_TRANSIENT_BUDGET`` (256 MiB) — at d=6144
    (302 MiB) and d=8192 (512 MiB) it is recorded as infeasible, which is
    the point: the distributed factor tops out at the (d/8, d) row tile
    and keeps going.

``--smoke`` shrinks every case (CI scale); ``python -m benchmarks.run``
registers this module and folds its wall times into the
``results/bench/BENCH_solve.json`` trajectory (gated run-over-run by
``tools/bench_gate.py``).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import print_table


def _time(fn, repeat=3):
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _gram(d, seed=0, n_mult=4):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n_mult * d, d))
    return x.T @ x, x


def bench_fused_sweep(d, c, n_gammas, repeat=3):
    """Fused Pallas sweep vs per-γ host loop vs one-eigh host sweep."""
    import jax.numpy as jnp

    from repro.core.engine import AnalyticEngine, SuffStats
    from repro.kernels import ops

    try:
        from scipy.linalg import solve_triangular
    except ImportError:                                  # pragma: no cover
        solve_triangular = None

    rng = np.random.default_rng(0)
    gram, x = _gram(d)
    q = x.T @ np.eye(c)[rng.integers(0, c, x.shape[0])]
    gammas = np.logspace(-3, 2, n_gammas)

    def host_loop():
        # the PR-3 per-γ path: materialize C + γI and factor, per γ
        # (exactly what `for g in gammas: engine.solve(stats, g)` costs)
        out = []
        for g in gammas:
            a = gram + g * np.eye(d)
            r = np.linalg.cholesky(a)
            if solve_triangular is not None:
                y = solve_triangular(r, q, lower=True)
                out.append(solve_triangular(r, y, lower=True, trans="T"))
            else:
                out.append(np.linalg.solve(a, q))
        return out

    eng = AnalyticEngine("numpy_f64", gamma=1.0)
    stats = SuffStats(gram=gram, moment=q, count=float(x.shape[0]),
                      clients=1.0)

    def eigh_sweep():
        return eng.solve_multi_gamma(stats, gammas)

    cj = jnp.asarray(gram, jnp.float32)
    qj = jnp.asarray(q, jnp.float32)
    gj = jnp.asarray(gammas, jnp.float32)

    def fused():
        np.asarray(ops.multi_gamma_solve(cj, qj, gj))

    fused()                                              # compile once
    t_loop = _time(host_loop, repeat)
    t_eigh = _time(eigh_sweep, repeat)
    t_fused = _time(fused, repeat)
    # accuracy of the f32 kernel sweep vs the f64 host loop
    ws = np.asarray(ops.multi_gamma_solve(cj, qj, gj), np.float64)
    ref = host_loop()
    err = max(np.abs(ws[i] - ref[i]).max() / np.abs(ref[i]).max()
              for i in range(n_gammas))
    return dict(bench="fused_sweep", d=d, c=c, n_gammas=n_gammas,
                host_loop_s=t_loop, eigh_sweep_s=t_eigh, fused_s=t_fused,
                speedup_vs_loop=t_loop / t_fused,
                speedup_vs_eigh=t_eigh / t_fused,
                fused_rel_err=float(err))


def bench_batched_factor(d, c, batch, repeat=3):
    """Batched blocked-Cholesky/substitution kernels vs a numpy loop."""
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(1)
    mats = np.stack([_gram(d, seed=i)[0] + np.eye(d) for i in range(batch)])
    rhs = rng.standard_normal((batch, d, c))

    def host():
        for i in range(batch):
            r = np.linalg.cholesky(mats[i])
            np.linalg.solve(mats[i], rhs[i])
            del r

    aj = jnp.asarray(mats, jnp.float32)
    bj = jnp.asarray(rhs, jnp.float32)

    def kernel():
        l = ops.blocked_cholesky(aj)
        np.asarray(ops.cholesky_solve(l, bj))

    kernel()                                             # compile once
    t_host = _time(host, repeat)
    t_kernel = _time(kernel, repeat)
    return dict(bench="batched_factor", d=d, c=c, batch=batch,
                host_s=t_host, kernel_s=t_kernel,
                speedup=t_host / t_kernel)


def bench_sweep_handle(d, c, n_gammas, ranks, repeat=3):
    """Woodbury-updated sweep handle vs re-eigh, as pending rank grows."""
    from repro.core.engine import AnalyticEngine

    rng = np.random.default_rng(2)
    eng = AnalyticEngine("numpy_f64", gamma=1.0)
    x = rng.standard_normal((4 * d, d))
    y = np.eye(c)[rng.integers(0, c, 4 * d)]
    stats = eng.client_stats(x, y)
    gammas = list(np.logspace(-2, 1, n_gammas))
    handle0 = eng.sweep_factor(stats)

    rows = []
    for k in ranks:
        u = rng.standard_normal((k, d))
        stats_k = eng.merge(stats, eng.client_stats(
            u, np.eye(c)[rng.integers(0, c, k)]))
        handle = handle0.rank_update(u) if k else handle0

        def woodbury():
            eng.sweep_solve(handle, stats_k.moment, gammas)

        def re_eigh():
            eng.sweep_solve(eng.sweep_factor(stats_k), stats_k.moment,
                            gammas)

        t_w = _time(woodbury, repeat)
        t_e = _time(re_eigh, repeat)
        rows.append(dict(bench="sweep_handle", d=d, n_gammas=n_gammas,
                         pending_rank=k, woodbury_s=t_w, re_eigh_s=t_e,
                         speedup=t_e / t_w))
    return rows


_TILED_SUBPROC_FLAG = "--tiled-subprocess"
_DIST_SUBPROC_FLAG = "--dist-subprocess"

# Per-device transient budget for the gather-then-factor baseline: a shard
# whose solve transiently materializes the full (d, d) f64 system must fit
# it next to the resident tile, the model weights, and XLA's workspace.
# 256 MiB is the d≈5792 line — d=6144 (302 MiB) and d=8192 (512 MiB) are
# where gather-then-factor stops being runnable per device and only the
# tile-parallel factor (peak d²/shards) proceeds.
DEVICE_TRANSIENT_BUDGET = 256 * 2**20


def _dist_subprocess_main(d: int, run_baseline: bool) -> None:
    """x64 / 8-device child: tile-parallel distributed factor vs the
    gather-then-factor baseline at dimension d, with static peak-transient
    accounting (the no-(d,d)-anywhere acceptance invariant)."""
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_ENABLE_X64"] = "1"
    os.environ["JAX_DEFAULT_DTYPE_BITS"] = "32"
    import numpy as np

    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.core.distributed import make_tiled_federated_solve
    from repro.launch.hlo_analysis import peak_aval_bytes

    n, c = 8, 16
    r = d // n
    rng = np.random.default_rng(0)
    # full-rank SPD aggregate built tile-by-tile (diagonal + rank-32), so
    # the STAGE never allocates a (d, d) either — only the host parity
    # reference below does, and only because numpy is the oracle
    u = rng.standard_normal((d, 32))
    diag = 1.0 + rng.random(d) * d
    q = rng.standard_normal((d, c))
    tiles = []
    for i in range(n):
        t = u[i * r:(i + 1) * r] @ u.T
        t[np.arange(r), i * r + np.arange(r)] += diag[i * r:(i + 1) * r]
        tiles.append(t)
    gt = jnp.asarray(np.stack(tiles))
    mt = jnp.asarray(np.stack([q[i * r:(i + 1) * r] for i in range(n)]))
    mesh = Mesh(np.array(jax.devices()), ("data",))

    fn_dist = make_tiled_federated_solve(
        mesh, target_gamma=0.5, distributed_factor=True, dim=d)
    peak_dist, peak_dist_shape = peak_aval_bytes(fn_dist, gt, mt)
    full_bytes = d * d * 8
    # the acceptance invariant, asserted where the numbers are recorded
    assert peak_dist < full_bytes, (
        f"distributed factor materialized a full-system transient: "
        f"{peak_dist_shape}")
    assert peak_dist <= r * d * 8, peak_dist_shape

    t0 = time.perf_counter()
    w_dist = np.asarray(fn_dist(gt, mt))
    t_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    w_dist = np.asarray(fn_dist(gt, mt))
    t_dist = time.perf_counter() - t0

    g_full = np.concatenate(tiles, 0)
    g_full[np.arange(d), np.arange(d)] += 0.5
    ref = np.linalg.solve(g_full, q)
    err = float(np.abs(w_dist - ref).max() / np.abs(ref).max())

    row = dict(
        bench="distributed_factor", d=d, shards=n,
        dist_first_s=t_first, dist_s=t_dist,
        peak_transient_bytes_dist=int(peak_dist),
        peak_transient_shape_dist=peak_dist_shape,
        tile_resident_bytes=int(r * d * 8),
        full_system_bytes=int(full_bytes),
        budget_bytes=int(DEVICE_TRANSIENT_BUDGET),
        baseline_feasible=bool(run_baseline),
        rel_err_vs_numpy_f64=err, parity_1e10=bool(err < 1e-10),
        # whole-resident Mosaic kernel needs the f32 system in VMEM (~16 MB)
        vmem_native_monolithic_ok=bool(d * d * 4 <= 16 * 2**20),
        base_s=None, base_first_s=None, peak_transient_bytes_base=None,
        speedup_vs_gather=None,
    )
    if run_baseline:
        fn_base = make_tiled_federated_solve(mesh, target_gamma=0.5, dim=d)
        peak_base, _ = peak_aval_bytes(fn_base, gt, mt)
        t0 = time.perf_counter()
        w_base = np.asarray(fn_base(gt, mt))
        row["base_first_s"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        w_base = np.asarray(fn_base(gt, mt))
        row["base_s"] = time.perf_counter() - t0
        row["peak_transient_bytes_base"] = int(peak_base)
        row["speedup_vs_gather"] = row["base_s"] / row["dist_s"]
        assert peak_base >= full_bytes      # the baseline DOES gather
        err_b = float(np.abs(w_base - ref).max() / np.abs(ref).max())
        row["base_rel_err_vs_numpy_f64"] = err_b
    print(json.dumps(row))


def bench_distributed_factor(d: int):
    """Run one distributed-factor measurement in a fresh 8-device x64 child
    (both knobs are process-global); the gather-then-factor baseline runs
    only where its (d, d) per-device transient fits the budget."""
    run_baseline = d * d * 8 <= DEVICE_TRANSIENT_BUDGET
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root] +
        ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    res = subprocess.run(
        [sys.executable, os.path.abspath(__file__), _DIST_SUBPROC_FLAG,
         str(d), str(int(run_baseline))],
        capture_output=True, text=True, env=env, cwd=root)
    if res.returncode != 0:
        raise RuntimeError(f"dist subprocess failed:\n{res.stderr}")
    return json.loads(res.stdout.strip().splitlines()[-1])


def _tiled_subprocess_main(d: int) -> None:
    """Runs inside the x64 / 8-device child: tiled vs sync at dimension d."""
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_ENABLE_X64"] = "1"
    import numpy as np

    from repro.core.engine import AnalyticEngine, SuffStats
    from repro.fl import ShardedCoordinator

    c = 100
    rng = np.random.default_rng(0)
    # a cheap full-rank SPD aggregate at d=6144 scale: diagonal + low rank
    # (a dense X of 4·d rows would cost a 463-GFlop host matmul just to
    # set the stage)
    u = rng.standard_normal((d, 64))
    gram = u @ u.T + np.diag(1.0 + rng.random(d) * d)
    q = rng.standard_normal((d, c))

    eng = AnalyticEngine("numpy_f64", gamma=1.0)
    stats = SuffStats(gram=gram, moment=q, count=float(d), clients=8.0)

    t0 = time.perf_counter()
    w_sync = eng.solve(stats, target_gamma=0.5)
    t_sync = time.perf_counter() - t0

    coord = ShardedCoordinator(d, c, gamma=1.0, tiled_gram=True)
    n = coord.num_shards
    r = d // n
    coord._gram_tiles = [gram[i * r:(i + 1) * r].copy() for i in range(n)]
    coord._moment_tiles = [q[i * r:(i + 1) * r].copy() for i in range(n)]
    coord._count = float(d)
    coord._seen = set(range(8))
    t0 = time.perf_counter()
    w_tiled = coord.solve(0.5)
    t_first = time.perf_counter() - t0                  # includes compile
    t0 = time.perf_counter()
    w_tiled = coord.solve(0.5)
    t_tiled = time.perf_counter() - t0

    err = float(np.abs(w_tiled - w_sync).max())
    print(json.dumps(dict(
        bench="tiled_6144", d=d, shards=n,
        sync_solve_s=t_sync, tiled_solve_s=t_tiled,
        tiled_first_solve_s=t_first,
        max_abs_err_vs_sync=err, parity_1e6=bool(err < 1e-6),
        resident_bytes_per_shard_tiled=int(r * d * 8),
        resident_bytes_per_shard_leaf=int(d * d * 8),
    )))


def bench_tiled(d: int):
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    # child needs repro (src) AND the benchmarks package (root) on its path
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root] +
        ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    res = subprocess.run(
        [sys.executable, os.path.abspath(__file__), _TILED_SUBPROC_FLAG,
         str(d)],
        capture_output=True, text=True, env=env, cwd=root)
    if res.returncode != 0:
        raise RuntimeError(f"tiled subprocess failed:\n{res.stderr}")
    return json.loads(res.stdout.strip().splitlines()[-1])


def run(quick: bool = False) -> list[dict]:
    out = []

    d, c, ng = (512, 20, 8) if quick else (2048, 100, 16)
    row = bench_fused_sweep(d, c, ng)
    out.append(row)
    print_table(
        "Fused multi-γ sweep (Pallas, interpret on CPU) vs host paths",
        ["case", "per-γ loop s", "eigh sweep s", "fused s", "vs loop",
         "vs eigh", "rel err"],
        [[f"d={d} C={c} |γ|={ng}", f"{row['host_loop_s']:.2f}",
          f"{row['eigh_sweep_s']:.2f}", f"{row['fused_s']:.2f}",
          f"{row['speedup_vs_loop']:.2f}x",
          f"{row['speedup_vs_eigh']:.2f}x",
          f"{row['fused_rel_err']:.1e}"]])

    d2, batch = (256, 4) if quick else (1024, 8)
    row = bench_batched_factor(d2, 16, batch)
    out.append(row)
    print_table(
        "Batched blocked Cholesky + substitution vs numpy loop",
        ["case", "numpy s", "kernel s", "speedup"],
        [[f"d={d2} batch={batch}", f"{row['host_s']:.2f}",
          f"{row['kernel_s']:.2f}", f"{row['speedup']:.2f}x"]])

    d3 = 256 if quick else 1024
    ranks = [0, d3 // 64, d3 // 16, d3 // 8, d3 // 4]
    rows = bench_sweep_handle(d3, 16, 8 if quick else 16, ranks)
    out.extend(rows)
    print_table(
        "Repeated sweeps on an evolving federation: Woodbury handle vs "
        "re-eigh",
        ["pending rank", "woodbury s", "re-eigh s", "speedup"],
        [[r["pending_rank"], f"{r['woodbury_s']:.3f}",
          f"{r['re_eigh_s']:.3f}", f"{r['speedup']:.1f}x"] for r in rows])

    d4 = 768 if quick else 6144
    row = bench_tiled(d4)
    out.append(row)
    print_table(
        "Tiled-Gram ShardedCoordinator, 8-way mesh, x64 subprocess",
        ["case", "sync s", "tiled s", "max |Δ| vs sync", "tile MB/shard",
         "leaf MB/shard"],
        [[f"d={d4}", f"{row['sync_solve_s']:.2f}",
          f"{row['tiled_solve_s']:.2f}",
          f"{row['max_abs_err_vs_sync']:.1e}",
          f"{row['resident_bytes_per_shard_tiled'] / 2**20:.0f}",
          f"{row['resident_bytes_per_shard_leaf'] / 2**20:.0f}"]])
    if not row["parity_1e6"]:
        raise AssertionError(
            f"tiled-vs-sync parity exceeded 1e-6: {row['max_abs_err_vs_sync']}")

    ds = [256, 512] if quick else [2048, 4096, 6144, 8192]
    dist_rows = [bench_distributed_factor(d) for d in ds]
    out.extend(dist_rows)
    print_table(
        "Tile-parallel distributed factor vs gather-then-factor, 8-way "
        "mesh, x64 subprocess per d",
        ["d", "dist s", "gather s", "speedup", "peak MB dist",
         "peak MB gather", "budget MB", "rel err"],
        [[r["d"], f"{r['dist_s']:.2f}",
          "infeasible" if r["base_s"] is None else f"{r['base_s']:.2f}",
          "—" if r["speedup_vs_gather"] is None
          else f"{r['speedup_vs_gather']:.2f}x",
          f"{r['peak_transient_bytes_dist'] / 2**20:.0f}",
          "—" if r["peak_transient_bytes_base"] is None
          else f"{r['peak_transient_bytes_base'] / 2**20:.0f}",
          f"{r['budget_bytes'] / 2**20:.0f}",
          f"{r['rel_err_vs_numpy_f64']:.1e}"] for r in dist_rows])
    bad = [r["d"] for r in dist_rows if not r["parity_1e10"]]
    if bad:
        raise AssertionError(
            f"distributed-factor parity exceeded 1e-10 at d={bad}")
    return out


if __name__ == "__main__":
    if len(sys.argv) >= 3 and sys.argv[1] == _TILED_SUBPROC_FLAG:
        _tiled_subprocess_main(int(sys.argv[2]))
        sys.exit(0)
    if len(sys.argv) >= 4 and sys.argv[1] == _DIST_SUBPROC_FLAG:
        _dist_subprocess_main(int(sys.argv[2]), bool(int(sys.argv[3])))
        sys.exit(0)
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale sizes (same as run.py --quick)")
    args = ap.parse_args()
    rows = run(quick=args.smoke)
    if not args.smoke:
        outdir = os.path.join("results", "bench")
        os.makedirs(outdir, exist_ok=True)
        with open(os.path.join(outdir, "solve_kernels_bench.json"),
                  "w") as fh:
            json.dump(rows, fh, indent=1)
