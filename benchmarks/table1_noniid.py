"""Paper Table 1: top-1 accuracy under NIID-1 (Dirichlet α) and NIID-2
(sharding s) — AFL vs gradient-FL baselines, frozen shared features.

Offline analogue: synthetic Gaussian-mixture features (see common.FEATURES).
Expected structure (the paper's claim): baselines degrade as α/s shrink; AFL
is bit-identical across every setting (zero std, equals the joint solve).
"""

from __future__ import annotations

import numpy as np

from repro.config import FLConfig
from repro.fl import afl, baselines

from benchmarks.common import feature_data, print_table


def run(quick: bool = False) -> list[dict]:
    train, test = feature_data()
    num_clients = 20 if quick else 50
    rounds = 10 if quick else 30
    settings = [
        ("NIID-1 a=0.1", dict(partition="niid1", alpha=0.1)),
        ("NIID-1 a=0.01", dict(partition="niid1", alpha=0.01)),
        ("NIID-2 s=4", dict(partition="niid2", shards_per_client=4)),
        ("NIID-2 s=2", dict(partition="niid2", shards_per_client=2)),
    ]
    rows, out = [], []
    for label, kw in settings:
        fl = FLConfig(num_clients=num_clients, **kw)
        fa = baselines.run_gradient_fl(train, test, fl, method="fedavg",
                                       rounds=rounds)
        fp = baselines.run_gradient_fl(train, test, fl, method="fedprox",
                                       rounds=rounds)
        ff = baselines.run_fedfisher_diag(train, test, fl)
        res = afl.run_afl(train, test, fl)
        rows.append([label, f"{fa.accuracy:.4f}", f"{fp.accuracy:.4f}",
                     f"{ff.accuracy:.4f}", f"{res.accuracy:.4f}"])
        out.append(dict(setting=label, fedavg=fa.accuracy, fedprox=fp.accuracy,
                        fedfisher=ff.accuracy, afl=res.accuracy))
    print_table(
        f"Table 1 analogue — non-IID accuracy (K={num_clients}, "
        f"{rounds} rounds for gradient FL; AFL: 1 round)",
        ["setting", "FedAvg", "FedProx", "FedFisher-diag", "AFL"], rows)
    afl_accs = {r["afl"] for r in out}
    print(f"AFL identical across settings: {len(afl_accs) == 1} "
          f"(value {out[0]['afl']:.6f})")
    return out
