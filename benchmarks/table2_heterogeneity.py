"""Paper Table 2: data-heterogeneity invariance — accuracy vs Dirichlet α
(0.005 → 1.0 → IID), AFL vs FedAvg, fixed client count.

Paper numbers: FedAvg 24.74% (α=0.005) → 57.89% (IID); AFL flat 58.56%.
Offline structure check: FedAvg monotone-ish in α; AFL bit-identical + above
FedAvg's IID ceiling (it equals the joint solve).
"""

from __future__ import annotations

from repro.config import FLConfig
from repro.fl import afl, baselines

from benchmarks.common import feature_data, print_table

ALPHAS = [0.005, 0.01, 0.1, 1.0, None]  # None → IID


def run(quick: bool = False) -> list[dict]:
    train, test = feature_data()
    num_clients = 20 if quick else 50
    rounds = 10 if quick else 30
    rows, out = [], []
    for alpha in ALPHAS:
        if alpha is None:
            fl = FLConfig(num_clients=num_clients, partition="iid")
            label = "IID"
        else:
            fl = FLConfig(num_clients=num_clients, partition="niid1", alpha=alpha)
            label = f"a={alpha}"
        fa = baselines.run_gradient_fl(train, test, fl, rounds=rounds)
        res = afl.run_afl(train, test, fl)
        rows.append([label, f"{fa.accuracy:.4f}", f"{res.accuracy:.4f}"])
        out.append(dict(alpha=label, fedavg=fa.accuracy, afl=res.accuracy))
    print_table(f"Table 2 analogue — heterogeneity invariance (K={num_clients})",
                ["setting", "FedAvg", "AFL"], rows)
    return out
