"""Paper Table 3: RI ablation — accuracy w/o and w/ the RI restore across
γ ∈ {0, 0.1, 1, 10, 100} and K ∈ {100, 500, 1000}.

Paper structure: γ=0 breaks for large K (rank-deficient local Grams); without
RI the accumulated KγI bias costs accuracy as γ grows; with RI every (γ>0, K)
cell lands on the same joint-solution accuracy.

Honesty note: on our well-conditioned synthetic features the KγI shrinkage is
near-isotropic, so argmax accuracy barely moves even at γ=100 — the paper's
9-point drop needs the ill-conditioned spectra of real CNN features. The bias
is demonstrated in *weight space* instead (Table A.1 deviations); this table
still shows the γ=0 rank-deficiency failure and the w/ RI identity.
"""

from __future__ import annotations

from repro.config import FLConfig
from repro.fl import afl

from benchmarks.common import feature_data, print_table

GAMMAS = [0.0, 0.1, 1.0, 10.0, 100.0]


def run(quick: bool = False) -> list[dict]:
    train, test = feature_data()
    ks = [100, 400] if quick else [100, 500, 1000]
    rows, out = [], []
    for k in ks:
        cells = [f"K={k}"]
        for gamma in GAMMAS:
            accs = {}
            for use_ri in (False, True):
                if gamma == 0.0:
                    if use_ri:
                        accs[use_ri] = None
                        continue
                    try:
                        # paper Algorithm 1 (pairwise recursion): γ=0 with
                        # N_k < d inverts singular Grams → the breakdown the
                        # paper reports. (The production sufficient-stats
                        # path is exact even here — see Table A.1 note.)
                        fl = FLConfig(num_clients=k, gamma=0.0, use_ri=False,
                                      partition="iid")
                        accs[use_ri] = afl.run_afl(train, test, fl,
                                                   pairwise=True).accuracy
                    except Exception:
                        accs[use_ri] = float("nan")
                else:
                    fl = FLConfig(num_clients=k, gamma=gamma, use_ri=use_ri,
                                  partition="iid")
                    accs[use_ri] = afl.run_afl(train, test, fl,
                                               pairwise=True).accuracy
            wo = "N/A" if accs[False] is None else f"{accs[False]:.4f}"
            w = "N/A" if accs[True] is None else f"{accs[True]:.4f}"
            cells.append(f"{wo}/{w}")
            out.append(dict(clients=k, gamma=gamma,
                            acc_no_ri=accs[False], acc_ri=accs[True]))
        rows.append(cells)
    print_table("Table 3 analogue — RI ablation (cells: w/o RI / w/ RI)",
                ["", *(f"g={g}" for g in GAMMAS)], rows)
    return out
