"""Paper Table 3: RI ablation — accuracy w/o and w/ the RI restore across
γ ∈ {0, 0.1, 1, 10, 100} and K ∈ {100, 500, 1000}.

Paper structure: γ=0 breaks for large K (rank-deficient local Grams); without
RI the accumulated KγI bias costs accuracy as γ grows; with RI every (γ>0, K)
cell lands on the same joint-solution accuracy.

The whole ablation now runs off **one eigendecomposition per K** via
``AFLServer.solve_multi_gamma`` (engine lazy-γ semantics): the w/ RI cell is
the solve at target ridge 0, and the w/o RI cell at table γ is the solve at
effective ridge K·γ (Σ C_k^r = C_raw + KγI, eq 15) — so every cell is a
d²·C spectral solve instead of its own Cholesky (or, previously, its own
full pairwise run). The per-K speedup vs per-cell factorizations is recorded
in the results JSON, together with a denser 64-point γ grid (the server-side
cross-validation endpoint) where the one-eigh amortization pays off hardest.
The γ=0 w/o-RI breakdown stays a paper-literal pairwise probe — that failure
mode (inverting singular local Grams) only exists on Algorithm 1's path.

Honesty note: on our well-conditioned synthetic features the KγI shrinkage is
near-isotropic, so argmax accuracy barely moves even at γ=100 — the paper's
9-point drop needs the ill-conditioned spectra of real CNN features. The bias
is demonstrated in *weight space* instead (Table A.1 deviations); this table
still shows the γ=0 rank-deficiency failure and the w/ RI identity.
"""

from __future__ import annotations

import numpy as np

from repro.config import FLConfig
from repro.fl import AFLClient, AFLServer, afl
from repro.fl.partition import make_partition

from benchmarks.common import feature_data, print_table

GAMMAS = [0.0, 0.1, 1.0, 10.0, 100.0]


def _best_of(fn, repeat=5):
    """min-of-N wall time — these solves are ms-scale at d=128, so single
    measurements are scheduler noise."""
    import time

    best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best


def run(quick: bool = False) -> list[dict]:
    train, test = feature_data()
    x_te = test.x.astype(np.float64)
    y_onehot = np.eye(train.num_classes, dtype=np.float64)[train.y]
    ks = [100, 400] if quick else [100, 500, 1000]
    rows, out = [], []
    for k in ks:
        parts = make_partition(train.y, k, "iid", seed=0)
        srv = AFLServer(train.x.shape[1], train.num_classes, gamma=1.0)
        for cid, idx in enumerate(parts):
            srv.submit(AFLClient(cid, gamma=1.0).local_stage(
                train.x[idx].astype(np.float64), y_onehot[idx]))

        # every cell from ONE eigendecomposition: target 0 is the w/ RI
        # restore; target K·γ is the biased no-RI aggregate of table γ
        targets = [0.0] + [k * g for g in GAMMAS if g > 0.0]

        def per_cell(ts):
            # per-cell reference: one independent Cholesky solve per target
            # (engine path, no factor-cache retention — the apples-to-apples
            # "each cell its own factorization" baseline)
            return [srv.engine.solve(srv._stats, target_gamma=t) for t in ts]

        _, t_cells = _best_of(lambda: per_cell(targets))
        ws, t_sweep = _best_of(lambda: srv.solve_multi_gamma(targets))
        accs = [afl.evaluate(w, x_te, test.y) for w in ws]
        acc_ri, acc_no_ri = accs[0], dict(zip([g for g in GAMMAS if g > 0.0],
                                              accs[1:]))

        # dense server-side cross-validation grid: the amortization regime
        grid = list(np.logspace(-3, 3, 64))
        _, t_grid_cells = _best_of(lambda: per_cell(grid))
        _, t_grid_sweep = _best_of(lambda: srv.solve_multi_gamma(grid))

        cells = [f"K={k}"]
        for gamma in GAMMAS:
            if gamma == 0.0:
                try:
                    # paper Algorithm 1 (pairwise recursion): γ=0 with
                    # N_k < d inverts singular Grams → the breakdown the
                    # paper reports. (The production sufficient-stats
                    # path is exact even here — see Table A.1 note.)
                    fl = FLConfig(num_clients=k, gamma=0.0, use_ri=False,
                                  partition="iid")
                    wo = afl.run_afl(train, test, fl, pairwise=True).accuracy
                except Exception:
                    wo = float("nan")
                w = None
            else:
                wo, w = acc_no_ri[gamma], acc_ri
            cells.append(f"{'N/A' if wo is None else f'{wo:.4f}'}/"
                         f"{'N/A' if w is None else f'{w:.4f}'}")
            out.append(dict(clients=k, gamma=gamma, acc_no_ri=wo, acc_ri=w))
        rows.append(cells)
        out.append(dict(
            clients=k, timing=dict(
                targets=len(targets),
                per_cell_seconds=t_cells, multi_gamma_seconds=t_sweep,
                speedup=t_cells / t_sweep,
                grid_points=len(grid),
                grid_per_cell_seconds=t_grid_cells,
                grid_multi_gamma_seconds=t_grid_sweep,
                grid_speedup=t_grid_cells / t_grid_sweep,
                note="min-of-5 wall times, host BLAS; at d=128 each "
                     "per-cell solve pays fixed BLAS-call overhead, so the "
                     "sweep's win here is overhead amortization on top of "
                     "the d3-vs-d2C algebra (see engine_bench for the "
                     "large-d algebraic ratio)")))
    print_table("Table 3 analogue — RI ablation (cells: w/o RI / w/ RI)",
                ["", *(f"g={g}" for g in GAMMAS)], rows)
    for entry in out:
        if "timing" in entry:
            t = entry["timing"]
            print(f"  K={entry['clients']}: multi-γ sweep {t['targets']} "
                  f"targets {t['speedup']:.2f}x vs per-cell; "
                  f"{t['grid_points']}-point grid {t['grid_speedup']:.2f}x")
    return out
