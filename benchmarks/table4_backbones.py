"""Paper Table 4 / §4.5: AFL with different backbones.

The paper swaps ResNet-18 / VGG11 / ViT-B-16; offline we swap three of the
assigned transformer families (dense / moe / xlstm, reduced configs, random
"pretrained" weights) as frozen feature extractors over a synthetic token-
classification task. Absolute accuracies are dataset-dependent; the claims
checked are (i) AFL works on any backbone that yields an embedding and
(ii) per-backbone, AFL equals its own joint solve under any partition.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.config import FLConfig
from repro.configs.registry import get_config
from repro.data import synthetic as D
from repro.fl import afl
from repro.models import transformer as T

from benchmarks.common import print_table

BACKBONES = ["qwen3_32b", "granite_moe_3b_a800m", "xlstm_350m"]


def embed_dataset(arch: str, ds: D.Dataset, batch: int = 128) -> D.Dataset:
    cfg = get_config(arch).reduced(vocab_size=512)
    params = T.init_params(jax.random.key(0), cfg)

    @jax.jit
    def fwd(tokens):
        return T.pool(T.forward(params, cfg, {"tokens": tokens}))

    feats = np.concatenate(
        [np.asarray(fwd(ds.x[i:i + batch])) for i in range(0, len(ds), batch)])
    return D.Dataset(feats, ds.y, ds.num_classes)


def run(quick: bool = False) -> list[dict]:
    n = 1_000 if quick else 3_000
    ds = D.token_classification(n=n, seq=32, vocab=512, num_classes=16, seed=0)
    rows, out = [], []
    for arch in BACKBONES:
        emb = embed_dataset(arch, ds)
        train, test = D.train_test_split(emb, 0.25, seed=0)
        fl = FLConfig(num_clients=10 if quick else 25, partition="niid1",
                      alpha=0.05)
        res = afl.run_afl(train, test, fl)
        _, acc_joint = afl.joint_ridge(train, test, gamma=0.0)
        rows.append([arch, f"{res.accuracy:.4f}", f"{acc_joint:.4f}",
                     "yes" if abs(res.accuracy - acc_joint) < 1e-9 else "NO"])
        out.append(dict(backbone=arch, afl=res.accuracy, joint=acc_joint))
    print_table("Table 4 analogue — AFL across backbones (frozen, random init)",
                ["backbone", "AFL acc", "joint acc", "AFL == joint"], rows)
    return out
