"""Paper Table A.1 (Supp. D): AA-law exactness on the dummy dataset.

ΔW = ||Ŵ_joint − Ŵ_agg,K||₁ on a random 512-dim, 10k-sample, 10-class dataset,
K ∈ {2, 10, 20, 50, 100, 200}, with and without the RI process. The paper
reports ~1e-13 growing to 3.67e12 without RI, and ~1e-10 flat with RI.
This is the paper's own validation of Theorems 1–2 and we reproduce it
exactly (it is backbone-free).
"""

from __future__ import annotations

import numpy as np

from repro.config import FLConfig
from repro.core import analytic as al
from repro.data import synthetic as D
from repro.fl.partition import make_partition

from benchmarks.common import print_table

KS = [2, 10, 20, 50, 100, 200]


def deviation(train: D.Dataset, k: int, gamma: float, use_ri: bool,
              pairwise: bool, seed: int = 0) -> float:
    y_onehot = np.eye(train.num_classes, dtype=np.float64)[train.y]
    w_joint = al.ridge_solve(train.x, y_onehot, 0.0)
    parts = make_partition(train.y, k, "iid", seed=seed)
    updates = [al.local_stage(train.x[idx].astype(np.float64), y_onehot[idx],
                              gamma) for idx in parts]
    w_agg = al.afl_aggregate(updates, use_ri=use_ri, pairwise=pairwise)
    return float(np.abs(w_joint - w_agg).sum())


def run(quick: bool = False) -> list[dict]:
    train = D.dummy_regression(seed=0)
    ks = [2, 20, 100] if quick else KS
    n_runs = 2 if quick else 3
    rows, out = [], []
    for label, gamma, use_ri in [("w/o RI", 0.0, False), ("w/ RI", 1.0, True)]:
        cells = [label]
        for k in ks:
            devs = [deviation(train, k, gamma, use_ri, pairwise=True,
                              seed=s) for s in range(n_runs)]
            d = float(np.mean(devs))
            cells.append(f"{d:.2e}")
            out.append(dict(mode=label, clients=k, deviation=d))
        rows.append(cells)
    print_table(
        f"Table A.1 — ΔW joint vs aggregated (avg of {n_runs} runs; "
        "paper Algorithm 1 pairwise AA recursion)",
        ["", *(f"K={k}" for k in ks)], rows)
    # The production sufficient-statistics form (used on-device) stays exact
    # even where the γ=0 pairwise recursion breaks — report it alongside.
    for k in (ks[-1],):
        d = deviation(train, k, 0.0, False, pairwise=False)
        print(f"sufficient-stats form, γ=0, K={k}: ΔW = {d:.2e} "
              "(exact — Q_k = C_k·W_k holds for the MP solution)")
        out.append(dict(mode="suff-stats g=0", clients=k, deviation=d))
    return out
