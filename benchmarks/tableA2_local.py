"""Paper Table A.2 (Supp. F): necessity of FL — local-only training vs
FedAvg vs AFL under NIID-1 α=0.1.

Paper: local max 16.36 / local avg 12.04 / FedAvg 56.57 / AFL 58.56 —
collaboration is beneficial even with a pre-trained backbone.
"""

from __future__ import annotations

from repro.config import FLConfig
from repro.fl import afl, baselines

from benchmarks.common import feature_data, print_table


def run(quick: bool = False) -> list[dict]:
    train, test = feature_data()
    num_clients = 20 if quick else 50
    rounds = 10 if quick else 30
    fl = FLConfig(num_clients=num_clients, partition="niid1", alpha=0.1)
    loc_avg, loc_max = baselines.run_local_only(train, test, fl, epochs=3)
    fa = baselines.run_gradient_fl(train, test, fl, rounds=rounds)
    res = afl.run_afl(train, test, fl)
    rows = [[f"{loc_max:.4f}", f"{loc_avg:.4f}", f"{fa.accuracy:.4f}",
             f"{res.accuracy:.4f}"]]
    print_table(f"Table A.2 analogue — FL vs local-only (K={num_clients})",
                ["Local Max", "Local Avg", "FedAvg", "AFL"], rows)
    return [dict(local_max=loc_max, local_avg=loc_avg, fedavg=fa.accuracy,
                 afl=res.accuracy)]
