"""Paper Table A.3 (Supp. G): AFL vs a single-round gradient competitor.

Paper compares against FedFisher at α=0.1, K=50 (AFL 35.87% vs 19.31%).
Offline competitor: the diagonal-Fisher one-shot merge (same family of
method — one local training pass + one Fisher-weighted aggregation).
"""

from __future__ import annotations

from repro.config import FLConfig
from repro.fl import afl, baselines

from benchmarks.common import feature_data, print_table


def run(quick: bool = False) -> list[dict]:
    train, test = feature_data()
    fl = FLConfig(num_clients=20 if quick else 50, partition="niid1", alpha=0.1)
    ff = baselines.run_fedfisher_diag(train, test, fl, epochs=2)
    res = afl.run_afl(train, test, fl)
    rows = [[f"{ff.accuracy:.4f}", f"{res.accuracy:.4f}"]]
    print_table(
        f"Table A.3 analogue — single-round methods (K={fl.num_clients}, a=0.1)",
        ["FedFisher-diag", "AFL"], rows)
    return [dict(fedfisher=ff.accuracy, afl=res.accuracy)]
