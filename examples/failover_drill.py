"""Elastic federation drill: grow the mesh, lose the coordinator, recover.

An operator's day-in-the-life for the elastic rung — every federation byte
crosses a loopback socket, and the coordinator that finishes the round is
NOT the one that started it:

  t0  a sharded coordinator serves; half the clients report; a
      :class:`repro.checkpoint.SnapshotDaemon` ticks in the background,
      writing versioned checkpoint-over-wire snapshots
  t1  load ramps: the operator grows the mesh over the wire (grow route);
      in-flight submits racing the resize see a RETRYABLE backpressure
      envelope, never corruption — the AA law makes the migration exact
  t2  the coordinator dies mid-round (simulated: federation suspended);
      clients see typed, retryable ``unavailable`` errors and keep their
      reports
  t3  a replacement cold-starts from the daemon's latest snapshot — on a
      DIFFERENT shard count than the fallen coordinator ever had — and the
      stragglers drain into it, duplicate retries answered idempotently
  t4  the finished head equals a never-crashed single server's oracle

  PYTHONPATH=src python examples/failover_drill.py
"""

import numpy as np

from repro.fl import (AFLServer, FederationService, RemoteCoordinator,
                      ShardedCoordinator, make_report, serve_http)
from repro.fl import errors as E
from repro.checkpoint import SnapshotDaemon

DIM, C, GAMMA, K = 64, 10, 1.0, 16

rng = np.random.default_rng(0)
x = rng.standard_normal((K * 32, DIM))
y = np.eye(C)[rng.integers(0, C, K * 32)]
reports = [make_report(k, x[k * 32:(k + 1) * 32], y[k * 32:(k + 1) * 32],
                       GAMMA) for k in range(K)]

oracle = AFLServer(DIM, C, gamma=GAMMA)
oracle.submit_many(reports)

import tempfile

with tempfile.TemporaryDirectory() as snapdir:
    # ---- t0: serve sharded, first half reports, daemon snapshots
    service = FederationService(ShardedCoordinator(DIM, C, gamma=GAMMA,
                                                   num_shards=2))
    with service, serve_http(service) as http:
        rc = RemoteCoordinator(http.url)
        rc.submit_many(reports[: K // 2])
        daemon = SnapshotDaemon(http.url, directory=snapdir, interval=3600)
        daemon.snapshot_once()
        print(f"t0  {rc.num_clients} clients in; snapshot "
              f"v{daemon.latest_version} at {daemon.latest()}")

        # ---- t1: live grow over the wire
        epoch = rc.grow(2)                      # 2 → 4 shards
        print(f"t1  mesh grown: {rc.num_shards} shards (epoch {epoch})")
        daemon.snapshot_once()                  # same version → no-op
        mid = reports[K // 2: 3 * K // 4]
        rc.submit_many(mid)
        daemon.snapshot_once()                  # new version → new snap
        print(f"t1  {rc.num_clients} clients in; snapshot "
              f"v{daemon.latest_version}")

        # ---- t2: the coordinator dies; clients see typed retryable errors
        fallen = service.suspend_federation()
        outage = 0
        for rep in reports[3 * K // 4:]:
            try:
                rc.submit(rep)
            except E.ServiceError as exc:
                assert isinstance(exc, E.Unavailable) and exc.retryable
                outage += 1
        print(f"t2  coordinator down: {outage} submits got retryable "
              f"'{E.Unavailable.code}' — reports kept client-side")

        # ---- t3: cold-start a replacement from the snapshot, resharded
        replacement = daemon.restore(cls=ShardedCoordinator, num_shards=3)
        service.restore_federation("default", replacement)
        rc.submit_many(reports[K // 2:])        # stragglers + dup retries
        print(f"t3  replacement up on {rc.num_shards} shards; "
              f"{rc.num_clients} clients after straggler drain "
              "(duplicate retries answered idempotently)")

        # ---- t4: the round finishes exactly
        w = np.asarray(rc.solve(), np.float64)
        ref = np.asarray(oracle.solve(), np.float64)
        dw = np.abs(w - ref).max()
        print(f"t4  max|ΔW| vs never-crashed oracle: {dw:.2e}")
        assert dw < 1e-4, dw
        rc.close()
        del fallen

print("drill OK — the coordinator is cattle, the statistics are the pet")
