"""Federated non-IID sweep with a real (reduced) transformer backbone.

End-to-end AFL over one of the assigned architectures as the frozen
feature extractor: tokens → backbone forward → pooled embeddings → per-client
analytic local stages → single-round aggregation — then the same data run
through the gradient-FL baseline for contrast, across heterogeneity levels.

  PYTHONPATH=src python examples/federated_niid.py [--arch qwen3_32b]
"""

import argparse

import jax
import numpy as np

from repro.config import FLConfig
from repro.configs.registry import get_config
from repro.data import synthetic as D
from repro.fl import AFLClient, AFLServer, ClientReport, afl, baselines
from repro.fl.partition import make_partition
from repro.models import transformer as T


def afl_over_wire(train, test, fl: FLConfig) -> float:
    """The AFL column through the canonical API: one AFLClient local stage
    per client, each report crossing the wire as validated bytes."""
    y_onehot = np.eye(train.num_classes)[train.y]
    parts = make_partition(train.y, fl.num_clients, fl.partition,
                           alpha=fl.alpha,
                           shards_per_client=fl.shards_per_client,
                           seed=fl.seed)
    server = AFLServer(train.x.shape[1], train.num_classes, gamma=fl.gamma)
    for cid, idx in enumerate(parts):
        payload = AFLClient(cid, gamma=fl.gamma).local_stage(
            train.x[idx], y_onehot[idx]).to_bytes()
        server.submit(ClientReport.from_bytes(payload))
    return afl.evaluate(server.solve(), test.x, test.y)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_32b")
    ap.add_argument("--samples", type=int, default=3000)
    ap.add_argument("--clients", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(vocab_size=512)
    params = T.init_params(jax.random.key(0), cfg)

    @jax.jit
    def embed(tokens):
        return T.pool(T.forward(params, cfg, {"tokens": tokens}))

    print(f"backbone: {cfg.name} (reduced: {cfg.num_layers}L d={cfg.d_model})")
    raw = D.token_classification(n=args.samples, seq=32, vocab=cfg.vocab_size,
                                 num_classes=16, skew=2.0, seed=0)
    feats = np.concatenate(
        [np.asarray(embed(raw.x[i:i + 256])) for i in range(0, len(raw), 256)])
    ds = D.Dataset(feats, raw.y, raw.num_classes)
    train, test = D.train_test_split(ds, 0.25, seed=0)

    print(f"{'setting':16s} {'FedAvg(30r)':>12s} {'AFL(1r)':>12s}")
    for label, kw in [("IID", dict(partition="iid")),
                      ("NIID-1 a=0.1", dict(partition="niid1", alpha=0.1)),
                      ("NIID-1 a=0.01", dict(partition="niid1", alpha=0.01)),
                      ("NIID-2 s=2", dict(partition="niid2", shards_per_client=2))]:
        fl = FLConfig(num_clients=args.clients, **kw)
        fa = baselines.run_gradient_fl(train, test, fl, rounds=30)
        acc = afl_over_wire(train, test, fl)
        print(f"{label:16s} {fa.accuracy:12.4f} {acc:12.4f}")
    print("\nAFL column is constant by construction (AA law); FedAvg drifts "
          "with heterogeneity.")


if __name__ == "__main__":
    main()
