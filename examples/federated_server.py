"""Operational AFL: stragglers, checkpoint/restart, secure aggregation.

A compressed "day in the life" of the AFL server (the paper's §5 limitations,
dissolved by the AA law — see fl/server.py):

  t0  60 % of clients report (the rest are stragglers)     → exact solve #1
  t1  server checkpoints and "restarts"                    → state restored
  t2  stragglers report, out of order, pairwise-masked     → exact solve #2
      (the server never sees any individual client's statistics)

  PYTHONPATH=src python examples/federated_server.py
"""

import numpy as np

from repro import checkpoint as ckpt
from repro.core import analytic as al
from repro.data import synthetic as D
from repro.fl.afl import evaluate
from repro.fl.partition import make_partition
from repro.fl.server import AFLServer, make_report, masked_reports

K, GAMMA = 30, 1.0

ds = D.gaussian_mixture(n=8000, dim=128, num_classes=40, separation=0.45)
train, test = D.train_test_split(ds, 0.25, seed=0)
y_onehot = np.eye(train.num_classes)[train.y]
parts = make_partition(train.y, K, "niid1", alpha=0.05, seed=0)

# The stragglers (last 40%) mask their uploads pairwise: any single report is
# noise to the server, the cohort sum is exact.
reports = [make_report(i, train.x[idx], y_onehot[idx], GAMMA)
           for i, idx in enumerate(parts)]
on_time, stragglers = reports[: int(K * 0.6)], reports[int(K * 0.6):]
stragglers = masked_reports(stragglers, seed=42)

server = AFLServer(dim=train.x.shape[1], num_classes=train.num_classes,
                   gamma=GAMMA)
server.submit_many(on_time)
acc1 = evaluate(server.solve(), test.x, test.y)
print(f"t0: {server.num_clients}/{K} clients → acc {acc1:.4f} "
      "(exact joint solution of the arrived subset)")

ckpt.save_server("/tmp/afl_server_ckpt", server, metadata={"phase": "t0"})
server = ckpt.load_server("/tmp/afl_server_ckpt")
print(f"t1: checkpoint → restart (state: {server.num_clients} clients, "
      "2 matrices, 1 id-set)")

rng = np.random.default_rng(7)
for r in rng.permutation(len(stragglers)):
    server.submit(stragglers[r])
acc2 = evaluate(server.solve(), test.x, test.y)

w_joint = al.ridge_solve(train.x, y_onehot, 0.0)
dev = np.abs(server.solve() - w_joint).max()
print(f"t2: all {server.num_clients}/{K} in (masked, shuffled) → acc "
      f"{acc2:.4f}; max |ΔW| vs centralized = {dev:.2e}")
assert dev < 1e-8
print("single-round, straggler-tolerant, secure — and still exact.")
