"""Operational AFL: stragglers, checkpoint/restart, secure aggregation,
and async event-loop serving.

A compressed "day in the life" of the AFL server (the paper's §5 limitations,
dissolved by the AA law — see fl/server.py and fl/async_server.py):

  t0  60 % of clients report (the rest are stragglers)     → exact solve #1
  t1  server checkpoints and "restarts"                    → state restored
  t2  stragglers report, out of order, pairwise-masked     → exact solve #2
      (the server never sees any individual client's statistics)
  t3  late trickle goes through the ASYNC server: arrivals stream through
      an event loop, each folded into the live Cholesky factor as a rank-n_k
      update, with solves served concurrently — still exact

  PYTHONPATH=src python examples/federated_server.py
"""

import asyncio

import numpy as np

from repro import checkpoint as ckpt
from repro.core import analytic as al
from repro.data import synthetic as D
from repro.fl import AFLServer, AsyncAFLServer, make_report, masked_reports
from repro.fl.afl import evaluate
from repro.fl.partition import make_partition

K, GAMMA, N_MICRO, MICRO_ROWS = 30, 1.0, 12, 16

ds = D.gaussian_mixture(n=8000, dim=128, num_classes=40, separation=0.45)
train, test = D.train_test_split(ds, 0.25, seed=0)
y_onehot = np.eye(train.num_classes)[train.y]
# hold the tail back as t3's late-joining micro-clients (tiny local batches,
# the rank-update sweet spot); the K regular clients split the rest
n_late = N_MICRO * MICRO_ROWS
parts = make_partition(train.y[:-n_late], K, "niid1", alpha=0.05, seed=0)

# The stragglers (last 40%) mask their uploads pairwise: any single report is
# noise to the server, the cohort sum is exact.
reports = [make_report(i, train.x[idx], y_onehot[idx], GAMMA)
           for i, idx in enumerate(parts)]
on_time, stragglers = reports[: int(K * 0.6)], reports[int(K * 0.6):]
stragglers = masked_reports(stragglers, seed=42)

server = AFLServer(dim=train.x.shape[1], num_classes=train.num_classes,
                   gamma=GAMMA)
server.submit_many(on_time)
acc1 = evaluate(server.solve(), test.x, test.y)
print(f"t0: {server.num_clients}/{K} clients → acc {acc1:.4f} "
      "(exact joint solution of the arrived subset)")

ckpt.save_server("/tmp/afl_server_ckpt", server, metadata={"phase": "t0"})
server = ckpt.load_server("/tmp/afl_server_ckpt")
print(f"t1: checkpoint → restart (state: {server.num_clients} clients, "
      "2 matrices, 1 id-set)")

rng = np.random.default_rng(7)
for r in rng.permutation(len(stragglers)):
    server.submit(stragglers[r])
acc2 = evaluate(server.solve(), test.x, test.y)
print(f"t2: all {server.num_clients}/{K} regulars in (masked, shuffled) → "
      f"acc {acc2:.4f}")


# t3: a late trickle of micro-clients through the EVENT LOOP. The async
# server adopts the live aggregate; each arrival (16 rows ≪ d=128) folds
# into the cached Cholesky factor as a rank-16 update — no refactorization
# on the hot path — while solves are served concurrently.
async def late_trickle(sync_server: AFLServer) -> np.ndarray:
    # micro-batches of 16 rows at d=128: above the default perf-crossover
    # budget (d//16 = 8), but this phase demonstrates the update *path*, so
    # widen the budget explicitly
    async with AsyncAFLServer(train.x.shape[1], train.num_classes,
                              gamma=GAMMA, server=sync_server,
                              update_rank_budget=MICRO_ROWS) as srv:
        await srv.solve()                          # prime the live factor
        a, b = len(train.x) - n_late, len(train.x)
        folded = 0
        for i, lo in enumerate(range(a, b, MICRO_ROWS)):
            # submit resolves to the sync server's fold outcome: True while
            # the live factor absorbs arrivals as rank updates
            folded += await srv.submit(make_report(
                K + i, train.x[lo:lo + MICRO_ROWS],
                y_onehot[lo:lo + MICRO_ROWS], GAMMA))
        w = await srv.solve()
        print(f"t3: {N_MICRO} micro-clients streamed through the event loop "
              f"— {folded} folded on arrival ({srv.updates} rank updates, "
              f"{srv.deferred_refactors} deferred refactors)")
        return w

w_async = asyncio.run(late_trickle(server))
acc3 = evaluate(w_async, test.x, test.y)

w_joint = al.ridge_solve(train.x, y_onehot, 0.0)
dev = np.abs(w_async - w_joint).max()
print(f"    all {server.num_clients}/{K + N_MICRO} in → acc {acc3:.4f}; "
      f"max |ΔW| vs centralized = {dev:.2e}")
assert dev < 1e-8

# t4: server-side γ cross-validation — the whole candidate grid off ONE
# eigendecomposition of the aggregate, scored against a holdout split.
sweep = server.sweep([0.0, 1e-3, 0.1, 1.0, 10.0], (test.x, test.y))
print(f"t4: γ sweep {sweep.gammas} → acc {tuple(round(a, 4) for a in sweep.accuracies)}; "
      f"best γ={sweep.best_gamma:g} ({sweep.best_accuracy:.4f})")
print("single-round, straggler-tolerant, secure, async — and still exact.")
