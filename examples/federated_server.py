"""Operational AFL over the wire: a real client/server pair on loopback HTTP.

A compressed "day in the life" of a served federation — every byte below
actually crosses a socket through :class:`repro.fl.service.FederationService`
and comes back through :class:`repro.fl.service.RemoteCoordinator`:

  t0  service up; 60 % of clients POST their report (the rest straggle);
      the solved head is downloaded versioned (ETag-style staleness token —
      the second download is a cheap not-modified)
  t1  an operator snapshots the LIVE federation over the wire and restarts
      it behind a new port — remote state() == one checkpoint schema
  t2  stragglers report, out of order, pairwise-masked (the server never
      sees any individual client's statistics) — still the exact joint
      solution, and bit-for-bit the in-proc answer (the CI smoke invariant)
  t3  a late trickle of micro-clients goes through submit_stream into an
      ASYNC coordinator: framed multi-report upload, fire-and-forget ingest,
      backpressure visible as `pending`
  t4  server-side γ cross-validation: the grid ships once, every candidate
      solved off ONE eigendecomposition
  t5  personalization: one client mixes its OWN local statistics into the
      shared aggregate for a per-client head (read-only — the shared state
      is untouched)

  PYTHONPATH=src python examples/federated_server.py
"""

import time

import numpy as np

from repro import checkpoint as ckpt
from repro.core import analytic as al
from repro.data import synthetic as D
from repro.fl import (AFLServer, AsyncAFLServer, FederationService,
                      RemoteCoordinator, make_report, masked_reports,
                      serve_http)
from repro.fl.afl import evaluate
from repro.fl.partition import make_partition

K, GAMMA, N_MICRO, MICRO_ROWS = 30, 1.0, 12, 16

ds = D.gaussian_mixture(n=8000, dim=128, num_classes=40, separation=0.45)
train, test = D.train_test_split(ds, 0.25, seed=0)
y_onehot = np.eye(train.num_classes)[train.y]
DIM, C = train.x.shape[1], train.num_classes
# hold the tail back as t3's late-joining micro-clients; K regulars split
# the rest
n_late = N_MICRO * MICRO_ROWS
parts = make_partition(train.y[:-n_late], K, "niid1", alpha=0.05, seed=0)

reports = [make_report(i, train.x[idx], y_onehot[idx], GAMMA)
           for i, idx in enumerate(parts)]
on_time, stragglers = reports[: int(K * 0.6)], reports[int(K * 0.6):]
stragglers = masked_reports(stragglers, seed=42)

# the wire-equivalence referee: the same reports folded in-process
inproc = AFLServer(dim=DIM, num_classes=C, gamma=GAMMA)

# ---- t0: serve, submit from "another process", download versioned weights
service = FederationService(AFLServer(dim=DIM, num_classes=C, gamma=GAMMA))
http = serve_http(service)
client = RemoteCoordinator(http.url)          # knows ONLY the URL
for r in on_time:
    client.submit(r)                          # ClientReport bytes over HTTP
inproc.submit_many(on_time)
vw = client.weights()
acc1 = evaluate(vw.weight, test.x, test.y)
again = client.weights(if_etag=vw.etag)
print(f"t0: {client.num_clients}/{K} clients over {http.url} → acc "
      f"{acc1:.4f} (weights v{vw.version}; re-poll: "
      f"not_modified={again.not_modified})")

# ---- t1: snapshot the live federation over the wire, restart elsewhere
ckpt.save_server("/tmp/afl_fed_ckpt", client, metadata={"phase": "t0"})
http.close()
service = FederationService(
    AFLServer.from_state(ckpt.restore("/tmp/afl_fed_ckpt")))
http = serve_http(service)
client = RemoteCoordinator(http.url)
print(f"t1: checkpoint → restart on {http.url} "
      f"({client.num_clients} clients restored)")
# the referee walks through the same checkpoint (restore re-derives the raw
# aggregate, rounding last ulps — both sides must round identically)
inproc = AFLServer.from_state(ckpt.restore("/tmp/afl_fed_ckpt"))

# ---- t2: masked stragglers, shuffled, over the wire
rng = np.random.default_rng(7)
order = rng.permutation(len(stragglers))
client.submit_stream([stragglers[i].to_bytes() for i in order])
inproc.submit_many([stragglers[i] for i in order])   # same fold order
w_remote = client.solve()
dev_wire = np.abs(w_remote - inproc.solve()).max()
acc2 = evaluate(w_remote, test.x, test.y)
print(f"t2: all {client.num_clients}/{K} regulars in (masked, shuffled) → "
      f"acc {acc2:.4f}; max |ΔW| wire vs in-proc = {dev_wire:.2e}")
assert dev_wire == 0.0, "wire transport must be bit-for-bit at f64"

# ---- t3: late micro-clients stream into an ASYNC coordinator
http.close()
service = FederationService(
    AsyncAFLServer(DIM, C, gamma=GAMMA, update_rank_budget=MICRO_ROWS,
                   server=service.coordinator()))
http = serve_http(service)
client = RemoteCoordinator(http.url)
a, b = len(train.x) - n_late, len(train.x)
frames = [make_report(K + i, train.x[lo:lo + MICRO_ROWS],
                      y_onehot[lo:lo + MICRO_ROWS],
                      GAMMA).to_bytes()
          for i, lo in enumerate(range(a, b, MICRO_ROWS))]
out = client.submit_stream(frames)
print(f"t3: {out['accepted']}/{N_MICRO} micro-reports queued in one framed "
      f"request (pending at ack: {out['pending']})")
while client.pending:                     # fire-and-forget: wait for drain
    time.sleep(0.01)
w_all = client.solve()
acc3 = evaluate(w_all, test.x, test.y)
w_joint = al.ridge_solve(train.x, y_onehot, 0.0)
dev = np.abs(w_all - w_joint).max()
print(f"    all {client.num_clients}/{K + N_MICRO} in → acc {acc3:.4f}; "
      f"max |ΔW| vs centralized = {dev:.2e}")
assert dev < 1e-8

# ---- t4: γ cross-validation, server-side, one eigendecomposition
sweep = client.sweep([0.0, 1e-3, 0.1, 1.0, 10.0], (test.x, test.y))
print(f"t4: γ sweep {sweep.gammas} → acc "
      f"{tuple(round(a, 4) for a in sweep.accuracies)}; best "
      f"γ={sweep.best_gamma:g} ({sweep.best_accuracy:.4f})")

# ---- t5: a personalized head for one client (local-stats mixture)
mine = reports[0]
w_personal = client.personalized_solve(0.0, report=mine, mix_weight=5.0)
tilt = np.abs(w_personal - w_all).max()
print(f"t5: client {mine.client_id} personalized head (β=5 local mixture): "
      f"max |ΔW| vs shared = {tilt:.2e} (shared aggregate untouched: "
      f"{client.num_clients} clients)")

http.close()
service.close()
print("single-round, straggler-tolerant, secure, async, served over HTTP — "
      "and still exact.")
