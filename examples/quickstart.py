"""Quickstart: AFL through the canonical client/coordinator API.

Trains a federated linear probe over frozen features with K=100 clients under
an extreme non-IID split, in ONE local epoch and ONE aggregation round — each
client's upload crossing the "network" as canonical wire bytes — and checks
the result is *identical* to training on the centralized dataset (the paper's
invariance-to-data-partitioning property).

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.data import synthetic as D
from repro.fl import AFLClient, AFLServer, ClientReport
from repro.fl.afl import evaluate, joint_ridge
from repro.fl.partition import make_partition

# 1. A dataset of frozen-backbone features (stand-in for ResNet/CIFAR).
ds = D.gaussian_mixture(n=10_000, dim=256, num_classes=50, separation=0.5)
train, test = D.train_test_split(ds, test_frac=0.2)
y_onehot = np.eye(train.num_classes)[train.y]

# 2. The centralized reference: one ridge solve on all data (γ→0).
w_joint, acc_joint = joint_ridge(train, test, gamma=0.0)
print(f"joint (centralized) accuracy: {acc_joint:.4f}")

# 3. AFL: 100 clients under a pathological non-IID split (Dirichlet α=0.01).
#    Each client runs its one-epoch local stage and uploads ONE report —
#    serialized to bytes, validated on ingest — to the coordinator.
parts = make_partition(train.y, 100, "niid1", alpha=0.01, seed=0)
server = AFLServer(dim=256, num_classes=50, gamma=1.0)
for cid, idx in enumerate(parts):
    payload = AFLClient(cid, gamma=1.0).local_stage(
        train.x[idx], y_onehot[idx]).to_bytes()
    server.submit(ClientReport.from_bytes(payload))
w_afl = server.solve()                    # single round, RI-restored
acc = evaluate(w_afl, test.x, test.y)
print(f"AFL accuracy (K={server.num_clients}, α=0.01): {acc:.4f}")

# 4. The paper's claim: exact equivalence, not approximation.
dev = np.abs(w_afl - w_joint).max()
print(f"max |W_afl - W_joint| = {dev:.2e}")
assert dev < 1e-6 and abs(acc - acc_joint) < 1e-12
print("AFL == joint training, under any partition. QED.")
