"""Quickstart: AFL in ~40 lines — the paper's Algorithm 1 end to end.

Trains a federated linear probe over frozen features with K=100 clients under
an extreme non-IID split, in ONE local epoch and ONE aggregation round, and
checks the result is *identical* to training on the centralized dataset
(the paper's invariance-to-data-partitioning property).

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.config import FLConfig
from repro.data import synthetic as D
from repro.fl import afl

# 1. A dataset of frozen-backbone features (stand-in for ResNet/CIFAR).
ds = D.gaussian_mixture(n=10_000, dim=256, num_classes=50, separation=0.5)
train, test = D.train_test_split(ds, test_frac=0.2)

# 2. The centralized reference: one ridge solve on all data (γ→0).
w_joint, acc_joint = afl.joint_ridge(train, test, gamma=0.0)
print(f"joint (centralized) accuracy: {acc_joint:.4f}")

# 3. AFL: 100 clients, pathological non-IID split (Dirichlet α=0.01),
#    one-epoch local stages + single-round aggregation + RI restore.
fl = FLConfig(num_clients=100, gamma=1.0, partition="niid1", alpha=0.01)
res = afl.run_afl(train, test, fl)
print(f"AFL accuracy (K=100, α=0.01): {res.accuracy:.4f} "
      f"in {res.train_seconds:.2f}s")

# 4. The paper's claim: exact equivalence, not approximation.
dev = np.abs(res.weight - w_joint).max()
print(f"max |W_afl - W_joint| = {dev:.2e}")
assert dev < 1e-6 and abs(res.accuracy - acc_joint) < 1e-12
print("AFL == joint training, under any partition. QED.")
