"""Replication drill: kill the primary mid-stream, lose nothing, read on.

The multi-box day-in-the-life for the replication rung. The primary writes
every accepted submit to a durable CRC-framed ledger; a warm standby tails
that ledger; a read replica follows the same ledger for the
solve-once/download-millions path:

  t0  a primary serves with ``--ledger-dir`` semantics (every accepted
      submit fsynced to the ledger before the ack); a snapshot daemon
      ticks; the first wave of clients reports
  t1  a second wave arrives as ONE framed ``submit_stream`` batch — acked
      the moment the frames are admitted and ledgered, NOT when folded
  t2  the primary dies mid-stream (simulated: federation suspended) with
      that batch barely acked; clients see typed retryable ``unavailable``
  t3  a warm standby cold-starts from the newest snapshot, tails the
      ledger suffix, and promotes: bit-for-bit (f64, ``assert_array_equal``)
      equal to a never-crashed oracle — ZERO reports lost, including the
      mid-stream batch; a straggler retry answers ``duplicate: true``
  t4  a weights read replica follows the same ledger: ETags are
      instance-scoped (a primary token never revalidates on the replica,
      and vice versa), reads never touch ingest, writes answer the typed
      ``read_only`` 403

  PYTHONPATH=src python examples/replication_drill.py
"""

import tempfile

import numpy as np

from repro.fl import (AFLServer, FederationService, RemoteCoordinator,
                      WarmStandby, WeightsReplica, make_report, serve_http)
from repro.fl import errors as E
from repro.checkpoint import SnapshotDaemon

DIM, C, GAMMA, K = 64, 10, 1.0, 16

rng = np.random.default_rng(0)
x = rng.standard_normal((K * 32, DIM))
y = np.eye(C)[rng.integers(0, C, K * 32)]
reports = [make_report(k, x[k * 32:(k + 1) * 32], y[k * 32:(k + 1) * 32],
                       GAMMA) for k in range(K)]

oracle = AFLServer(DIM, C, gamma=GAMMA)
oracle.submit_many(reports)
oracle_w = np.asarray(oracle.solve(0.25), np.float64)

with tempfile.TemporaryDirectory() as tmp:
    ledger_dir, snap_dir = f"{tmp}/ledger", f"{tmp}/snapshots"

    # ---- t0: primary with a durable submit ledger; first wave; snapshot
    service = FederationService(AFLServer(DIM, C, gamma=GAMMA),
                                ledger_dir=ledger_dir)
    with service, serve_http(service) as http:
        rc = RemoteCoordinator(http.url)
        rc.submit_many(reports[: K // 2])
        daemon = SnapshotDaemon(http.url, directory=snap_dir, interval=3600)
        daemon.snapshot_once()
        print(f"t0  {rc.num_clients} clients in, ledger at seq "
              f"{rc.describe()['ledger_seq']}; snapshot "
              f"v{daemon.latest_version}")

        # ---- t1: a framed stream batch — acked on admission + ledger write
        batch = [r.to_bytes() for r in reports[K // 2: 3 * K // 4]]
        out = rc.submit_stream(batch)
        assert out["accepted"] == len(batch)
        print(f"t1  stream batch of {out['accepted']} acked, ledger at seq "
              f"{rc.describe()['ledger_seq']}")

        # ---- t2: the primary dies; the last wave bounces off the outage
        service.suspend_federation()
        outage = 0
        for rep in reports[3 * K // 4:]:
            try:
                rc.submit(rep)
            except E.Unavailable as exc:
                assert exc.retryable
                outage += 1
        print(f"t2  primary down: {outage} submits got retryable "
              f"'{E.Unavailable.code}' — reports kept client-side")

        # ---- t3: warm standby = snapshot prefix + ledger suffix → promote
        standby = WarmStandby(ledger_dir, snapshot_dir=snap_dir)
        promoted = standby.promote()
        assert promoted.num_clients == 3 * K // 4      # zero loss
        service.restore_federation("default", promoted)
        rc.submit_many(reports[3 * K // 4:])           # stragglers drain
        dup = rc.submit_stream(batch)                  # mid-stream retry
        assert all(r.get("duplicate") for r in dup["results"])
        w = np.asarray(rc.solve(0.25), np.float64)
        np.testing.assert_array_equal(w, oracle_w)     # bit-for-bit, f64
        print(f"t3  standby promoted from snapshot v{daemon.latest_version}"
              f" + {standby.applied} ledger records "
              f"({standby.skipped} already in snapshot); "
              f"{rc.num_clients} clients; max|ΔW| vs oracle = 0.0 "
              "(assert_array_equal) — zero reports lost")

        # ---- t4: a read replica follows the ledger; ETags never cross
        replica = WeightsReplica(ledger_dir, snapshot_dir=snap_dir)
        rep_svc = FederationService(replica)
        with rep_svc, serve_http(rep_svc) as rep_http:
            rrc = RemoteCoordinator(rep_http.url)
            info = rrc.describe()
            assert info["read_only"] and info["replica_lag"] == 0
            vw_p = rc.weights(0.25)
            vw_r = rrc.weights(0.25)
            assert vw_p.etag != vw_r.etag
            assert not rrc.weights(0.25, if_etag=vw_p.etag).not_modified
            assert rrc.weights(0.25, if_etag=vw_r.etag).not_modified
            np.testing.assert_array_equal(
                np.asarray(vw_r.weight, np.float64), w)
            try:
                rrc.submit(reports[0])
                raise AssertionError("replica accepted a write")
            except E.ReadOnlyFederation:
                pass
            print(f"t4  replica serving at lag {rrc.describe()['replica_lag']}"
                  ": primary ETag re-downloads once, replica ETag caches, "
                  f"writes answer '{E.ReadOnlyFederation.code}'")
            rrc.close()
        rc.close()

print("drill OK — the ledger is the federation; boxes are cattle")
