"""Serving demo: batched requests against three architecture families.

Exercises the inference substrate the decode input-shapes lower: prefill a
batch of prompts, decode tokens against each family's cache (KV / SSM state /
recurrent state / enc-dec cross-attn memory). This is the CPU-scale analogue
of the decode_32k / long_500k dry-run configurations.

  PYTHONPATH=src python examples/serve_demo.py
"""

from repro.configs.registry import get_config
from repro.launch.serve import serve

REQUESTS = [
    ("gemma3_12b", "dense, 5:1 local:global sliding window"),
    ("zamba2_7b", "hybrid Mamba2 + shared attention"),
    ("seamless_m4t_medium", "enc-dec (audio frontend stubbed)"),
]


def main() -> None:
    for arch, note in REQUESTS:
        cfg = get_config(arch).reduced()
        out, prefill_s, decode_s = serve(cfg, batch=4, prompt_len=24, gen=12)
        rate = 4 * 12 / decode_s
        print(f"{arch:22s} [{note}]")
        print(f"  prefill {prefill_s*1e3:7.1f}ms  decode {decode_s*1e3:7.1f}ms "
              f"({rate:5.1f} tok/s)  sample: ...{out[0, -6:].tolist()}")


if __name__ == "__main__":
    main()
