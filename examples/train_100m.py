"""End-to-end driver: pre-train a ~100M-param LM for a few hundred steps,
then AFL-probe it — the paper's full "pre-trained backbone + analytic
downstream" pipeline in one script.

Stage 1 pre-trains a ~100M dense decoder (a scaled-down minicpm family
member, WSD schedule) with the generic gradient train step on synthetic
token streams. Stage 2 freezes it and runs AFL over 50 non-IID clients,
verifying the federated probe equals the centralized probe.

  PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.config import FLConfig, ModelConfig
from repro.data import synthetic as D
from repro.fl import afl
from repro.launch import steps as ST
from repro.launch.inputs import sample_batch
from repro.models import transformer as T
from repro.optim import wsd_schedule

# ~100M params: 12L, d=768, 12H, ffn 2048, vocab 32k (embed ≈ 2×24.6M).
CFG_100M = ModelConfig(
    name="dense-100m", arch_type="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32_000,
    num_classes=16, source="scaled minicpm family [arXiv:2404.06395]")


def count_params(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = CFG_100M
    params = T.init_params(jax.random.key(0), cfg)
    print(f"model: {cfg.name}, {count_params(params)/1e6:.1f}M params")

    # ---- stage 1: LM pre-training (gradient, WSD schedule) ----
    step = jax.jit(ST.make_full_train_step(cfg))
    sched = wsd_schedule(0.1, warmup=max(args.steps // 10, 5), total=args.steps)
    t0, losses = time.time(), []
    for i in range(args.steps):
        batch = {"tokens": D.lm_stream(args.batch, args.seq, cfg.vocab_size,
                                       seed=i)}
        params, loss = step(params, batch, sched(i))
        losses.append(float(loss))
        if i % max(args.steps // 10, 1) == 0:
            print(f"  step {i:4d} loss {losses[-1]:.4f} lr {float(sched(i)):.2e}")
    head_m = float(np.mean(losses[:5]))
    tail_m = float(np.mean(losses[-5:]))
    print(f"pre-training: loss {head_m:.3f} → {tail_m:.3f} "
          f"(smoothed; {time.time()-t0:.0f}s)")
    assert tail_m < head_m, "LM loss should decrease"

    # ---- stage 2: freeze + AFL downstream probe ----
    # Note: with a synthetic 32k-vocab task the absolute probe accuracy is
    # modest — the claims checked are (i) the federated probe is *identical*
    # to the centralized probe and (ii) it beats chance. The paper's absolute
    # numbers need ImageNet-pretrained backbones (see DESIGN.md §2).
    raw = D.token_classification(n=2500, seq=64, vocab=cfg.vocab_size,
                                 num_classes=16, skew=5.0, seed=1)

    @jax.jit
    def embed(tokens):
        return T.pool(T.forward(params, cfg, {"tokens": tokens}))

    feats = np.concatenate(
        [np.asarray(embed(raw.x[i:i + 128])) for i in range(0, len(raw), 128)])
    ds = D.Dataset(feats, raw.y, raw.num_classes)
    train, test = D.train_test_split(ds, 0.25, seed=0)
    fl = FLConfig(num_clients=50, partition="niid1", alpha=0.05)
    res = afl.run_afl(train, test, fl)
    _, acc_joint = afl.joint_ridge(train, test, gamma=0.0)
    chance = 1.0 / raw.num_classes
    print(f"AFL probe: {res.accuracy:.4f} (centralized: {acc_joint:.4f}, "
          f"chance: {chance:.4f}) — K=50, α=0.05, single round")
    assert abs(res.accuracy - acc_joint) < 1e-9, "AA-law equivalence violated"
    assert res.accuracy > chance, "probe should beat chance"


if __name__ == "__main__":
    main()
