"""Checkpointing: params / analytic state / FL-server state, sharding-aware.

Layout (a directory per checkpoint):
    <dir>/manifest.json     pytree structure + leaf metadata + user metadata
    <dir>/arrays.npz        leaf arrays keyed by flattened path

Works on any pytree of jax or numpy arrays. For sharded arrays the save path
pulls addressable shards and reassembles the global array on host (fine for
the head/statistics scale this framework checkpoints — the frozen backbone is
reproducible from its seed and is usually *not* checkpointed, which is itself
an AFL property: the only trained state is (C_agg, Q_agg, W)).

``save_server`` / ``load_server`` round-trip any :class:`repro.fl.api.
Coordinator` state (all coordinator kinds share one checkpoint schema),
enabling the straggler workflow: checkpoint mid-aggregation, restart — as
the same kind or a different one — and late clients keep submitting. A
:class:`~repro.fl.service.RemoteCoordinator` works as the source too — its
``state()`` downloads the federation checkpoint over the wire — so an
operator can snapshot a live remote federation and restore it into any
local coordinator kind behind a fresh FederationService.
"""

from __future__ import annotations

import json
import pathlib
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "save_server", "load_server",
           "SnapshotDaemon"]

_SEP = "/"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        out[key or "_root"] = np.asarray(leaf)
    return out


def save(path, tree: Any, metadata: Optional[dict] = None) -> None:
    """Write a pytree checkpoint (atomic-ish: npz then manifest last)."""
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    arrays = _flatten(tree)
    np.savez(path / "arrays.npz", **arrays)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "treedef": str(treedef),
        "keys": sorted(arrays),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
        "metadata": metadata or {},
    }
    (path / "manifest.json").write_text(json.dumps(manifest, indent=1))


def restore(path, like: Any = None) -> Any:
    """Read a checkpoint. With ``like`` (a pytree of the same structure —
    arrays or ShapeDtypeStructs), returns that structure filled with the
    stored arrays, validating shapes; without it, returns {key: array}."""
    path = pathlib.Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "arrays.npz") as z:
        arrays = {k: z[k] for k in z.files}
    if sorted(arrays) != manifest["keys"]:
        raise ValueError("checkpoint corrupt: manifest/npz key mismatch")
    if like is None:
        return arrays
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pth, leaf in flat:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in pth)
        key = key or "_root"
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch at {key!r}: ckpt {arr.shape} vs {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_server(path, server, metadata: Optional[dict] = None) -> None:
    """Checkpoint a coordinator (``state()`` speaks one shared schema).

    For the async coordinator ``state()`` is a coroutine — checkpoint it
    from its event loop: ``ckpt.save(path, await server.state())``.
    """
    import inspect

    state = server.state()
    if inspect.isawaitable(state):
        state.close()
        raise TypeError(
            "async coordinator state() is a coroutine; checkpoint it from "
            "the event loop: ckpt.save(path, await server.state())")
    meta = dict(metadata or {})
    meta["kind"] = type(server).__name__
    save(path, state, metadata=meta)


def load_server(path, cls=None, **kwargs):
    """Restore a coordinator: :class:`repro.fl.api.AFLServer` by default, or
    any ``cls`` with the protocol's ``from_state`` (e.g. ShardedCoordinator,
    AsyncAFLServer). Extra kwargs pass through to ``from_state`` — e.g.
    ``num_shards=8`` to reshard an elastic restore, ``tiled_gram=True`` for
    the row-tiled layout."""
    if cls is None:
        from repro.fl.api import AFLServer as cls

    state = restore(path)
    return cls.from_state(state, **kwargs)


# at the bottom: snapshot.py imports this module for save/load_server
from repro.checkpoint.snapshot import SnapshotDaemon  # noqa: E402
