"""The snapshot daemon: checkpoint-over-wire pulls for failover.

AFL's one-state-schema property (every coordinator kind writes and restores
the same ``state()`` dict) means a *single* periodic puller gives any
federation durable failover: snapshot the live service over the wire, and a
replacement coordinator — of ANY kind, on ANY shard count — cold-starts from
the latest snapshot with zero aggregation loss for everything the snapshot
saw. The AA law does the rest: clients that reported after the snapshot
simply resubmit (the service's idempotent ingest and duplicate-client guard
make that safe), and the restored aggregate is exact, not approximate.

:class:`SnapshotDaemon` is deliberately dumb: pull ``state``, write a
versioned checkpoint directory, prune old ones, repeat — and *survive*
outages (a dead service is the exact moment the existing snapshots matter,
so a failed pull is recorded and retried, never fatal). ``tools/snapshotd.py``
is the CLI wrapper; the failover drill in ``tests/test_elastic.py`` and
``examples/failover_drill.py`` exercise kill → restore end-to-end.

Snapshot naming: ``snap-{version:012d}-{epoch:06d}`` where version is the
federation's submission version (client count) at pull time and epoch is the
coordinator's ``mesh_epoch`` — monotone under ingest AND resharding, so
lexicographic order IS recency order and ``latest()`` is a directory
listing. Client count alone is not an identity: a grow/shrink or a γ change
mutates the state without admitting a client, so idempotence is decided by a
digest of the pulled state, not by the name — same name + different digest
means the snapshot on disk is stale and gets overwritten in place.
"""

from __future__ import annotations

import json
import pathlib
import threading
import time
import zlib
from typing import Any, List, Optional, Tuple

import numpy as np

import repro.checkpoint as ckpt

__all__ = ["SnapshotDaemon", "state_digest"]


def state_digest(state: dict) -> str:
    """CRC-32 over the state's arrays and scalars in sorted-key order — a
    cheap, deterministic identity for "did the aggregate actually change".
    Two pulls with equal digests are byte-identical snapshots."""
    crc = 0
    for key in sorted(state):
        crc = zlib.crc32(key.encode(), crc)
        val = state[key]
        if hasattr(val, "shape") and hasattr(val, "dtype"):   # np OR jax
            crc = zlib.crc32(np.ascontiguousarray(val).tobytes(), crc)
        else:
            crc = zlib.crc32(
                json.dumps(val, sort_keys=True, default=str).encode(), crc)
    return f"{crc:08x}"


class SnapshotDaemon:
    """Periodically snapshot a live federation to versioned checkpoints.

    ``source`` may be a service URL string, a
    :class:`~repro.fl.service.FederationService`, any transport object, or
    a local coordinator — anything a
    :class:`~repro.fl.service.RemoteCoordinator` can speak to, or anything
    with a ``state()`` method. The connection is made lazily per pull, so
    the daemon can be constructed (and keeps running) while the service is
    down.

    >>> d = SnapshotDaemon(srv.url, directory=tmp, interval=0.5, keep=3)
    >>> d.start()                      # background thread
    >>> ...                            # coordinator dies
    >>> coord = d.restore(ShardedCoordinator, num_shards=8)
    >>> d.stop()
    """

    def __init__(self, source: Any, *, directory, interval: float = 30.0,
                 keep: int = 5, federation: str = "default",
                 ledger: Any = None, auth_token: Optional[str] = None):
        self.source = source
        self.directory = pathlib.Path(directory)
        self.interval = float(interval)
        self.keep = int(keep)
        self.federation = str(federation)
        # ledger-aware compaction: a ReportLedger object (same process as
        # the writer) or a ledger directory path (out-of-process — uses the
        # non-truncating compact_ledger_dir). Each successful snapshot tick
        # compacts the ledger to the highest sequence number the snapshot
        # provably covers — only when the pull observed pending == 0, so an
        # async coordinator's queued-but-unapplied records always survive.
        self.ledger = ledger
        self.auth_token = auth_token
        self.errors: List[Tuple[float, str]] = []   # (monotonic time, msg)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- one pull -----------------------------------------------------------

    def _local_floor(self) -> int:
        """Compaction floor for a bare-coordinator source: ledger position
        read BEFORE pending (the same ordering contract as the service's
        describe route) — any record appended after the seq read either
        shows as pending (floor 0, skip) or carries a higher seq."""
        if self.ledger is None:
            return 0
        if hasattr(self.ledger, "last_seq"):
            seq = int(self.ledger.last_seq)
        else:
            from repro.fl.replication import last_seq_on_disk

            seq = int(last_seq_on_disk(self.ledger))
        pending = int(getattr(self.source, "pending", 0) or 0)
        return seq if pending == 0 else 0

    def _pull_state(self):
        if hasattr(self.source, "state") and not hasattr(
                self.source, "handle"):
            floor = self._local_floor()
            return (self.source.state(), type(self.source).__name__,
                    int(getattr(self.source, "mesh_epoch", 0)), floor)
        from repro.fl.service import RemoteCoordinator

        # per-pull client: a stale connection to a restarted service must
        # never wedge the daemon
        remote = RemoteCoordinator(self.source, federation=self.federation,
                                   auth_token=self.auth_token)
        try:
            info = remote.describe()
            floor = 0
            if self.ledger is not None and int(info.get("pending", 0)) == 0:
                # describe reads ledger_seq before pending, so with
                # pending == 0 everything ≤ ledger_seq is applied — and
                # the state pulled below can only cover MORE than that
                floor = int(info.get("ledger_seq", 0))
            return remote.state(), remote.kind, remote.mesh_epoch, floor
        finally:
            remote.close()

    def snapshot_once(self) -> Optional[pathlib.Path]:
        """Pull and persist one snapshot; returns its directory, or ``None``
        when this exact state is already on disk (an idempotent no-op).
        Idempotence is by state digest, not name: a resharding or γ change
        that kept the client count rewrites the stale snapshot in place.
        Either way the tick ends by compacting the attached ledger (when
        one is configured) to what the on-disk snapshot now covers."""
        state, kind, epoch, floor = self._pull_state()
        version = int(len(state["seen"]))
        digest = state_digest(state)
        path = self.directory / f"snap-{version:012d}-{epoch:06d}"
        manifest = path / "manifest.json"
        if manifest.exists():
            meta = json.loads(manifest.read_text()).get("metadata", {})
            if meta.get("digest") == digest:
                self._compact(path, floor)
                return None
            for f in sorted(path.iterdir(), reverse=True):    # stale: redo
                f.unlink()
            path.rmdir()
        ckpt.save(path, dict(state),
                  metadata={"federation": self.federation,
                            "source_kind": kind, "version": version,
                            "mesh_epoch": epoch, "digest": digest})
        self.prune()
        self._compact(path, floor)
        return path

    def _compact(self, snapshot_path: pathlib.Path, base_seq: int) -> None:
        """Tick compaction: drop ledger segments the snapshot covers. A
        failure here never fails the snapshot — compaction is advisory."""
        if self.ledger is None or base_seq <= 0:
            return
        try:
            if hasattr(self.ledger, "compact"):
                self.ledger.compact(snapshot_path, base_seq)
            else:
                from repro.fl.replication import compact_ledger_dir

                compact_ledger_dir(self.ledger, snapshot_path, base_seq)
        except Exception as exc:                       # noqa: BLE001
            self.errors.append((time.monotonic(),
                                f"compact: {type(exc).__name__}: {exc}"))

    def prune(self) -> None:
        """Drop all but the newest ``keep`` snapshots."""
        for path in self.snapshots()[:-self.keep] if self.keep > 0 else []:
            for f in sorted(path.iterdir(), reverse=True):
                f.unlink()
            path.rmdir()

    # -- the archive --------------------------------------------------------

    def snapshots(self) -> List[pathlib.Path]:
        """Complete snapshot directories, oldest → newest."""
        if not self.directory.is_dir():
            return []
        return sorted(p for p in self.directory.glob("snap-*")
                      if (p / "manifest.json").exists())

    def latest(self) -> Optional[pathlib.Path]:
        snaps = self.snapshots()
        return snaps[-1] if snaps else None

    @property
    def latest_version(self) -> Optional[int]:
        """Client count of the newest snapshot (the ``-{epoch}`` suffix is
        tie-break, not version — ``wait_for_version`` waits on ingest)."""
        latest = self.latest()
        return None if latest is None else int(latest.name.split("-")[1])

    def restore(self, cls=None, **kwargs):
        """Cold-start a replacement coordinator from the latest snapshot —
        any kind, any shard count (``cls``/kwargs go to ``from_state``)."""
        latest = self.latest()
        if latest is None:
            raise FileNotFoundError(
                f"no snapshots under {self.directory} — nothing to restore")
        return ckpt.load_server(latest, cls, **kwargs)

    # -- the daemon loop ----------------------------------------------------

    def start(self) -> "SnapshotDaemon":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._run, daemon=True, name="afl-snapshotd")
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=max(5.0, 2 * self.interval))
            self._thread = None

    def wait_for_version(self, version: int,
                         timeout: float = 30.0) -> bool:
        """Block until a snapshot at ≥ ``version`` exists (the drill's
        deterministic cut point), or the timeout expires."""
        deadline = time.monotonic() + float(timeout)
        while time.monotonic() < deadline:
            v = self.latest_version
            if v is not None and v >= int(version):
                return True
            time.sleep(min(0.02, max(self.interval / 4, 0.002)))
        return False

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                self.snapshot_once()
            except Exception as exc:                   # noqa: BLE001
                # an unreachable service is the daemon's reason to exist:
                # record, keep the existing snapshots, try again next tick
                self.errors.append((time.monotonic(),
                                    f"{type(exc).__name__}: {exc}"))
            self._stop.wait(self.interval)

    def __enter__(self) -> "SnapshotDaemon":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
