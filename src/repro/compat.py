"""Version-drift shims for the jax APIs this repo relies on.

Two renames bite across the jax versions this codebase meets:

  * Pallas TPU compiler params: ``pltpu.TPUCompilerParams`` (<= 0.4.x) was
    renamed to ``pltpu.CompilerParams`` (newer releases keep the old name as
    a deprecated alias for a while). :func:`tpu_compiler_params` constructs
    whichever class the installed jax provides.
  * ``shard_map``: lives at ``jax.experimental.shard_map.shard_map`` on
    0.4.x and is re-exported as ``jax.shard_map`` on newer releases.

Everything else in the repo imports these names from here so the drift is
handled in exactly one place.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

try:  # newer jax
    from jax import shard_map  # type: ignore[attr-defined]
except (ImportError, AttributeError):  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def tpu_compiler_params(**kwargs):
    """Build Pallas TPU compiler params under either jax naming scheme."""
    return _COMPILER_PARAMS_CLS(**kwargs)
