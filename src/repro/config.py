"""Central configuration dataclasses for models, FL runs and input shapes."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 512  # token grouping for one-hot dispatch (see models/moe.py)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256
    num_heads: int = 0       # 0 → derived from d_inner // 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One backbone. ``arch_type`` selects the block program:

    dense   — uniform [attn + MLP] stack (minicpm, qwen3, nemotron, llava,
              gemma3 via window_pattern)
    moe     — uniform [attn + MoE] stack (grok-1, granite)
    hybrid  — Mamba2 stack with a shared attention block every
              ``shared_attn_every`` layers (zamba2)
    xlstm   — mLSTM stack with an sLSTM block every ``slstm_every`` (xLSTM)
    encdec  — bidirectional encoder + causal decoder w/ cross-attn (seamless)
    """

    name: str
    arch_type: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 → d_model // num_heads
    activation: str = "swiglu"             # swiglu | relu2 | gelu
    norm: str = "rms"                      # rms | layer
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0         # gemma3 global layers (0 → same)
    # Sliding-window attention. window>0 applies to "local" layers;
    # global_every=N → every Nth layer is global (full attn). gemma3: window
    # 1024, global_every=6 (5 local : 1 global).
    window: int = 0
    global_every: int = 0
    logit_softcap: float = 0.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    shared_attn_every: int = 6             # hybrid: shared attn cadence
    slstm_every: int = 8                   # xlstm: one sLSTM per N layers
    encoder_layers: int = 0                # encdec only
    encoder_seq: int = 4096                # encdec: encoder memory length
    prefix_tokens: int = 0                 # VLM patch / audio frame stub prefix
    num_classes: int = 1000                # AFL head width (downstream task)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "float32"                 # activations/params dtype
    source: str = ""                       # citation (paper / model card)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: ≤2-ish layers, d_model≤512, ≤4 experts."""
        small: dict = dict(
            num_layers=2,
            d_model=min(self.d_model, 128),
            num_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads * 4 // self.num_heads)),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=32,
            window=min(self.window, 32) if self.window else 0,
            global_every=2 if self.global_every else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=32 if self.encoder_layers else self.encoder_seq,
            prefix_tokens=8 if self.prefix_tokens else 0,
            num_classes=16,
            shared_attn_every=2,
            slstm_every=2,
            dtype="float32",
        )
        if self.moe is not None:
            ne = min(4, self.moe.num_experts)
            tk = min(2, self.moe.top_k)
            # capacity ≥ group → no token dropping, so reduced-config decode
            # is exactly consistent with the full forward pass.
            small["moe"] = MoEConfig(
                num_experts=ne, top_k=tk, capacity_factor=float(ne) / tk,
                group_size=16,
            )
        if self.ssm is not None:
            small["ssm"] = SSMConfig(d_state=16, chunk=16, num_heads=4)
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class FLConfig:
    """Federated-run configuration (paper §4 settings)."""

    num_clients: int = 100
    gamma: float = 1.0
    use_ri: bool = True
    partition: str = "niid1"   # iid | niid1 (Dirichlet) | niid2 (sharding)
    alpha: float = 0.1         # NIID-1 Dirichlet concentration
    shards_per_client: int = 4  # NIID-2
    seed: int = 0
