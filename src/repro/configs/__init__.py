from repro.configs.registry import get_config, list_archs, canonical  # noqa: F401
