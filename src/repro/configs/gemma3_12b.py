"""Gemma3-12B [hf:google/gemma-3-1b-pt family card] — 5:1 local:global.

48L, d_model 3840, 16 heads / 8 kv, head_dim 256, d_ff 15360, vocab 262144.
Local layers: sliding window 1024, rope theta 10k; every 6th layer global
(full attention, theta 1M). 128k context natively; long_500k uses the
all-window variant (see launch/dryrun.py --variant sliding_window).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    arch_type="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15_360,
    vocab_size=262_144,
    activation="gelu",
    qk_norm=True,
    window=1024,
    global_every=6,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)
