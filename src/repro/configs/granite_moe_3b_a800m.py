"""Granite-MoE 3B-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base family].

32L, d_model 1536, 24 heads / 8 kv, vocab 49155. MoE: 40 experts, top-8,
d_ff 512 per expert. (The assignment bracket note says "32 experts"; the
numeric field says 40e — we follow the numeric field, see DESIGN.md §6.)
"""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49_155,
    activation="swiglu",
    moe=MoEConfig(num_experts=40, top_k=8),
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
