"""Grok-1 314B [hf:xai-org/grok-1] — MoE, 8 experts top-2, GQA(kv=8).

64L, d_model 6144, 48 heads / 8 kv, d_ff 32768 per expert, vocab 131072.
"""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    arch_type="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32_768,
    vocab_size=131_072,
    activation="gelu",
    logit_softcap=30.0,
    moe=MoEConfig(num_experts=8, top_k=2),
    source="hf:xai-org/grok-1",
)
