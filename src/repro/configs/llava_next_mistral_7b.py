"""LLaVA-NeXT (Mistral-7B) [hf:llava-hf/llava-v1.6-mistral-7b-hf] — VLM.

Language backbone: 32L, d_model 4096, 32 heads / 8 kv, d_ff 14336,
vocab 32000. AnyRes tiling: the vision frontend is a STUB — input_specs()
provides 2880 pre-computed patch embeddings (5 tiles x 576 patches) that are
projected and consumed as prefix tokens.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=32_000,
    activation="swiglu",
    rope_theta=1_000_000.0,
    prefix_tokens=2880,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
