"""MiniCPM-2B [arXiv:2404.06395] — dense llama-like, trained with WSD.

40L, d_model 2304, 36 heads (kv=36, i.e. MHA), d_ff 5760, vocab 122753.
The WSD (warmup-stable-decay) schedule is provided in repro.optim.wsd for the
gradient-FL baseline path (AFL itself is gradient-free).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    arch_type="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    activation="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="arXiv:2404.06395",
)
