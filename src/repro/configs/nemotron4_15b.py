"""Nemotron-4 15B [arXiv:2402.16819] — dense, GQA(kv=8), squared-ReLU MLP.

32L, d_model 6144, 48 heads / 8 kv, d_ff 24576, vocab 256000, LayerNorm.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    arch_type="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24_576,
    vocab_size=256_000,
    activation="relu2",
    norm="layer",
    rope_theta=10_000.0,
    source="arXiv:2402.16819",
)
