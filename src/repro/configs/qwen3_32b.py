"""Qwen3-32B [hf:Qwen/Qwen3-8B family card] — dense, GQA(kv=8), qk-norm.

64L, d_model 5120, 64 heads / 8 kv heads, head_dim 128, d_ff 25600,
vocab 151936.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    arch_type="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25_600,
    vocab_size=151_936,
    activation="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B",
)
