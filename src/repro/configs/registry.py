"""Architecture registry: ``get_config(name)`` / ``list_archs()``.

Each ``<arch>.py`` module defines ``CONFIG`` with the exact published
dimensions (source cited in ``ModelConfig.source``). ``--arch <id>`` in the
launchers resolves through this registry.
"""

from __future__ import annotations

import importlib

from repro.config import ModelConfig

ARCH_IDS = [
    "minicpm_2b",
    "qwen3_32b",
    "gemma3_12b",
    "grok1_314b",
    "zamba2_7b",
    "llava_next_mistral_7b",
    "granite_moe_3b_a800m",
    "seamless_m4t_medium",
    "nemotron4_15b",
    "xlstm_350m",
]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS}


def canonical(name: str) -> str:
    key = name.replace("-", "_")
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ALIAS)}")
    return key


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def list_archs() -> list[str]:
    return list(ARCH_IDS)
