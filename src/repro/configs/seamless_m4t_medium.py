"""SeamlessM4T-medium [arXiv:2308.11596] — encoder-decoder, multimodal.

12 decoder layers + 12 encoder layers, d_model 1024, 16 heads (kv=16),
d_ff 4096, vocab 256206. The audio frontend (mel + conformer feature
extractor) is a STUB: input_specs() provides pre-computed frame embeddings
(B, encoder_seq, d_model) consumed by the encoder; decode shapes use a fixed
4096-frame encoder memory. long_500k is SKIPPED for this arch (cross-attn to
the full encoder memory is irreducibly dense — DESIGN.md §6).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="encdec",
    num_layers=12,
    encoder_layers=12,
    encoder_seq=4096,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256_206,
    activation="gelu",
    norm="layer",
    source="arXiv:2308.11596",
)
