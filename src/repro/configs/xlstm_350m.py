"""xLSTM-350M [arXiv:2405.04517] — sLSTM + mLSTM blocks, no FFN stack.

24L, d_model 1024, 4 heads, vocab 50304, d_ff=0 (projection-only blocks:
up-factor-2 + recurrent mixer + down). One sLSTM block per 8 layers
(7 mLSTM : 1 sLSTM), matching the paper's sparse-sLSTM placements.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    arch_type="xlstm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    slstm_every=8,
    source="arXiv:2405.04517",
)
