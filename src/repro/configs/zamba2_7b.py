"""Zamba2-7B [arXiv:2411.15242] — Mamba2 backbone + shared attention blocks.

81 Mamba2 layers (d_model 3584, ssm_state 64) with ONE shared transformer
block (32 heads, kv=32, d_ff 14336) applied every 6 layers (13 applications
for 81 layers; weights shared, per-application KV caches).
"""
from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14_336,
    vocab_size=32_000,
    activation="swiglu",
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, chunk=128, num_heads=32),
    shared_attn_every=6,
    source="arXiv:2411.15242",
)
