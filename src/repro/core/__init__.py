"""AFL core: analytic (closed-form) federated learning.

Engine (ONE implementation of the math): :mod:`repro.core.engine`
Host path (float64, paper-literal API):  :mod:`repro.core.analytic`
Device path (f32, jit/shard_map API):    :mod:`repro.core.streaming`,
                                         :mod:`repro.core.distributed`
"""

from repro.core.engine import (  # noqa: F401
    AnalyticEngine,
    SuffStats,
)
from repro.core.analytic import (  # noqa: F401
    ClientUpdate,
    aa_merge,
    afl_aggregate,
    aggregate_pairwise,
    aggregate_sufficient_stats,
    local_stage,
    ridge_solve,
    ri_restore,
)
from repro.core.streaming import (  # noqa: F401
    AnalyticState,
    init_state,
    merge_states,
    solve,
    update_state,
)
