"""Activation-sharding policy: logical constraints the model code can emit.

The model definitions stay mesh-agnostic; they call ``constrain(x, dims)``
with *logical* dim labels ("batch", "model", None). When a policy is active
(the launchers install one around trace time), the label resolves to mesh
axes with a divisibility guard and a ``with_sharding_constraint`` is applied;
with no policy (CPU smoke tests, examples) it is the identity.

This is what keeps GSPMD from drifting into batch-replicated layouts inside
the layer scan when a head count (e.g. minicpm's 36) does not divide the
tensor-parallel axis.
"""

from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_POLICY: contextvars.ContextVar[Optional[dict]] = contextvars.ContextVar(
    "activation_policy", default=None)


@contextlib.contextmanager
def activation_policy(mesh: Mesh, batch_axes: Sequence[str],
                      model_axes: Sequence[str], *,
                      flash_surrogate: bool = False):
    token = _POLICY.set({
        "mesh": mesh,
        "batch": tuple(batch_axes),
        "model": tuple(model_axes),
        "flash_surrogate": flash_surrogate,
    })
    try:
        yield
    finally:
        _POLICY.reset(token)


def active() -> bool:
    return _POLICY.get() is not None


def flash_surrogate_active() -> bool:
    """True when the dry-run stands in the Pallas flash-attention kernel.

    The surrogate (see layers.sdpa) reads q/k/v once and writes the output —
    exactly the HBM boundary traffic of the fused kernel — so the compiled
    HLO's memory analysis models the kernel-integrated step; the kernel's MXU
    FLOPs are added analytically by the dry-run (launch/dryrun.py).
    """
    pol = _POLICY.get()
    return bool(pol and pol.get("flash_surrogate"))


def constrain(x: jax.Array, dims: Sequence[Optional[str]]) -> jax.Array:
    """dims: one logical label per array dim — "batch" | "model" | None."""
    pol = _POLICY.get()
    if pol is None:
        return x
    mesh: Mesh = pol["mesh"]
    entries = []
    for label, size in zip(dims, x.shape):
        axes = pol.get(label) if label else ()
        total = math.prod(mesh.shape[a] for a in axes) if axes else 1
        entries.append(tuple(axes) if total > 1 and size % total == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


def constrain_bsd(x: jax.Array) -> jax.Array:
    """(B, S, D) activations: batch over the federation axes."""
    return constrain(x, ("batch", None, None))


def constrain_heads(x: jax.Array) -> jax.Array:
    """(B, H, S, hd): batch + heads over 'model' when the count divides."""
    return constrain(x, ("batch", "model", None, None))
