"""Paper-faithful analytic learning API (host-side, float64).

Implements, term-by-term, the math of AFL:

  - eq (4)/(13): local-stage (regularized) least-squares solution
  - Theorem 1 / eq (7)-(8): Absolute Aggregation (AA) law for two clients
  - eq (9)-(11): pairwise accumulated aggregation (AcAg) for K clients
  - Theorem 2 / eq (14)-(16): Regularization Intermediary (RI) restore

This module is the *paper-literal reference API*: it mirrors the paper's
released torch-f64 implementation symbol-for-symbol. The numerics themselves
live in ONE place — :mod:`repro.core.engine` — and every function here is a
thin wrapper over the engine's ``numpy_f64`` backend. The pairwise recursion
(:func:`aa_merge` / :func:`aggregate_pairwise`) is intentionally literal
(matrix products per eq (10)) rather than algebraically simplified — it
exists to *validate* the AA law against the engine's sufficient-statistics
form, which production uses.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.engine import AnalyticEngine, Factorization

__all__ = [
    "ClientUpdate",
    "ridge_solve",
    "local_stage",
    "aa_merge",
    "aggregate_pairwise",
    "aggregate_sufficient_stats",
    "ri_restore",
    "afl_aggregate",
]

# The single host-f64 engine behind every function in this module. γ is per
# call here (the paper API passes it explicitly), so the instance default is
# irrelevant; it exists to own the backend.
_ENGINE = AnalyticEngine("numpy_f64")
_B = _ENGINE.backend


def ridge_solve(x: np.ndarray, y: np.ndarray, gamma: float) -> np.ndarray:
    """eq (13): ``(XᵀX + γI)^{-1} Xᵀ Y`` (γ=0 reduces to the MP solution, eq (4))."""
    stats = _ENGINE.client_stats(x, y)
    return _ENGINE.solve(stats, use_ri=True, target_gamma=gamma)


@dataclasses.dataclass(frozen=True)
class ClientUpdate:
    """What a client uploads after its one-epoch local stage (Algorithm 1).

    Attributes:
      weight: ``Ŵ_k^r = (X_kᵀX_k + γI)^{-1} X_kᵀ Y_k``   (eq. 13), shape (d, C).
      gram:   ``C_k^r = X_kᵀX_k + γI``                    (Algorithm 1 step 3),
              shape (d, d).
      gamma:  the regularization used locally (must match across clients).
    """

    weight: np.ndarray
    gram: np.ndarray
    gamma: float

    @property
    def dim(self) -> int:
        return self.weight.shape[0]


def local_stage(x: np.ndarray, y: np.ndarray, gamma: float) -> ClientUpdate:
    """Algorithm 1, Local Stage: returns (Ŵ_k^r, C_k^r)."""
    stats = _ENGINE.client_stats(x, y)
    gram = _ENGINE.regularized_gram(stats, gamma)
    weight = _B.solve_sym(gram, stats.moment)
    return ClientUpdate(weight=weight, gram=gram, gamma=gamma)


def _fsolve(f: Factorization, b: np.ndarray) -> np.ndarray:
    return _B.factor_solve(f, b)


def aa_merge(
    w_u: np.ndarray, c_u: np.ndarray, w_v: np.ndarray, c_v: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Theorem 1 / eq (9)-(10): merge two trained weights into the joint weight.

    Literal AA-law form:  ``W = 𝒲_u W_u + 𝒲_v W_v`` with
      𝒲_u = I - C_u^{-1} C_v (I - (C_u+C_v)^{-1} C_v)
      𝒲_v = I - C_v^{-1} C_u (I - (C_u+C_v)^{-1} C_u)

    Returns the merged (weight, gram). Grams add: C = C_u + C_v (eq. 11).
    Each symmetric matrix is factored once (engine backend) and the factor
    reused across the solves (identical math, ~2× fewer 512³ ops).
    """
    d = c_u.shape[0]
    eye = np.eye(d)
    c_sum = c_u + c_v
    # (C_u + C_v)^{-1} [C_v | C_u] from one factorization
    s = _fsolve(_B.factor(c_sum), np.concatenate([c_v, c_u], axis=1))
    s_v, s_u = s[:, :d], s[:, d:]
    cal_u = eye - _fsolve(_B.factor(c_u), c_v @ (eye - s_v))
    cal_v = eye - _fsolve(_B.factor(c_v), c_u @ (eye - s_u))
    return cal_u @ w_u + cal_v @ w_v, c_sum


def aggregate_pairwise(updates: Sequence[ClientUpdate]) -> tuple[np.ndarray, np.ndarray]:
    """Algorithm 1, Aggregation Stage (the paper's sequential AcAg loop).

    Aggregates clients one at a time with the AA law. Order does not matter
    (tested); the paper notes clients may be sampled in any order.
    Returns (Ŵ_agg^r, C_agg^r).
    """
    if not updates:
        raise ValueError("no client updates to aggregate")
    w_agg = updates[0].weight.copy()
    c_agg = updates[0].gram.copy()
    for upd in updates[1:]:
        w_agg, c_agg = aa_merge(w_agg, c_agg, upd.weight, upd.gram)
    return w_agg, c_agg


def aggregate_sufficient_stats(
    updates: Sequence[ClientUpdate],
) -> tuple[np.ndarray, np.ndarray]:
    """Production form: ΣC_k^r and ΣQ_k recovered from the uploads.

    Since Q_k = XᵀY = C_k^r Ŵ_k^r, the server can reconstruct the global
    normal equations without clients ever sharing raw features. Algebraically
    identical to :func:`aggregate_pairwise` (the AA law proves the
    associativity); numerically far cheaper (no per-step inverses).
    """
    c_sum = sum(u.gram for u in updates)
    q_sum = sum(u.gram @ u.weight for u in updates)
    return _B.solve_sym(c_sum, q_sum), c_sum


def ri_restore(
    w_agg_r: np.ndarray,
    c_agg_r: np.ndarray,
    num_clients: int,
    gamma: float,
    target_gamma: float = 0.0,
) -> np.ndarray:
    """Theorem 2 / eq (16): remove the accumulated ``Kγ`` regularization.

    ``Ŵ_agg = (C_agg^r − KγI)^{-1} C_agg^r Ŵ_agg^r`` restores the joint
    MP-inverse solution.  ``target_gamma`` generalizes eq (16): restoring to a
    small final ridge (instead of exactly 0) keeps the solve PD when even the
    *joint* dataset is rank-deficient; ``target_gamma=0`` is the paper's form.
    """
    return _ENGINE.ri_restore(
        w_agg_r, c_agg_r, num_clients, gamma, target_gamma=target_gamma)


def afl_aggregate(
    updates: Sequence[ClientUpdate],
    *,
    use_ri: bool = True,
    pairwise: bool = False,
    target_gamma: float = 0.0,
) -> np.ndarray:
    """Full AFL server: aggregate K client updates into the joint weight.

    Args:
      updates: one :class:`ClientUpdate` per client.
      use_ri: apply the RI restore (eq 16). Without it the result carries the
        accumulated KγI bias the paper ablates in Table 3.
      pairwise: use the literal AA-law recursion (paper Algorithm 1) instead of
        the sufficient-statistics solve. Both are tested equal.
    """
    gammas = {float(u.gamma) for u in updates}
    if len(gammas) != 1:
        raise ValueError(f"clients used different γ: {sorted(gammas)}")
    gamma = gammas.pop()
    if pairwise:
        w_r, c_r = aggregate_pairwise(updates)
    else:
        w_r, c_r = aggregate_sufficient_stats(updates)
    if not use_ri:
        return w_r
    return _ENGINE.ri_restore(
        w_r, c_r, len(updates), gamma, target_gamma=target_gamma)
