"""Distributed AFL aggregation: the single round as a single collective.

On the TPU mesh each shard along the federation axes (``('data',)`` or
``('pod', 'data')``) plays one client cohort. Each shard holds a local
:class:`~repro.core.engine.SuffStats` (C_k^r implicit: raw Gram + a client
count, adding γ per-client lazily — the engine's shared bookkeeping,
algebraically identical to the paper's C_k^r = C_k + γI per client, see
eq (15): Σ C_i^r = Σ C_i + kγI).

``federated_solve`` then performs the paper's entire aggregation stage as:

    psum(SuffStats)  →  RI restore  →  Cholesky solve (engine, jax backend)

i.e. ONE all-reduce round — the communication pattern the AA law licenses.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.engine import AnalyticEngine, SuffStats
from repro.core.streaming import AnalyticState, to_stats

__all__ = [
    "psum_stats",
    "psum_state",
    "federation_mesh",
    "federated_solve",
    "federated_solve_no_ri",
    "make_federated_solve",
    "make_tiled_federated_solve",
]

_ENGINE = AnalyticEngine("jax")


def federation_mesh(n_shards: int, axis_names: Sequence[str] = ("data",),
                    *, devices=None) -> Mesh:
    """A 1-axis federation mesh over the first ``n_shards`` devices.

    The elastic coordinator (``ShardedCoordinator.grow/shrink`` and the
    shard-count-changing ``from_state``) admits and retires mesh devices
    through this single constructor, so "which devices back n shards" has
    one answer everywhere. More shards than devices is a caller error —
    the tiled-Gram layout is one row tile per device.
    """
    devices = list(jax.devices() if devices is None else devices)
    n = int(n_shards)
    if n < 1:
        raise ValueError(f"a federation mesh needs ≥1 shard, got {n}")
    if n > len(devices):
        raise ValueError(
            f"{n} shards need {n} devices, only {len(devices)} available")
    if len(tuple(axis_names)) != 1:
        raise ValueError(
            f"federation_mesh builds 1-axis meshes, got {tuple(axis_names)}")
    return Mesh(np.array(devices[:n]), tuple(axis_names))


def psum_stats(stats: SuffStats, axis_names: Sequence[str]) -> SuffStats:
    """All-reduce the sufficient statistics over the federation axes.

    The AA law (Thm 1) makes this one psum *the whole aggregation stage*:
    statistics (and the lazy client count) simply add.
    """
    ax = tuple(axis_names)
    return jax.tree.map(lambda x: jax.lax.psum(x, ax), stats)


def psum_state(state: AnalyticState, axis_names: Sequence[str]) -> AnalyticState:
    """Back-compat: all-reduce a bare 3-leaf AnalyticState."""
    ax = tuple(axis_names)
    return jax.tree.map(lambda x: jax.lax.psum(x, ax), state)


def federated_solve(
    state: AnalyticState,
    *,
    axis_names: Sequence[str],
    num_clients: int,
    gamma: float,
    target_gamma: float = 0.0,
) -> jax.Array:
    """AFL aggregation stage inside shard_map: one psum + RI + solve.

    ``state`` holds this shard's *raw* Gram/moment (no γ added). Per the RI
    process (Thm 2), the regularized aggregate would be C_agg + KγI; restoring
    (eq 16) means solving with C_agg + target_γ·I directly — the engine's
    lazy-γ semantics, so the KγI term is never materialized. The
    γ/num_clients arguments are kept so callers can instead request the
    *biased* (no-RI) solution for the Table-3 ablation.
    """
    agg = psum_stats(to_stats(state, clients=1.0), axis_names)
    return _ENGINE.solve(agg, use_ri=True, target_gamma=target_gamma)


def federated_solve_no_ri(
    state: AnalyticState,
    *,
    axis_names: Sequence[str],
    num_clients: int,
    gamma: float,
) -> jax.Array:
    """Biased aggregate w/o RI: solves with C_agg + KγI (Table 3 left columns).

    ``num_clients`` is authoritative for K — a shard cohort may stand in for
    more than one client, so the per-shard clients tags are overridden.
    """
    agg = psum_stats(to_stats(state, clients=1.0), axis_names)
    agg = agg._replace(clients=jnp.asarray(num_clients, agg.gram.dtype))
    eng = AnalyticEngine("jax", gamma=gamma)
    return eng.solve(agg, use_ri=False)


def make_federated_solve(
    mesh: Mesh,
    *,
    axis_names: Sequence[str] = ("data",),
    gamma: float = 1.0,
    target_gamma: float = 0.0,
    use_ri: bool = True,
):
    """Build a jitted shard-mapped aggregation: AnalyticState-per-shard → W.

    The returned function consumes an ``AnalyticState`` whose leaves carry a
    leading federation-shard dimension laid out over ``axis_names`` and
    returns the replicated global weight — the whole FL round in one XLA
    program containing exactly one all-reduce family per statistic.
    """
    ax = tuple(axis_names)
    num_clients = 1
    for a in ax:
        num_clients *= mesh.shape[a]
    in_spec = AnalyticState(P(ax), P(ax), P(ax))
    solver = federated_solve if use_ri else federated_solve_no_ri

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(in_spec,), out_specs=P()
    )
    def _agg(stacked: AnalyticState) -> jax.Array:
        local = jax.tree.map(lambda x: jnp.sum(x, axis=0), stacked)
        return solver(
            local, axis_names=ax, num_clients=num_clients, gamma=gamma,
            **({"target_gamma": target_gamma} if use_ri else {}),
        )

    return jax.jit(_agg)


def make_tiled_federated_solve(
    mesh: Mesh,
    *,
    axis_names: Sequence[str] = ("data",),
    target_gamma: float = 0.0,
    use_kernel: bool = False,
    distributed_factor: bool = False,
    dim: int | None = None,
    block: int | None = None,
):
    """Build a jitted aggregation over a row-TILED Gram: tiles-per-shard → W.

    ``make_federated_solve`` psums whole (d, d) leaves — every shard holds a
    full-size partial aggregate, so per-device resident memory is d²
    regardless of the mesh. At d=6144 that is ~302 MB of f64 per device just
    for the Gram partials, which is what capped the PR-3 sharded backend.
    Here each shard instead holds ONE ``(d/shards, d)`` row tile of the one
    global Gram (``ShardedCoordinator(tiled_gram=True)`` scatters every
    arrival across the tiles at ingest, so the tiles already ARE the
    aggregate — d²/shards resident per device). The returned function takes
    the stacked tiles ``(shards, d/shards, d)`` and the matching moment
    tiles ``(shards, d/shards, C)``, and in one XLA program:

      1. each shard scatters its tile into an otherwise-zero full system at
         its own row offset (``axis_index`` → ``dynamic_update_slice``),
      2. ONE psum assembles the replicated global (d, d) system — the same
         collective family as the leaf psum, but each shard contributes
         every Gram entry exactly once instead of a full-size partial
         (the full matrix is a transient of the solve, not resident state),
      3. RI restore is a diagonal shift (raw tiles + ``target_gamma``·I —
         the engine's lazy-γ semantics), and the replicated system is
         factored and solved in-graph (``use_kernel=True`` routes this
         through the blocked Pallas Cholesky of ``repro.kernels.solve``).

    With ``distributed_factor=True`` step 2 never happens: instead of
    gathering the system, the factorization itself runs tile-parallel
    (:func:`repro.kernels.solve.tile_cholesky_factor`): each panel's owner
    shard is static, one all-gather-of-a-panel replicates its (b, b)
    diagonal block and its (d, b) L-column, and every shard applies
    trsm/syrk to its own rows through the streamed Pallas panel kernels —
    peak per-device live bytes stay at the (r, d) tile plus one panel
    column, never the (d, d) transient. ``dim`` gives the TRUE head width
    when the tiles are padded (``ShardedCoordinator`` pads indivisible dims
    with zero rows); pad rows get a unit diagonal so the padded block
    factors to I and decouples, and the returned weight is sliced back to
    ``dim`` rows.

    Device arithmetic follows jax's global precision; under
    ``jax_enable_x64`` the result matches the sync host path ≤1e-10 at
    d=2048 on an 8-way mesh (``tests/test_distributed_cholesky.py``).
    """
    ax = tuple(axis_names)
    engine = AnalyticEngine("jax", use_kernel=use_kernel)
    n_shards = 1
    for a in ax:
        n_shards *= mesh.shape[a]
    interpret = jax.default_backend() != "tpu"

    if distributed_factor:
        from repro.kernels.solve import (
            DEFAULT_STREAM_BLOCK, panel_width,
            tile_cholesky_factor, tile_cholesky_solve)

        @functools.partial(
            shard_map, mesh=mesh, in_specs=(P(ax), P(ax)), out_specs=P(),
            check_rep=False,   # gathers + dynamic slices defeat rep inference
        )
        def _agg_dist(gram_tiles: jax.Array,
                      moment_tiles: jax.Array) -> jax.Array:
            idx = jnp.asarray(0)
            for a in ax:
                idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
            gt = gram_tiles[0]                 # (rows, d_p) — this shard's tile
            mt = moment_tiles[0]               # (rows, C)
            rows, d_p = gt.shape
            d_true = d_p if dim is None else dim
            # RI restore on the true diagonal (lazy-γ: raw tiles + γ·I) and a
            # unit diagonal on pad rows so the pad block factors to I and
            # never couples back. Selects, not adds, so off-diagonal entries
            # pass through bit-identically.
            cols = jnp.arange(d_p)
            gr = idx * rows + jnp.arange(rows)
            on_diag = gr[:, None] == cols[None, :]
            a_tile = jnp.where(
                on_diag & (gr[:, None] < d_true),
                gt + jnp.asarray(target_gamma, gt.dtype), gt)
            a_tile = jnp.where(on_diag & (gr[:, None] >= d_true),
                               jnp.ones((), gt.dtype), a_tile)
            b = panel_width(rows, block or DEFAULT_STREAM_BLOCK)
            gather = lambda v: jax.lax.all_gather(v, ax)
            tile_l, zs = tile_cholesky_factor(
                a_tile, shard=idx, n_shards=n_shards, gather=gather,
                block=b, interpret=interpret)
            w = tile_cholesky_solve(
                tile_l, mt, zs, shard=idx, n_shards=n_shards, gather=gather,
                psum=lambda v: jax.lax.psum(v, ax), block=b,
                interpret=interpret)
            return w[:d_true]

        return jax.jit(_agg_dist)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(P(ax), P(ax)), out_specs=P()
    )
    def _agg(gram_tiles: jax.Array, moment_tiles: jax.Array) -> jax.Array:
        # linear shard index over the (possibly multi-axis) federation mesh
        idx = jnp.asarray(0)
        for a in ax:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        gt = gram_tiles[0]                     # (rows, d) — this shard's tile
        mt = moment_tiles[0]                   # (rows, C)
        rows, d = gt.shape
        offset = (idx * rows).astype(jnp.int32)
        zero = jnp.zeros((), jnp.int32)
        full_g = jax.lax.dynamic_update_slice(
            jnp.zeros((d, d), gt.dtype), gt, (offset, zero))
        full_m = jax.lax.dynamic_update_slice(
            jnp.zeros((d, mt.shape[1]), mt.dtype), mt, (offset, zero))
        full_g = jax.lax.psum(full_g, ax)
        full_m = jax.lax.psum(full_m, ax)
        d_true = d if dim is None else dim
        a_sys = full_g + jnp.asarray(target_gamma, gt.dtype) * jnp.eye(
            d, dtype=gt.dtype)
        if d_true != d:
            # padded system: unit diagonal on the pad block, then slice back
            tail = jnp.arange(d) >= d_true
            a_sys = jnp.where(
                (jnp.arange(d)[:, None] == jnp.arange(d)[None, :])
                & tail[:, None], jnp.ones((), gt.dtype), a_sys)
            return engine.backend.solve_sym(a_sys, full_m)[:d_true]
        return engine.backend.solve_sym(a_sys, full_m)

    return jax.jit(_agg)
