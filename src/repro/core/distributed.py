"""Distributed AFL aggregation: the single round as a single collective.

On the TPU mesh each shard along the federation axes (``('data',)`` or
``('pod', 'data')``) plays one client cohort. Each shard holds a local
``AnalyticState`` (C_k^r implicit: we keep the *raw* Gram and track the client
count, adding γ per-client lazily — algebraically identical to the paper's
C_k^r = C_k + γI per client, see eq (15): Σ C_i^r = Σ C_i + kγI).

``federated_solve`` then performs the paper's entire aggregation stage as:

    psum(C), psum(Q), psum(k)  →  RI restore  →  Cholesky solve

i.e. ONE all-reduce round — the communication pattern the AA law licenses.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.streaming import AnalyticState

__all__ = ["psum_state", "federated_solve", "make_federated_solve"]


def psum_state(state: AnalyticState, axis_names: Sequence[str]) -> AnalyticState:
    """All-reduce the sufficient statistics over the federation axes."""
    ax = tuple(axis_names)
    return AnalyticState(
        gram=jax.lax.psum(state.gram, ax),
        moment=jax.lax.psum(state.moment, ax),
        count=jax.lax.psum(state.count, ax),
    )


def federated_solve(
    state: AnalyticState,
    *,
    axis_names: Sequence[str],
    num_clients: int,
    gamma: float,
    target_gamma: float = 0.0,
) -> jax.Array:
    """AFL aggregation stage inside shard_map: one psum + RI + solve.

    ``state`` holds this shard's *raw* Gram/moment (no γ added). Per the RI
    process (Thm 2), the regularized aggregate would be C_agg + KγI; restoring
    (eq 16) means solving with C_agg + target_γ·I directly — the KγI term is
    added and removed analytically, so we skip materializing it. The
    γ/num_clients arguments are kept so callers can instead request the
    *biased* (no-RI) solution for the Table-3 ablation.
    """
    agg = psum_state(state, axis_names)
    d = agg.gram.shape[0]
    eye = jnp.eye(d, dtype=agg.gram.dtype)
    a = agg.gram + jnp.asarray(target_gamma, agg.gram.dtype) * eye
    cf = jax.scipy.linalg.cho_factor(a)
    return jax.scipy.linalg.cho_solve(cf, agg.moment)


def federated_solve_no_ri(
    state: AnalyticState,
    *,
    axis_names: Sequence[str],
    num_clients: int,
    gamma: float,
) -> jax.Array:
    """Biased aggregate w/o RI: solves with C_agg + KγI (Table 3 left columns)."""
    agg = psum_state(state, axis_names)
    d = agg.gram.shape[0]
    a = agg.gram + jnp.asarray(num_clients * gamma, agg.gram.dtype) * jnp.eye(
        d, dtype=agg.gram.dtype
    )
    cf = jax.scipy.linalg.cho_factor(a)
    return jax.scipy.linalg.cho_solve(cf, agg.moment)


def make_federated_solve(
    mesh: Mesh,
    *,
    axis_names: Sequence[str] = ("data",),
    gamma: float = 1.0,
    target_gamma: float = 0.0,
    use_ri: bool = True,
):
    """Build a jitted shard-mapped aggregation: AnalyticState-per-shard → W.

    The returned function consumes an ``AnalyticState`` whose leaves carry a
    leading federation-shard dimension laid out over ``axis_names`` and
    returns the replicated global weight — the whole FL round in one XLA
    program containing exactly one all-reduce family per statistic.
    """
    ax = tuple(axis_names)
    num_clients = 1
    for a in ax:
        num_clients *= mesh.shape[a]
    in_spec = AnalyticState(P(ax), P(ax), P(ax))
    solver = federated_solve if use_ri else federated_solve_no_ri

    @functools.partial(
        jax.shard_map, mesh=mesh, in_specs=(in_spec,), out_specs=P()
    )
    def _agg(stacked: AnalyticState) -> jax.Array:
        local = jax.tree.map(lambda x: jnp.sum(x, axis=0), stacked)
        return solver(
            local, axis_names=ax, num_clients=num_clients, gamma=gamma,
            **({"target_gamma": target_gamma} if use_ri else {}),
        )

    return jax.jit(_agg)
