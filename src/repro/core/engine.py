"""The sufficient-statistics engine: ONE implementation of AFL's math.

Every path in this repo that touches the paper's statistics→solve pipeline —
the host f64 reference (`core.analytic`), the device streaming accumulator
(`core.streaming`), the one-collective federated solve (`core.distributed`),
and the serving coordinators (`fl.api`) — routes through this module.
The math appears exactly once:

  * ``SuffStats``: the sufficient statistics of a (partial) analytic
    regression, in *raw-Gram* form — ``gram = Σ XᵀX`` with NO γ baked in,
    plus a ``clients`` counter so the per-client γI of the paper's
    C_k^r = X_kᵀX_k + γI is applied *lazily* at solve time
    (Σ C_k^r = Σ C_k + kγI, eq (15); `core.distributed` already used this
    bookkeeping — it is now the shared semantics).
  * ``AnalyticEngine``: update / merge / ri_restore / solve /
    solve_multi_gamma over a pluggable backend.

Backends:
  * ``numpy_f64`` — host numpy in float64, Cholesky with pseudo-inverse
    fallback for the rank-deficient γ=0 ablations (paper Table 3 / A.1).
  * ``jax`` — device f32 (or f64 where enabled), jit-able, with an optional
    Kahan-compensated accumulator for long streaming reductions and the
    Pallas Gram kernel (`repro.kernels.gram`) as the update path
    (``use_kernel=True``).

The engine also exposes an explicit factorization handle
(:meth:`AnalyticEngine.factor` / :meth:`AnalyticEngine.factor_solve`) so hot
serving paths (``fl.api.AFLServer``) can cache the d³ Cholesky across
repeated ``solve()`` polls and pay only the d²·C triangular solves. The
handle is *rank-updatable* (:meth:`Factorization.rank_update` /
:meth:`AnalyticEngine.factor_update`): a low-rank client arrival folds into
the cached factor in O(k·d²), which is what makes event-loop serving
(``fl.async_server``) refactor-free on the straggler hot path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Sequence

import numpy as np

try:  # d²·C triangular solves for cached factors (vs np.linalg.solve's LU)
    from scipy.linalg import solve_triangular as _solve_triangular
except ImportError:  # pragma: no cover - scipy ships with jax, but stay soft
    _solve_triangular = None

__all__ = [
    "SuffStats",
    "Factorization",
    "SweepFactorization",
    "SweepRefreshNeeded",
    "AnalyticEngine",
    "NumpyF64Backend",
    "JaxBackend",
    "get_backend",
]


class SuffStats(NamedTuple):
    """Sufficient statistics of a (partial) analytic regression (a pytree).

    gram:    ``Σ XᵀX``  (d, d) — RAW, no regularization baked in.
    moment:  ``Σ XᵀY``  (d, C).
    count:   number of samples folded in (scalar).
    clients: number of client contributions merged in (scalar). The paper's
             per-client +γI is applied lazily as ``clients·γ·I`` wherever a
             regularized aggregate is needed; the RI restore (Thm 2) then
             amounts to *not* adding it back (eq 16).
    gram_c / moment_c: optional Kahan compensation carries (same shapes as
             gram/moment; ``None`` unless the engine runs compensated
             accumulation). ``None`` leaves vanish from the pytree, so the
             plain 4-leaf layout is unchanged for psum/sharding.
    """

    gram: Any
    moment: Any
    count: Any
    clients: Any
    gram_c: Any = None
    moment_c: Any = None

    @property
    def dim(self) -> int:
        return self.gram.shape[0]

    @property
    def num_classes(self) -> int:
        return self.moment.shape[1]


@dataclasses.dataclass(frozen=True)
class Factorization:
    """Opaque reusable factorization of a regularized Gram matrix.

    ``handle`` is backend-specific (host Cholesky factor or jax cho_factor
    output; ``None`` marks the numpy pinv fallback for singular systems, in
    which case ``matrix`` holds the system for the per-solve pseudo-inverse —
    on the successful-factor path ``matrix`` is ``None`` so cached entries
    carry only the factor).

    ``backend`` is the backend that produced the factor; it makes the handle
    *updatable*: :meth:`rank_update` folds a positive rank-k perturbation
    ``XᵀX`` into the factor in O(k·d²) instead of the O(d³) refactorization.
    """

    handle: Any
    matrix: Any = None
    backend: Any = None

    @property
    def updatable(self) -> bool:
        """True when :meth:`rank_update` is available (a real triangular
        factor from a backend; the pinv fallback has nothing to rotate)."""
        return self.backend is not None and self.handle is not None

    def rank_update(self, xs) -> "Factorization":
        """chol(A) → chol(A + xsᵀ·xs) for update rows ``xs`` of shape (k, d).

        k sequential rank-1 Cholesky updates fused into one Householder
        column sweep — O(k·d²) versus the d³ refactor, numerically exact for
        a *positive* update (which a Gram delta always is, so no hyperbolic
        downdates are ever needed on the serving path).
        """
        if not self.updatable:
            raise ValueError(
                "factorization is not rank-updatable (pinv fallback for a "
                "singular system, or constructed without a backend)")
        return self.backend.rank_update(self, xs)

    def rank_update_many(self, roots) -> "Factorization":
        """Fold a *sequence* of update roots in one pass — the micro-batch
        twin of :meth:`rank_update`.

        Semantically ``functools.reduce(Factorization.rank_update, roots)``,
        but executed as ONE column sweep interleaving each group's
        reflections in arrival order. On the host backend that interleaving
        performs the *identical* scalar operation schedule as the sequential
        folds (row i of the factor is only touched at column step i, and
        each group couples to the others solely through those rows), so the
        result is bit-for-bit equal to sequential updates — the property the
        batched ingest fold is pinned to.
        """
        if not self.updatable:
            raise ValueError(
                "factorization is not rank-updatable (pinv fallback for a "
                "singular system, or constructed without a backend)")
        return self.backend.rank_update_many(self, roots)


class SweepRefreshNeeded(RuntimeError):
    """A rank-updated sweep handle cannot answer this γ grid exactly (the
    base spectrum hits the pinv cutoff with pending low-rank corrections) —
    re-eigendecompose the current statistics and retry."""


@dataclasses.dataclass(frozen=True)
class SweepFactorization:
    """Rank-updatable eigendecomposition handle for repeated multi-γ sweeps.

    ``vals/vecs`` are the eigendecomposition ``base = V Λ Vᵀ`` of the raw
    (RI) — or regularized (no-RI) — aggregate Gram at the time the handle
    was built; the d³ ``eigh`` is the whole cost of a γ sweep, so a serving
    coordinator wants to pay it once and keep sweeping as the federation
    evolves. ``u`` accumulates the low-rank roots of every Gram delta merged
    since (``uᵀu`` = the raw update), with ``vu = Vᵀuᵀ`` cached so each
    sweep works entirely in the fixed eigenbasis:

        (B(γ) + uᵀu)⁻¹ Q  =  B⁻¹Q − B⁻¹uᵀ (I + u B⁻¹ uᵀ)⁻¹ u B⁻¹ Q,
        B(γ) = V (Λ+γ) Vᵀ

    — exact Woodbury algebra, O(d²·(C+k) + k³) per γ instead of a fresh d³
    eigendecomposition. The update itself (:meth:`rank_update`) is O(d²·k):
    one projection of the new roots into the eigenbasis. Past
    ``AFLServer.sweep_rank_budget`` accumulated rows (default d/8; see
    ``benchmarks/solve_kernels_bench.py`` for the measured crossover) a
    fresh handle is cheaper per sweep again and callers rebuild.

    With no pending update (``rank == 0``) the solve path is the plain
    spectral sweep — bit-identical to :meth:`AnalyticEngine.
    solve_multi_gamma`'s historical output, including the pinv-style
    truncation for rank-deficient γ=0 systems. With pending updates the
    truncation would no longer equal the pseudo-inverse of the *updated*
    system, so that combination raises :class:`SweepRefreshNeeded` instead
    of silently answering a subtly different question.
    """

    vals: Any
    vecs: Any
    backend: Any
    u: np.ndarray                 # (k, d) pending raw-Gram update roots
    vu: np.ndarray                # (d, k) = vecsᵀ · uᵀ, cached projection

    @property
    def rank(self) -> int:
        return int(self.u.shape[0])

    @property
    def dim(self) -> int:
        return int(self.u.shape[1])

    def rank_update(self, xs) -> "SweepFactorization":
        """Fold update rows ``xs (k, d)`` (``xsᵀxs`` = the merged raw-Gram
        delta) into the handle: append to ``u`` and project once."""
        xs = np.asarray(xs, np.float64).reshape(-1, self.dim)
        if not xs.shape[0]:
            return self
        proj = np.asarray(self.vecs, np.float64).T @ xs.T
        return dataclasses.replace(
            self, u=np.concatenate([self.u, xs], 0),
            vu=np.concatenate([self.vu, proj], 1))


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------


class NumpyF64Backend:
    """Host numpy, float64 — the paper-faithful reference arithmetic."""

    name = "numpy_f64"

    def asarray(self, a):
        return np.asarray(a, np.float64)

    def eye(self, d, like=None):
        return np.eye(d)

    def zeros(self, shape):
        return np.zeros(shape, np.float64)

    def scalar(self, v):
        return float(v)

    def gram_update(self, x, y):
        x = self.asarray(x)
        y = self.asarray(y)
        return x.T @ x, x.T @ y, float(x.shape[0])

    def factor(self, a) -> Factorization:
        """Cholesky when PD; ``handle=None`` → pinv fallback per solve, so the
        γ=0 rank-deficient ablations (paper Table 3 / A.1) run instead of
        raising. The handle is the UPPER factor R (A = RᵀR), C-contiguous:
        the rank-update sweep then walks contiguous rows instead of strided
        columns (~3× faster at d=2048)."""
        try:
            return Factorization(
                np.ascontiguousarray(np.linalg.cholesky(a).T), backend=self)
        except np.linalg.LinAlgError:
            return Factorization(None, a, backend=self)

    def rank_update(self, f: Factorization, xs) -> Factorization:
        """Rank-k Cholesky update: R → chol(RᵀR + xsᵀxs)."""
        xs = self.asarray(xs).reshape(-1, f.handle.shape[0])
        return Factorization(_chol_rank_update(f.handle, xs), backend=self)

    def rank_update_many(self, f: Factorization, roots) -> Factorization:
        """One grouped column sweep over a sequence of update roots —
        bit-for-bit equal to folding them with :meth:`rank_update` one at a
        time (see :func:`_chol_rank_update_grouped`)."""
        d = f.handle.shape[0]
        roots = [self.asarray(x).reshape(-1, d) for x in roots]
        return Factorization(
            _chol_rank_update_grouped(f.handle, roots), backend=self)

    def factor_solve(self, f: Factorization, b):
        if f.handle is None:
            return np.linalg.pinv(f.matrix) @ b
        if _solve_triangular is not None:
            y = _solve_triangular(f.handle, b, trans="T", lower=False)
            return _solve_triangular(f.handle, y, lower=False)
        y = np.linalg.solve(f.handle.T, b)
        return np.linalg.solve(f.handle, y)

    def solve_sym(self, a, b):
        return self.factor_solve(self.factor(a), b)

    def eigh(self, a):
        return np.linalg.eigh(a)

    def safe_reciprocal(self, v, cutoff):
        """1/v where |v| > cutoff, else 0 — pinv-style spectral truncation."""
        return np.where(np.abs(v) > cutoff, 1.0 / np.where(v == 0, 1.0, v), 0.0)


class JaxBackend:
    """Device jax arrays, jit-able; f32 by default (f64 where x64 is on).

    ``use_kernel=True`` routes the Gram update through the fused Pallas
    kernel (`repro.kernels.ops.gram_update`) AND the factor/solve/γ-sweep
    through the blocked Pallas solve kernels (`repro.kernels.solve`:
    blocked Cholesky, batched substitution, fused multi-γ sweep — Mosaic on
    TPU, interpreter elsewhere). The solve path assumes PD systems (γ>0 or
    full-rank statistics); a singular system surfaces as NaNs, which
    :meth:`AnalyticEngine.solve_multi_gamma` detects and reroutes to the
    eigendecomposition/pinv path (direct ``solve`` callers needing γ=0
    rank-deficient semantics use the ``numpy_f64`` backend).
    """

    name = "jax"

    def __init__(self, dtype=None, use_kernel: bool = False):
        import jax.numpy as jnp

        self._jnp = jnp
        self.dtype = dtype or jnp.float32
        self.use_kernel = use_kernel
        self._rank_update_fn = None

    def asarray(self, a):
        return self._jnp.asarray(a, self.dtype)

    def eye(self, d, like=None):
        return self._jnp.eye(d, dtype=self.dtype)

    def zeros(self, shape):
        return self._jnp.zeros(shape, self.dtype)

    def scalar(self, v):
        return self._jnp.asarray(v, self.dtype)

    def gram_update(self, x, y):
        jnp = self._jnp
        x = x.reshape(-1, x.shape[-1]).astype(self.dtype)
        y = y.reshape(-1, y.shape[-1]).astype(self.dtype)
        if self.use_kernel:
            from repro.kernels import ops as _kops

            g, q = _kops.gram_update(x, y)
            g = g.astype(self.dtype)
            q = q.astype(self.dtype)
        else:
            g = x.T @ x
            q = x.T @ y
        return g, q, jnp.asarray(x.shape[0], self.dtype)

    def factor(self, a) -> Factorization:
        if self.use_kernel:
            from repro.kernels import ops as _kops

            # blocked Pallas Cholesky; handle shape matches cho_factor's
            # (tri, lower) convention so rank_update works unchanged. Wide
            # single systems go through the HBM-streamed panel path — the
            # whole-resident batch kernel exceeds VMEM past d≈1024 f32.
            if a.shape[-1] >= _kops.STREAM_MIN_DIM:
                return Factorization(
                    (_kops.streamed_cholesky(a), True), backend=self)
            return Factorization(
                (_kops.blocked_cholesky(a[None])[0], True), backend=self)
        import jax.scipy.linalg as jsl

        return Factorization(jsl.cho_factor(a), backend=self)

    def rank_update(self, f: Factorization, xs) -> Factorization:
        """Rank-k update of a cho_factor handle. Kernel path: the whole
        stacked update in ONE fused Pallas sweep (`repro.kernels.ops.
        chol_rank_update`); otherwise a jit-compiled fori_loop column
        sweep."""
        import jax

        c, lower = f.handle
        xs = self.asarray(xs).reshape(-1, c.shape[0])
        # cho_factor leaves garbage in the untouched triangle — extract a
        # clean lower factor, sweep, and hand back a (lower, True) handle.
        tri = self._jnp.tril(c) if lower else self._jnp.triu(c).T
        if self.use_kernel:
            from repro.kernels import ops as _kops

            return Factorization(
                (_kops.chol_rank_update(tri, xs), True), backend=self)
        if self._rank_update_fn is None:
            self._rank_update_fn = jax.jit(_chol_rank_update_jax)
        return Factorization((self._rank_update_fn(tri, xs), True), backend=self)

    def rank_update_many(self, f: Factorization, roots) -> Factorization:
        """Batched fold on the device backend: the concatenated roots go
        through one rank-(Σk) sweep. Exact in exact arithmetic (a sum of
        Gram deltas is a Gram delta); the bit-for-bit-vs-sequential
        guarantee is the host backend's."""
        c, _ = f.handle
        d = c.shape[0]
        xs = [self.asarray(x).reshape(-1, d) for x in roots]
        stacked = xs[0] if len(xs) == 1 else self._jnp.concatenate(xs, 0)
        return self.rank_update(f, stacked)

    def factor_solve(self, f: Factorization, b):
        if self.use_kernel:
            from repro.kernels import ops as _kops

            tri, lower = f.handle
            l = tri if lower else tri.T
            if l.shape[-1] >= _kops.STREAM_MIN_DIM:
                return _kops.streamed_cholesky_solve(l, b)
            return _kops.cholesky_solve(l[None], b[None])[0]
        import jax.scipy.linalg as jsl

        return jsl.cho_solve(f.handle, b)

    def solve_sym(self, a, b):
        return self.factor_solve(self.factor(a), b)

    def fused_sweep(self, a, b, gammas):
        """Whole-γ-grid solve ``(a + γ_j I) W_j = b`` via the fused Pallas
        sweep kernel (kernel path only); singular γs come back as NaNs."""
        from repro.kernels import ops as _kops

        return _kops.multi_gamma_solve(
            a, b, self._jnp.asarray(gammas, self.dtype))

    def eigh(self, a):
        return self._jnp.linalg.eigh(a)

    def safe_reciprocal(self, v, cutoff):
        """1/v where |v| > cutoff, else 0 — pinv-style spectral truncation."""
        jnp = self._jnp
        return jnp.where(jnp.abs(v) > cutoff, 1.0 / jnp.where(v == 0, 1.0, v), 0.0)


def _chol_rank_update(R, xs):
    """Host rank-k Cholesky update: R upper with A = RᵀR → chol(A + xsᵀxs).

    One Householder column sweep over the implicit QR of ``[R; xs]``: at
    column i a single (k+1)-reflection annihilates all k update entries at
    once, so the work is k fused rank-1 updates — O(k·d²) flops in d
    vectorized iterations (not d·k scalar ones). Everything the inner loop
    touches (a row of R, the tail of xsᵀ) is contiguous in the C layout.
    The update is positive (a Gram delta), so the sweep cannot break down.
    """
    d = R.shape[0]
    R = np.array(R, np.float64, copy=True, order="C")
    xt = np.array(xs.T, np.float64, copy=True, order="C")  # (d, k) rows contiguous
    for i in range(d):
        w = xt[i]
        s = w @ w
        if s == 0.0:
            continue
        a = R[i, i]
        r = np.sqrt(a * a + s)
        amr = -s / (r + a)                 # a − r without cancellation
        beta = (r + a) / (r * s)           # 2 / uᵀu for u = [a−r; w]
        row = R[i, i + 1:]
        t = amr * row + xt[i + 1:] @ w     # uᵀ · [row; xs-tail]
        R[i, i] = r
        R[i, i + 1:] = row - (beta * amr) * t
        xt[i + 1:] -= (beta * t)[:, None] * w[None, :]
    return R


def _chol_rank_update_grouped(R, roots):
    """Grouped rank-(Σk) update: one column sweep folding a *sequence* of
    update-row groups, bit-for-bit equal to sequential per-group
    :func:`_chol_rank_update` calls.

    Why interleaving is exact, not just exact-in-exact-arithmetic: the
    sequential sweep reads and writes row i of R only at column step i, and
    a group's reflections couple to later groups solely through those rows —
    each group's own ``xt`` tail is private. So running column i for group
    1, then group 2, … performs the *same scalar operations in the same
    order* as finishing group 1's whole sweep before starting group 2's.
    Each group keeps its own contiguous (d, k_g) ``xt`` buffer so every
    BLAS call sees the exact shapes/strides of the sequential path.
    """
    d = R.shape[0]
    R = np.array(R, np.float64, copy=True, order="C")
    xts = [np.array(x.T, np.float64, copy=True, order="C") for x in roots]
    for i in range(d):
        for xt in xts:
            w = xt[i]
            s = w @ w
            if s == 0.0:
                continue
            a = R[i, i]
            r = np.sqrt(a * a + s)
            amr = -s / (r + a)
            beta = (r + a) / (r * s)
            row = R[i, i + 1:]
            t = amr * row + xt[i + 1:] @ w
            R[i, i] = r
            R[i, i + 1:] = row - (beta * amr) * t
            xt[i + 1:] -= (beta * t)[:, None] * w[None, :]
    return R


def _chol_rank_update_jax(L, xs):
    """Device twin of :func:`_chol_rank_update`: masked full-width columns so
    every iteration has static shapes under ``lax.fori_loop`` + ``jit``."""
    import jax
    import jax.numpy as jnp

    d = L.shape[0]
    idx = jnp.arange(d)

    def body(i, carry):
        L, xt = carry
        w = xt[i]
        s = w @ w
        s_ = jnp.where(s > 0, s, 1.0)      # w == 0 ⇒ t == 0, updates vanish
        a = L[i, i]
        r = jnp.sqrt(a * a + s)
        amr = -s / (r + a)
        beta = (r + a) / (r * s_)
        below = idx > i
        col = L[:, i]
        t = amr * col + xt @ w
        new_col = jnp.where(below, col - (beta * amr) * t, col).at[i].set(r)
        L = L.at[:, i].set(new_col)
        xt = jnp.where(below[:, None], xt - (beta * t)[:, None] * w[None, :], xt)
        return L, xt

    L, _ = jax.lax.fori_loop(0, d, body, (L, xs.T))
    return L


def _factor_has_nan(f: Factorization) -> bool:
    """True when a factor handle carries NaNs (host upper R, or a device
    ``(tri, lower)`` handle — reading the latter materializes it, which the
    host-driven serving path does anyway before solving)."""
    h = f.handle
    tri = h[0] if isinstance(h, tuple) else h
    return bool(np.any(np.isnan(np.asarray(tri))))


def get_backend(name: str, **kwargs):
    """Backend registry: ``numpy_f64`` | ``jax`` (+ dtype / use_kernel)."""
    if name == "numpy_f64":
        if kwargs.get("use_kernel"):
            raise ValueError("the Pallas kernel path requires the jax backend")
        return NumpyF64Backend()
    if name == "jax":
        return JaxBackend(dtype=kwargs.get("dtype"), use_kernel=bool(kwargs.get("use_kernel")))
    raise ValueError(f"unknown engine backend {name!r}")


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class AnalyticEngine:
    """Backend-agnostic AFL statistics→solve pipeline.

    One instance carries the protocol-level configuration (backend, the γ
    every client uses locally, accumulation policy); the statistics
    themselves travel as explicit :class:`SuffStats` values, so the engine is
    stateless and its methods are safe inside ``jit``/``shard_map`` for the
    jax backend.

    >>> eng = AnalyticEngine("numpy_f64", gamma=1.0)
    >>> stats = eng.merge(eng.client_stats(x1, y1), eng.client_stats(x2, y2))
    >>> w = eng.solve(stats)          # RI-restored joint solution (Thm 1+2)
    """

    def __init__(
        self,
        backend: str = "numpy_f64",
        *,
        gamma: float = 1.0,
        dtype=None,
        use_kernel: bool = False,
        kahan: bool = False,
    ):
        self.backend = get_backend(backend, dtype=dtype, use_kernel=use_kernel)
        self.gamma = float(gamma)
        if kahan and backend != "jax":
            raise ValueError("Kahan accumulation targets the f32 jax backend")
        self.kahan = bool(kahan)

    # -- accumulation -------------------------------------------------------

    def init(self, dim: int, num_classes: int) -> SuffStats:
        """Empty statistics (0 samples, 0 clients)."""
        b = self.backend
        comp_g = b.zeros((dim, dim)) if self.kahan else None
        comp_q = b.zeros((dim, num_classes)) if self.kahan else None
        return SuffStats(
            gram=b.zeros((dim, dim)),
            moment=b.zeros((dim, num_classes)),
            count=b.scalar(0.0),
            clients=b.scalar(0.0),
            gram_c=comp_g,
            moment_c=comp_q,
        )

    def update(self, stats: SuffStats, x, y) -> SuffStats:
        """Fold a batch of (embeddings, one-hot targets) into the statistics.

        Pure accumulation: ``clients`` is untouched — a participant marks
        itself with :meth:`finalize_client` (or arrives via
        :meth:`client_stats`) once its local stage is complete.
        """
        g_upd, q_upd, n = self.backend.gram_update(x, y)
        if self.kahan and stats.gram_c is not None:
            gram, gram_c = _kahan_add(stats.gram, stats.gram_c, g_upd)
            moment, moment_c = _kahan_add(stats.moment, stats.moment_c, q_upd)
        else:
            gram, gram_c = stats.gram + g_upd, stats.gram_c
            moment, moment_c = stats.moment + q_upd, stats.moment_c
        return SuffStats(gram, moment, stats.count + n, stats.clients,
                         gram_c, moment_c)

    def finalize_client(self, stats: SuffStats) -> SuffStats:
        """Mark accumulated statistics as ONE client's upload (clients=1)."""
        return stats._replace(clients=self.backend.scalar(1.0))

    def client_stats(self, x, y) -> SuffStats:
        """One client's local stage in a single call: raw stats, clients=1."""
        x = self.backend.asarray(x)
        y = self.backend.asarray(y)
        return self.finalize_client(
            self.update(self.init(x.shape[-1], y.shape[-1]), x, y))

    def merge(self, a: SuffStats, b: SuffStats) -> SuffStats:
        """The AA law in sufficient-statistics form: everything adds
        (Thm 1 / eq (11): C_agg = ΣC_k, Q_agg = ΣQ_k; client counts add for
        the lazy-γ bookkeeping of eq (15))."""
        return SuffStats(
            gram=a.gram + b.gram,
            moment=a.moment + b.moment,
            count=a.count + b.count,
            clients=a.clients + b.clients,
            gram_c=_maybe_add(a.gram_c, b.gram_c),
            moment_c=_maybe_add(a.moment_c, b.moment_c),
        )

    def merge_many(self, stats: SuffStats, uploads) -> SuffStats:
        """Left-fold a whole micro-batch of uploads in ONE stacked reduction.

        ``np.add.reduce`` over the leading axis of a stacked array
        accumulates strictly in index order (pairwise re-association only
        kicks in when reducing a contiguous *inner* axis), so the gram and
        moment come out bit-for-bit equal to sequential :meth:`merge` calls
        — the AA law is order-free in exact arithmetic, but the batched
        ingest fold is pinned to the sequential schedule exactly. The scalar
        ``count``/``clients`` fields fold in an explicit Python loop for the
        same reason. Kahan-compensated statistics (and non-host backends)
        keep the sequential path: compensation is intrinsically pairwise.
        """
        uploads = list(uploads)
        if not uploads:
            return stats
        if (not isinstance(self.backend, NumpyF64Backend)
                or stats.gram_c is not None
                or any(u.gram_c is not None for u in uploads)):
            for u in uploads:
                stats = self.merge(stats, u)
            return stats
        gram = np.add.reduce(
            np.stack([np.asarray(stats.gram)]
                     + [np.asarray(u.gram) for u in uploads]), axis=0)
        moment = np.add.reduce(
            np.stack([np.asarray(stats.moment)]
                     + [np.asarray(u.moment) for u in uploads]), axis=0)
        count, clients = stats.count, stats.clients
        for u in uploads:
            count = count + u.count
            clients = clients + u.clients
        return SuffStats(gram, moment, count, clients,
                         stats.gram_c, stats.moment_c)

    # -- regularization bookkeeping -----------------------------------------

    def regularized_gram(self, stats: SuffStats, gamma: Optional[float] = None):
        """``C_agg^r = Σ XᵀX + kγI`` — the regularized aggregate the paper's
        Algorithm 1 materializes (here derived lazily from raw stats)."""
        g = self.gamma if gamma is None else float(gamma)
        d = stats.gram.shape[0]
        return stats.gram + (stats.clients * g) * self.backend.eye(d)

    def _system(self, stats: SuffStats, use_ri: bool, target_gamma: float):
        d = stats.gram.shape[0]
        eye = self.backend.eye(d)
        if use_ri:
            # RI restore (Thm 2 / eq 16) on raw stats: the kγI term would be
            # added (eq 15) and removed (eq 16) analytically — so it is never
            # materialized; only the final target ridge remains.
            return stats.gram + self.backend.scalar(target_gamma) * eye
        return stats.gram + stats.clients * self.backend.scalar(self.gamma) * eye

    # -- solves -------------------------------------------------------------

    def solve(
        self,
        stats: SuffStats,
        *,
        use_ri: bool = True,
        target_gamma: float = 0.0,
    ):
        """Joint weight over everything merged into ``stats``.

        use_ri=True  → the paper's full pipeline (exact joint solution,
                       restored to ``target_gamma`` ridge; 0 = eq 16).
        use_ri=False → the biased no-RI aggregate carrying the accumulated
                       ``kγI`` (paper Table 3 ablation).
        """
        return self.backend.solve_sym(
            self._system(stats, use_ri, target_gamma), stats.moment)

    def factor(
        self,
        stats: SuffStats,
        *,
        use_ri: bool = True,
        target_gamma: float = 0.0,
    ) -> Factorization:
        """Factor the regularized system once; reuse via :meth:`factor_solve`.

        This is the serving hot path: one d³ factorization amortized over
        every straggler-poll ``solve()`` until new statistics arrive.
        """
        return self.backend.factor(self._system(stats, use_ri, target_gamma))

    def factor_solve(self, factorization: Factorization, b):
        """Solve against a cached factorization (d²·C instead of d³)."""
        return self.backend.factor_solve(factorization, b)

    def factor_update(
        self,
        factorization: Factorization,
        stats: SuffStats,
        root=None,
        *,
        use_ri: bool = True,
        target_gamma: float = 0.0,
        max_rank: Optional[int] = None,
    ) -> Factorization:
        """Fold a newly-merged low-rank delta into an existing factor.

        ``stats`` is the POST-merge aggregate (used only for the fallback);
        ``root`` is a (k, d) square root of the raw-Gram delta that was
        merged — ``rootᵀ·root == ΔGram`` — e.g. the client batch X_k itself
        or its QR ``R`` factor (same information as C_k, no raw features).

        When the delta is genuinely low-rank (k ≤ ``max_rank``; the default
        d//16 is the measured update-vs-refactor crossover at d=2048, see
        benchmarks/async_server_bench.py) and the factor is updatable, this
        is the O(k·d²) rank-k Cholesky update. Otherwise it falls back to a
        full refactor from ``stats``: dense delta (``root=None``), rank past
        the crossover, a pinv-fallback factor (the γ=0 rank-deficient path),
        or ``use_ri=False`` — whose per-client +γI delta is full-rank by
        construction.

        ``root`` may also be a list/tuple of (k_i, d) roots — a micro-batch
        of deltas folded in one grouped sweep (:meth:`Factorization.
        rank_update_many`); the budget then applies to Σk_i. Either way the
        updated factor is checked for NaNs (a breakdown can only come from
        non-finite inputs — the update itself is positive) and a poisoned
        sweep falls back to the full refactor instead of caching NaNs.
        """
        if root is not None and use_ri and factorization.updatable:
            roots = list(root) if isinstance(root, (list, tuple)) else [root]
            roots = [self.backend.asarray(r).reshape(-1, stats.dim)
                     for r in roots]
            total = sum(int(r.shape[0]) for r in roots)
            budget = max(1, stats.dim // 16) if max_rank is None else int(max_rank)
            if total <= budget:
                updated = (factorization.rank_update(roots[0])
                           if len(roots) == 1
                           else factorization.rank_update_many(roots))
                if not _factor_has_nan(updated):
                    return updated
        return self.factor(stats, use_ri=use_ri, target_gamma=target_gamma)

    def ri_restore(
        self,
        w_agg_r,
        c_agg_r,
        num_clients: int,
        gamma: Optional[float] = None,
        target_gamma: float = 0.0,
    ):
        """Theorem 2 / eq (16) in its explicit form, for *regularized*
        aggregates (Ŵ_agg^r, C_agg^r) as produced by the paper-literal
        Algorithm 1: ``Ŵ_agg = (C_agg^r − KγI)^{-1} C_agg^r Ŵ_agg^r``."""
        b = self.backend
        g = self.gamma if gamma is None else float(gamma)
        d = c_agg_r.shape[0]
        shift = b.scalar(num_clients * g - target_gamma) * b.eye(d)
        return b.solve_sym(c_agg_r - shift, c_agg_r @ w_agg_r)

    def solve_multi_gamma(
        self,
        stats: SuffStats,
        gammas: Sequence[float],
        *,
        use_ri: bool = True,
        rcond: float = 1e-12,
    ):
        """Solve the same statistics under several target ridges at once.

        One eigendecomposition ``C = VΛVᵀ`` (d³) serves every γ:
        ``W(γ) = V (Λ+γ)^{-1} Vᵀ Q`` is then d²·C per γ — the γ model sweep
        costs barely more than a single solve. Eigenvalues with
        ``λ+γ <= rcond·λ_max`` are treated as zero (pinv semantics), so the
        γ=0 rank-deficient case matches the fallback of the direct solve.

        Returns a list of weights, one per γ, each the RI-restored
        (``use_ri=True``) or biased (``use_ri=False``, γ then *adds* the
        lazy kγ term per eq (15)) solution.

        Backends route differently: the Pallas-kernel jax backend runs the
        whole grid through ONE fused factor+solve kernel call
        (:func:`repro.kernels.solve.multi_gamma_solve`), falling back to the
        eigendecomposition below only when a system in the grid is singular
        (the γ=0 rank-deficient ablations — NaNs trip the fallback, so pinv
        semantics match the numpy_f64 oracle). Everything else goes through
        a fresh :class:`SweepFactorization` — one eigendecomposition, every
        γ; serving coordinators keep that handle and rank-update it instead
        (see :meth:`sweep_factor` / :meth:`sweep_solve`).
        """
        gammas = [float(g) for g in gammas]
        if getattr(self.backend, "use_kernel", False) and gammas:
            base = stats.gram if use_ri else self.regularized_gram(stats)
            ws = self.backend.fused_sweep(base, stats.moment, gammas)
            ws_host = np.asarray(ws)
            if (bool(np.isfinite(ws_host).all())
                    and _cholesky_sweep_trustworthy(
                        base, stats.moment, ws_host, rcond)):
                return [ws[i] for i in range(len(gammas))]
            # singular or ≈singular system in the grid (NaNs, or a solution
            # blown up past what the pinv truncation would allow):
            # eigendecomposition/pinv fallback with the caller's rcond
        return self.sweep_solve(self.sweep_factor(stats, use_ri=use_ri),
                                stats.moment, gammas, rcond=rcond)

    def sweep_factor(self, stats: SuffStats, *,
                     use_ri: bool = True) -> SweepFactorization:
        """Eigendecompose the aggregate once for repeated γ sweeps.

        The returned handle is rank-updatable: as low-rank arrivals merge
        into an evolving federation, :meth:`SweepFactorization.rank_update`
        folds their roots in O(d²·k) and :meth:`sweep_solve` stays exact via
        Woodbury in the fixed eigenbasis — no per-sweep d³ re-factorization.
        """
        base = stats.gram if use_ri else self.regularized_gram(stats)
        vals, vecs = self.backend.eigh(base)
        d = stats.dim
        return SweepFactorization(vals, vecs, self.backend,
                                  u=np.zeros((0, d)), vu=np.zeros((d, 0)))

    def sweep_solve(
        self,
        handle: SweepFactorization,
        moment,
        gammas: Sequence[float],
        *,
        rcond: float = 1e-12,
    ):
        """Solve the γ grid against a (possibly rank-updated) sweep handle.

        rank == 0 reproduces the plain spectral sweep bit-for-bit; with
        pending updates each γ costs one extra k×k solve (exact Woodbury).
        Raises :class:`SweepRefreshNeeded` when pending updates meet the
        pinv truncation cutoff (rank-deficient base at γ≈0) — the caller
        rebuilds the handle from current statistics, which always succeeds.
        """
        b = handle.backend
        vals, vecs = handle.vals, handle.vecs
        vq = vecs.T @ moment
        scale = abs(float(np.max(np.asarray(vals)))) if np.asarray(vals).size else 1.0
        cutoff = rcond * max(scale, np.finfo(np.float32).tiny)
        k = handle.rank
        eye_k = np.eye(k)
        out = []
        for g in gammas:
            inv = b.safe_reciprocal(vals + b.scalar(float(g)), cutoff)
            if k == 0:
                out.append(vecs @ (inv[:, None] * vq))
                continue
            inv_h = np.asarray(inv, np.float64)
            if np.any(inv_h == 0.0):
                raise SweepRefreshNeeded(
                    f"spectral truncation at γ={g} with {k} pending update "
                    "rows — rebuild the sweep handle from current stats")
            su = inv_h[:, None] * np.asarray(handle.vu, np.float64)  # (d, k)
            cap = eye_k + handle.vu.T @ su                           # (k, k)
            rhs = su.T @ np.asarray(vq, np.float64)                  # (k, C)
            coeff = inv_h[:, None] * np.asarray(vq, np.float64) \
                - su @ np.linalg.solve(cap, rhs)
            out.append(np.asarray(vecs, np.float64) @ coeff)
        return out


def _cholesky_sweep_trustworthy(base, moment, ws_host, rcond) -> bool:
    """Should a finite fused-Cholesky sweep result be trusted, or does the
    grid need the eigendecomposition/pinv path?

    NaN catches exactly-singular pivots, but roundoff can leave a
    rank-deficient system's smallest pivots tiny-*positive*: the factor
    then succeeds and returns finite weights with norms ~1/λ_noise — where
    the documented pinv semantics (eigenvalues ≤ rcond·λ_max treated as
    zero) would have truncated. For any γ the pinv solution satisfies
    ``‖W‖ ≤ ‖Q‖ / (rcond·λ_max)``, and trace(base) ≥ λ_max for PSD base —
    so a solution with ``‖W‖·rcond·trace > ‖Q‖`` can only come from
    inverting spectrum the truncation would have zeroed. Conservative by at
    most the d× gap between trace and λ_max (extra fallbacks are merely
    slower, never wrong)."""
    scale = float(np.trace(np.asarray(base, np.float64)))
    q_norm = float(np.linalg.norm(np.asarray(moment, np.float64)))
    w_norm = float(max(np.linalg.norm(w) for w in ws_host))
    return w_norm * float(rcond) * max(scale, np.finfo(np.float32).tiny) \
        <= q_norm


def _kahan_add(total, comp, upd):
    """One compensated-summation step: returns (new_total, new_comp)."""
    y = upd - comp
    t = total + y
    comp = (t - total) - y
    return t, comp


def _maybe_add(a, b):
    if a is None or b is None:
        return None
    return a + b
