"""The sufficient-statistics engine: ONE implementation of AFL's math.

Every path in this repo that touches the paper's statistics→solve pipeline —
the host f64 reference (`core.analytic`), the device streaming accumulator
(`core.streaming`), the one-collective federated solve (`core.distributed`),
and the serving coordinators (`fl.api`) — routes through this module.
The math appears exactly once:

  * ``SuffStats``: the sufficient statistics of a (partial) analytic
    regression, in *raw-Gram* form — ``gram = Σ XᵀX`` with NO γ baked in,
    plus a ``clients`` counter so the per-client γI of the paper's
    C_k^r = X_kᵀX_k + γI is applied *lazily* at solve time
    (Σ C_k^r = Σ C_k + kγI, eq (15); `core.distributed` already used this
    bookkeeping — it is now the shared semantics).
  * ``AnalyticEngine``: update / merge / ri_restore / solve /
    solve_multi_gamma over a pluggable backend.

Backends:
  * ``numpy_f64`` — host numpy in float64, Cholesky with pseudo-inverse
    fallback for the rank-deficient γ=0 ablations (paper Table 3 / A.1).
  * ``jax`` — device f32 (or f64 where enabled), jit-able, with an optional
    Kahan-compensated accumulator for long streaming reductions and the
    Pallas Gram kernel (`repro.kernels.gram`) as the update path
    (``use_kernel=True``).

The engine also exposes an explicit factorization handle
(:meth:`AnalyticEngine.factor` / :meth:`AnalyticEngine.factor_solve`) so hot
serving paths (``fl.api.AFLServer``) can cache the d³ Cholesky across
repeated ``solve()`` polls and pay only the d²·C triangular solves. The
handle is *rank-updatable* (:meth:`Factorization.rank_update` /
:meth:`AnalyticEngine.factor_update`): a low-rank client arrival folds into
the cached factor in O(k·d²), which is what makes event-loop serving
(``fl.async_server``) refactor-free on the straggler hot path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Sequence

import numpy as np

try:  # d²·C triangular solves for cached factors (vs np.linalg.solve's LU)
    from scipy.linalg import solve_triangular as _solve_triangular
except ImportError:  # pragma: no cover - scipy ships with jax, but stay soft
    _solve_triangular = None

__all__ = [
    "SuffStats",
    "Factorization",
    "AnalyticEngine",
    "NumpyF64Backend",
    "JaxBackend",
    "get_backend",
]


class SuffStats(NamedTuple):
    """Sufficient statistics of a (partial) analytic regression (a pytree).

    gram:    ``Σ XᵀX``  (d, d) — RAW, no regularization baked in.
    moment:  ``Σ XᵀY``  (d, C).
    count:   number of samples folded in (scalar).
    clients: number of client contributions merged in (scalar). The paper's
             per-client +γI is applied lazily as ``clients·γ·I`` wherever a
             regularized aggregate is needed; the RI restore (Thm 2) then
             amounts to *not* adding it back (eq 16).
    gram_c / moment_c: optional Kahan compensation carries (same shapes as
             gram/moment; ``None`` unless the engine runs compensated
             accumulation). ``None`` leaves vanish from the pytree, so the
             plain 4-leaf layout is unchanged for psum/sharding.
    """

    gram: Any
    moment: Any
    count: Any
    clients: Any
    gram_c: Any = None
    moment_c: Any = None

    @property
    def dim(self) -> int:
        return self.gram.shape[0]

    @property
    def num_classes(self) -> int:
        return self.moment.shape[1]


@dataclasses.dataclass(frozen=True)
class Factorization:
    """Opaque reusable factorization of a regularized Gram matrix.

    ``handle`` is backend-specific (host Cholesky factor or jax cho_factor
    output; ``None`` marks the numpy pinv fallback for singular systems, in
    which case ``matrix`` holds the system for the per-solve pseudo-inverse —
    on the successful-factor path ``matrix`` is ``None`` so cached entries
    carry only the factor).

    ``backend`` is the backend that produced the factor; it makes the handle
    *updatable*: :meth:`rank_update` folds a positive rank-k perturbation
    ``XᵀX`` into the factor in O(k·d²) instead of the O(d³) refactorization.
    """

    handle: Any
    matrix: Any = None
    backend: Any = None

    @property
    def updatable(self) -> bool:
        """True when :meth:`rank_update` is available (a real triangular
        factor from a backend; the pinv fallback has nothing to rotate)."""
        return self.backend is not None and self.handle is not None

    def rank_update(self, xs) -> "Factorization":
        """chol(A) → chol(A + xsᵀ·xs) for update rows ``xs`` of shape (k, d).

        k sequential rank-1 Cholesky updates fused into one Householder
        column sweep — O(k·d²) versus the d³ refactor, numerically exact for
        a *positive* update (which a Gram delta always is, so no hyperbolic
        downdates are ever needed on the serving path).
        """
        if not self.updatable:
            raise ValueError(
                "factorization is not rank-updatable (pinv fallback for a "
                "singular system, or constructed without a backend)")
        return self.backend.rank_update(self, xs)


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------


class NumpyF64Backend:
    """Host numpy, float64 — the paper-faithful reference arithmetic."""

    name = "numpy_f64"

    def asarray(self, a):
        return np.asarray(a, np.float64)

    def eye(self, d, like=None):
        return np.eye(d)

    def zeros(self, shape):
        return np.zeros(shape, np.float64)

    def scalar(self, v):
        return float(v)

    def gram_update(self, x, y):
        x = self.asarray(x)
        y = self.asarray(y)
        return x.T @ x, x.T @ y, float(x.shape[0])

    def factor(self, a) -> Factorization:
        """Cholesky when PD; ``handle=None`` → pinv fallback per solve, so the
        γ=0 rank-deficient ablations (paper Table 3 / A.1) run instead of
        raising. The handle is the UPPER factor R (A = RᵀR), C-contiguous:
        the rank-update sweep then walks contiguous rows instead of strided
        columns (~3× faster at d=2048)."""
        try:
            return Factorization(
                np.ascontiguousarray(np.linalg.cholesky(a).T), backend=self)
        except np.linalg.LinAlgError:
            return Factorization(None, a, backend=self)

    def rank_update(self, f: Factorization, xs) -> Factorization:
        """Rank-k Cholesky update: R → chol(RᵀR + xsᵀxs)."""
        xs = self.asarray(xs).reshape(-1, f.handle.shape[0])
        return Factorization(_chol_rank_update(f.handle, xs), backend=self)

    def factor_solve(self, f: Factorization, b):
        if f.handle is None:
            return np.linalg.pinv(f.matrix) @ b
        if _solve_triangular is not None:
            y = _solve_triangular(f.handle, b, trans="T", lower=False)
            return _solve_triangular(f.handle, y, lower=False)
        y = np.linalg.solve(f.handle.T, b)
        return np.linalg.solve(f.handle, y)

    def solve_sym(self, a, b):
        return self.factor_solve(self.factor(a), b)

    def eigh(self, a):
        return np.linalg.eigh(a)

    def safe_reciprocal(self, v, cutoff):
        """1/v where |v| > cutoff, else 0 — pinv-style spectral truncation."""
        return np.where(np.abs(v) > cutoff, 1.0 / np.where(v == 0, 1.0, v), 0.0)


class JaxBackend:
    """Device jax arrays, jit-able; f32 by default (f64 where x64 is on).

    ``use_kernel=True`` routes the Gram update through the fused Pallas
    kernel (`repro.kernels.ops.gram_update`: Mosaic on TPU, interpreter
    elsewhere). The solve is an in-graph Cholesky — by construction the
    engine only hands it PD systems (γ>0 or full-rank statistics); callers
    needing the singular γ=0 path use the ``numpy_f64`` backend.
    """

    name = "jax"

    def __init__(self, dtype=None, use_kernel: bool = False):
        import jax.numpy as jnp

        self._jnp = jnp
        self.dtype = dtype or jnp.float32
        self.use_kernel = use_kernel
        self._rank_update_fn = None

    def asarray(self, a):
        return self._jnp.asarray(a, self.dtype)

    def eye(self, d, like=None):
        return self._jnp.eye(d, dtype=self.dtype)

    def zeros(self, shape):
        return self._jnp.zeros(shape, self.dtype)

    def scalar(self, v):
        return self._jnp.asarray(v, self.dtype)

    def gram_update(self, x, y):
        jnp = self._jnp
        x = x.reshape(-1, x.shape[-1]).astype(self.dtype)
        y = y.reshape(-1, y.shape[-1]).astype(self.dtype)
        if self.use_kernel:
            from repro.kernels import ops as _kops

            g, q = _kops.gram_update(x, y)
            g = g.astype(self.dtype)
            q = q.astype(self.dtype)
        else:
            g = x.T @ x
            q = x.T @ y
        return g, q, jnp.asarray(x.shape[0], self.dtype)

    def factor(self, a) -> Factorization:
        import jax.scipy.linalg as jsl

        return Factorization(jsl.cho_factor(a), backend=self)

    def rank_update(self, f: Factorization, xs) -> Factorization:
        """Rank-k update of a cho_factor handle (jit-compiled column sweep)."""
        import jax

        c, lower = f.handle
        xs = self.asarray(xs).reshape(-1, c.shape[0])
        if self._rank_update_fn is None:
            self._rank_update_fn = jax.jit(_chol_rank_update_jax)
        # cho_factor leaves garbage in the untouched triangle — extract a
        # clean lower factor, sweep, and hand back a (lower, True) handle.
        tri = self._jnp.tril(c) if lower else self._jnp.triu(c).T
        return Factorization((self._rank_update_fn(tri, xs), True), backend=self)

    def factor_solve(self, f: Factorization, b):
        import jax.scipy.linalg as jsl

        return jsl.cho_solve(f.handle, b)

    def solve_sym(self, a, b):
        return self.factor_solve(self.factor(a), b)

    def eigh(self, a):
        return self._jnp.linalg.eigh(a)

    def safe_reciprocal(self, v, cutoff):
        """1/v where |v| > cutoff, else 0 — pinv-style spectral truncation."""
        jnp = self._jnp
        return jnp.where(jnp.abs(v) > cutoff, 1.0 / jnp.where(v == 0, 1.0, v), 0.0)


def _chol_rank_update(R, xs):
    """Host rank-k Cholesky update: R upper with A = RᵀR → chol(A + xsᵀxs).

    One Householder column sweep over the implicit QR of ``[R; xs]``: at
    column i a single (k+1)-reflection annihilates all k update entries at
    once, so the work is k fused rank-1 updates — O(k·d²) flops in d
    vectorized iterations (not d·k scalar ones). Everything the inner loop
    touches (a row of R, the tail of xsᵀ) is contiguous in the C layout.
    The update is positive (a Gram delta), so the sweep cannot break down.
    """
    d = R.shape[0]
    R = np.array(R, np.float64, copy=True, order="C")
    xt = np.array(xs.T, np.float64, copy=True, order="C")  # (d, k) rows contiguous
    for i in range(d):
        w = xt[i]
        s = w @ w
        if s == 0.0:
            continue
        a = R[i, i]
        r = np.sqrt(a * a + s)
        amr = -s / (r + a)                 # a − r without cancellation
        beta = (r + a) / (r * s)           # 2 / uᵀu for u = [a−r; w]
        row = R[i, i + 1:]
        t = amr * row + xt[i + 1:] @ w     # uᵀ · [row; xs-tail]
        R[i, i] = r
        R[i, i + 1:] = row - (beta * amr) * t
        xt[i + 1:] -= (beta * t)[:, None] * w[None, :]
    return R


def _chol_rank_update_jax(L, xs):
    """Device twin of :func:`_chol_rank_update`: masked full-width columns so
    every iteration has static shapes under ``lax.fori_loop`` + ``jit``."""
    import jax
    import jax.numpy as jnp

    d = L.shape[0]
    idx = jnp.arange(d)

    def body(i, carry):
        L, xt = carry
        w = xt[i]
        s = w @ w
        s_ = jnp.where(s > 0, s, 1.0)      # w == 0 ⇒ t == 0, updates vanish
        a = L[i, i]
        r = jnp.sqrt(a * a + s)
        amr = -s / (r + a)
        beta = (r + a) / (r * s_)
        below = idx > i
        col = L[:, i]
        t = amr * col + xt @ w
        new_col = jnp.where(below, col - (beta * amr) * t, col).at[i].set(r)
        L = L.at[:, i].set(new_col)
        xt = jnp.where(below[:, None], xt - (beta * t)[:, None] * w[None, :], xt)
        return L, xt

    L, _ = jax.lax.fori_loop(0, d, body, (L, xs.T))
    return L


def get_backend(name: str, **kwargs):
    """Backend registry: ``numpy_f64`` | ``jax`` (+ dtype / use_kernel)."""
    if name == "numpy_f64":
        if kwargs.get("use_kernel"):
            raise ValueError("the Pallas kernel path requires the jax backend")
        return NumpyF64Backend()
    if name == "jax":
        return JaxBackend(dtype=kwargs.get("dtype"), use_kernel=bool(kwargs.get("use_kernel")))
    raise ValueError(f"unknown engine backend {name!r}")


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class AnalyticEngine:
    """Backend-agnostic AFL statistics→solve pipeline.

    One instance carries the protocol-level configuration (backend, the γ
    every client uses locally, accumulation policy); the statistics
    themselves travel as explicit :class:`SuffStats` values, so the engine is
    stateless and its methods are safe inside ``jit``/``shard_map`` for the
    jax backend.

    >>> eng = AnalyticEngine("numpy_f64", gamma=1.0)
    >>> stats = eng.merge(eng.client_stats(x1, y1), eng.client_stats(x2, y2))
    >>> w = eng.solve(stats)          # RI-restored joint solution (Thm 1+2)
    """

    def __init__(
        self,
        backend: str = "numpy_f64",
        *,
        gamma: float = 1.0,
        dtype=None,
        use_kernel: bool = False,
        kahan: bool = False,
    ):
        self.backend = get_backend(backend, dtype=dtype, use_kernel=use_kernel)
        self.gamma = float(gamma)
        if kahan and backend != "jax":
            raise ValueError("Kahan accumulation targets the f32 jax backend")
        self.kahan = bool(kahan)

    # -- accumulation -------------------------------------------------------

    def init(self, dim: int, num_classes: int) -> SuffStats:
        """Empty statistics (0 samples, 0 clients)."""
        b = self.backend
        comp_g = b.zeros((dim, dim)) if self.kahan else None
        comp_q = b.zeros((dim, num_classes)) if self.kahan else None
        return SuffStats(
            gram=b.zeros((dim, dim)),
            moment=b.zeros((dim, num_classes)),
            count=b.scalar(0.0),
            clients=b.scalar(0.0),
            gram_c=comp_g,
            moment_c=comp_q,
        )

    def update(self, stats: SuffStats, x, y) -> SuffStats:
        """Fold a batch of (embeddings, one-hot targets) into the statistics.

        Pure accumulation: ``clients`` is untouched — a participant marks
        itself with :meth:`finalize_client` (or arrives via
        :meth:`client_stats`) once its local stage is complete.
        """
        g_upd, q_upd, n = self.backend.gram_update(x, y)
        if self.kahan and stats.gram_c is not None:
            gram, gram_c = _kahan_add(stats.gram, stats.gram_c, g_upd)
            moment, moment_c = _kahan_add(stats.moment, stats.moment_c, q_upd)
        else:
            gram, gram_c = stats.gram + g_upd, stats.gram_c
            moment, moment_c = stats.moment + q_upd, stats.moment_c
        return SuffStats(gram, moment, stats.count + n, stats.clients,
                         gram_c, moment_c)

    def finalize_client(self, stats: SuffStats) -> SuffStats:
        """Mark accumulated statistics as ONE client's upload (clients=1)."""
        return stats._replace(clients=self.backend.scalar(1.0))

    def client_stats(self, x, y) -> SuffStats:
        """One client's local stage in a single call: raw stats, clients=1."""
        x = self.backend.asarray(x)
        y = self.backend.asarray(y)
        return self.finalize_client(
            self.update(self.init(x.shape[-1], y.shape[-1]), x, y))

    def merge(self, a: SuffStats, b: SuffStats) -> SuffStats:
        """The AA law in sufficient-statistics form: everything adds
        (Thm 1 / eq (11): C_agg = ΣC_k, Q_agg = ΣQ_k; client counts add for
        the lazy-γ bookkeeping of eq (15))."""
        return SuffStats(
            gram=a.gram + b.gram,
            moment=a.moment + b.moment,
            count=a.count + b.count,
            clients=a.clients + b.clients,
            gram_c=_maybe_add(a.gram_c, b.gram_c),
            moment_c=_maybe_add(a.moment_c, b.moment_c),
        )

    # -- regularization bookkeeping -----------------------------------------

    def regularized_gram(self, stats: SuffStats, gamma: Optional[float] = None):
        """``C_agg^r = Σ XᵀX + kγI`` — the regularized aggregate the paper's
        Algorithm 1 materializes (here derived lazily from raw stats)."""
        g = self.gamma if gamma is None else float(gamma)
        d = stats.gram.shape[0]
        return stats.gram + (stats.clients * g) * self.backend.eye(d)

    def _system(self, stats: SuffStats, use_ri: bool, target_gamma: float):
        d = stats.gram.shape[0]
        eye = self.backend.eye(d)
        if use_ri:
            # RI restore (Thm 2 / eq 16) on raw stats: the kγI term would be
            # added (eq 15) and removed (eq 16) analytically — so it is never
            # materialized; only the final target ridge remains.
            return stats.gram + self.backend.scalar(target_gamma) * eye
        return stats.gram + stats.clients * self.backend.scalar(self.gamma) * eye

    # -- solves -------------------------------------------------------------

    def solve(
        self,
        stats: SuffStats,
        *,
        use_ri: bool = True,
        target_gamma: float = 0.0,
    ):
        """Joint weight over everything merged into ``stats``.

        use_ri=True  → the paper's full pipeline (exact joint solution,
                       restored to ``target_gamma`` ridge; 0 = eq 16).
        use_ri=False → the biased no-RI aggregate carrying the accumulated
                       ``kγI`` (paper Table 3 ablation).
        """
        return self.backend.solve_sym(
            self._system(stats, use_ri, target_gamma), stats.moment)

    def factor(
        self,
        stats: SuffStats,
        *,
        use_ri: bool = True,
        target_gamma: float = 0.0,
    ) -> Factorization:
        """Factor the regularized system once; reuse via :meth:`factor_solve`.

        This is the serving hot path: one d³ factorization amortized over
        every straggler-poll ``solve()`` until new statistics arrive.
        """
        return self.backend.factor(self._system(stats, use_ri, target_gamma))

    def factor_solve(self, factorization: Factorization, b):
        """Solve against a cached factorization (d²·C instead of d³)."""
        return self.backend.factor_solve(factorization, b)

    def factor_update(
        self,
        factorization: Factorization,
        stats: SuffStats,
        root=None,
        *,
        use_ri: bool = True,
        target_gamma: float = 0.0,
        max_rank: Optional[int] = None,
    ) -> Factorization:
        """Fold a newly-merged low-rank delta into an existing factor.

        ``stats`` is the POST-merge aggregate (used only for the fallback);
        ``root`` is a (k, d) square root of the raw-Gram delta that was
        merged — ``rootᵀ·root == ΔGram`` — e.g. the client batch X_k itself
        or its QR ``R`` factor (same information as C_k, no raw features).

        When the delta is genuinely low-rank (k ≤ ``max_rank``; the default
        d//16 is the measured update-vs-refactor crossover at d=2048, see
        benchmarks/async_server_bench.py) and the factor is updatable, this
        is the O(k·d²) rank-k Cholesky update. Otherwise it falls back to a
        full refactor from ``stats``: dense delta (``root=None``), rank past
        the crossover, a pinv-fallback factor (the γ=0 rank-deficient path),
        or ``use_ri=False`` — whose per-client +γI delta is full-rank by
        construction.
        """
        if root is not None and use_ri and factorization.updatable:
            root = self.backend.asarray(root).reshape(-1, stats.dim)
            budget = max(1, stats.dim // 16) if max_rank is None else int(max_rank)
            if root.shape[0] <= budget:
                return factorization.rank_update(root)
        return self.factor(stats, use_ri=use_ri, target_gamma=target_gamma)

    def ri_restore(
        self,
        w_agg_r,
        c_agg_r,
        num_clients: int,
        gamma: Optional[float] = None,
        target_gamma: float = 0.0,
    ):
        """Theorem 2 / eq (16) in its explicit form, for *regularized*
        aggregates (Ŵ_agg^r, C_agg^r) as produced by the paper-literal
        Algorithm 1: ``Ŵ_agg = (C_agg^r − KγI)^{-1} C_agg^r Ŵ_agg^r``."""
        b = self.backend
        g = self.gamma if gamma is None else float(gamma)
        d = c_agg_r.shape[0]
        shift = b.scalar(num_clients * g - target_gamma) * b.eye(d)
        return b.solve_sym(c_agg_r - shift, c_agg_r @ w_agg_r)

    def solve_multi_gamma(
        self,
        stats: SuffStats,
        gammas: Sequence[float],
        *,
        use_ri: bool = True,
        rcond: float = 1e-12,
    ):
        """Solve the same statistics under several target ridges at once.

        One eigendecomposition ``C = VΛVᵀ`` (d³) serves every γ:
        ``W(γ) = V (Λ+γ)^{-1} Vᵀ Q`` is then d²·C per γ — the γ model sweep
        costs barely more than a single solve. Eigenvalues with
        ``λ+γ <= rcond·λ_max`` are treated as zero (pinv semantics), so the
        γ=0 rank-deficient case matches the fallback of the direct solve.

        Returns a list of weights, one per γ, each the RI-restored
        (``use_ri=True``) or biased (``use_ri=False``, γ then *adds* the
        lazy kγ term per eq (15)) solution.
        """
        b = self.backend
        base = stats.gram if use_ri else self.regularized_gram(stats)
        vals, vecs = b.eigh(base)
        vq = vecs.T @ stats.moment
        scale = abs(float(np.max(np.asarray(vals)))) if np.asarray(vals).size else 1.0
        cutoff = rcond * max(scale, np.finfo(np.float32).tiny)
        out = []
        for g in gammas:
            inv = b.safe_reciprocal(vals + b.scalar(float(g)), cutoff)
            out.append(vecs @ (inv[:, None] * vq))
        return out


def _kahan_add(total, comp, upd):
    """One compensated-summation step: returns (new_total, new_comp)."""
    y = upd - comp
    t = total + y
    comp = (t - total) - y
    return t, comp


def _maybe_add(a, b):
    if a is None or b is None:
        return None
    return a + b
