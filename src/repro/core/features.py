"""Non-linear feature maps for the analytic head (paper §5 future work).

"The AFL is established upon linear classifiers and may be less effective
with non-linear data distribution. To address this, AFL can incorporate
non-linear projections including non-linear activations or kernel functions
... and the AA law holds theoretically."  — paper §5.

We implement exactly that: a fixed random feature map φ applied to the frozen
backbone's embeddings *before* the Gram statistics. Because φ is deterministic
and shared (seeded like the backbone), the regression in φ-space is still
linear ⇒ every AFL property (AA law exactness, RI restore, partition
invariance) holds verbatim in φ-space. Two maps:

  * Random Fourier Features (RFF, Rahimi–Recht): φ(x) = √(2/D)·cos(xW + b)
    approximates an RBF kernel — the paper's "kernel functions" option.
  * Random ReLU features: φ(x) = relu(xW)/√D — the "non-linear activations"
    option (a one-layer random MLP head).

Both are pure-jnp and run inside the jit'd analytic train step.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FeatureMap", "rff_map", "relu_map", "identity_map"]


@dataclasses.dataclass(frozen=True)
class FeatureMap:
    """A fixed map x (…, d_in) → φ(x) (…, d_out), shareable by seed."""

    kind: str
    d_in: int
    d_out: int
    w: np.ndarray                 # (d_in, d_out)
    b: Optional[np.ndarray]       # (d_out,) or None
    scale: float

    def __call__(self, x):
        xp = jnp if isinstance(x, jax.Array) else np
        h = x @ xp.asarray(self.w, dtype=x.dtype if hasattr(x, "dtype") else None)
        if self.kind == "rff":
            return self.scale * xp.cos(h + xp.asarray(self.b, h.dtype))
        if self.kind == "relu":
            return self.scale * xp.maximum(h + xp.asarray(self.b, h.dtype), 0)
        return x


def rff_map(d_in: int, d_out: int, lengthscale: float = 1.0,
            seed: int = 0) -> FeatureMap:
    """RBF-kernel random Fourier features, k(x,x') ≈ exp(−‖x−x'‖²/2ℓ²)."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((d_in, d_out)) / lengthscale
    b = rng.uniform(0, 2 * np.pi, d_out)
    return FeatureMap("rff", d_in, d_out, w, b, float(np.sqrt(2.0 / d_out)))


def relu_map(d_in: int, d_out: int, seed: int = 0) -> FeatureMap:
    """One random ReLU layer with bias (bias breaks homogeneity — without it
    radius-like concepts are not representable)."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((d_in, d_out)) / np.sqrt(d_in)
    b = rng.standard_normal(d_out)
    return FeatureMap("relu", d_in, d_out, w, b, float(np.sqrt(1.0 / d_out)))


def identity_map(d_in: int) -> FeatureMap:
    return FeatureMap("id", d_in, d_in, np.eye(d_in), None, 1.0)
