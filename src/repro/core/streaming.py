"""Device-side streaming Gram statistics (jit-able, f32, TPU path).

The AFL local stage never needs to materialize the full ``(N, d)`` embedding
matrix: ``C = XᵀX`` and ``Q = XᵀY`` are additive over batches, so a client (or
a TPU data shard standing in for a client cohort) folds mini-batches into an
``AnalyticState`` accumulator. This is the in-graph half of the analytic
module; the float64 host half (literal AA law / RI) lives in
``repro.core.analytic``.

The Gram update itself is the AFL compute hot spot beyond the backbone — it is
backed by the Pallas kernel in ``repro.kernels.gram`` (``use_kernel=True``)
with ``repro.kernels.ref`` as oracle.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AnalyticState", "init_state", "update_state", "merge_states", "solve"]


class AnalyticState(NamedTuple):
    """Sufficient statistics of a (partial) analytic regression.

    gram:  ``Σ XᵀX``  (d, d), f32
    moment: ``Σ XᵀY`` (d, C), f32
    count: number of samples folded in (scalar f32; used for diagnostics and
      per-client sample-count bookkeeping, not needed by the solve itself).
    """

    gram: jax.Array
    moment: jax.Array
    count: jax.Array


def init_state(dim: int, num_classes: int, dtype=jnp.float32) -> AnalyticState:
    return AnalyticState(
        gram=jnp.zeros((dim, dim), dtype),
        moment=jnp.zeros((dim, num_classes), dtype),
        count=jnp.zeros((), dtype),
    )


def update_state(
    state: AnalyticState,
    embeddings: jax.Array,
    targets: jax.Array,
    *,
    use_kernel: bool = False,
) -> AnalyticState:
    """Fold a batch of (embeddings, one-hot targets) into the statistics.

    embeddings: (N, d) — any leading dims are flattened.
    targets: (N, C) one-hot (or soft) labels.
    """
    x = embeddings.reshape(-1, embeddings.shape[-1]).astype(jnp.float32)
    y = targets.reshape(-1, targets.shape[-1]).astype(jnp.float32)
    if use_kernel:
        from repro.kernels import ops as _kops

        gram_upd, moment_upd = _kops.gram_update(x, y)
    else:
        gram_upd = x.T @ x
        moment_upd = x.T @ y
    return AnalyticState(
        gram=state.gram + gram_upd,
        moment=state.moment + moment_upd,
        count=state.count + x.shape[0],
    )


def merge_states(a: AnalyticState, b: AnalyticState) -> AnalyticState:
    """AA law in sufficient-statistics form: statistics simply add."""
    return AnalyticState(a.gram + b.gram, a.moment + b.moment, a.count + b.count)


def solve(state: AnalyticState, gamma: float | jax.Array = 0.0) -> jax.Array:
    """Ridge solve ``(C + γI)^{-1} Q`` in-graph (f32 Cholesky).

    For γ=0 on rank-deficient C this is the caller's responsibility (use the
    host f64 path with pinv fallback); in-graph we always add γI.
    """
    d = state.gram.shape[0]
    a = state.gram + gamma * jnp.eye(d, dtype=state.gram.dtype)
    cf = jax.scipy.linalg.cho_factor(a)
    return jax.scipy.linalg.cho_solve(cf, state.moment)
