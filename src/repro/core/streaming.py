"""Device-side streaming Gram statistics (jit-able, f32, TPU path).

The AFL local stage never needs to materialize the full ``(N, d)`` embedding
matrix: ``C = XᵀX`` and ``Q = XᵀY`` are additive over batches, so a client (or
a TPU data shard standing in for a client cohort) folds mini-batches into an
``AnalyticState`` accumulator.

This module is the paper-literal *device* API; the arithmetic lives in
:mod:`repro.core.engine` (jax backend), shared with the host f64 path and the
distributed collective. ``AnalyticState`` keeps its minimal 3-leaf pytree
layout — (gram, moment, count) — because the launch-layer shardings and the
shard_map in_specs are written against it; :func:`to_stats` /
:func:`from_stats` convert to the engine's :class:`~repro.core.engine.
SuffStats` (which additionally tracks the client count for lazy-γ
bookkeeping).

The Gram update itself is the AFL compute hot spot beyond the backbone — it is
backed by the Pallas kernel in ``repro.kernels.gram`` (``use_kernel=True``)
with ``repro.kernels.ref`` as oracle.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.engine import AnalyticEngine, SuffStats

__all__ = [
    "AnalyticState",
    "init_state",
    "update_state",
    "merge_states",
    "solve",
    "to_stats",
    "from_stats",
]

# Module-level jax engines: plain accumulation and the Pallas-kernel path.
_ENGINE = AnalyticEngine("jax")
_ENGINE_KERNEL = AnalyticEngine("jax", use_kernel=True)


class AnalyticState(NamedTuple):
    """Sufficient statistics of a (partial) analytic regression.

    gram:  ``Σ XᵀX``  (d, d), f32
    moment: ``Σ XᵀY`` (d, C), f32
    count: number of samples folded in (scalar f32; used for diagnostics and
      per-client sample-count bookkeeping, not needed by the solve itself).
    """

    gram: jax.Array
    moment: jax.Array
    count: jax.Array


def to_stats(state: AnalyticState, clients: float | jax.Array = 1.0) -> SuffStats:
    """View an accumulator as engine SuffStats for ``clients`` contributions."""
    return SuffStats(
        gram=state.gram,
        moment=state.moment,
        count=state.count,
        clients=jnp.asarray(clients, state.gram.dtype),
    )


def from_stats(stats: SuffStats) -> AnalyticState:
    """Project engine SuffStats back onto the 3-leaf device layout."""
    return AnalyticState(gram=stats.gram, moment=stats.moment, count=stats.count)


def init_state(dim: int, num_classes: int, dtype=jnp.float32) -> AnalyticState:
    eng = _ENGINE if dtype == _ENGINE.backend.dtype else AnalyticEngine("jax", dtype=dtype)
    return from_stats(eng.init(dim, num_classes))


def update_state(
    state: AnalyticState,
    embeddings: jax.Array,
    targets: jax.Array,
    *,
    use_kernel: bool = False,
) -> AnalyticState:
    """Fold a batch of (embeddings, one-hot targets) into the statistics.

    embeddings: (N, d) — any leading dims are flattened.
    targets: (N, C) one-hot (or soft) labels.
    """
    eng = _ENGINE_KERNEL if use_kernel else _ENGINE
    return from_stats(eng.update(to_stats(state, 0.0), embeddings, targets))


def merge_states(a: AnalyticState, b: AnalyticState) -> AnalyticState:
    """AA law in sufficient-statistics form: statistics simply add."""
    return from_stats(_ENGINE.merge(to_stats(a, 0.0), to_stats(b, 0.0)))


def solve(state: AnalyticState, gamma: float | jax.Array = 0.0) -> jax.Array:
    """Ridge solve ``(C + γI)^{-1} Q`` in-graph (f32 Cholesky).

    For γ=0 on rank-deficient C this is the caller's responsibility (use the
    host f64 path with pinv fallback); in-graph we always add γI.
    """
    return _ENGINE.solve(to_stats(state, 0.0), use_ri=True, target_gamma=gamma)
