"""Synthetic datasets (offline substitutes for CIFAR/Tiny-ImageNet features).

The paper's pipeline is: frozen pre-trained backbone → embeddings → linear
head. Offline we cannot download CIFAR or ImageNet weights, so benchmarks use:

  * ``gaussian_mixture`` — embedding-space classification with controllable
    class separation. This stands in for "backbone features of a C-class
    dataset": AFL's exactness/invariance claims are feature-distribution
    independent, and accuracy degradation effects for gradient FL under
    non-IID splits reproduce qualitatively (benchmarks/table1 etc.).
  * ``dummy_regression`` — the paper's own Supp. D dummy dataset (512-dim,
    10k samples, 10 balanced classes) for the ΔW deviation experiment.
  * ``token_classification`` — token sequences whose class shifts the token
    distribution; used end-to-end with real (randomly-initialized, frozen)
    transformer backbones from the architecture pool.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Dataset:
    x: np.ndarray          # features (N, d) float32 or tokens (N, S) int32
    y: np.ndarray          # labels (N,) int64
    num_classes: int

    def __len__(self):
        return len(self.y)


def gaussian_mixture(
    n: int = 20_000,
    dim: int = 512,
    num_classes: int = 100,
    separation: float = 1.0,
    within_scale: float = 1.0,
    seed: int = 0,
) -> Dataset:
    rng = np.random.default_rng(seed)
    means = rng.standard_normal((num_classes, dim)) * separation
    y = rng.integers(0, num_classes, n)
    x = means[y] + rng.standard_normal((n, dim)) * within_scale
    return Dataset(x.astype(np.float32), y, num_classes)


def dummy_regression(seed: int = 0) -> Dataset:
    """Paper Supp. D: 512-dim, 10,000 samples, 10 balanced classes."""
    rng = np.random.default_rng(seed)
    n, dim, c = 10_000, 512, 10
    x = rng.standard_normal((n, dim)).astype(np.float32)
    y = np.repeat(np.arange(c), n // c)
    rng.shuffle(y)
    return Dataset(x, y, c)


def token_classification(
    n: int = 2_000,
    seq: int = 32,
    vocab: int = 512,
    num_classes: int = 16,
    skew: float = 3.0,
    seed: int = 0,
) -> Dataset:
    """Class k biases token frequencies toward a class-specific region of the
    vocab, so even a random frozen backbone's mean-pooled features separate."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, num_classes, n)
    base = np.ones(vocab)
    toks = np.empty((n, seq), np.int32)
    block = vocab // num_classes
    for i in range(n):
        w = base.copy()
        lo = y[i] * block
        w[lo : lo + block] *= np.exp(skew)
        w /= w.sum()
        toks[i] = rng.choice(vocab, size=seq, p=w)
    return Dataset(toks, y, num_classes)


def train_test_split(ds: Dataset, test_frac: float = 0.2, seed: int = 0):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(ds))
    cut = int(len(ds) * (1 - test_frac))
    tr, te = perm[:cut], perm[cut:]
    return (Dataset(ds.x[tr], ds.y[tr], ds.num_classes),
            Dataset(ds.x[te], ds.y[te], ds.num_classes))


def lm_stream(batch: int, seq: int, vocab: int, seed: int = 0) -> np.ndarray:
    """Learnable token stream for LM pre-training: a noisy random-walk
    bigram process (next token ≈ current + small step, mod vocab) over a
    Zipf-weighted alphabet — a few hundred SGD steps visibly lower CE."""
    rng = np.random.default_rng(seed)
    toks = np.empty((batch, seq), np.int32)
    toks[:, 0] = rng.zipf(1.5, batch) % vocab
    steps = rng.integers(-8, 9, (batch, seq - 1))
    jumps = rng.random((batch, seq - 1)) < 0.05
    jump_to = rng.integers(0, vocab, (batch, seq - 1))
    for t in range(1, seq):
        nxt = (toks[:, t - 1] + steps[:, t - 1]) % vocab
        toks[:, t] = np.where(jumps[:, t - 1], jump_to[:, t - 1], nxt)
    return toks
