"""Federated-learning surface: one client/coordinator API (see fl/api.py).

Canonical in-process names live in :mod:`repro.fl.api`; the serving layer —
:class:`FederationService`, the in-proc/HTTP transports, and the wire-true
:class:`RemoteCoordinator` — lives in :mod:`repro.fl.service`, with the
typed failure taxonomy in :mod:`repro.fl.errors`. All are re-exported here.
Driver loops (:mod:`repro.fl.afl`), gradient baselines, and partitioners
stay as submodules.
"""

from repro.fl.api import (AFLClient, AFLServer, ClientReport, Coordinator,
                          GammaSweep, SCHEMA_VERSION, ShardedCoordinator,
                          Transport, VersionedWeights, evaluate_weight,
                          make_report, masked_reports)
from repro.fl.async_server import AsyncAFLServer, SubmitAborted
from repro.fl.errors import ServiceError
from repro.fl.mux import (MuxFederationServer, MuxTransport,
                          client_ssl_context, generate_self_signed_cert,
                          mux_ping, probe_alive, serve_mux,
                          server_ssl_context)
from repro.fl.replication import (LedgerTailer, ReportLedger, WarmStandby,
                                  WeightsReplica, compact_ledger_dir,
                                  watch_primary)
from repro.fl.service import (FederationService, HttpTransport,
                              InProcTransport, RemoteCoordinator,
                              promote_remote, serve_http)

__all__ = [
    "AFLClient",
    "AFLServer",
    "AsyncAFLServer",
    "ClientReport",
    "Coordinator",
    "FederationService",
    "GammaSweep",
    "HttpTransport",
    "InProcTransport",
    "LedgerTailer",
    "MuxFederationServer",
    "MuxTransport",
    "RemoteCoordinator",
    "ReportLedger",
    "SCHEMA_VERSION",
    "ServiceError",
    "ShardedCoordinator",
    "SubmitAborted",
    "Transport",
    "VersionedWeights",
    "WarmStandby",
    "WeightsReplica",
    "client_ssl_context",
    "compact_ledger_dir",
    "evaluate_weight",
    "generate_self_signed_cert",
    "make_report",
    "masked_reports",
    "mux_ping",
    "probe_alive",
    "promote_remote",
    "serve_http",
    "serve_mux",
    "server_ssl_context",
    "watch_primary",
]
