"""Federated-learning surface: one client/coordinator API (see fl/api.py).

Canonical names live in :mod:`repro.fl.api` and are re-exported here;
``repro.fl.server`` is a one-release deprecation shim over the same objects.
Driver loops (:mod:`repro.fl.afl`), gradient baselines, and partitioners stay
as submodules.
"""

from repro.fl.api import (AFLClient, AFLServer, ClientReport, Coordinator,
                          GammaSweep, SCHEMA_VERSION, ShardedCoordinator,
                          evaluate_weight, make_report, masked_reports)
from repro.fl.async_server import AsyncAFLServer

__all__ = [
    "AFLClient",
    "AFLServer",
    "AsyncAFLServer",
    "ClientReport",
    "Coordinator",
    "GammaSweep",
    "SCHEMA_VERSION",
    "ShardedCoordinator",
    "evaluate_weight",
    "make_report",
    "masked_reports",
]
