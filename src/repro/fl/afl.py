"""End-to-end AFL driver (Algorithm 1) over a client partition.

Two feature paths:
  * feature-space datasets (x already embeddings): clients run local_stage
    directly — this is the configuration of every paper table.
  * token datasets + a frozen backbone: clients first embed their shard with
    the shared pre-trained backbone (repro.models), then run local_stage.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.config import FLConfig
from repro.core import analytic as al
from repro.data.synthetic import Dataset
from repro.fl.api import AFLClient, AFLServer, evaluate_weight
from repro.fl.partition import make_partition


@dataclasses.dataclass
class AFLResult:
    weight: np.ndarray
    accuracy: float
    train_seconds: float
    num_clients: int
    client_sizes: list


def embed_with_backbone(backbone_fn: Callable, x: np.ndarray,
                        batch: int = 256) -> np.ndarray:
    """Run the frozen backbone over token inputs in mini-batches → (N, d)."""
    outs = []
    for i in range(0, len(x), batch):
        outs.append(np.asarray(backbone_fn(x[i : i + batch])))
    return np.concatenate(outs, 0)


def evaluate(weight: np.ndarray, x: np.ndarray, y: np.ndarray) -> float:
    return evaluate_weight(weight, x, y)


def run_afl(
    train: Dataset,
    test: Dataset,
    fl: FLConfig,
    *,
    backbone_fn: Optional[Callable] = None,
    feature_map: Optional[Callable] = None,
    pairwise: bool = False,
    coordinator=None,
) -> AFLResult:
    """Full AFL: partition → local stages (one epoch each) → single-round
    aggregation (+ RI restore) → evaluate.

    ``feature_map``: optional shared non-linear projection φ applied to the
    (backbone) features before the analytic head (paper §5 / core.features) —
    the regression stays linear in φ-space, so every AFL invariance holds.

    ``coordinator``: where the reports go — any synchronous
    :class:`~repro.fl.api.Coordinator` (defaults to a fresh in-process
    :class:`~repro.fl.api.AFLServer`), or a ``http://`` URL string, which is
    wrapped in a :class:`~repro.fl.service.RemoteCoordinator` so the whole
    driver runs against a live :class:`~repro.fl.service.FederationService`
    with no other call-site change.

    The production path (``use_ri=True``, ``pairwise=False``) drives the
    canonical API: one :class:`~repro.fl.api.AFLClient` local stage per
    client, one :class:`~repro.fl.api.ClientReport` submitted to the
    coordinator, one solve. The paper-literal ``pairwise`` recursion and the
    no-RI ablation route through :mod:`repro.core.analytic` (Table 3 / A.1).
    """
    t0 = time.perf_counter()
    x_tr, x_te = train.x, test.x
    if backbone_fn is not None:
        x_tr = embed_with_backbone(backbone_fn, x_tr)
        x_te = embed_with_backbone(backbone_fn, x_te)
    if feature_map is not None:
        x_tr = np.asarray(feature_map(x_tr))
        x_te = np.asarray(feature_map(x_te))
    y_tr = np.eye(train.num_classes, dtype=np.float64)[train.y]

    parts = make_partition(train.y, fl.num_clients, fl.partition,
                           alpha=fl.alpha, shards_per_client=fl.shards_per_client,
                           seed=fl.seed)
    if fl.use_ri and not pairwise:
        if isinstance(coordinator, str):
            from repro.fl.service import RemoteCoordinator

            coordinator = RemoteCoordinator(coordinator)
        server = coordinator if coordinator is not None else AFLServer(
            x_tr.shape[1], train.num_classes, gamma=fl.gamma)
        if (server.dim, server.gamma) != (x_tr.shape[1], fl.gamma):
            raise ValueError(
                f"coordinator (dim={server.dim}, γ={server.gamma}) does not "
                f"match the run (dim={x_tr.shape[1]}, γ={fl.gamma})")
        for cid, idx in enumerate(parts):
            # empty clients still upload (γI Gram, 0 moment) — the AA law
            # and the RI restore handle them exactly.
            server.submit(AFLClient(cid, gamma=fl.gamma).local_stage(
                x_tr[idx].astype(np.float64), y_tr[idx]))
        weight = server.solve(target_gamma=0.0)
    else:
        # paper-literal ablation path: per-client (Ŵ_k^r, C_k^r) uploads,
        # AA-law recursion and/or the biased no-RI aggregate
        updates = [al.local_stage(x_tr[idx].astype(np.float64), y_tr[idx],
                                  fl.gamma) for idx in parts]
        weight = al.afl_aggregate(updates, use_ri=fl.use_ri, pairwise=pairwise)
    dt = time.perf_counter() - t0
    acc = evaluate(weight, x_te.astype(np.float64), test.y)
    return AFLResult(weight, acc, dt, fl.num_clients, [len(p) for p in parts])


def joint_ridge(train: Dataset, test: Dataset, gamma: float = 0.0,
                backbone_fn: Optional[Callable] = None):
    """Centralized joint-training reference (the equivalence target)."""
    x_tr, x_te = train.x, test.x
    if backbone_fn is not None:
        x_tr = embed_with_backbone(backbone_fn, x_tr)
        x_te = embed_with_backbone(backbone_fn, x_te)
    y = np.eye(train.num_classes, dtype=np.float64)[train.y]
    w = al.ridge_solve(x_tr.astype(np.float64), y, gamma)
    return w, evaluate(w, x_te.astype(np.float64), test.y)
