"""The one AFL client/coordinator API.

The paper's whole pitch is a *single-round* protocol — one upload per client,
one aggregation — and this module is that protocol's single surface:

  * :class:`ClientReport` — the canonical, versioned wire format of a client
    upload: regularized sufficient statistics (C_k^r, Q_k), the sample count,
    and an optional low-rank root of the raw Gram. ``to_bytes()`` /
    ``from_bytes()`` serialize it (configurable dtype, optional f32-root
    compression, CRC-checked schema validation on ingest) so a report can
    actually cross a network instead of living as three incompatible
    in-process payloads.
  * :class:`AFLClient` — the one-epoch local stage: (optionally) embed with a
    frozen backbone / feature map, fold batches into engine ``SuffStats``,
    track a low-rank QR root, and emit one :class:`ClientReport`.
  * :class:`Coordinator` — the protocol every server-side implementation
    satisfies: ``submit / submit_many / solve / solve_multi_gamma /
    sweep(gammas, holdout) / state / from_state / num_clients``. Three
    implementations ship: :class:`AFLServer` (synchronous, cached rank-
    updatable Cholesky), :class:`~repro.fl.async_server.AsyncAFLServer`
    (event-loop serving over the same seam), and :class:`ShardedCoordinator`
    (the Gram pytree sharded over a jax mesh via
    ``core.distributed.make_federated_solve`` — the K≥1000-client backend).

All aggregation math routes through :class:`repro.core.engine.AnalyticEngine`;
this module owns only protocol-level bookkeeping (ids, γ checks, caches,
shard placement). The transport layer — :class:`~repro.fl.service.
FederationService`, the in-proc/HTTP transports, and the wire-true
:class:`~repro.fl.service.RemoteCoordinator` client — lives in
:mod:`repro.fl.service`; failure modes are the typed taxonomy of
:mod:`repro.fl.errors`.
"""

from __future__ import annotations

import dataclasses
import json
import struct
import uuid
import zlib
from typing import (Any, Callable, Dict, Iterable, List, Optional, Protocol,
                    Sequence, Tuple, runtime_checkable)

import numpy as np

from repro.core.engine import (AnalyticEngine, Factorization, SuffStats,
                               SweepFactorization, SweepRefreshNeeded)
from repro.fl.errors import (BadRequest, Backpressure, DuplicateClient,
                             EmptyFederation, GammaMismatch)

__all__ = [
    "SCHEMA_VERSION",
    "ClientReport",
    "AFLClient",
    "make_report",
    "masked_reports",
    "evaluate_weight",
    "GammaSweep",
    "VersionedWeights",
    "Coordinator",
    "Transport",
    "AFLServer",
    "ShardedCoordinator",
]

# ---------------------------------------------------------------------------
# Canonical wire format
# ---------------------------------------------------------------------------

SCHEMA_VERSION = 1
_MAGIC = b"AFLR"
_WIRE_DTYPES = {"float64": np.float64, "float32": np.float32}


@dataclasses.dataclass(frozen=True)
class ClientReport:
    """What one client uploads: regularized sufficient statistics.

    gram:   C_k^r = X_kᵀX_k + γI   (d, d)
    moment: Q_k   = X_kᵀY_k        (d, C)
    (Equivalent information to the paper's (Ŵ_k^r, C_k^r) upload —
    Q_k = C_k^r Ŵ_k^r — but numerically nicer to accumulate.)
    count: number of local samples (diagnostics only; 0 when unknown).
    root:  optional (n_k, d) square root of the RAW Gram, ``rootᵀroot =
           X_kᵀX_k`` (e.g. the R factor of QR(X_k)). It carries exactly the
           information already in ``gram`` — no extra privacy exposure — but
           lets a coordinator fold the arrival into a cached Cholesky factor
           as a rank-n_k update instead of refactoring. ``None`` (unknown
           root, e.g. after masking) forces the refactor path.

    Wire format (``to_bytes`` / ``from_bytes``), schema version 1::

        b"AFLR" | u32 header_len | header JSON | gram | moment | [root]

    Arrays travel C-order in the header-declared dtype; the header carries a
    CRC-32 of the payload, so a flipped or truncated byte is rejected on
    ingest (``ValueError``), as are unknown versions/dtypes and inconsistent
    shapes. The default encoding (float64, uncompressed root) round-trips
    **losslessly**; ``dtype=np.float32`` halves the wire size at ~1e-7
    relative error, and ``compress_root=True`` stores only the root in f32
    (the folded rootᵀ·root then deviates by ≲1e-6 relative — documented
    tolerance for the rank-update path; gram/moment stay exact).
    """

    client_id: int
    gram: np.ndarray
    moment: np.ndarray
    gamma: float
    count: float = 0.0
    root: Optional[np.ndarray] = None

    def to_bytes(self, *, dtype=np.float64, compress_root: bool = False) -> bytes:
        """Serialize to the canonical wire format (see class docstring)."""
        dt = np.dtype(dtype)
        if dt.name not in _WIRE_DTYPES:
            raise ValueError(f"unsupported wire dtype {dt.name!r} "
                             f"(one of {sorted(_WIRE_DTYPES)})")
        gram = np.ascontiguousarray(np.asarray(self.gram, dt))
        if gram.ndim != 2 or gram.shape[0] != gram.shape[1]:
            raise ValueError(f"gram must be square, got {gram.shape}")
        moment = np.ascontiguousarray(np.asarray(self.moment, dt))
        if moment.ndim != 2 or moment.shape[0] != gram.shape[0]:
            raise ValueError(f"moment shape {moment.shape} does not match "
                             f"dim {gram.shape[0]}")
        root = None
        root_dt = np.dtype(np.float32) if compress_root else dt
        if self.root is not None:
            root = np.ascontiguousarray(
                np.asarray(self.root, root_dt).reshape(-1, gram.shape[0]))
        payload = gram.tobytes() + moment.tobytes() + (
            root.tobytes() if root is not None else b"")
        header = {
            "version": SCHEMA_VERSION,
            "client_id": int(self.client_id),
            "gamma": float(self.gamma),
            "count": float(self.count),
            "dtype": dt.name,
            "dim": int(gram.shape[0]),
            "num_classes": int(moment.shape[1]),
            "root_dtype": root_dt.name if root is not None else None,
            "root_rows": int(root.shape[0]) if root is not None else None,
            "crc32": zlib.crc32(payload),
        }
        hb = json.dumps(header, sort_keys=True).encode("utf-8")
        return _MAGIC + struct.pack("<I", len(hb)) + hb + payload

    @classmethod
    def from_bytes(cls, data: bytes) -> "ClientReport":
        """Parse + validate a wire report; arrays land host-f64.

        Raises ``ValueError`` for anything that is not a well-formed,
        checksum-clean, schema-consistent version-1 report.
        """
        data = bytes(data)
        if len(data) < len(_MAGIC) + 4 or data[: len(_MAGIC)] != _MAGIC:
            raise ValueError("not an AFL client report (bad magic)")
        (hlen,) = struct.unpack("<I", data[len(_MAGIC): len(_MAGIC) + 4])
        body = len(_MAGIC) + 4
        if len(data) < body + hlen:
            raise ValueError("truncated report header")
        try:
            header = json.loads(data[body: body + hlen].decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise ValueError(f"corrupt report header: {e}") from None
        if header.get("version") != SCHEMA_VERSION:
            raise ValueError(
                f"unsupported report schema version {header.get('version')!r}"
                f" (expected {SCHEMA_VERSION})")
        try:
            dt = _WIRE_DTYPES[header["dtype"]]
            dim, num_classes = int(header["dim"]), int(header["num_classes"])
            root_rows = header["root_rows"]
            root_dt = (_WIRE_DTYPES[header["root_dtype"]]
                       if root_rows is not None else None)
            client_id = int(header["client_id"])
            gamma, count = float(header["gamma"]), float(header["count"])
            crc = int(header["crc32"])
        except (KeyError, TypeError, ValueError) as e:
            raise ValueError(f"malformed report header: {e}") from None
        if dim <= 0 or num_classes <= 0 or (
                root_rows is not None and root_rows < 0):
            raise ValueError("malformed report header: non-positive shapes")
        isz = np.dtype(dt).itemsize
        n_gram, n_mom = dim * dim * isz, dim * num_classes * isz
        n_root = (root_rows * dim * np.dtype(root_dt).itemsize
                  if root_rows is not None else 0)
        payload = data[body + hlen:]
        if len(payload) != n_gram + n_mom + n_root:
            raise ValueError(
                f"payload length {len(payload)} does not match header shapes")
        if zlib.crc32(payload) != crc:
            raise ValueError("report payload failed its CRC-32 check")
        gram = np.frombuffer(payload, dt, dim * dim).reshape(dim, dim)
        moment = np.frombuffer(
            payload, dt, dim * num_classes, offset=n_gram
        ).reshape(dim, num_classes)
        root = None
        if root_rows is not None:
            root = np.frombuffer(
                payload, root_dt, root_rows * dim, offset=n_gram + n_mom
            ).reshape(root_rows, dim).astype(np.float64)
        if not (np.isfinite(gram).all() and np.isfinite(moment).all()
                and (root is None or np.isfinite(root).all())
                and np.isfinite(gamma) and np.isfinite(count)):
            raise ValueError("report carries non-finite statistics")
        return cls(client_id, gram.astype(np.float64),
                   moment.astype(np.float64), gamma, count=count, root=root)


# ---------------------------------------------------------------------------
# The client side
# ---------------------------------------------------------------------------


class AFLClient:
    """One client's local stage, start to finish.

    ``update()`` folds (token or feature) batches — embedding them first when
    a frozen ``backbone_fn`` / ``feature_map`` is configured — into engine
    :class:`~repro.core.engine.SuffStats`; ``report()`` emits the single
    canonical :class:`ClientReport` (regularized Gram, moment, sample count,
    and — while the local row count stays below ``d`` — the low-rank QR root
    of the raw Gram that lets coordinators rank-update cached factors).

    >>> report = AFLClient(client_id=3, gamma=1.0).local_stage(x, y_onehot)
    >>> payload = report.to_bytes()            # ...crosses the network...
    >>> server.submit(ClientReport.from_bytes(payload))

    The engine backend is pluggable: ``numpy_f64`` (default, paper-faithful
    host arithmetic) or ``jax`` (device accumulation, optionally through the
    Pallas Gram kernel; pass ``dtype=jnp.float64`` under ``jax_enable_x64``
    for f64-on-device, or ``kahan=True`` for compensated-f32 accumulation —
    see ``benchmarks/kahan_f32_bench.py`` for the measured accuracy/cost
    trade against both).
    """

    def __init__(
        self,
        client_id: int,
        gamma: float = 1.0,
        *,
        backbone_fn: Optional[Callable] = None,
        feature_map: Optional[Callable] = None,
        backend: str = "numpy_f64",
        dtype=None,
        use_kernel: bool = False,
        kahan: bool = False,
        embed_batch: int = 256,
    ):
        self.client_id = client_id
        self.gamma = float(gamma)
        self.backbone_fn = backbone_fn
        self.feature_map = feature_map
        self.embed_batch = int(embed_batch)
        self.engine = AnalyticEngine(
            backend, gamma=gamma, dtype=dtype, use_kernel=use_kernel,
            kahan=kahan)
        self._stats: Optional[SuffStats] = None
        self._root_blocks: Optional[List[np.ndarray]] = []
        self._rows = 0

    def _embed(self, x):
        if self.backbone_fn is not None:
            x = np.asarray(x)
            b = self.embed_batch
            x = np.concatenate(
                [np.asarray(self.backbone_fn(x[i: i + b]))
                 for i in range(0, len(x), b)], 0) if len(x) else x
        if self.feature_map is not None:
            x = np.asarray(self.feature_map(np.asarray(x)))
        return x

    def update(self, x, y_onehot) -> "AFLClient":
        """Fold one batch of local data into the running statistics."""
        x = self._embed(x)
        dim = int(np.asarray(x.shape)[-1])
        classes = int(np.asarray(y_onehot.shape)[-1])
        if self._stats is None:
            self._stats = self.engine.init(dim, classes)
        if self._stats.dim != dim:
            raise ValueError(
                f"batch dim {dim} != client dim {self._stats.dim}")
        self._stats = self.engine.update(self._stats, x, y_onehot)
        n = int(np.prod(np.asarray(x.shape)[:-1]))
        self._rows += n
        if self._root_blocks is not None:
            if self._rows >= dim:
                # a ≥ d-row root is no cheaper than a refactor — stop tracking
                self._root_blocks = None
            elif n:
                self._root_blocks.append(
                    np.asarray(x, np.float64).reshape(-1, dim))
        return self

    def report(self) -> ClientReport:
        """Finish the local stage: one canonical report (host f64)."""
        if self._stats is None:
            raise ValueError("no local data folded in (call update first)")
        stats = self.engine.finalize_client(self._stats)
        gram = np.asarray(self.engine.regularized_gram(stats), np.float64)
        moment = np.asarray(stats.moment, np.float64)
        root = None
        if self._root_blocks is not None:
            rows = (np.concatenate(self._root_blocks, 0) if self._root_blocks
                    else np.zeros((0, stats.dim)))
            root = np.linalg.qr(rows, mode="r") if len(rows) else rows
        return ClientReport(self.client_id, gram, moment, self.gamma,
                            count=float(stats.count), root=root)

    def local_stage(self, x, y_onehot) -> ClientReport:
        """One-shot convenience: ``update(x, y)`` then ``report()``."""
        return self.update(x, y_onehot).report()


def make_report(client_id: int, x: np.ndarray, y_onehot: np.ndarray,
                gamma: float) -> ClientReport:
    """One client's local stage → upload (thin :class:`AFLClient` wrapper)."""
    return AFLClient(client_id, gamma=gamma).local_stage(x, y_onehot)


def masked_reports(reports: Sequence[ClientReport],
                   seed: int = 0) -> list[ClientReport]:
    """SecAgg-style pairwise masking of the uploads.

    Every pair (u, v), u < v derives a shared mask from a common seed; u adds
    it, v subtracts it. Any single report is then statistically useless to
    the server, but Σ reports is unchanged — and since AFL aggregation IS
    that sum, the masked protocol is exact (tested to ~1e-9).
    """
    n = len(reports)
    masked_g = [r.gram.astype(np.float64).copy() for r in reports]
    masked_q = [r.moment.astype(np.float64).copy() for r in reports]
    for u in range(n):
        for v in range(u + 1, n):
            rng = np.random.default_rng(
                (seed, reports[u].client_id, reports[v].client_id))
            mg = rng.standard_normal(masked_g[u].shape)
            mq = rng.standard_normal(masked_q[u].shape)
            masked_g[u] += mg
            masked_g[v] -= mg
            masked_q[u] += mq
            masked_q[v] -= mq
    return [
        # the mask is dense and full-rank, so a masked gram has no usable
        # low-rank root — drop it and let the server take the refactor path
        dataclasses.replace(r, gram=g, moment=q, root=None)
        for r, g, q in zip(reports, masked_g, masked_q)
    ]


# ---------------------------------------------------------------------------
# The coordinator protocol
# ---------------------------------------------------------------------------


def evaluate_weight(weight, x, y) -> float:
    """Top-1 accuracy of a linear head ``weight`` on features/int labels."""
    pred = np.argmax(np.asarray(x) @ np.asarray(weight), axis=-1)
    return float(np.mean(pred == np.asarray(y)))


@dataclasses.dataclass(frozen=True)
class VersionedWeights:
    """A solved-head snapshot stamped with its ETag-style staleness token.

    ``etag`` is opaque and binds everything that identifies THIS head: the
    coordinator's submission epoch (``version``, bumped on every successful
    submit), the requested ``target_gamma``, and a per-coordinator-instance
    salt (so a token minted before a checkpoint restore can never
    accidentally match a restored server that happens to reach the same
    epoch count). A downloader that remembers its last token asks
    ``weights(target_gamma, if_etag=token)`` and gets a cheap not-modified
    answer (``weight is None``) instead of a re-solve + re-download when
    nothing new arrived — and a token minted for one γ can never validate a
    download of another.
    """

    version: int
    target_gamma: float
    weight: Optional[np.ndarray]
    etag: str = ""

    @property
    def not_modified(self) -> bool:
        return self.weight is None


@dataclasses.dataclass(frozen=True)
class GammaSweep:
    """Result of a server-side γ model sweep against a holdout set."""

    gammas: Tuple[float, ...]
    weights: List[np.ndarray]
    accuracies: Tuple[float, ...]
    best_gamma: float
    best_weight: np.ndarray

    @property
    def best_accuracy(self) -> float:
        return max(self.accuracies)


def _sweep_from_weights(weights: Sequence[np.ndarray],
                        gammas: Sequence[float], holdout) -> GammaSweep:
    x, y = holdout
    accs = tuple(evaluate_weight(w, x, y) for w in weights)
    best = int(np.argmax(accs))
    return GammaSweep(tuple(float(g) for g in gammas), list(weights), accs,
                      float(gammas[best]), weights[best])


def _ingest_upload(report: ClientReport, *, dim: int, gamma: float,
                   seen) -> SuffStats:
    """Shared coordinator ingest: duplicate-id and γ checks, then strip the
    lazily re-derivable γI (uploads carry the regularized C_k^r, the engine
    keeps raw Grams with lazy per-client γ)."""
    if report.client_id in seen:
        raise DuplicateClient(f"client {report.client_id} already aggregated")
    if report.gamma != gamma:
        raise GammaMismatch(f"client γ={report.gamma} != server γ={gamma}")
    # subtract γ on the diagonal only — bitwise equal to the full
    # ``gram − γ·eye`` (x − 0.0 ≡ x in IEEE, −0.0 included) at O(d) instead
    # of materializing and subtracting a d² identity per report
    raw = np.array(report.gram, np.float64, copy=True)
    if raw.shape != (dim, dim):
        raise ValueError(
            f"report gram shape {raw.shape} != ({dim}, {dim})")
    idx = np.arange(dim)
    raw[idx, idx] -= gamma
    return SuffStats(
        gram=raw,
        moment=np.asarray(report.moment, np.float64),
        count=float(report.count),
        clients=1.0,
    )


def _restore_stats(state: Dict[str, np.ndarray], gamma: float, dim: int):
    """Shared checkpoint restore: (SuffStats, seen ids) from the one state
    schema every coordinator writes (regularized aggregate → raw + k)."""
    seen = set(int(i) for i in state["seen"])
    k = len(seen)
    gram = np.array(state["gram"], np.float64) - k * gamma * np.eye(dim)
    diag = state.get("gram_diag_raw")
    if diag is not None:
        # The regularized form loses last-ulp diagonal bits to the
        # +kγ − kγ round trip; checkpoints also carry the raw diagonal
        # (d scalars — negligible next to the d² gram) so a restore is
        # bit-for-bit lossless. Off-diagonal entries are untouched by
        # regularization and were exact already.
        np.fill_diagonal(gram, np.asarray(diag, np.float64))
    stats = SuffStats(
        gram=gram,
        moment=np.array(state["moment"], np.float64),
        # older checkpoints predate the count field — restore as 0
        count=float(state.get("count", 0.0)),
        clients=float(k),
    )
    return stats, seen


def _validate_state(state: Dict[str, np.ndarray],
                    num_classes: Optional[int] = None) -> Tuple[int, int]:
    """Up-front checkpoint validation shared by every ``from_state``:
    returns ``(dim, num_classes)`` or raises the typed ``bad_request``.

    Without this, a caller-supplied ``num_classes`` that contradicts the
    checkpointed moment shape used to construct a coordinator whose solves
    crashed much later with an opaque broadcasting error."""
    try:
        gram = np.asarray(state["gram"])
        moment = np.asarray(state["moment"])
    except KeyError as exc:
        raise BadRequest(f"checkpoint missing key {exc}") from None
    if gram.ndim != 2 or gram.shape[0] != gram.shape[1]:
        raise BadRequest(f"checkpoint gram must be square, got {gram.shape}")
    if moment.ndim != 2 or moment.shape[0] != gram.shape[0]:
        raise BadRequest(
            f"checkpoint moment shape {moment.shape} does not match "
            f"gram dim {gram.shape[0]}")
    classes = int(moment.shape[1])
    if num_classes is not None and int(num_classes) != classes:
        raise BadRequest(
            f"num_classes={num_classes} contradicts the checkpoint moment "
            f"shape {tuple(moment.shape)} ({classes} classes)")
    return int(gram.shape[0]), classes


@runtime_checkable
class Coordinator(Protocol):
    """What every AFL coordinator — sync, async, sharded — satisfies.

    Methods may be coroutines (``AsyncAFLServer``); callers that must not
    care use ``await``-when-awaitable dispatch (see the conformance suite).
    ``submit`` returns the fold outcome: True when any cached factorization
    survived the arrival (rank-updated in place, or nothing was cached),
    False when the next solve will refactor. ``version`` is the submission
    epoch — it changes on every successful submit — and ``weights`` returns
    a :class:`VersionedWeights` snapshot honoring ``if_etag`` as an
    ETag-style staleness token (opaque; binds epoch + γ + instance).
    """

    dim: int
    num_classes: int
    gamma: float

    @property
    def num_clients(self) -> int: ...

    @property
    def version(self) -> int: ...

    def submit(self, report: ClientReport): ...

    def submit_many(self, reports: Iterable[ClientReport]): ...

    def solve(self, target_gamma: float = 0.0): ...

    def solve_multi_gamma(self, gammas: Sequence[float]): ...

    def sweep(self, gammas: Sequence[float], holdout): ...

    def weights(self, target_gamma: float = 0.0, *,
                if_etag: Optional[str] = None): ...

    def state(self) -> Dict[str, np.ndarray]: ...


@runtime_checkable
class Transport(Protocol):
    """What every service transport satisfies — opaque byte envelopes in,
    opaque byte envelopes out, no knowledge of what they carry. Implemented
    by :class:`~repro.fl.service.InProcTransport`,
    :class:`~repro.fl.service.HttpTransport`, and
    :class:`~repro.fl.mux.MuxTransport`; anything satisfying it plugs into
    :class:`~repro.fl.service.RemoteCoordinator` unchanged."""

    def request(self, route: str, body: bytes = b"",
                federation: str = "default") -> bytes: ...

    def close(self) -> None: ...


# ---------------------------------------------------------------------------
# Synchronous coordinator
# ---------------------------------------------------------------------------


class AFLServer:
    """Incremental AFL aggregation with RI restore at solve time.

    >>> server = AFLServer(dim=d, num_classes=c, gamma=1.0)
    >>> server.submit(report)              # any order, any time
    >>> w = server.solve()                 # exact joint weight over arrivals

    The AA law makes sufficient statistics additive ⇒ clients aggregate
    **incrementally, in any order, at any time**; after any subset S has
    reported, ``solve()`` is the exact joint solution over ∪S (Thm 1), and a
    straggler that reports later just extends the subset. ``solve()`` factors
    the regularized aggregate once per submission epoch (and per distinct
    ``target_gamma``); repeated polls between arrivals reuse the cached
    factor. A ``submit`` whose report carries a low-rank ``root`` (n_k ≤
    ``update_rank_budget``) folds the arrival into every cached factor as an
    O(n_k·d²) rank update; any other submit invalidates the cache and the
    next solve refactors.
    """

    def __init__(self, dim: int, num_classes: int, gamma: float = 1.0,
                 *, update_rank_budget: Optional[int] = None,
                 sweep_rank_budget: Optional[int] = None):
        self.dim = dim
        self.num_classes = num_classes
        self.gamma = gamma
        self.engine = AnalyticEngine("numpy_f64", gamma=gamma)
        # Rank-update crossover: past ~d/16 rows the k fused rank-1 sweeps
        # cost as much as the BLAS refactor (measured at d=2048 in
        # benchmarks/async_server_bench.py; small d always favors refactor).
        self.update_rank_budget = (
            max(1, dim // 16) if update_rank_budget is None
            else int(update_rank_budget))
        # Sweep-handle crossover: the eigendecomposition behind
        # solve_multi_gamma is ~10× a Cholesky, so the Woodbury-updated
        # handle stays worthwhile to much higher accumulated rank than the
        # d/16 factor budget — past ~d/8 pending rows the per-γ k×k extras
        # rival a fresh eigh (measured in benchmarks/solve_kernels_bench.py).
        self.sweep_rank_budget = (
            max(1, dim // 8) if sweep_rank_budget is None
            else int(sweep_rank_budget))
        self._stats = self.engine.init(dim, num_classes)
        self._seen: set[int] = set()
        self._factor_cache: Dict[float, Factorization] = {}
        self._sweep_cache: Optional[SweepFactorization] = None
        self._version = 0
        # per-instance etag salt: tokens minted against THIS coordinator can
        # never validate against a restored/rebuilt one at the same epoch
        self._etag_salt = uuid.uuid4().hex[:8]

    @property
    def num_clients(self) -> int:
        return len(self._seen)

    @property
    def version(self) -> int:
        """Submission epoch: bumps on every successful submit. The staleness
        token :meth:`weights` honors (restored checkpoints resume at k)."""
        return self._version

    def submit(self, report: ClientReport) -> bool:
        """Merge one upload; returns True when the cached factors survived
        (rank-updated in place, or nothing was cached), False when the
        arrival invalidated them and the next solve will refactor."""
        upload = _ingest_upload(report, dim=self.dim, gamma=self.gamma,
                                seen=self._seen)
        self._stats = self.engine.merge(self._stats, upload)
        self._seen.add(report.client_id)
        self._version += 1
        self._maintain_sweep_cache(report.root)
        if self._try_factor_update(report.root):
            return True
        self._factor_cache.clear()
        return False

    def _maintain_sweep_cache(self, root: Optional[np.ndarray]) -> None:
        """Fold an arrival's root into the cached eigendecomposition handle
        (Woodbury pending set), or drop the handle when the arrival has no
        root / would push past the sweep rank budget. Independent of the
        Cholesky factor cache — the two have different crossovers."""
        h = self._sweep_cache
        if h is None:
            return
        if root is None:
            self._sweep_cache = None
            return
        root = np.asarray(root, np.float64).reshape(-1, self.dim)
        if h.rank + root.shape[0] > self.sweep_rank_budget:
            self._sweep_cache = None
            return
        self._sweep_cache = h.rank_update(root)

    def _try_factor_update(self, root: Optional[np.ndarray]) -> bool:
        """Fold an arrival's low-rank root into every cached factor; False
        when the cache must be invalidated instead (no root, rank past the
        crossover, or a non-updatable pinv-fallback factor)."""
        if not self._factor_cache:
            return True                    # nothing cached — nothing to do
        if root is None:
            return False
        root = np.asarray(root, np.float64).reshape(-1, self.dim)
        if root.shape[0] > self.update_rank_budget:
            return False
        if not all(f.updatable for f in self._factor_cache.values()):
            return False
        self._factor_cache = {
            key: f.rank_update(root) for key, f in self._factor_cache.items()}
        return True

    def submit_many(self, reports: Iterable[ClientReport]) -> None:
        for r in reports:
            self.submit(r)

    # -- micro-batch fold ---------------------------------------------------

    def _validate_report(self, report: ClientReport, seen):
        """Validation half of a submit, against a caller-owned ``seen``
        overlay (so a batch can track intra-batch duplicates without
        touching coordinator state): reshapes the root, runs the ingest
        checks, touches nothing. Returns ``(upload, root)`` or raises."""
        root = report.root
        if root is not None:
            root = np.asarray(root, np.float64).reshape(-1, self.dim)
        upload = _ingest_upload(report, dim=self.dim, gamma=self.gamma,
                                seen=seen)
        return upload, root

    def _apply_validated(self, items) -> list:
        """Application half of a batched submit: ``items`` is a list of
        ``(client_id, upload, root)`` that already passed
        :meth:`_validate_report` (``root`` may be None — e.g. stripped by
        the async deferred-refactor policy). Cannot reject; returns the
        per-report fold-outcome bools. ONE stacked statistics merge and ONE
        grouped rank-(Σk) factor sweep replace the per-report passes,
        bit-for-bit equal to sequential submits."""
        self._stats = self.engine.merge_many(
            self._stats, [upload for _, upload, _ in items])
        for client_id, _, _ in items:
            self._seen.add(client_id)
        self._version += len(items)
        roots = [root for _, _, root in items]
        self._maintain_sweep_cache_batch(roots)
        return self._try_factor_update_batch(roots)

    def submit_batch(self, reports: Sequence[ClientReport]) -> list:
        """Fold a micro-batch of uploads in one pass.

        Each report validates individually — a bad one (duplicate id, γ
        mismatch, malformed arrays) rejects ALONE, recorded as the exception
        instance in its slot rather than raised, and the rest of the batch
        still folds. Returns a list aligned with ``reports``: the
        fold-outcome bool per accepted report (same meaning as
        :meth:`submit`) or the rejecting exception. State after the call is
        bit-for-bit what sequential :meth:`submit` calls (skipping the
        rejected reports) would leave — the property the conformance suite
        pins. Unlike bare :meth:`submit`, the root is validated BEFORE any
        state changes, so a malformed root cannot half-apply.
        """
        outcomes: list = [None] * len(reports)
        seen = set(self._seen)
        accepted = []
        for i, report in enumerate(reports):
            try:
                upload, root = self._validate_report(report, seen)
            except Exception as exc:           # noqa: BLE001 — per-report
                outcomes[i] = exc
                continue
            seen.add(report.client_id)
            accepted.append((i, report.client_id, upload, root))
        if accepted:
            flags = self._apply_validated(
                [(cid, upload, root) for _, cid, upload, root in accepted])
            for (i, *_), flag in zip(accepted, flags):
                outcomes[i] = flag
        return outcomes

    def _maintain_sweep_cache_batch(self, roots) -> None:
        """Batch twin of :meth:`_maintain_sweep_cache`. A cache-killing root
        anywhere in the batch drops the handle outright — sequential folds
        the prefix and then discards it, so skipping the dead projections
        reaches the identical end state with none of the work."""
        h = self._sweep_cache
        if h is None:
            return
        rank = h.rank
        for root in roots:
            if root is None:
                self._sweep_cache = None
                return
            rank += int(root.shape[0])
            if rank > self.sweep_rank_budget:
                self._sweep_cache = None
                return
        for root in roots:
            # per-root projections, in order — bitwise what sequential
            # rank_update calls produce (each projects against the same
            # fixed eigenbasis)
            h = h.rank_update(root)
        self._sweep_cache = h

    def _try_factor_update_batch(self, roots) -> list:
        """Batch twin of :meth:`_try_factor_update`: per-report survived
        flags under sequential semantics, fused execution. Updatable roots
        ahead of any cache kill fold as ONE grouped rank-(Σk) sweep per
        cached factor; a killer anywhere clears the cache with no prefix
        work (sequential's prefix updates die with the cache — same end
        state, bit for bit)."""
        flags = []
        alive = bool(self._factor_cache)
        updatable = alive and all(
            f.updatable for f in self._factor_cache.values())
        fuse = []
        killed = False
        for root in roots:
            if not alive:
                flags.append(True)         # nothing cached — nothing to do
                continue
            if (root is None or root.shape[0] > self.update_rank_budget
                    or not updatable):
                flags.append(False)
                alive = False
                killed = True
                continue
            fuse.append(root)
            flags.append(True)
        if killed:
            self._factor_cache.clear()
        elif fuse:
            self._factor_cache = {
                key: f.rank_update_many(fuse)
                for key, f in self._factor_cache.items()}
        return flags

    def solve(self, target_gamma: float = 0.0) -> np.ndarray:
        """Exact joint solution over all clients aggregated *so far*.

        RI restore (Thm 2): the engine's lazy-γ bookkeeping means the kγI of
        the k arrivals is never materialized; only ``target_gamma`` enters
        the system. Stragglers simply have not been added yet — calling
        solve() again after they report gives the exact larger-joint
        solution (and re-factors, since the statistics changed).
        """
        if not self._seen:
            raise EmptyFederation("no clients aggregated")
        key = float(target_gamma)
        fact = self._factor_cache.get(key)
        if fact is None:
            fact = self.engine.factor(self._stats, target_gamma=key)
            self._factor_cache[key] = fact
        return self.engine.factor_solve(fact, self._stats.moment)

    def solve_multi_gamma(self, gammas: Sequence[float]) -> list[np.ndarray]:
        """γ model sweep over the current aggregate from a CACHED
        eigendecomposition: the d³ eigh is paid once per cache lifetime, and
        low-rank arrivals rank-update the handle (exact Woodbury in the
        fixed eigenbasis) instead of invalidating it — repeated sweeps on an
        evolving federation cost d²·(C+k) per γ, not d³ each (see
        ``AnalyticEngine.sweep_factor``)."""
        if not self._seen:
            raise EmptyFederation("no clients aggregated")
        if self._sweep_cache is None:
            self._sweep_cache = self.engine.sweep_factor(self._stats)
        try:
            return self.engine.sweep_solve(self._sweep_cache,
                                           self._stats.moment, gammas)
        except SweepRefreshNeeded:
            # pending updates + spectral truncation: rebuild from current
            # statistics (a fresh handle always answers exactly)
            self._sweep_cache = self.engine.sweep_factor(self._stats)
            return self.engine.sweep_solve(self._sweep_cache,
                                           self._stats.moment, gammas)

    def sweep(self, gammas: Sequence[float], holdout) -> GammaSweep:
        """Server-side cross-validation: solve every candidate γ off ONE
        eigendecomposition and score each on ``holdout = (x, y)``."""
        return _sweep_from_weights(
            self.solve_multi_gamma(gammas), gammas, holdout)

    def _etag(self, target_gamma: float) -> str:
        return f"{self._etag_salt}-{self._version}-{float(target_gamma)!r}"

    def new_etag_salt(self) -> str:
        """Refresh the instance ETag salt, permanently invalidating every
        outstanding ``weights`` token. Tokens are *instance*-scoped on
        purpose: a restore, promotion, or reshard installs a coordinator
        whose state history diverges from the one that minted the token,
        so revalidating across the boundary could serve a stale head as
        fresh. New instances mint a fresh salt in ``__init__``; this is
        the hook for in-place identity changes (standby promotion, mesh
        resize)."""
        self._etag_salt = uuid.uuid4().hex[:8]
        return self._etag_salt

    def weights(self, target_gamma: float = 0.0, *,
                if_etag: Optional[str] = None) -> VersionedWeights:
        """Versioned solved-head download. ``if_etag`` equal to the current
        token for this (epoch, γ) short-circuits to a not-modified snapshot
        (``weight is None``) without solving; the token is opaque and
        γ-bound, so a head cached for one γ can never be revalidated as
        another's."""
        tag = self._etag(target_gamma)
        if if_etag is not None and str(if_etag) == tag:
            return VersionedWeights(self._version, float(target_gamma),
                                    None, tag)
        return VersionedWeights(self._version, float(target_gamma),
                                self.solve(target_gamma), tag)

    def state(self) -> Dict[str, np.ndarray]:
        """Serializable coordinator state (see repro.checkpoint). ``gram``
        is the paper-form regularized aggregate C_agg^r = ΣC_k^r, kept for
        format stability across the raw-Gram refactor."""
        return {
            "gram": self.engine.regularized_gram(self._stats).copy(),
            "moment": self._stats.moment.copy(),
            "seen": np.array(sorted(self._seen), np.int64),
            "gamma": np.float64(self.gamma),
            "count": np.float64(self._stats.count),
            # raw diagonal rider: restores undo +kγ on the diagonal, which
            # rounds — carrying the d raw entries makes restore bit-lossless
            "gram_diag_raw": np.array(np.diag(self._stats.gram), np.float64),
        }

    @classmethod
    def from_state(cls, state: Dict[str, np.ndarray],
                   num_classes: Optional[int] = None) -> "AFLServer":
        dim, classes = _validate_state(state, num_classes)
        srv = cls(dim, classes, float(state["gamma"]))
        srv._stats, srv._seen = _restore_stats(state, srv.gamma, dim)
        srv._version = len(srv._seen)
        return srv


# ---------------------------------------------------------------------------
# Sharded coordinator (the 1000-client backend)
# ---------------------------------------------------------------------------


class ShardedCoordinator:
    """AFL coordination with the Gram pytree sharded over a jax mesh.

    The statistics of a K-client federation are a 4-leaf additive pytree, so
    at K≥1000 the coordinator does not need one global host aggregate:
    arrivals land in per-shard accumulators (host f64, so ingest stays exact
    and lock-free), and ``solve()`` runs the whole aggregation stage —
    per-shard partial sums → one psum → RI restore → Cholesky — as a single
    XLA program via :func:`repro.core.distributed.make_federated_solve`,
    with each shard's (d, d) Gram tile resident on its own device.

    Placement is **load-aware** by default: ``submit`` routes each arrival
    to the emptiest shard (ties broken cyclically, so uniform traffic
    degenerates to exact round-robin), which keeps occupancy flat under
    skewed arrival patterns and makes :meth:`rebalance` a recovery tool
    rather than routine maintenance. ``placement="round_robin"`` restores
    the PR-3 behavior (placement never changes the aggregate — the AA law
    makes shard contents additive — only the occupancy profile).

    ``tiled_gram=True`` changes what a shard *holds*: instead of a whole
    (d, d) partial aggregate per shard (memory d² per device, psum of whole
    leaves), each shard keeps only its (d/shards, d) **row tile of the one
    global Gram** — every arrival's statistics are scattered across all
    tiles, so placement is the aggregation and per-shard resident memory
    scales as d²/shards. ``solve()`` then runs
    :func:`repro.core.distributed.make_tiled_federated_solve` with
    ``distributed_factor=True`` (the default here): the factorization runs
    tile-parallel on the shards where the Gram lives — panel owners
    broadcast one (d, b) L-column per panel and every shard applies
    trsm/syrk to its own rows through the streamed Pallas panel kernels —
    so no device ever materializes the full (d, d) system.
    ``distributed_factor=False`` restores the PR-5 gather-then-factor
    collective (one psum'd (d, d) transient per device). This is the
    d=6144-head configuration (a whole-leaf psum at that size keeps
    8 × 302 MB of f64 partials resident; tiles keep 38 MB per shard) —
    verified ≤1e-6 against the sync path on an 8-way mesh in
    ``benchmarks/solve_kernels_bench.py``. Dims that don't divide the shard
    count are padded up to the next tile multiple (zero pad rows, unit
    diagonal inside the solve, sliced away from the result — d=6144 on 7
    shards just works); the explicit error remains only when padding would
    exceed one extra tile (e.g. dim=10 on 8 shards). A solve that comes
    back non-finite (rank-deficient γ=0 ablations) falls back to the host
    engine's pinv path on the merged statistics.

    Device arithmetic follows jax's global precision: f32 by default,
    f64 end-to-end under ``jax_enable_x64`` (the 1e-6-vs-sync conformance
    path). ``solve_multi_gamma`` / ``sweep`` run on the merged statistics
    through the host engine — one eigendecomposition, every γ — matching
    :class:`AFLServer` exactly, and ``state()`` speaks the same checkpoint
    schema, so the coordinator kinds are interchangeable behind
    :class:`Coordinator`.
    """

    def __init__(self, dim: int, num_classes: int, gamma: float = 1.0,
                 *, mesh=None, axis_names: Optional[Sequence[str]] = None,
                 placement: str = "load_aware", tiled_gram: bool = False,
                 distributed_factor: bool = True,
                 num_shards: Optional[int] = None):
        import jax

        self.dim = dim
        self.num_classes = num_classes
        self.gamma = gamma
        self.engine = AnalyticEngine("numpy_f64", gamma=gamma)
        self.tiled_gram = bool(tiled_gram)
        if num_shards is not None and int(num_shards) < 1:
            raise BadRequest(f"num_shards must be ≥1, got {num_shards}")
        if mesh is None:
            if num_shards is None:
                mesh = jax.make_mesh((len(jax.devices()),), ("data",))
            else:
                # tiled mode keeps one row tile per device, so the mesh IS
                # the shard count; non-tiled shards are host accumulators —
                # logical, grouped onto however many devices exist
                mesh = self._make_mesh(
                    int(num_shards), axis_names or ("data",))
        self.mesh = mesh
        self.axis_names = tuple(axis_names) if axis_names is not None \
            else tuple(mesh.axis_names)
        n_shards = (self._mesh_size() if num_shards is None
                    else int(num_shards))
        if self.tiled_gram and n_shards != self._mesh_size():
            raise BadRequest(
                f"tiled_gram keeps one row tile per mesh device: "
                f"num_shards={n_shards} != mesh size {self._mesh_size()}")
        if not self.tiled_gram and n_shards < self._mesh_size():
            raise BadRequest(
                f"num_shards={n_shards} < mesh size {self._mesh_size()} — "
                "logical shards group onto devices, never the reverse")
        if placement not in ("load_aware", "round_robin"):
            raise ValueError(f"unknown placement policy {placement!r} "
                             "(load_aware | round_robin)")
        self.placement = placement
        self.distributed_factor = bool(distributed_factor)
        if self.tiled_gram:
            self._init_tiles(n_shards)
            self._shards: List[SuffStats] = []
        else:
            self._shards = [
                self.engine.init(dim, num_classes) for _ in range(n_shards)]
        self._seen: set[int] = set()
        self._order = 0
        self._solve_fns: Dict[float, Any] = {}
        self._version = 0
        self._mesh_epoch = 0
        self._resizing = False
        self._etag_salt = uuid.uuid4().hex[:8]
        self._last_rebalance: Optional[Tuple[int, int]] = None

    # -- elastic-mesh plumbing ----------------------------------------------

    def _make_mesh(self, n_shards: int, axis_names: Sequence[str]):
        """A mesh backing ``n_shards``: exactly that many devices in tiled
        mode, else as many as exist (logical shards group onto them)."""
        import jax

        from repro.core.distributed import federation_mesh

        n_dev = (n_shards if self.tiled_gram
                 else min(n_shards, len(jax.devices())))
        try:
            return federation_mesh(n_dev, axis_names)
        except ValueError as exc:
            raise BadRequest(str(exc)) from None

    def _mesh_size(self) -> int:
        n = 1
        for a in self.axis_names:
            n *= self.mesh.shape[a]
        return n

    @staticmethod
    def _plan_tile_rows(dim: int, n_shards: int) -> int:
        """Rows per tile for ``dim`` over ``n_shards`` — indivisible dims
        pad up to the next tile multiple; prefer 8-row-aligned tiles
        (Pallas panel widths divide the tile) when alignment keeps the pad
        under one tile."""
        rows = -(-dim // n_shards)
        if rows >= 16:
            r8 = ((rows + 7) // 8) * 8
            if n_shards * r8 - dim < r8:
                rows = r8
        if n_shards * rows - dim >= rows:
            raise BadRequest(
                f"tiled_gram would pad dim={dim} by a full tile on "
                f"{n_shards} shards (tile_rows={rows}) — use fewer "
                f"shards or a wider head")
        return rows

    def _init_tiles(self, n_shards: int) -> None:
        rows = self._plan_tile_rows(self.dim, n_shards)
        self._tile_rows = rows
        self._dim_padded = n_shards * rows
        self._gram_tiles: List[np.ndarray] = [
            np.zeros((rows, self._dim_padded)) for _ in range(n_shards)]
        self._moment_tiles: List[np.ndarray] = [
            np.zeros((rows, self.num_classes)) for _ in range(n_shards)]
        self._count = 0.0

    def _scatter_tiles(self, gram: np.ndarray, moment: np.ndarray) -> None:
        """Place true-dim aggregate rows into the per-shard row tiles
        (pad rows stay zero) — the tiled restore/retile primitive."""
        r = self._tile_rows
        for i in range(self.num_shards):
            lo, hi = i * r, min(i * r + r, self.dim)
            if hi > lo:
                self._gram_tiles[i][:hi - lo, :self.dim] = gram[lo:hi]
                self._moment_tiles[i][:hi - lo] = moment[lo:hi]

    def _check_resizing(self) -> None:
        if self._resizing:
            raise Backpressure(
                f"mesh resize in flight (epoch {self._mesh_epoch} → "
                f"{self._mesh_epoch + 1}) — back off and retry")

    @property
    def mesh_epoch(self) -> int:
        """Bumps on every completed :meth:`grow`/:meth:`shrink`. In-flight
        requests that race a resize get a retryable backpressure error, so
        an epoch observed around a call brackets which mesh answered it."""
        return self._mesh_epoch

    @property
    def num_shards(self) -> int:
        return (len(self._gram_tiles) if self.tiled_gram
                else len(self._shards))

    @property
    def num_clients(self) -> int:
        return len(self._seen)

    @property
    def version(self) -> int:
        """Submission epoch (see :meth:`AFLServer.version`)."""
        return self._version

    def _place(self) -> int:
        """Pick the shard for the next arrival: emptiest under the default
        load-aware policy (cyclic tie-break from the round-robin cursor, so
        equal occupancy IS round-robin), or the plain cursor."""
        n = self.num_shards
        if self.placement == "round_robin":
            i = self._order % n
            self._order += 1
            return i
        occ = self.occupancy()
        low = min(occ)
        for off in range(n):
            j = (self._order + off) % n
            if occ[j] == low:
                self._order = j + 1
                return j
        raise AssertionError("unreachable: some shard holds the minimum")

    def submit(self, report: ClientReport) -> bool:
        """Merge one upload — into the emptiest shard (load-aware default),
        or scattered as row tiles across every shard in tiled-Gram mode.
        Returns True — the sharded backend keeps no host factor cache to
        invalidate (the device program refactors per solve), so every
        arrival 'survives'."""
        self._check_resizing()
        upload = _ingest_upload(report, dim=self.dim, gamma=self.gamma,
                                seen=self._seen)
        if self.tiled_gram:
            gram = np.asarray(upload.gram, np.float64)
            moment = np.asarray(upload.moment, np.float64)
            r = self._tile_rows
            for i in range(self.num_shards):
                lo, hi = i * r, min(i * r + r, self.dim)
                if hi > lo:
                    self._gram_tiles[i][:hi - lo, :self.dim] += gram[lo:hi]
                    self._moment_tiles[i][:hi - lo] += moment[lo:hi]
            self._count += float(upload.count)
        else:
            i = self._place()
            self._shards[i] = self.engine.merge(self._shards[i], upload)
        self._seen.add(report.client_id)
        self._version += 1
        return True

    def submit_many(self, reports: Iterable[ClientReport]) -> None:
        for r in reports:
            self.submit(r)

    def occupancy(self) -> List[int]:
        """Per-shard residency: clients per shard (the signal load-aware
        placement and :meth:`rebalance` act on), or — in tiled-Gram mode,
        where every client's statistics span all shards — the per-shard
        resident Gram rows (always balanced by construction)."""
        if self.tiled_gram:
            return [self._tile_rows] * self.num_shards
        return [int(s.clients) for s in self._shards]

    def rebalance(self) -> Optional[Tuple[int, int]]:
        """Migrate the fullest shard's statistics into the emptiest.

        The AA law makes shard contents additive, so migration is a merge:
        the aggregate — and therefore every solve — is invariant under it.
        This is the primitive mid-federation mesh growth / load-aware
        placement builds on: a new empty shard can absorb the hottest
        shard's load in O(d²) host work, no device traffic. The freed shard
        becomes the next round-robin target, so future arrivals fill the
        vacated slot first.

        Returns ``(src, dst)`` shard indices, or ``None`` when there is
        nothing to move: fewer than 2 shards, the fullest holds at most one
        more client than the emptiest, tiled-Gram mode (tiles are balanced
        by construction), or the candidate move would just undo this
        epoch's previous migration (without this guard,
        ``while coord.rebalance(): ...`` would ping-pong the same blob
        between two shards forever — at most one migration is performed per
        submission epoch).
        """
        self._check_resizing()
        if self.tiled_gram:
            return None
        occ = self.occupancy()
        if len(occ) < 2:
            return None
        src = int(np.argmax(occ))
        dst = int(np.argmin(occ))
        if occ[src] - occ[dst] <= 1:
            return None
        if self._last_rebalance == (self._version, src):
            return None                    # would re-move this epoch's blob
        self._shards[dst] = self.engine.merge(self._shards[dst],
                                              self._shards[src])
        self._shards[src] = self.engine.init(self.dim, self.num_classes)
        self._order = src                  # fill the vacated shard next
        self._last_rebalance = (self._version, dst)
        return src, dst

    def grow(self, n: int = 1) -> int:
        """Admit ``n`` fresh empty shards mid-federation.

        Exact by the AA law: the aggregate is a sum over shards and the new
        shards join empty, so every solve is invariant. Load-aware placement
        then fills the admitted shards first. In tiled-Gram mode the global
        Gram is re-tiled to the new row plan (one tile per device, so growth
        needs that many devices). Returns the new :attr:`mesh_epoch`.
        """
        if int(n) < 1:
            raise BadRequest(f"grow() admits ≥1 shard, got {n}")
        return self._resize(self.num_shards + int(n))

    def shrink(self, n: int = 1) -> int:
        """Retire the ``n`` highest-numbered shards, folding their
        statistics into the survivors (shard ``i`` → ``i % remaining`` —
        merge = migration, so solves are invariant). At least one shard must
        survive. Returns the new :attr:`mesh_epoch`."""
        if int(n) < 1:
            raise BadRequest(f"shrink() retires ≥1 shard, got {n}")
        if int(n) >= self.num_shards:
            raise BadRequest(
                f"cannot retire {n} of {self.num_shards} shards — at least "
                "one must survive")
        return self._resize(self.num_shards - int(n))

    def _resize(self, new_count: int) -> int:
        """Re-shard to ``new_count`` under the epoch guard: validate the new
        mesh and tile plan FIRST (a rejected resize must leave the
        coordinator untouched), then migrate, then bump the epoch. Requests
        racing the migration window get retryable :class:`Backpressure`."""
        new_count = int(new_count)
        if new_count == self.num_shards:
            return self._mesh_epoch
        if self.tiled_gram:
            rows = self._plan_tile_rows(self.dim, new_count)
        new_mesh = self._make_mesh(new_count, self.axis_names)
        self._resizing = True
        try:
            if self.tiled_gram:
                agg = self._merged()       # true-dim rows, old tile plan
                self._tile_rows = rows
                self._dim_padded = new_count * rows
                self._gram_tiles = [
                    np.zeros((rows, self._dim_padded))
                    for _ in range(new_count)]
                self._moment_tiles = [
                    np.zeros((rows, self.num_classes))
                    for _ in range(new_count)]
                self._scatter_tiles(np.asarray(agg.gram, np.float64),
                                    np.asarray(agg.moment, np.float64))
            elif new_count > self.num_shards:
                self._shards = self._shards + [
                    self.engine.init(self.dim, self.num_classes)
                    for _ in range(new_count - self.num_shards)]
            else:
                kept = list(self._shards[:new_count])
                for i in range(new_count, self.num_shards):
                    j = i % new_count
                    kept[j] = self.engine.merge(kept[j], self._shards[i])
                self._shards = kept
            self.mesh = new_mesh
            self._solve_fns.clear()        # compiled for the old mesh
            self._last_rebalance = None
            self.new_etag_salt()           # old-epoch tokens must die here
            self._mesh_epoch += 1
        finally:
            self._resizing = False
        return self._mesh_epoch

    def _merged(self) -> SuffStats:
        if self.tiled_gram:
            # the tiles ARE the aggregate, partitioned by (padded) rows
            d = self.dim
            return SuffStats(
                gram=np.concatenate(self._gram_tiles, 0)[:d, :d],
                moment=np.concatenate(self._moment_tiles, 0)[:d],
                count=float(self._count),
                clients=float(len(self._seen)),
            )
        agg = self._shards[0]
        for s in self._shards[1:]:
            agg = self.engine.merge(agg, s)
        return agg

    def _stacked(self):
        """Per-shard statistics stacked on a leading federation dim, as the
        3-leaf :class:`~repro.core.streaming.AnalyticState` the collective
        consumes (clients bookkeeping is irrelevant under RI).

        Logical shards may outnumber mesh devices (non-tiled shards are host
        accumulators); device ``g`` then carries the host-f64 merge of
        logical shards ``g, g+m, g+2m, …`` — additive, so the psummed
        aggregate is unchanged."""
        import jax.numpy as jnp

        from repro.core.streaming import AnalyticState

        m = self._mesh_size()
        if m == self.num_shards:
            groups: List[SuffStats] = self._shards
        else:
            groups = []
            for g in range(m):
                agg = self._shards[g]
                for s in self._shards[g + m::m]:
                    agg = self.engine.merge(agg, s)
                groups.append(agg)
        return AnalyticState(
            gram=jnp.asarray(np.stack([s.gram for s in groups])),
            moment=jnp.asarray(np.stack([s.moment for s in groups])),
            count=jnp.asarray(np.stack(
                [np.float64(s.count) for s in groups])),
        )

    def solve(self, target_gamma: float = 0.0) -> np.ndarray:
        """One collective: psum the sharded statistics (whole leaves, or
        row tiles placed into the global system in tiled-Gram mode),
        RI-restore, solve."""
        from repro.core.distributed import (make_federated_solve,
                                            make_tiled_federated_solve)

        import jax.numpy as jnp

        self._check_resizing()
        if not self._seen:
            raise EmptyFederation("no clients aggregated")
        key = float(target_gamma)
        fn = self._solve_fns.get(key)
        if fn is None:
            if self.tiled_gram:
                fn = make_tiled_federated_solve(
                    self.mesh, axis_names=self.axis_names, target_gamma=key,
                    distributed_factor=self.distributed_factor,
                    dim=self.dim)
            else:
                fn = make_federated_solve(
                    self.mesh, axis_names=self.axis_names, gamma=self.gamma,
                    target_gamma=key)
            self._solve_fns[key] = fn
        if self.tiled_gram:
            w = np.asarray(
                fn(jnp.asarray(np.stack(self._gram_tiles)),
                   jnp.asarray(np.stack(self._moment_tiles))), np.float64)
        else:
            w = np.asarray(fn(self._stacked()), np.float64)
        if not np.isfinite(w).all():
            # singular system (rank-deficient γ=0 ablation): the device
            # Cholesky surfaces NaNs by design — reroute to the host
            # engine's pinv fallback on the merged statistics
            return np.asarray(
                self.engine.solve(self._merged(), use_ri=True,
                                  target_gamma=key), np.float64)
        return w

    def solve_multi_gamma(self, gammas: Sequence[float]) -> list[np.ndarray]:
        """γ model sweep on the merged statistics (host engine, one eigh) —
        identical math to :meth:`AFLServer.solve_multi_gamma`."""
        self._check_resizing()
        if not self._seen:
            raise EmptyFederation("no clients aggregated")
        return self.engine.solve_multi_gamma(self._merged(), gammas)

    def sweep(self, gammas: Sequence[float], holdout) -> GammaSweep:
        return _sweep_from_weights(
            self.solve_multi_gamma(gammas), gammas, holdout)

    def _etag(self, target_gamma: float) -> str:
        return f"{self._etag_salt}-{self._version}-{float(target_gamma)!r}"

    def new_etag_salt(self) -> str:
        """Refresh the instance ETag salt (see
        :meth:`AFLServer.new_etag_salt`) — called by :meth:`_resize`, so a
        token minted against one mesh epoch can never revalidate against
        another."""
        self._etag_salt = uuid.uuid4().hex[:8]
        return self._etag_salt

    def weights(self, target_gamma: float = 0.0, *,
                if_etag: Optional[str] = None) -> VersionedWeights:
        """Versioned solved-head download (see :meth:`AFLServer.weights`)."""
        tag = self._etag(target_gamma)
        if if_etag is not None and str(if_etag) == tag:
            return VersionedWeights(self._version, float(target_gamma),
                                    None, tag)
        return VersionedWeights(self._version, float(target_gamma),
                                self.solve(target_gamma), tag)

    def state(self) -> Dict[str, np.ndarray]:
        """Same checkpoint schema as :meth:`AFLServer.state` — coordinator
        kinds are interchangeable across a save/restore boundary — plus
        ``shard_clients``, the per-shard occupancy (extra keys are ignored
        by every ``from_state``, so interchange still holds)."""
        self._check_resizing()
        agg = self._merged()
        return {
            "gram": self.engine.regularized_gram(agg).copy(),
            "moment": agg.moment.copy(),
            "gram_diag_raw": np.array(np.diag(agg.gram), np.float64),
            "seen": np.array(sorted(self._seen), np.int64),
            "gamma": np.float64(self.gamma),
            "count": np.float64(agg.count),
            "shard_clients": np.array(self.occupancy(), np.int64),
        }

    def _restore_split(self, stats: SuffStats,
                       shard_clients=None) -> None:
        """Split a restored aggregate across the shards as disjoint row
        blocks: shard ``i`` holds rows ``[i·r, (i+1)·r)`` of the aggregate
        Gram/moment and zeros elsewhere, so the shard sum reproduces the
        aggregate *bitwise* (0 + x = x) on any shard count. The sample
        count rides whole on shard 0 for the same reason.

        Occupancy is reconstructed from the checkpointed ``shard_clients``
        folded onto this shard count (old shard ``i`` → ``i % n``). Tiled
        checkpoints record resident rows there, not clients — when the
        folded counts don't account for every seen client, fall back to an
        even client split."""
        n = self.num_shards
        dim = self.dim
        gram = np.asarray(stats.gram, np.float64)
        moment = np.asarray(stats.moment, np.float64)
        clients = None
        if shard_clients is not None:
            folded = [0] * n
            for i, c in enumerate(np.asarray(shard_clients, np.int64)):
                folded[i % n] += int(c)
            if sum(folded) == int(stats.clients):
                clients = folded
        if clients is None:
            k, rem = divmod(int(stats.clients), n)
            clients = [k + (1 if i < rem else 0) for i in range(n)]
        r = -(-dim // n)
        shards = []
        for i in range(n):
            g = np.zeros((dim, dim))
            m = np.zeros((dim, moment.shape[1]))
            lo, hi = i * r, min(i * r + r, dim)
            if hi > lo:
                g[lo:hi] = gram[lo:hi]
                m[lo:hi] = moment[lo:hi]
            shards.append(SuffStats(
                gram=g, moment=m,
                count=float(stats.count) if i == 0 else 0.0,
                clients=float(clients[i])))
        self._shards = shards

    @classmethod
    def from_state(cls, state: Dict[str, np.ndarray],
                   num_classes: Optional[int] = None, *,
                   mesh=None, axis_names: Optional[Sequence[str]] = None,
                   placement: str = "load_aware", tiled_gram: bool = False,
                   distributed_factor: bool = True,
                   num_shards: Optional[int] = None,
                   ) -> "ShardedCoordinator":
        """Cold-start from any coordinator kind's checkpoint, on ANY shard
        count — resharding is exact because the statistics are additive
        (merge = migration). ``num_shards`` defaults to one per device."""
        dim, classes = _validate_state(state, num_classes)
        coord = cls(dim, classes, float(state["gamma"]), mesh=mesh,
                    axis_names=axis_names, placement=placement,
                    tiled_gram=tiled_gram,
                    distributed_factor=distributed_factor,
                    num_shards=num_shards)
        stats, seen = _restore_stats(state, coord.gamma, dim)
        coord._seen = seen
        if tiled_gram:
            coord._scatter_tiles(np.asarray(stats.gram, np.float64),
                                 np.asarray(stats.moment, np.float64))
            coord._count = float(stats.count)
        else:
            coord._restore_split(stats, state.get("shard_clients"))
        coord._order = len(coord._seen)
        coord._version = len(coord._seen)
        return coord
