"""Event-loop AFL serving: submissions stream in, solves never wait.

The AA law makes AFL aggregation a *sum* of sufficient statistics, so there
is no round structure to synchronize on: the server can accept a client
upload at any moment and every ``solve()`` is the exact joint solution of
whatever has arrived so far. :class:`AsyncAFLServer` turns that property
into a serving loop conforming to the :class:`repro.fl.api.Coordinator`
protocol (same methods, same return values, awaited):

  * ``submit()`` hands a :class:`~repro.fl.api.ClientReport` to a single
    worker task that drains arrivals in order, and resolves to the same
    fold-outcome bool the synchronous server returns (True: cached factors
    survived; False: the next solve refactors). ``enqueue()`` is the
    fire-and-forget variant for producers that must not block on apply.
  * Each arrival is folded into the live cached Cholesky factors as a
    **rank-n_k update** (``AFLServer.submit`` → ``engine.factor_update``,
    O(n_k·d²)) instead of invalidating them — the d³ refactorization
    disappears from the arrival hot path.
  * ``solve()`` / ``solve_multi_gamma()`` / ``sweep()`` serve concurrently
    from the live factor: they reflect every arrival *applied* so far and
    never block on submissions still queued (``join()`` waits for the queue
    to drain when a caller wants the everyone-included answer).
  * ``state()`` / ``from_state()`` round-trip the same checkpoint schema as
    the synchronous server, so an event-loop deployment checkpoints and
    restarts like any other coordinator.

Deferred-refactor policy
------------------------
Rank updates are exact in exact arithmetic but each sweep rounds; after many
updates the cached factor drifts from chol(Σ XᵀX + γI), and past a rank
crossover (≈ d/16 rows per arrival at d=2048, measured in
``benchmarks/async_server_bench.py``) updating costs more than refactoring.
The worker therefore tracks, per submission epoch:

  * ``applied_rank`` — total update rows folded into the live factors, and
  * an error proxy ``ε·√d·applied_rank`` for the worst-case relative drift
    of the factor (each rank-1 sweep is one pass of d Householder
    rotations, backward-stable to O(ε) each).

When an arrival has no usable root (masked upload, batch past the rank
budget) or would push either counter over its threshold
(``refactor_rank``, default d/2; ``error_budget``, default 1e-8), the
worker *invalidates* instead of updating and resets the counters. The
refactor itself is deferred to the next ``solve()`` — so a burst of
cache-killing arrivals is batched into ONE d³ factorization rather than one
per arrival, and pure-submission periods never pay d³ at all.
"""

from __future__ import annotations

import asyncio
import dataclasses
from typing import Dict, Iterable, Optional, Sequence

import numpy as np

from repro.fl.api import (AFLServer, ClientReport, GammaSweep,
                          VersionedWeights, _sweep_from_weights)
from repro.fl.errors import Backpressure

__all__ = ["AsyncAFLServer"]


class AsyncAFLServer:
    """Asyncio front-end over :class:`AFLServer` with incremental factors.

    >>> async with AsyncAFLServer(dim=d, num_classes=c, gamma=1.0) as srv:
    ...     folded = await srv.submit(report)  # fold outcome, like sync
    ...     w_now = await srv.solve()      # exact for everything applied
    ...     await srv.join()               # drain stragglers
    ...     w_all = await srv.solve()

    Statistics are always merged exactly on arrival; the policy only decides
    whether the cached *factorization* is updated in place or lazily
    rebuilt. ``updates`` / ``deferred_refactors`` count the two paths.
    """

    def __init__(
        self,
        dim: int,
        num_classes: int,
        gamma: float = 1.0,
        *,
        update_rank_budget: Optional[int] = None,
        refactor_rank: Optional[int] = None,
        error_budget: float = 1e-8,
        max_pending: Optional[int] = None,
        server: Optional[AFLServer] = None,
    ):
        # ``server`` adopts an existing aggregate (e.g. restored from a
        # checkpoint) instead of starting empty
        if server is not None:
            if (server.dim, server.num_classes,
                    server.gamma) != (dim, num_classes, gamma):
                raise ValueError("adopted server disagrees with (dim, C, γ)")
            if update_rank_budget is not None:
                server.update_rank_budget = int(update_rank_budget)
            self._server = server
        else:
            self._server = AFLServer(dim, num_classes, gamma,
                                     update_rank_budget=update_rank_budget)
        self.refactor_rank = max(1, dim // 2) if refactor_rank is None \
            else int(refactor_rank)
        self.error_budget = float(error_budget)
        # ingest high-watermark: with max_pending set, enqueue() refuses new
        # fire-and-forget uploads once the queue holds that many unapplied
        # reports (the backpressure signal transports surface as HTTP 429).
        # submit() is unaffected — an awaiting producer IS the backpressure.
        self.max_pending = None if max_pending is None else int(max_pending)
        self._queue: asyncio.Queue = asyncio.Queue()
        self._lock = asyncio.Lock()
        self._worker: Optional[asyncio.Task] = None
        self._applied_rank = 0
        # observability: arrivals folded as rank updates vs cache kills,
        # plus uploads the wrapped server refused (duplicate id, γ mismatch)
        self.updates = 0
        self.deferred_refactors = 0
        self.rejected: list = []

    # -- protocol surface (delegated) ---------------------------------------

    @property
    def dim(self) -> int:
        return self._server.dim

    @property
    def num_classes(self) -> int:
        return self._server.num_classes

    @property
    def gamma(self) -> float:
        return self._server.gamma

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> "AsyncAFLServer":
        if self._worker is None:
            self._worker = asyncio.create_task(self._run())
        return self

    async def close(self) -> None:
        if self._worker is not None:
            await self._queue.join()
            self._worker.cancel()
            try:
                await self._worker
            except asyncio.CancelledError:
                pass
            self._worker = None

    async def __aenter__(self) -> "AsyncAFLServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.close()

    # -- submission side ----------------------------------------------------

    async def submit(self, report: ClientReport) -> bool:
        """Submit one upload and await its application, resolving to the
        same fold-outcome bool :meth:`AFLServer.submit` returns. A rejected
        upload (duplicate id, γ mismatch, malformed report) raises here —
        exactly like the sync server — without killing the worker."""
        fut: asyncio.Future = asyncio.get_running_loop().create_future()
        await self._queue.put((report, fut))
        return await fut

    async def enqueue(self, report: ClientReport) -> None:
        """Fire-and-forget: enqueue an upload and return immediately; the
        worker applies it in arrival order. Rejections land in
        ``self.rejected`` instead of raising to the producer. With
        ``max_pending`` configured, a full queue raises
        :class:`~repro.fl.errors.Backpressure` — the report is NOT queued
        and coordinator state is untouched; back off and resubmit."""
        if self.max_pending is not None \
                and self._queue.qsize() >= self.max_pending:
            raise Backpressure(
                f"ingest queue at high-watermark ({self._queue.qsize()} "
                f"pending ≥ max_pending={self.max_pending})")
        await self._queue.put((report, None))

    async def submit_many(self, reports: Iterable[ClientReport]) -> None:
        """Bulk submit with sync semantics: applied in order, stopping at
        the first rejection (later reports are NOT aggregated) — so post-
        exception state matches :meth:`AFLServer.submit_many` exactly. Use
        :meth:`enqueue` per report for fire-and-forget pipelining."""
        for r in reports:
            await self.submit(r)

    async def join(self) -> None:
        """Wait until every enqueued submission has been applied."""
        await self._queue.join()

    async def _run(self) -> None:
        while True:
            report, fut = await self._queue.get()
            try:
                async with self._lock:
                    outcome = self._apply(report)
                if fut is not None and not fut.cancelled():
                    fut.set_result(outcome)
            except Exception as e:
                # a bad upload (duplicate id, γ mismatch, malformed arrays)
                # must not kill the serving loop
                self.rejected.append((getattr(report, "client_id", None),
                                      str(e)))
                if fut is not None and not fut.cancelled():
                    fut.set_exception(e)
            finally:
                self._queue.task_done()

    def _apply(self, report: ClientReport) -> bool:
        srv = self._server
        rank = (0 if report.root is None
                else int(np.asarray(report.root).reshape(-1, srv.dim).shape[0]))
        # rank 0 (an empty client's root) folds trivially — same outcome as
        # the sync server, no reason to kill the cache
        usable = report.root is not None and rank <= srv.update_rank_budget
        over = (self._applied_rank + rank > self.refactor_rank
                or self._error_proxy(self._applied_rank + rank)
                > self.error_budget)
        had_factor = bool(srv._factor_cache)
        if usable and not over:
            survived = srv.submit(report)
        else:
            # policy says refactor: strip the root so the cache dies and the
            # NEXT solve pays the d³ once for this and any further
            # cache-killing arrivals in the burst
            survived = srv.submit(dataclasses.replace(report, root=None))
        if not had_factor:
            return survived                 # no live factor — nothing to track
        if survived:
            self._applied_rank += rank
            self.updates += 1 if rank else 0
        else:
            # fold refused (policy, or a non-updatable pinv-fallback factor)
            self._applied_rank = 0
            self.deferred_refactors += 1
        return survived

    def _error_proxy(self, applied_rank: int) -> float:
        """Worst-case relative drift of a factor after ``applied_rank``
        rank-1 sweeps: each sweep is d Householder rotations, each backward
        stable to O(ε) — proxy ε·√d per sweep, summed."""
        eps = float(np.finfo(np.float64).eps)
        return eps * np.sqrt(self._server.dim) * applied_rank

    # -- serving side -------------------------------------------------------

    async def solve(self, target_gamma: float = 0.0) -> np.ndarray:
        """Joint solution over every *applied* arrival, from the live factor
        (rank-updated in place, or refactored here if a deferral is due)."""
        async with self._lock:
            return self._server.solve(target_gamma)

    async def solve_multi_gamma(self, gammas: Sequence[float]) -> list:
        """γ sweep over everything applied, served from the wrapped
        server's rank-updated eigendecomposition handle: low-rank arrivals
        fold into the cached eigenbasis (Woodbury) instead of forcing a d³
        re-factorization per sweep — the event-loop twin of the factor-cache
        rank updates on the single-solve path."""
        async with self._lock:
            return self._server.solve_multi_gamma(gammas)

    async def sweep(self, gammas: Sequence[float], holdout) -> GammaSweep:
        """Server-side γ cross-validation off the cached (rank-updated)
        eigendecomposition — see :meth:`solve_multi_gamma`."""
        async with self._lock:
            weights = self._server.solve_multi_gamma(gammas)
        return _sweep_from_weights(weights, gammas, holdout)

    async def weights(self, target_gamma: float = 0.0, *,
                      if_etag: Optional[str] = None) -> VersionedWeights:
        """Versioned solved-head download over everything *applied* so far
        (see :meth:`repro.fl.api.AFLServer.weights`)."""
        async with self._lock:
            return self._server.weights(target_gamma, if_etag=if_etag)

    # -- checkpointing ------------------------------------------------------

    async def state(self) -> Dict[str, np.ndarray]:
        """Serializable state of everything *applied* so far (same schema as
        :meth:`AFLServer.state`; ``await join()`` first to include queued
        arrivals)."""
        async with self._lock:
            return self._server.state()

    async def checkpoint(self) -> Dict[str, np.ndarray]:
        """Drain-then-state: wait for every queued arrival to apply, then
        snapshot — the consistent cut a failover daemon wants (a plain
        :meth:`state` can miss reports still sitting in the ingest queue)."""
        await self.join()
        return await self.state()

    @classmethod
    def from_state(cls, state: Dict[str, np.ndarray],
                   num_classes: Optional[int] = None,
                   **kwargs) -> "AsyncAFLServer":
        """Rebuild an (unstarted) async coordinator from a checkpoint; use
        ``async with`` / ``await start()`` to bring the worker up."""
        server = AFLServer.from_state(state, num_classes)
        return cls(server.dim, server.num_classes, server.gamma,
                   server=server, **kwargs)

    # -- introspection ------------------------------------------------------

    @property
    def num_clients(self) -> int:
        """Clients applied so far (excludes queued-but-unapplied)."""
        return self._server.num_clients

    @property
    def version(self) -> int:
        """Submission epoch of everything *applied* so far."""
        return self._server.version

    @property
    def pending(self) -> int:
        return self._queue.qsize()

    @property
    def server(self) -> AFLServer:
        """The wrapped synchronous server (shared statistics, same cache)."""
        return self._server

    def new_etag_salt(self) -> str:
        """Mint a fresh ETag salt (see :meth:`AFLServer.new_etag_salt`) —
        tokens are minted by the wrapped server, so the salt lives there.
        Synchronous: an identity change (promotion) happens outside the
        serving loop."""
        return self._server.new_etag_salt()
