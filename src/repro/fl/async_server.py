"""Event-loop AFL serving: submissions stream in, solves never wait.

The AA law makes AFL aggregation a *sum* of sufficient statistics, so there
is no round structure to synchronize on: the server can accept a client
upload at any moment and every ``solve()`` is the exact joint solution of
whatever has arrived so far. :class:`AsyncAFLServer` turns that property
into a serving loop conforming to the :class:`repro.fl.api.Coordinator`
protocol (same methods, same return values, awaited):

  * ``submit()`` hands a :class:`~repro.fl.api.ClientReport` to a single
    worker task that drains arrivals in order, and resolves to the same
    fold-outcome bool the synchronous server returns (True: cached factors
    survived; False: the next solve refactors). ``enqueue()`` /
    ``enqueue_many()`` are the fire-and-forget variants for producers that
    must not block on apply.
  * The worker folds arrivals as **micro-batches**: each wakeup drains the
    whole pending queue (up to ``batch_max``), validates every report
    individually (a bad one rejects alone, exactly as if submitted
    sequentially), then applies the batch in ONE pass — one stacked
    SuffStats merge and one grouped rank-(Σk) Cholesky sweep over the
    concatenated roots (``AFLServer.submit_batch`` machinery) instead of B
    separate O(d²) merges and column sweeps. The fold is bit-for-bit the
    sequential result at f64; outcomes fan back to the per-report futures.
  * Usable low-rank arrivals therefore still fold into the live cached
    factors as **rank updates** (O(Σn_k·d²) per batch) instead of
    invalidating them — the d³ refactorization stays off the arrival hot
    path, now with the per-arrival wakeup/lock/merge overhead amortized
    across the batch.
  * ``solve()`` / ``solve_multi_gamma()`` / ``sweep()`` serve concurrently
    from the live factor: they reflect every arrival *applied* so far and
    never block on submissions still queued (``join()`` waits for the queue
    to drain when a caller wants the everyone-included answer).
  * ``state()`` / ``from_state()`` round-trip the same checkpoint schema as
    the synchronous server, so an event-loop deployment checkpoints and
    restarts like any other coordinator.

Deferred-refactor policy
------------------------
Rank updates are exact in exact arithmetic but each sweep rounds; after many
updates the cached factor drifts from chol(Σ XᵀX + γI), and past a rank
crossover (≈ d/16 rows per arrival at d=2048, measured in
``benchmarks/async_server_bench.py``) updating costs more than refactoring.
The worker therefore tracks, per submission epoch:

  * ``applied_rank`` — total update rows folded into the live factors, and
  * an error proxy ``ε·√d·applied_rank`` for the worst-case relative drift
    of the factor (each rank-1 sweep is one pass of d Householder
    rotations, backward-stable to O(ε) each).

When an arrival has no usable root (masked upload, batch past the rank
budget) or would push either counter over its threshold
(``refactor_rank``, default d/2; ``error_budget``, default 1e-8), the
worker *invalidates* instead of updating and resets the counters. The
refactor itself is deferred to the next ``solve()`` — so a burst of
cache-killing arrivals is batched into ONE d³ factorization rather than one
per arrival, and pure-submission periods never pay d³ at all.
"""

from __future__ import annotations

import asyncio
import collections
from typing import Dict, Iterable, Optional, Sequence

import numpy as np

from repro.fl.api import (AFLServer, ClientReport, GammaSweep,
                          VersionedWeights, _sweep_from_weights)
from repro.fl.errors import Backpressure

__all__ = ["AsyncAFLServer", "SubmitAborted"]


class SubmitAborted(RuntimeError):
    """A report in a :meth:`AsyncAFLServer.submit_many` pipeline was skipped
    because an earlier report in the same call was rejected — sync
    stop-at-first-rejection semantics: the skipped report was NOT
    aggregated."""


class _SubmitGroup:
    """Shared abort token for one pipelined ``submit_many`` call."""

    __slots__ = ("failed",)

    def __init__(self):
        self.failed = False


class AsyncAFLServer:
    """Asyncio front-end over :class:`AFLServer` with incremental factors.

    >>> async with AsyncAFLServer(dim=d, num_classes=c, gamma=1.0) as srv:
    ...     folded = await srv.submit(report)  # fold outcome, like sync
    ...     w_now = await srv.solve()      # exact for everything applied
    ...     await srv.join()               # drain stragglers
    ...     w_all = await srv.solve()

    Statistics are always merged exactly on arrival; the policy only decides
    whether the cached *factorization* is updated in place or lazily
    rebuilt. ``updates`` / ``deferred_refactors`` count the two paths.
    """

    def __init__(
        self,
        dim: int,
        num_classes: int,
        gamma: float = 1.0,
        *,
        update_rank_budget: Optional[int] = None,
        refactor_rank: Optional[int] = None,
        error_budget: float = 1e-8,
        max_pending: Optional[int] = None,
        batch_max: int = 32,
        rejected_max: int = 256,
        server: Optional[AFLServer] = None,
    ):
        # ``server`` adopts an existing aggregate (e.g. restored from a
        # checkpoint) instead of starting empty
        if server is not None:
            if (server.dim, server.num_classes,
                    server.gamma) != (dim, num_classes, gamma):
                raise ValueError("adopted server disagrees with (dim, C, γ)")
            if update_rank_budget is not None:
                server.update_rank_budget = int(update_rank_budget)
            self._server = server
        else:
            self._server = AFLServer(dim, num_classes, gamma,
                                     update_rank_budget=update_rank_budget)
        self.refactor_rank = max(1, dim // 2) if refactor_rank is None \
            else int(refactor_rank)
        self.error_budget = float(error_budget)
        # ingest high-watermark: with max_pending set, enqueue() refuses new
        # fire-and-forget uploads once the queue holds that many unapplied
        # reports (the backpressure signal transports surface as HTTP 429).
        # submit() is unaffected — an awaiting producer IS the backpressure.
        self.max_pending = None if max_pending is None else int(max_pending)
        # micro-batch fold cap: the worker drains up to this many queued
        # reports per wakeup and folds them in ONE pass (one stacked
        # statistics merge + one grouped rank-(Σk) factor sweep). 1 restores
        # strict per-report apply; larger values amortize the per-wakeup
        # lock/future/sweep overhead at the cost of coarser fold latency.
        self.batch_max = max(1, int(batch_max))
        self._queue: asyncio.Queue = asyncio.Queue()
        self._lock = asyncio.Lock()
        self._worker: Optional[asyncio.Task] = None
        self._applied_rank = 0
        # observability: arrivals folded as rank updates vs cache kills,
        # plus uploads the wrapped server refused (duplicate id, γ mismatch).
        # ``rejected`` is BOUNDED (a long-lived server facing a misbehaving
        # client must not leak); overflow evicts the oldest entry and bumps
        # ``rejected_dropped``.
        self.updates = 0
        self.deferred_refactors = 0
        self.rejected: collections.deque = collections.deque(
            maxlen=max(1, int(rejected_max)))
        self.rejected_dropped = 0
        self.batches_folded = 0
        self.last_batch = 0

    # -- protocol surface (delegated) ---------------------------------------

    @property
    def dim(self) -> int:
        return self._server.dim

    @property
    def num_classes(self) -> int:
        return self._server.num_classes

    @property
    def gamma(self) -> float:
        return self._server.gamma

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> "AsyncAFLServer":
        if self._worker is None:
            self._worker = asyncio.create_task(self._run())
        return self

    async def close(self) -> None:
        if self._worker is not None:
            await self._queue.join()
            self._worker.cancel()
            try:
                await self._worker
            except asyncio.CancelledError:
                pass
            self._worker = None

    async def __aenter__(self) -> "AsyncAFLServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.close()

    # -- submission side ----------------------------------------------------

    async def submit(self, report: ClientReport) -> bool:
        """Submit one upload and await its application, resolving to the
        same fold-outcome bool :meth:`AFLServer.submit` returns. A rejected
        upload (duplicate id, γ mismatch, malformed report) raises here —
        exactly like the sync server — without killing the worker."""
        fut: asyncio.Future = asyncio.get_running_loop().create_future()
        await self._queue.put((report, fut, None))
        return await fut

    async def enqueue(self, report: ClientReport) -> None:
        """Fire-and-forget: enqueue an upload and return immediately; the
        worker applies it in arrival order. Rejections land in
        ``self.rejected`` instead of raising to the producer. With
        ``max_pending`` configured, a full queue raises
        :class:`~repro.fl.errors.Backpressure` — the report is NOT queued
        and coordinator state is untouched; back off and resubmit."""
        if self.max_pending is not None \
                and self._queue.qsize() >= self.max_pending:
            raise Backpressure(
                f"ingest queue at high-watermark ({self._queue.qsize()} "
                f"pending ≥ max_pending={self.max_pending})")
        await self._queue.put((report, None, None))

    async def enqueue_many(self, reports: Sequence[ClientReport]) -> int:
        """Bulk fire-and-forget: queue reports until the ``max_pending``
        watermark trips, returning how many were admitted (the rest were
        NOT queued — back off and resubmit them). One event-loop crossing
        for the whole batch, which is what lets a streaming transport hand
        the worker real micro-batches instead of a report per crossing."""
        admitted = 0
        for report in reports:
            if self.max_pending is not None \
                    and self._queue.qsize() >= self.max_pending:
                break
            self._queue.put_nowait((report, None, None))
            admitted += 1
        return admitted

    async def submit_many(self, reports: Iterable[ClientReport]) -> None:
        """Bulk submit with sync semantics: applied in order, stopping at
        the first rejection (later reports are NOT aggregated) — post-
        exception state matches :meth:`AFLServer.submit_many` exactly.
        Pipelined: the whole iterable is enqueued before any outcome is
        awaited, so the worker folds it as micro-batches; the
        stop-at-first-rejection contract survives via a shared abort token
        the worker checks per report (reports after a rejection are skipped,
        never validated or aggregated)."""
        loop = asyncio.get_running_loop()
        group = _SubmitGroup()
        futs = []
        for r in reports:
            fut: asyncio.Future = loop.create_future()
            await self._queue.put((r, fut, group))
            futs.append(fut)
        outcomes = await asyncio.gather(*futs, return_exceptions=True)
        for out in outcomes:
            if isinstance(out, BaseException) \
                    and not isinstance(out, SubmitAborted):
                raise out

    async def join(self) -> None:
        """Wait until every enqueued submission has been applied."""
        await self._queue.join()

    async def _run(self) -> None:
        while True:
            batch = [await self._queue.get()]
            while len(batch) < self.batch_max:
                try:
                    batch.append(self._queue.get_nowait())
                except asyncio.QueueEmpty:
                    break
            try:
                async with self._lock:
                    self._fold_batch(batch)
            except Exception as e:             # noqa: BLE001 — worker must
                # survive; _fold_batch already fanned out per-report errors,
                # so anything landing here is systemic — fail the batch's
                # still-unresolved futures rather than hang their awaiters
                for _, fut, _ in batch:
                    self._resolve(fut, exc=e)
            finally:
                for _ in batch:
                    self._queue.task_done()

    def _fold_batch(self, batch) -> None:
        """Fold one drained micro-batch under the lock: per-report
        validation and deferred-refactor policy in arrival order (each bad
        report rejects alone, bit-for-bit the sequential semantics), then
        ONE :meth:`AFLServer._apply_validated` pass — one stacked statistics
        merge, one grouped rank-(Σk) factor sweep — with the fold outcomes
        fanned back to the per-report futures."""
        srv = self._server
        seen = set(srv._seen)
        items = []                    # (client_id, upload, root-or-None)
        futs = []                     # aligned with items
        # the policy trajectory is fully determined by root ranks and the
        # cache-alive state, so simulate the sequential per-report decisions
        # upfront; _try_factor_update_batch then reproduces exactly these
        # survived flags from the roots we hand it
        cache_alive = bool(srv._factor_cache)
        updatable = cache_alive and all(
            f.updatable for f in srv._factor_cache.values())
        applied = self._applied_rank
        for report, fut, group in batch:
            if group is not None and group.failed:
                self._resolve(fut, exc=SubmitAborted(
                    "skipped: an earlier report in this submit_many call "
                    "was rejected"))
                continue
            try:
                upload, root = srv._validate_report(report, seen)
            except Exception as e:             # noqa: BLE001 — per-report
                self._record_rejected(report, e)
                if group is not None:
                    group.failed = True
                self._resolve(fut, exc=e)
                continue
            seen.add(report.client_id)
            rank = 0 if root is None else int(root.shape[0])
            # rank 0 (an empty client's root) folds trivially — same
            # outcome as the sync server, no reason to kill the cache
            usable = root is not None and rank <= srv.update_rank_budget
            over = (applied + rank > self.refactor_rank
                    or self._error_proxy(applied + rank) > self.error_budget)
            if not (usable and not over):
                # policy says refactor: strip the root so the cache dies and
                # the NEXT solve pays the d³ once for this and any further
                # cache-killing arrivals in the burst
                root = None
            if cache_alive:
                survived = root is not None and updatable
                if survived:
                    applied += rank
                    self.updates += 1 if rank else 0
                else:
                    # fold refused (policy, or non-updatable pinv fallback)
                    applied = 0
                    cache_alive = False
                    self.deferred_refactors += 1
            items.append((report.client_id, upload, root))
            futs.append(fut)
        self._applied_rank = applied
        if items:
            flags = srv._apply_validated(items)
            for fut, flag in zip(futs, flags):
                self._resolve(fut, result=flag)
        self.batches_folded += 1
        self.last_batch = len(batch)

    def _record_rejected(self, report, exc: Exception) -> None:
        if len(self.rejected) == self.rejected.maxlen:
            self.rejected_dropped += 1
        self.rejected.append((getattr(report, "client_id", None), str(exc)))

    @staticmethod
    def _resolve(fut: Optional[asyncio.Future], result=None,
                 exc: Optional[BaseException] = None) -> None:
        if fut is None or fut.done():
            return
        if exc is not None:
            fut.set_exception(exc)
        else:
            fut.set_result(result)

    def _error_proxy(self, applied_rank: int) -> float:
        """Worst-case relative drift of a factor after ``applied_rank``
        rank-1 sweeps: each sweep is d Householder rotations, each backward
        stable to O(ε) — proxy ε·√d per sweep, summed."""
        eps = float(np.finfo(np.float64).eps)
        return eps * np.sqrt(self._server.dim) * applied_rank

    # -- serving side -------------------------------------------------------

    async def solve(self, target_gamma: float = 0.0) -> np.ndarray:
        """Joint solution over every *applied* arrival, from the live factor
        (rank-updated in place, or refactored here if a deferral is due)."""
        async with self._lock:
            return self._server.solve(target_gamma)

    async def solve_multi_gamma(self, gammas: Sequence[float]) -> list:
        """γ sweep over everything applied, served from the wrapped
        server's rank-updated eigendecomposition handle: low-rank arrivals
        fold into the cached eigenbasis (Woodbury) instead of forcing a d³
        re-factorization per sweep — the event-loop twin of the factor-cache
        rank updates on the single-solve path."""
        async with self._lock:
            return self._server.solve_multi_gamma(gammas)

    async def sweep(self, gammas: Sequence[float], holdout) -> GammaSweep:
        """Server-side γ cross-validation off the cached (rank-updated)
        eigendecomposition — see :meth:`solve_multi_gamma`."""
        async with self._lock:
            weights = self._server.solve_multi_gamma(gammas)
        return _sweep_from_weights(weights, gammas, holdout)

    async def weights(self, target_gamma: float = 0.0, *,
                      if_etag: Optional[str] = None) -> VersionedWeights:
        """Versioned solved-head download over everything *applied* so far
        (see :meth:`repro.fl.api.AFLServer.weights`)."""
        async with self._lock:
            return self._server.weights(target_gamma, if_etag=if_etag)

    # -- checkpointing ------------------------------------------------------

    async def state(self) -> Dict[str, np.ndarray]:
        """Serializable state of everything *applied* so far (same schema as
        :meth:`AFLServer.state`; ``await join()`` first to include queued
        arrivals)."""
        async with self._lock:
            return self._server.state()

    async def checkpoint(self) -> Dict[str, np.ndarray]:
        """Drain-then-state: wait for every queued arrival to apply, then
        snapshot — the consistent cut a failover daemon wants (a plain
        :meth:`state` can miss reports still sitting in the ingest queue)."""
        await self.join()
        return await self.state()

    @classmethod
    def from_state(cls, state: Dict[str, np.ndarray],
                   num_classes: Optional[int] = None,
                   **kwargs) -> "AsyncAFLServer":
        """Rebuild an (unstarted) async coordinator from a checkpoint; use
        ``async with`` / ``await start()`` to bring the worker up."""
        server = AFLServer.from_state(state, num_classes)
        return cls(server.dim, server.num_classes, server.gamma,
                   server=server, **kwargs)

    # -- introspection ------------------------------------------------------

    @property
    def num_clients(self) -> int:
        """Clients applied so far (excludes queued-but-unapplied)."""
        return self._server.num_clients

    @property
    def version(self) -> int:
        """Submission epoch of everything *applied* so far."""
        return self._server.version

    @property
    def pending(self) -> int:
        return self._queue.qsize()

    @property
    def server(self) -> AFLServer:
        """The wrapped synchronous server (shared statistics, same cache)."""
        return self._server

    def new_etag_salt(self) -> str:
        """Mint a fresh ETag salt (see :meth:`AFLServer.new_etag_salt`) —
        tokens are minted by the wrapped server, so the salt lives there.
        Synchronous: an identity change (promotion) happens outside the
        serving loop."""
        return self._server.new_etag_salt()
