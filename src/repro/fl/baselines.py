"""Gradient-FL baselines the paper compares against (frozen backbone, linear
head): FedAvg, FedProx, and local-only training (paper Supp. E & F settings:
local epoch 1, batch 64, SGD lr 0.05, full participation).

These run on feature matrices (the shared frozen backbone's embeddings) —
exactly the paper's experimental configuration. Implemented with numpy-level
loops over clients and jit-able inner steps kept as plain numpy for
determinism and speed at these sizes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from repro.config import FLConfig
from repro.data.synthetic import Dataset
from repro.fl.afl import evaluate
from repro.fl.partition import make_partition


@dataclasses.dataclass
class FLRunResult:
    accuracy: float          # best test acc over rounds (paper metric)
    curve: List[float]       # test acc per round
    train_seconds: float
    rounds: int


def _softmax(z):
    z = z - z.max(-1, keepdims=True)
    e = np.exp(z)
    return e / e.sum(-1, keepdims=True)


def _local_sgd(w, x, y_onehot, lr, batch, rng, mu=0.0, w_global=None):
    """One local epoch of SGD on softmax-CE; FedProx adds μ/2·||w−w_g||²."""
    n = len(x)
    if n == 0:
        return w
    perm = rng.permutation(n)
    for i in range(0, n, batch):
        idx = perm[i : i + batch]
        xb, yb = x[idx], y_onehot[idx]
        probs = _softmax(xb @ w)
        grad = xb.T @ (probs - yb) / len(idx)
        if mu and w_global is not None:
            grad = grad + mu * (w - w_global)
        w = w - lr * grad
    return w


def run_gradient_fl(
    train: Dataset,
    test: Dataset,
    fl: FLConfig,
    *,
    method: str = "fedavg",       # fedavg | fedprox
    rounds: int = 50,
    lr: float = 0.05,
    batch: int = 64,
    mu: float = 0.001,            # FedProx μ (paper's tuned value)
    eval_every: int = 1,
) -> FLRunResult:
    t0 = time.perf_counter()
    rng = np.random.default_rng(fl.seed)
    d, c = train.x.shape[1], train.num_classes
    y_onehot = np.eye(c)[train.y]
    parts = make_partition(train.y, fl.num_clients, fl.partition,
                           alpha=fl.alpha, shards_per_client=fl.shards_per_client,
                           seed=fl.seed)
    sizes = np.array([len(p) for p in parts], float)
    weights = sizes / sizes.sum()
    w_global = np.zeros((d, c))
    curve = []
    for r in range(rounds):
        locals_ = []
        for k, idx in enumerate(parts):
            wk = _local_sgd(
                w_global.copy(), train.x[idx], y_onehot[idx], lr, batch, rng,
                mu=(mu if method == "fedprox" else 0.0), w_global=w_global,
            )
            locals_.append(wk)
        w_global = sum(w * lw for w, lw in zip(locals_, weights))
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            curve.append(evaluate(w_global, test.x, test.y))
    return FLRunResult(max(curve), curve, time.perf_counter() - t0, rounds)


def run_local_only(train: Dataset, test: Dataset, fl: FLConfig,
                   epochs: int = 5, lr: float = 0.05, batch: int = 64):
    """Paper Supp. F: per-client training without aggregation.
    Returns (avg acc, max acc) across clients."""
    rng = np.random.default_rng(fl.seed)
    d, c = train.x.shape[1], train.num_classes
    y_onehot = np.eye(c)[train.y]
    parts = make_partition(train.y, fl.num_clients, fl.partition,
                           alpha=fl.alpha, shards_per_client=fl.shards_per_client,
                           seed=fl.seed)
    accs = []
    for idx in parts:
        if len(idx) == 0:
            accs.append(1.0 / c)
            continue
        w = np.zeros((d, c))
        for _ in range(epochs):
            w = _local_sgd(w, train.x[idx], y_onehot[idx], lr, batch, rng)
        accs.append(evaluate(w, test.x, test.y))
    return float(np.mean(accs)), float(np.max(accs))


def run_fedfisher_diag(train: Dataset, test: Dataset, fl: FLConfig,
                       epochs: int = 1, lr: float = 0.05, batch: int = 64,
                       eps: float = 1e-8) -> FLRunResult:
    """One-shot Fisher-weighted aggregation (FedFisher [11]-style, diagonal).

    Each client trains its head locally, estimates the diagonal empirical
    Fisher of its solution, and the server merges in ONE round:
        w = (Σ F_k + εI)^{-1} Σ F_k w_k   (elementwise).
    This is the single-round *gradient* competitor the paper compares against
    in Table A.3 — unlike AFL's AA law it is an approximation, so it retains
    heterogeneity sensitivity.
    """
    t0 = time.perf_counter()
    rng = np.random.default_rng(fl.seed)
    d, c = train.x.shape[1], train.num_classes
    y_onehot = np.eye(c)[train.y]
    parts = make_partition(train.y, fl.num_clients, fl.partition,
                           alpha=fl.alpha, shards_per_client=fl.shards_per_client,
                           seed=fl.seed)
    fisher_sum = np.zeros((d, c))
    fw_sum = np.zeros((d, c))
    for idx in parts:
        if len(idx) == 0:
            continue
        w = np.zeros((d, c))
        for _ in range(epochs):
            w = _local_sgd(w, train.x[idx], y_onehot[idx], lr, batch, rng)
        # diagonal empirical Fisher of the local softmax head:
        # F[d, c] = E[ x_d² · p_c(1-p_c) ]
        p = _softmax(train.x[idx] @ w)
        fisher = (train.x[idx] ** 2).T @ (p * (1 - p)) / len(idx)
        fisher_sum += fisher
        fw_sum += fisher * w
    w_global = fw_sum / (fisher_sum + eps)
    acc = evaluate(w_global, test.x, test.y)
    return FLRunResult(acc, [acc], time.perf_counter() - t0, 1)
