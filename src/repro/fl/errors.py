"""The canonical AFL serving error taxonomy.

Every way a federation request can fail is one of the typed errors below —
raised in-process by the coordinators and the service, and carried over the
wire as a stable ``code`` string plus message, so a remote caller re-raises
the *same* exception type it would have seen in-process (wire-equivalence
extends to the failure paths, not just the happy ones).

Design rules:

  * Errors that an in-process coordinator historically raised as
    ``ValueError`` (duplicate client, γ mismatch, corrupt report, solving an
    empty federation) stay ``ValueError`` subclasses, so pre-service call
    sites and tests keep working unchanged.
  * ``code`` is the wire-stable identity (never rename), ``http_status`` is
    what the HTTP transport maps it to, and ``retryable`` marks the errors a
    well-behaved client may back off and retry (today: backpressure).
"""

from __future__ import annotations

from typing import Dict, Type

__all__ = [
    "ServiceError",
    "BadRequest",
    "CorruptReport",
    "OversizedReport",
    "DuplicateClient",
    "GammaMismatch",
    "EmptyFederation",
    "Backpressure",
    "ReadOnlyFederation",
    "Unauthorized",
    "Unavailable",
    "UnknownFederation",
    "ERROR_CODES",
    "from_code",
]


class ServiceError(Exception):
    """Base of the taxonomy: a wire-stable ``code``, an HTTP status, and a
    retryability flag. Never raised bare — always one of the subclasses."""

    code: str = "internal"
    http_status: int = 500
    retryable: bool = False


class BadRequest(ServiceError, ValueError):
    """Malformed request at the protocol level: unknown route, unparseable
    request envelope, missing required fields."""

    code = "bad_request"
    http_status = 400


class CorruptReport(ServiceError, ValueError):
    """A :class:`~repro.fl.api.ClientReport` payload that failed parsing or
    validation (bad magic, CRC mismatch, truncated arrays, non-finite
    statistics, unknown schema version, wrong dimensions)."""

    code = "corrupt_report"
    http_status = 400


class OversizedReport(ServiceError, ValueError):
    """A report payload larger than the service's ``max_report_bytes`` —
    rejected before parsing, so a hostile upload cannot balloon memory."""

    code = "oversized_report"
    http_status = 413


class DuplicateClient(ServiceError, ValueError):
    """A client id that already contributed to this federation (the AA law
    aggregates each client exactly once)."""

    code = "duplicate_client"
    http_status = 409


class GammaMismatch(ServiceError, ValueError):
    """A report whose local regularizer γ differs from the federation's —
    the RI restore is only exact when every client used the same γ."""

    code = "gamma_mismatch"
    http_status = 409


class EmptyFederation(ServiceError, ValueError):
    """A solve/sweep/weights request before any client has reported."""

    code = "empty_federation"
    http_status = 409


class Backpressure(ServiceError):
    """The async ingest queue is at its high-watermark — or a mesh resize
    (grow/shrink) is migrating shards — and the submission was NOT
    aggregated. Retryable — back off and resubmit."""

    code = "backpressure"
    http_status = 429
    retryable = True


class ReadOnlyFederation(ServiceError, ValueError):
    """A mutating request (submit / grow / shrink) sent to a weights read
    replica. Replicas follow the primary's ledger and never ingest — send
    writes to the primary endpoint. Not retryable *here*: retrying against
    the replica can never succeed."""

    code = "read_only"
    http_status = 403


class Unauthorized(ServiceError):
    """The federation requires a bearer token and the request carried a
    missing or wrong one. Checked before routing, so nothing was applied and
    coordinator state is untouched. Not retryable: resending the same
    credentials can never succeed — obtain a valid token first."""

    code = "unauthorized"
    http_status = 401


class Unavailable(ServiceError):
    """The federation exists but is temporarily not being served — its
    coordinator died and a failover restore is in flight. Nothing was
    applied. Retryable — back off until the replacement coordinator is
    installed (``FederationService.restore_federation``)."""

    code = "unavailable"
    http_status = 503
    retryable = True


class UnknownFederation(ServiceError, KeyError):
    """A federation id the service does not host."""

    code = "unknown_federation"
    http_status = 404


ERROR_CODES: Dict[str, Type[ServiceError]] = {
    cls.code: cls
    for cls in (BadRequest, CorruptReport, OversizedReport, DuplicateClient,
                GammaMismatch, EmptyFederation, Backpressure,
                ReadOnlyFederation, Unauthorized, Unavailable,
                UnknownFederation)
}


def from_code(code: str, message: str) -> ServiceError:
    """Rebuild the typed error a wire response carried (client side). An
    unknown code (newer server) degrades to the ``ServiceError`` base."""
    cls = ERROR_CODES.get(code)
    if cls is None:
        err = ServiceError(f"[{code}] {message}")
        return err
    return cls(message)
