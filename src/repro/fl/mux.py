"""AFLMux: one socket, many streams — the traffic-grade federation transport.

The stdlib HTTP/1.1 server proved the wire contract (PR 4/5) but serializes
uploaders per connection and speaks neither TLS nor auth. This module is the
layer you put in front of many concurrent clients: an h2-style multiplexed
binary framing protocol carrying the existing CRC-checked
:class:`~repro.fl.service.FederationService` byte envelopes *unchanged* —
the envelope is the payload; this file only frames, interleaves, and secures
it.

Protocol (all integers little-endian):

* Connection preface: the client sends ``AFLMUX1\\n`` (8 bytes) immediately
  after connecting (and after the TLS handshake, when enabled). Anything
  else is answered with GOAWAY and a closed connection.
* Frame: ``u32 length | u8 type | u8 flags | u32 stream_id | payload`` —
  a 10-byte header. ``length`` counts payload bytes only and is capped
  (``max_frame_bytes``, default 1 MiB); an oversized or torn frame is a
  connection error (GOAWAY), not something to resynchronize past.
* Streams are client-initiated with odd, strictly increasing ids. A request
  is one HEADERS frame (JSON: route, federation, optional bearer token)
  followed by DATA frames carrying the request envelope; ``END_STREAM``
  marks the last frame. The response is one RESPONSE frame (JSON: the HTTP
  status the envelope maps to) followed by DATA frames with the response
  envelope. Frames of different streams interleave freely — one slow
  submit_stream upload never blocks a weights poll on the same socket.
* Flow control is per-stream: each sender starts with ``initial_window``
  bytes of credit and the receiver returns credit with WINDOW_UPDATE frames
  as it consumes DATA, so one firehose stream cannot starve the connection.
* PING (8-byte opaque payload, ACK flag) measures liveness without touching
  any federation — standby probes ride it. GOAWAY (``u32 last_stream_id |
  message``) promises that streams above ``last_stream_id`` were never
  processed and drains the rest — the graceful-shutdown half.

Security: ``serve_mux(..., ssl_context=...)`` wraps every connection in TLS
(:func:`server_ssl_context` builds the context from a cert/key pair, with
optional required client certificates), and a per-federation bearer token
(``FederationService(auth_token=...)``) is enforced *before* routing, so an
unauthorized request leaves coordinator state untouched.

Replay discipline is stricter than HTTP's: once a request's HEADERS frame
has been written, :class:`MuxTransport` never re-sends it — a connection
that dies mid-request surfaces ``ConnectionError`` (reads included). The
single transparent retry happens only when writing HEADERS on a previously
established (stale) connection fails: the server cannot have routed a
request whose first frame never arrived whole (a torn frame kills the
connection before dispatch), so a sent submit is never re-sent.
"""

from __future__ import annotations

import json
import socket
import ssl
import struct
import subprocess
import threading
import time
import urllib.parse
from typing import Dict, List, Optional, Tuple

from repro.fl import errors as E
from repro.fl.service import FederationService

__all__ = [
    "MuxTransport",
    "MuxFederationServer",
    "serve_mux",
    "mux_ping",
    "probe_alive",
    "server_ssl_context",
    "client_ssl_context",
    "generate_self_signed_cert",
    "MuxProtocolError",
]

PREFACE = b"AFLMUX1\n"
_HDR = struct.Struct("<IBBI")            # length, type, flags, stream_id
_U32 = struct.Struct("<I")

T_HEADERS, T_DATA, T_RESPONSE, T_WINDOW, T_PING, T_GOAWAY = 1, 2, 3, 4, 5, 6
F_END_STREAM = 0x1
F_ACK = 0x2

MAX_FRAME_BYTES = 1 << 20                # hard cap on one frame's payload
DATA_CHUNK = 64 << 10                    # DATA frame size senders use
INITIAL_WINDOW = 4 << 20                 # per-stream send credit at open

# routes whose replay could mutate state — a MuxTransport never re-sends
# ANY request after its HEADERS frame is on the wire, but these are the
# reason the discipline exists
MUTATING_ROUTES = frozenset(
    {"submit", "submit_stream", "grow", "shrink", "promote"})


class MuxProtocolError(E.BadRequest):
    """A frame-level protocol violation (bad preface, torn or oversized
    frame, unknown frame type, corrupt HEADERS). Connection-fatal: framing
    is lost, so the peer answers GOAWAY and closes rather than guessing at
    resynchronization."""

    code = "bad_request"


class _StaleConn(Exception):
    """Internal: writing HEADERS failed on a previously established
    connection — nothing of the request reached the peer's router, so ONE
    retry on a fresh connection is safe for every route."""

    def __init__(self, cause: BaseException):
        super().__init__(str(cause))
        self.cause = cause


def _read_exact(rfile, n: int) -> bytes:
    """Read exactly n bytes; b"" at a clean boundary start, else raises
    MuxProtocolError on a torn read."""
    data = rfile.read(n)
    if data is None:
        data = b""
    if data and len(data) < n:
        raise MuxProtocolError(
            f"torn frame: wanted {n} bytes, connection yielded {len(data)}")
    return data


def _read_frame(rfile, max_frame: int
                ) -> Optional[Tuple[int, int, int, bytes]]:
    """One frame off the wire → (type, flags, stream_id, payload), or None
    on clean EOF between frames."""
    hdr = _read_exact(rfile, _HDR.size)
    if not hdr:
        return None
    length, ftype, flags, sid = _HDR.unpack(hdr)
    if length > max_frame:
        raise MuxProtocolError(
            f"frame payload of {length} bytes exceeds the "
            f"{max_frame}-byte frame cap")
    payload = rfile.read(length) if length else b""
    if len(payload or b"") < length:
        raise MuxProtocolError(
            f"torn frame: header promised {length} payload bytes, "
            f"got {len(payload or b'')}")
    return ftype, flags, sid, payload


class _FlowWindow:
    """Per-stream send credit: ``take`` blocks until the peer grants more
    via WINDOW_UPDATE (or the stream/connection dies)."""

    def __init__(self, n: int):
        self.cv = threading.Condition()
        self.n = int(n)
        self.dead: Optional[BaseException] = None

    def grant(self, k: int) -> None:
        with self.cv:
            self.n += int(k)
            self.cv.notify_all()

    def kill(self, exc: BaseException) -> None:
        with self.cv:
            if self.dead is None:
                self.dead = exc
            self.cv.notify_all()

    def take(self, want: int, deadline: float) -> int:
        with self.cv:
            while self.n <= 0:
                if self.dead is not None:
                    raise self.dead
                left = deadline - time.monotonic()
                if left <= 0:
                    raise TimeoutError(
                        "flow-control window starved (peer stopped "
                        "granting credit)")
                self.cv.wait(left)
            k = min(int(want), self.n)
            self.n -= k
            return k


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------


class _ClientStream:
    __slots__ = ("win", "done", "chunks", "status", "error")

    def __init__(self, window: int):
        self.win = _FlowWindow(window)
        self.done = threading.Event()
        self.chunks: List[bytes] = []
        self.status: Optional[int] = None
        self.error: Optional[BaseException] = None

    def finish(self, error: Optional[BaseException] = None) -> None:
        if error is not None:
            if self.error is None:
                self.error = error
            self.win.kill(error)
        else:
            # unblock a sender mid-body: the response is already here
            # (early reject) — it stops sending and reads it
            self.win.grant(1 << 40)
        self.done.set()


class _ClientConn:
    """One connection generation: socket, reader thread, live streams."""

    def __init__(self, sock, rfile):
        self.sock = sock
        self.rfile = rfile
        self.wlock = threading.Lock()
        self.slock = threading.Lock()
        self.streams: Dict[int, _ClientStream] = {}
        self.next_id = 1                 # guarded by wlock (see open_stream)
        self.ping_seq = 0
        self.pings: Dict[bytes, list] = {}
        self.goaway_last: Optional[int] = None
        self.dead = False

    def write_frame(self, ftype: int, flags: int, sid: int,
                    payload: bytes = b"") -> None:
        buf = _HDR.pack(len(payload), ftype, flags, sid) + payload
        with self.wlock:
            self.sock.sendall(buf)

    def open_stream(self, st: "_ClientStream", payload: bytes,
                    flags: int, first_data: Optional[bytes] = None) -> int:
        """Allocate a stream id AND write its HEADERS frame atomically —
        id order must equal wire order (the server rejects out-of-order
        ids), so concurrent callers cannot interleave between the two.
        ``first_data`` piggybacks a small complete body as a DATA frame in
        the same write (one syscall per request for the common case)."""
        with self.wlock:
            sid = self.next_id
            self.next_id += 2
            with self.slock:
                self.streams[sid] = st
            buf = _HDR.pack(len(payload), T_HEADERS, flags, sid) + payload
            if first_data is not None:
                buf += _HDR.pack(len(first_data), T_DATA, F_END_STREAM,
                                 sid) + first_data
            try:
                self.sock.sendall(buf)
            except BaseException:
                with self.slock:
                    self.streams.pop(sid, None)
                raise
        return sid


class MuxTransport:
    """Client side of the mux transport — same ``request``/``close``
    surface as :class:`~repro.fl.service.HttpTransport`, so
    :class:`~repro.fl.service.RemoteCoordinator` (and anything else built
    on the Transport protocol) runs over it unchanged.

    One persistent connection carries every concurrent caller: each
    ``request`` opens a fresh stream, so N threads interleave on one socket
    (one TCP + TLS handshake total, not one per client). ``mux://host:port``
    is plaintext, ``muxs://host:port`` is TLS (pass ``ssl_context`` or
    ``cafile``; self-signed server certs verify against their own PEM).
    ``auth_token`` rides in every request's HEADERS frame and is enforced
    by the service before routing.
    """

    def __init__(self, url: str, *, auth_token: Optional[str] = None,
                 ssl_context: Optional[ssl.SSLContext] = None,
                 cafile: Optional[str] = None, timeout: float = 60.0,
                 max_frame_bytes: int = MAX_FRAME_BYTES,
                 initial_window: int = INITIAL_WINDOW,
                 chunk_bytes: int = DATA_CHUNK):
        parts = urllib.parse.urlsplit(url)
        if parts.scheme not in ("mux", "muxs"):
            raise ValueError(
                f"MuxTransport speaks mux:// or muxs:// only, got {url!r}")
        self._host = parts.hostname or "127.0.0.1"
        self._port = parts.port or 8791
        self._tls = parts.scheme == "muxs"
        if self._tls and ssl_context is None:
            ssl_context = client_ssl_context(cafile)
        self._ssl = ssl_context
        self.auth_token = auth_token
        self._timeout = float(timeout)
        self._max_frame = int(max_frame_bytes)
        self._window = int(initial_window)
        self._chunk = int(chunk_bytes)
        self._lock = threading.RLock()
        self._conn: Optional[_ClientConn] = None
        self._reader: Optional[threading.Thread] = None
        self._closed = False
        self.reconnects = 0                 # observability (tests/bench)

    # -- connection lifecycle -----------------------------------------------

    def _connect(self) -> _ClientConn:
        sock = socket.create_connection((self._host, self._port),
                                        timeout=self._timeout)
        try:
            # frames are written back-to-back (HEADERS, then DATA) — Nagle
            # plus delayed ACK turns that into ~40ms stalls per request
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            if self._tls:
                sock = self._ssl.wrap_socket(
                    sock, server_hostname=self._host)
            sock.settimeout(None)
            sock.sendall(PREFACE)
        except BaseException:
            sock.close()
            raise
        conn = _ClientConn(sock, sock.makefile("rb"))
        t = threading.Thread(target=self._read_loop, args=(conn,),
                             daemon=True, name="afl-mux-client-reader")
        t.start()
        self._reader = t
        return conn

    def _ensure_conn(self) -> Tuple[_ClientConn, bool]:
        """(conn, reused) — reused=False when this call established it."""
        with self._lock:
            if self._closed:
                raise ConnectionError("MuxTransport is closed")
            conn = self._conn
            if conn is not None and not conn.dead \
                    and conn.goaway_last is None:
                return conn, True
            if conn is not None:
                self.reconnects += 1
            self._conn = conn = self._connect()
            return conn, False

    def _kill_conn(self, conn: _ClientConn,
                   error: Optional[BaseException] = None) -> None:
        conn.dead = True
        try:
            # shutdown, not just close: the reader's makefile handle keeps
            # the fd alive, so close alone would neither send a FIN nor
            # unblock a read parked on this socket
            conn.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            conn.sock.close()
        except OSError:
            pass
        with conn.slock:
            streams = list(conn.streams.values())
            conn.streams.clear()
            pings = list(conn.pings.values())
            conn.pings.clear()
        for st in streams:
            if not st.done.is_set():
                st.finish(ConnectionError(
                    f"mux connection lost mid-request: {error}"
                    if error else "mux connection lost mid-request"))
        for slot in pings:
            slot[1].set()

    # -- the reader thread --------------------------------------------------

    def _read_loop(self, conn: _ClientConn) -> None:
        error: Optional[BaseException] = None
        try:
            while True:
                fr = _read_frame(conn.rfile, self._max_frame)
                if fr is None:
                    break
                ftype, flags, sid, payload = fr
                if ftype == T_RESPONSE:
                    with conn.slock:
                        st = conn.streams.get(sid)
                    if st is not None:
                        st.status = int(json.loads(payload or b"{}")
                                        .get("status", 200))
                        if flags & F_END_STREAM:
                            self._finish_stream(conn, sid, st)
                elif ftype == T_DATA:
                    with conn.slock:
                        st = conn.streams.get(sid)
                    if st is not None:
                        st.chunks.append(payload)
                        if payload:
                            try:
                                conn.write_frame(T_WINDOW, 0, sid,
                                                 _U32.pack(len(payload)))
                            except OSError:
                                pass
                        if flags & F_END_STREAM:
                            self._finish_stream(conn, sid, st)
                elif ftype == T_WINDOW:
                    with conn.slock:
                        st = conn.streams.get(sid)
                    if st is not None:
                        st.win.grant(_U32.unpack(payload[:4])[0])
                elif ftype == T_PING:
                    if flags & F_ACK:
                        with conn.slock:
                            slot = conn.pings.pop(payload, None)
                        if slot is not None:
                            slot[0] = time.perf_counter()
                            slot[1].set()
                    else:
                        conn.write_frame(T_PING, F_ACK, 0, payload)
                elif ftype == T_GOAWAY:
                    last = _U32.unpack(payload[:4])[0]
                    msg = payload[4:].decode("utf-8", "replace")
                    conn.goaway_last = last
                    with conn.slock:
                        doomed = [(s, st)
                                  for s, st in conn.streams.items()
                                  if s > last]
                        for s, _ in doomed:
                            conn.streams.pop(s)
                    for s, st in doomed:
                        st.finish(E.Unavailable(
                            f"server going away before stream {s} was "
                            f"processed ({msg or 'shutdown'}) — safe to "
                            "retry against a live endpoint"))
                else:
                    raise MuxProtocolError(f"unknown frame type {ftype}")
        except Exception as exc:                          # noqa: BLE001
            error = exc
        finally:
            self._kill_conn(conn, error)

    def _finish_stream(self, conn: _ClientConn, sid: int,
                       st: _ClientStream) -> None:
        with conn.slock:
            conn.streams.pop(sid, None)
        st.finish()

    # -- requests -----------------------------------------------------------

    def request(self, route: str, body: bytes = b"",
                federation: str = "default") -> bytes:
        body = bytes(body)
        try:
            return self._request_once(route, body, federation)
        except _StaleConn:
            # HEADERS never made it whole onto a stale connection — the
            # server cannot have routed it (a torn first frame is a
            # connection error before dispatch), so one retry is safe
            # for every route, submits included.
            try:
                return self._request_once(route, body, federation)
            except _StaleConn as exc:
                raise ConnectionError(str(exc)) from exc.cause

    def _request_once(self, route: str, body: bytes,
                      federation: str) -> bytes:
        conn, reused = self._ensure_conn()
        st = _ClientStream(self._window)
        header = {"route": route, "federation": federation}
        if self.auth_token is not None:
            header["token"] = self.auth_token
        # a body that fits one DATA frame rides in the same write as
        # HEADERS — and the combined write failing still means nothing of
        # the request was routed (torn frames are connection-fatal before
        # dispatch), so the stale-retry rule below stays sound
        inline = body if 0 < len(body) <= min(self._chunk,
                                              self._window) else None
        flags = 0 if body else F_END_STREAM
        try:
            sid = conn.open_stream(st, json.dumps(header).encode("utf-8"),
                                   flags, first_data=inline)
        except OSError as exc:
            self._kill_conn(conn, exc)
            if reused:
                raise _StaleConn(exc) from exc
            raise ConnectionError(f"mux send failed: {exc}") from exc
        if inline is not None:
            body = b""                      # fully sent with the HEADERS
        # From here on the request is SENT: any failure surfaces — a
        # replayed submit is never an option past this line.
        deadline = time.monotonic() + self._timeout
        off = 0
        while off < len(body):
            if st.done.is_set():
                break                       # early response (e.g. reject)
            n = st.win.take(min(self._chunk, len(body) - off), deadline)
            chunk = body[off:off + n]
            off += n
            try:
                conn.write_frame(
                    T_DATA, F_END_STREAM if off == len(body) else 0,
                    sid, chunk)
            except OSError as exc:
                self._kill_conn(conn, exc)
                raise ConnectionError(
                    f"mux send failed mid-request: {exc}") from exc
        if not st.done.wait(max(0.0, deadline - time.monotonic())):
            with conn.slock:
                conn.streams.pop(sid, None)
            raise TimeoutError(
                f"mux request {route!r} timed out after {self._timeout}s")
        if st.error is not None:
            raise st.error
        return b"".join(st.chunks)

    def ping(self, timeout: Optional[float] = None) -> float:
        """Round-trip a PING frame → latency in seconds. Touches no
        federation (no auth needed) — the standby liveness probe."""
        conn, _ = self._ensure_conn()
        with conn.slock:
            conn.ping_seq += 1
            token = struct.pack("<Q", conn.ping_seq)
            slot = [None, threading.Event()]
            conn.pings[token] = slot
        t0 = time.perf_counter()
        try:
            conn.write_frame(T_PING, 0, 0, token)
        except OSError as exc:
            self._kill_conn(conn, exc)
            raise ConnectionError(f"mux ping failed: {exc}") from exc
        if not slot[1].wait(timeout if timeout is not None
                            else self._timeout):
            raise TimeoutError("mux ping timed out")
        if slot[0] is None:
            raise ConnectionError("mux connection lost during ping")
        return slot[0] - t0

    def close(self) -> None:
        with self._lock:
            self._closed = True
            conn, self._conn = self._conn, None
        if conn is not None and not conn.dead:
            try:
                conn.write_frame(T_GOAWAY, 0, 0,
                                 _U32.pack(0) + b"client closing")
            except OSError:
                pass
            self._kill_conn(conn)
        if self._reader is not None:
            self._reader.join(timeout=2)
            self._reader = None


# ---------------------------------------------------------------------------
# Server
# ---------------------------------------------------------------------------


class _ServerStream:
    __slots__ = ("header", "body", "out", "poisoned", "responded")

    def __init__(self, header: dict, window: int):
        self.header = header
        self.body = bytearray()
        self.out = _FlowWindow(window)
        self.poisoned = False
        self.responded = False


class _ServerConn:
    def __init__(self, sock, rfile, addr):
        self.sock = sock
        self.rfile = rfile
        self.addr = addr
        self.wlock = threading.Lock()
        self.lock = threading.Lock()
        self.drain_cv = threading.Condition(self.lock)
        self.streams: Dict[int, _ServerStream] = {}
        self.inflight = 0
        self.last_sid = 0
        self.goaway_sent = False
        self.dead = False

    def write_frame(self, ftype: int, flags: int, sid: int,
                    payload: bytes = b"") -> None:
        buf = _HDR.pack(len(payload), ftype, flags, sid) + payload
        with self.wlock:
            self.sock.sendall(buf)

    def begin_goaway(self, message: str) -> None:
        with self.lock:
            if self.goaway_sent:
                return
            self.goaway_sent = True
            last = self.last_sid
        try:
            self.write_frame(T_GOAWAY, 0, 0,
                             _U32.pack(last) + message.encode("utf-8"))
        except OSError:
            pass

    def wait_drain(self, deadline: float) -> bool:
        with self.lock:
            while self.inflight or self.streams:
                left = deadline - time.monotonic()
                if left <= 0 or self.dead:
                    return not (self.inflight or self.streams)
                self.drain_cv.wait(left)
        return True

    def close(self) -> None:
        self.dead = True
        try:
            # see MuxTransport._kill_conn: shutdown so the FIN actually
            # goes out despite rfile's reference to the fd
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass
        with self.lock:
            streams = list(self.streams.values())
            self.streams.clear()
            self.drain_cv.notify_all()
        for st in streams:
            st.out.kill(ConnectionError("mux connection closed"))


class MuxFederationServer:
    """A threaded mux server hosting one :class:`FederationService` —
    thread-per-connection reader, thread-per-stream dispatch, so many
    uploaders interleave on each socket and across sockets. Optional TLS
    (``ssl_context`` from :func:`server_ssl_context`; client-cert auth when
    the context demands it) and graceful GOAWAY drain on ``close``.
    Context-manager friendly, same shape as ``HttpFederationServer``::

        with serve_mux(FederationService(server, auth_token=tok),
                       ssl_context=ctx) as srv:
            coord = RemoteCoordinator(srv.url, auth_token=tok, cafile=cert)
    """

    def __init__(self, service: FederationService, host: str = "127.0.0.1",
                 port: int = 0, *,
                 ssl_context: Optional[ssl.SSLContext] = None,
                 max_frame_bytes: int = MAX_FRAME_BYTES,
                 initial_window: int = INITIAL_WINDOW,
                 chunk_bytes: int = DATA_CHUNK):
        self.service = service
        self._ssl = ssl_context
        self._max_frame = int(max_frame_bytes)
        self._window = int(initial_window)
        self._chunk = int(chunk_bytes)
        self._lsock = socket.create_server((host, port))
        self.host, self.port = self._lsock.getsockname()[:2]
        self.url = (f"{'muxs' if ssl_context is not None else 'mux'}"
                    f"://{self.host}:{self.port}")
        self._conns: set = set()
        self._conns_lock = threading.Lock()
        self._closing = False
        self._thread: Optional[threading.Thread] = None
        self.errors: List[Tuple[str, str]] = []   # (where, message)

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "MuxFederationServer":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._accept_loop, daemon=True,
                name="afl-mux-server")
            self._thread.start()
        return self

    def _accept_loop(self) -> None:
        while not self._closing:
            try:
                sock, addr = self._lsock.accept()
            except OSError:
                break
            threading.Thread(target=self._serve_conn, args=(sock, addr),
                             daemon=True,
                             name="afl-mux-conn").start()

    def close(self, *, drain: bool = True, timeout: float = 10.0,
              close_service: bool = False) -> None:
        """Stop accepting, GOAWAY every connection, and (with ``drain``)
        wait for in-flight streams to finish before closing sockets —
        a sent submit is either fully answered or provably unprocessed."""
        self._closing = True
        try:
            self._lsock.close()
        except OSError:
            pass
        with self._conns_lock:
            conns = list(self._conns)
        for conn in conns:
            conn.begin_goaway("server shutdown")
        if drain:
            deadline = time.monotonic() + timeout
            for conn in conns:
                conn.wait_drain(deadline)
        for conn in conns:
            conn.close()
        if self._thread is not None:
            self._thread.join(timeout=2)
            self._thread = None
        if close_service:
            self.service.close()

    def __enter__(self) -> "MuxFederationServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- per-connection machinery -------------------------------------------

    def _serve_conn(self, raw, addr) -> None:
        raw.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        raw.settimeout(15.0)               # bound the handshake + preface
        if self._ssl is not None:
            try:
                sock = self._ssl.wrap_socket(raw, server_side=True)
            except (ssl.SSLError, OSError) as exc:
                # a failed handshake (bad client cert, protocol mismatch)
                # drops that connection only — the server keeps serving
                self.errors.append(("tls", str(exc)))
                raw.close()
                return
        else:
            sock = raw
        conn = _ServerConn(sock, sock.makefile("rb"), addr)
        try:
            preface = conn.rfile.read(len(PREFACE))
        except OSError:
            conn.close()
            return
        if preface != PREFACE:
            try:
                conn.write_frame(T_GOAWAY, 0, 0, _U32.pack(0) +
                                 b"bad connection preface")
            except OSError:
                pass
            conn.close()
            return
        sock.settimeout(None)
        with self._conns_lock:
            self._conns.add(conn)
        try:
            self._frame_loop(conn)
        except MuxProtocolError as exc:
            conn.begin_goaway(str(exc))
        except OSError:
            pass
        finally:
            conn.close()
            with self._conns_lock:
                self._conns.discard(conn)

    def _frame_loop(self, conn: _ServerConn) -> None:
        body_cap = 8 * self.service.max_report_bytes
        while True:
            fr = _read_frame(conn.rfile, self._max_frame)
            if fr is None:
                return
            ftype, flags, sid, payload = fr
            if ftype == T_HEADERS:
                with conn.lock:
                    if conn.goaway_sent:
                        # promised: streams past last_sid never processed
                        continue
                    if sid % 2 == 0 or sid <= conn.last_sid:
                        raise MuxProtocolError(
                            f"stream id {sid} is not odd and increasing")
                    conn.last_sid = sid
                try:
                    header = json.loads(payload.decode("utf-8"))
                    if not isinstance(header, dict):
                        raise ValueError("HEADERS payload is not an object")
                except (ValueError, UnicodeDecodeError) as exc:
                    raise MuxProtocolError(
                        f"corrupt HEADERS on stream {sid}: {exc}") from None
                st = _ServerStream(header, self._window)
                with conn.lock:
                    conn.streams[sid] = st
                if flags & F_END_STREAM:
                    self._finish_request(conn, sid, st)
            elif ftype == T_DATA:
                with conn.lock:
                    st = conn.streams.get(sid)
                if st is None:
                    continue           # post-GOAWAY residue / aborted stream
                if not st.poisoned:
                    st.body.extend(payload)
                    if len(st.body) > body_cap:
                        st.poisoned = True
                        self._respond(conn, sid, st,
                                      *FederationService._error(
                                          E.OversizedReport(
                                              f"mux request body exceeds "
                                              f"{body_cap} bytes")))
                if payload:
                    try:
                        conn.write_frame(T_WINDOW, 0, sid,
                                         _U32.pack(len(payload)))
                    except OSError:
                        return
                if flags & F_END_STREAM:
                    self._finish_request(conn, sid, st)
            elif ftype == T_WINDOW:
                with conn.lock:
                    st = conn.streams.get(sid)
                if st is not None:
                    st.out.grant(_U32.unpack(payload[:4])[0])
            elif ftype == T_PING:
                if not flags & F_ACK:
                    conn.write_frame(T_PING, F_ACK, 0, payload)
            elif ftype == T_GOAWAY:
                # client is closing; serve what's in flight, read to EOF
                continue
            else:
                raise MuxProtocolError(f"unknown frame type {ftype}")

    def _finish_request(self, conn: _ServerConn, sid: int,
                        st: _ServerStream) -> None:
        if st.poisoned:
            with conn.lock:
                conn.streams.pop(sid, None)
                conn.drain_cv.notify_all()
            return
        with conn.lock:
            conn.inflight += 1
        threading.Thread(target=self._dispatch, args=(conn, sid, st),
                         daemon=True, name="afl-mux-stream").start()

    def _dispatch(self, conn: _ServerConn, sid: int,
                  st: _ServerStream) -> None:
        try:
            header = st.header
            data, status = self.service.handle(
                str(header.get("route", "")), bytes(st.body),
                str(header.get("federation", "default")),
                token=header.get("token"))
            self._respond(conn, sid, st, data, status)
        except (OSError, ConnectionError):
            pass                            # peer went away mid-response
        except Exception as exc:            # noqa: BLE001
            self.errors.append(("dispatch", f"{type(exc).__name__}: {exc}"))
        finally:
            with conn.lock:
                conn.streams.pop(sid, None)
                conn.inflight -= 1
                conn.drain_cv.notify_all()

    def _respond(self, conn: _ServerConn, sid: int, st: _ServerStream,
                 data: bytes, status: int) -> None:
        if st.responded:
            return
        st.responded = True
        head = json.dumps({"status": int(status)}).encode("utf-8")
        if not data:
            conn.write_frame(T_RESPONSE, F_END_STREAM, sid, head)
            return
        conn.write_frame(T_RESPONSE, 0, sid, head)
        deadline = time.monotonic() + 60.0
        off = 0
        while off < len(data):
            n = st.out.take(min(self._chunk, len(data) - off), deadline)
            chunk = data[off:off + n]
            off += n
            conn.write_frame(
                T_DATA, F_END_STREAM if off == len(data) else 0, sid, chunk)


def serve_mux(service: FederationService, host: str = "127.0.0.1",
              port: int = 0, *,
              ssl_context: Optional[ssl.SSLContext] = None,
              **kw) -> MuxFederationServer:
    """Serve a federation over the mux protocol; returns the started server
    (``.url`` is ``mux://`` or ``muxs://`` with the ephemeral port)."""
    return MuxFederationServer(service, host, port,
                               ssl_context=ssl_context, **kw).start()


def mux_ping(url: str, *, timeout: float = 5.0,
             ssl_context: Optional[ssl.SSLContext] = None,
             cafile: Optional[str] = None) -> float:
    """One-shot liveness probe: connect, PING, close → latency seconds.
    Raises on any failure — callers treat an exception as 'not alive'."""
    tr = MuxTransport(url, ssl_context=ssl_context, cafile=cafile,
                      timeout=timeout)
    try:
        return tr.ping(timeout)
    finally:
        tr.close()


def probe_alive(url: str, *, timeout: float = 5.0,
                cafile: Optional[str] = None,
                auth_token: Optional[str] = None) -> bool:
    """Scheme-dispatching liveness probe for standby watchers: ``mux(s)://``
    rides a PING frame (no federation touched, no auth needed),
    ``http(s)://`` does a describe round-trip. True iff the endpoint
    answered."""
    try:
        if urllib.parse.urlsplit(url).scheme in ("mux", "muxs"):
            mux_ping(url, timeout=timeout, cafile=cafile)
        else:
            from repro.fl.service import RemoteCoordinator

            RemoteCoordinator(url, auth_token=auth_token,
                              cafile=cafile).close()
        return True
    except Exception:                                     # noqa: BLE001
        return False


# ---------------------------------------------------------------------------
# TLS helpers
# ---------------------------------------------------------------------------


def server_ssl_context(certfile: str, keyfile: str, *,
                       client_ca: Optional[str] = None) -> ssl.SSLContext:
    """Server-side TLS context from a cert/key PEM pair. With
    ``client_ca`` the server *requires* client certificates signed by (or
    identical to) that CA — mutual TLS."""
    ctx = ssl.SSLContext(ssl.PROTOCOL_TLS_SERVER)
    ctx.load_cert_chain(certfile, keyfile)
    if client_ca is not None:
        ctx.load_verify_locations(client_ca)
        ctx.verify_mode = ssl.CERT_REQUIRED
    return ctx


def client_ssl_context(cafile: Optional[str] = None, *,
                       certfile: Optional[str] = None,
                       keyfile: Optional[str] = None,
                       insecure: bool = False) -> ssl.SSLContext:
    """Client-side TLS context. ``cafile`` pins the server cert (pass the
    server's own PEM for self-signed deployments); ``certfile``/``keyfile``
    present a client certificate for mutual TLS; ``insecure`` disables
    verification (test rigs only)."""
    ctx = ssl.create_default_context(cafile=cafile)
    if certfile is not None:
        ctx.load_cert_chain(certfile, keyfile)
    if insecure:
        ctx.check_hostname = False
        ctx.verify_mode = ssl.CERT_NONE
    return ctx


def generate_self_signed_cert(directory, *, common_name: str = "127.0.0.1",
                              days: int = 2) -> Tuple[str, str]:
    """(cert.pem, key.pem) under ``directory`` via the ``openssl`` CLI —
    the no-extra-deps path tests, benches, and the runbook share. The cert
    carries a SAN for ``common_name`` as both DNS name and IP, so default
    hostname checking passes against loopback."""
    import pathlib

    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    cert, key = str(d / "cert.pem"), str(d / "key.pem")
    san = f"subjectAltName=DNS:{common_name},IP:{common_name}" \
        if _is_ip(common_name) else f"subjectAltName=DNS:{common_name}"
    subprocess.run(
        ["openssl", "req", "-x509", "-newkey", "rsa:2048", "-sha256",
         "-keyout", key, "-out", cert, "-days", str(days), "-nodes",
         "-subj", f"/CN={common_name}", "-addext", san],
        check=True, capture_output=True)
    return cert, key


def _is_ip(name: str) -> bool:
    try:
        socket.inet_aton(name)
        return True
    except OSError:
        return False
