"""Client data partitioners: IID, NIID-1 (Dirichlet/LDA), NIID-2 (Sharding).

Paper §4.1: NIID-1 draws each client's class mixture from Dir(α) (smaller α →
more heterogeneous; the paper stresses α down to 0.005). NIID-2 sorts by
label, cuts into equal shards and deals s shards per client (smaller s → more
heterogeneous; down to s=2). All partitioners return a list of K index arrays
covering the dataset (possibly empty for extreme α — AFL tolerates empty
clients, their Gram contribution is γI which the RI process removes).
"""

from __future__ import annotations

import numpy as np


def iid(labels: np.ndarray, num_clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(labels))
    return [np.sort(p) for p in np.array_split(perm, num_clients)]


def dirichlet(labels: np.ndarray, num_clients: int, alpha: float, seed: int = 0):
    """NIID-1 (LDA): for each class, split its samples across clients with
    proportions ~ Dir(α)."""
    rng = np.random.default_rng(seed)
    out = [[] for _ in range(num_clients)]
    for c in np.unique(labels):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * num_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for k, part in enumerate(np.split(idx, cuts)):
            out[k].append(part)
    return [np.sort(np.concatenate(p)) if p else np.array([], int) for p in out]


def sharding(labels: np.ndarray, num_clients: int, shards_per_client: int,
             seed: int = 0):
    """NIID-2: sort by label, cut into K*s equal shards, deal s per client."""
    rng = np.random.default_rng(seed)
    order = np.argsort(labels, kind="stable")
    n_shards = num_clients * shards_per_client
    shards = np.array_split(order, n_shards)
    assign = rng.permutation(n_shards)
    out = []
    for k in range(num_clients):
        mine = assign[k * shards_per_client : (k + 1) * shards_per_client]
        out.append(np.sort(np.concatenate([shards[s] for s in mine])))
    return out


def make_partition(labels, num_clients, scheme: str, *, alpha=0.1,
                   shards_per_client=4, seed=0):
    if scheme == "iid":
        return iid(labels, num_clients, seed)
    if scheme == "niid1":
        return dirichlet(labels, num_clients, alpha, seed)
    if scheme == "niid2":
        return sharding(labels, num_clients, shards_per_client, seed)
    raise ValueError(f"unknown partition scheme {scheme!r}")
