"""Replication: the durable submit ledger, warm standby, and read replicas.

AFL's absolute-aggregation law makes the server's entire state an additive
sum of client SuffStats — so an append-only log of the *accepted report
payloads* is a complete replication log. Replaying it through any
coordinator's ``submit`` (which re-runs the exact validation the primary
ran: duplicate-client guard, γ mismatch, CRC) reproduces the aggregate
exactly, on any box, at any shard count. That one observation yields the
whole multi-box story in three small pieces:

  * :class:`ReportLedger` — a durable, CRC-framed, append-only segment log
    the :class:`~repro.fl.service.FederationService` writes on every
    accepted ``submit`` / ``submit_stream`` frame. Batched fsync (one
    ``sync()`` per stream batch, not per record), sealed-segment rotation,
    crash-truncated-tail recovery on open, and compaction down to a
    snapshot reference plus the suffix of records the snapshot missed.
  * :class:`LedgerTailer` + :class:`WarmStandby` — a follower that
    cold-starts from the latest :class:`~repro.checkpoint.SnapshotDaemon`
    snapshot and tails the ledger. Because replay goes through ``submit``,
    records the snapshot already covers skip on the coordinator's own
    duplicate-client guard *before any mutation* — so ``promote()`` yields
    a coordinator bit-for-bit (f64) equal to the never-crashed oracle:
    snapshot state is bitwise the oracle's prefix (the ``gram_diag_raw``
    checkpoint rider), and the replayed suffix folds in the primary's
    accept order. Zero reports lost.
  * :class:`WeightsReplica` — a read-only coordinator that follows the
    primary's epoch through the same ledger and serves ``weights`` /
    ``solve`` / ``personalized_solve`` / ``sweep`` from its *own* cached
    factor. Its ETag salt is its own (every coordinator instance mints a
    fresh one), so a token minted by the primary never revalidates on a
    replica and vice versa; while catching up past ``max_lag`` it answers
    the typed retryable ``unavailable`` instead of serving stale heads.

Ledger layout (one directory)::

    ledger-{start_seq:012d}.seg       segment: 8-byte magic, then records
    ledger-checkpoint.json            compaction floor: snapshot ref + base_seq

Record framing (little-endian)::

    u32 body_len | u32 crc32(body) | body
    body = u32 meta_len | meta JSON ({"seq": .., "cid": ..}) | report payload

A torn tail (crash mid-append) fails the CRC of its last record; open-time
recovery truncates the file back to the last clean record, and a tailer
reading a live segment simply stops at the tear and retries next poll.
"""

from __future__ import annotations

import inspect
import json
import os
import pathlib
import struct
import threading
import zlib
from typing import (Any, Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple, Type)

import numpy as np

from repro.fl import errors as E
from repro.fl.api import AFLServer, ClientReport, GammaSweep, VersionedWeights

__all__ = [
    "ReportLedger",
    "LedgerTailer",
    "WarmStandby",
    "WeightsReplica",
    "compact_ledger_dir",
    "last_seq_on_disk",
    "watch_primary",
]

_SEG_MAGIC = b"AFLGSG1\n"               # 8 bytes, versioned
_SEG_GLOB = "ledger-*.seg"
_CKPT_NAME = "ledger-checkpoint.json"
_REC_HDR = struct.Struct("<II")         # body_len, crc32(body)
_U32 = struct.Struct("<I")


def _seg_start(path: pathlib.Path) -> int:
    """First sequence number a segment file may contain (from its name)."""
    return int(path.name[len("ledger-"):-len(".seg")])


def _seg_name(start_seq: int) -> str:
    return f"ledger-{start_seq:012d}.seg"


def _list_segments(directory: pathlib.Path) -> List[pathlib.Path]:
    return sorted(directory.glob(_SEG_GLOB), key=_seg_start)


def _parse_records(buf: bytes, base_off: int):
    """Yield ``(end_offset, seq, client_id, payload)`` for every complete,
    CRC-clean record in ``buf`` (whose first byte sits at file offset
    ``base_off``); stop at the first incomplete or corrupt record — a live
    tail and a torn tail look the same to a reader, and both mean "no more
    records *yet*"."""
    off = 0
    n = len(buf)
    while off + _REC_HDR.size <= n:
        body_len, crc = _REC_HDR.unpack_from(buf, off)
        end = off + _REC_HDR.size + body_len
        if body_len < _U32.size or end > n:
            return                          # incomplete (torn or still being written)
        body = buf[off + _REC_HDR.size: end]
        if zlib.crc32(body) != crc:
            return                          # torn mid-record
        (meta_len,) = _U32.unpack_from(body, 0)
        if _U32.size + meta_len > len(body):
            return
        try:
            meta = json.loads(body[_U32.size: _U32.size + meta_len])
            seq, cid = int(meta["seq"]), int(meta["cid"])
        except (ValueError, KeyError, TypeError):
            return
        payload = body[_U32.size + meta_len:]
        off = end
        yield base_off + off, seq, cid, payload


class ReportLedger:
    """Durable append-only log of accepted report payloads.

    One writer (the serving process) appends; any number of tailers read.
    Appends buffer in the OS; durability is explicit — the service calls
    :meth:`sync` once per acknowledged request (one fsync per stream
    *batch*, not per record), and a safety valve fsyncs automatically every
    ``fsync_batch`` appends. ``segment_bytes`` caps a segment before
    rotation seals it; sealed segments are immutable and therefore safe to
    delete under :meth:`compact` once a snapshot covers them.

    Open-time recovery: the final (active) segment is scanned and
    physically truncated back to its last CRC-clean record, so a crash
    mid-append can never leave a half-record in front of future appends.
    """

    def __init__(self, directory, *, segment_bytes: int = 8 << 20,
                 fsync_batch: int = 64):
        self.directory = pathlib.Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self.segment_bytes = int(segment_bytes)
        self.fsync_batch = max(1, int(fsync_batch))
        self._lock = threading.RLock()
        self._fh = None
        self._unsynced = 0
        self._last_seq = 0
        self._durable_seq = 0
        self._recover()

    # -- open / recovery -----------------------------------------------------

    def _recover(self) -> None:
        segs = _list_segments(self.directory)
        last_seq = self.base_seq
        for i, path in enumerate(segs):
            data = path.read_bytes()
            good_end = len(_SEG_MAGIC)
            if data[:len(_SEG_MAGIC)] != _SEG_MAGIC:
                good_end = 0                # torn header write
            else:
                for end, seq, _cid, _p in _parse_records(
                        data[len(_SEG_MAGIC):], len(_SEG_MAGIC)):
                    good_end, last_seq = end, seq
            if i == len(segs) - 1 and good_end < len(data):
                # active segment: truncate the torn tail away
                with path.open("r+b") as f:
                    f.truncate(good_end)
                if good_end == 0:           # header itself was torn
                    path.write_bytes(_SEG_MAGIC)
        self._last_seq = self._durable_seq = last_seq
        if segs:
            self._fh = segs[-1].open("ab")
        else:
            self._open_segment(1)

    def _open_segment(self, start_seq: int) -> None:
        if self._fh is not None:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self._fh.close()
        path = self.directory / _seg_name(start_seq)
        self._fh = path.open("ab")
        if self._fh.tell() == 0:
            self._fh.write(_SEG_MAGIC)
            self._fh.flush()

    # -- append side ---------------------------------------------------------

    @property
    def last_seq(self) -> int:
        """Sequence number of the newest appended record (0 when empty)."""
        return self._last_seq

    @property
    def durable_seq(self) -> int:
        """Newest sequence number known to have reached stable storage."""
        return self._durable_seq

    def append(self, payload: bytes, client_id: int) -> int:
        """Append one accepted report payload; returns its sequence number.
        Buffered — call :meth:`sync` before acknowledging the client."""
        payload = bytes(payload)
        with self._lock:
            if self._fh.tell() >= self.segment_bytes:
                self.rotate()
            seq = self._last_seq + 1
            meta = json.dumps({"seq": seq, "cid": int(client_id)},
                              separators=(",", ":")).encode()
            body = _U32.pack(len(meta)) + meta + payload
            self._fh.write(_REC_HDR.pack(len(body), zlib.crc32(body)) + body)
            self._last_seq = seq
            self._unsynced += 1
            if self._unsynced >= self.fsync_batch:
                self.sync()
            return seq

    def sync(self) -> int:
        """Flush and fsync everything appended so far; returns the durable
        sequence number. The service calls this once per acknowledged
        request — the fsync-batching win for streamed uploads."""
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
                os.fsync(self._fh.fileno())
            self._durable_seq = self._last_seq
            self._unsynced = 0
            return self._durable_seq

    def rotate(self) -> None:
        """Seal the active segment and start a fresh one. Sealed segments
        never change again — the compaction-safety invariant."""
        with self._lock:
            self._open_segment(self._last_seq + 1)

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self.sync()
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "ReportLedger":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- read side -----------------------------------------------------------

    def records(self, after_seq: int = 0
                ) -> Iterator[Tuple[int, int, bytes]]:
        """Yield ``(seq, client_id, payload)`` for every record with
        ``seq > after_seq``, oldest first, reading straight from disk (a
        fresh view — safe from any process)."""
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
        for path in _list_segments(self.directory):
            data = path.read_bytes()
            if data[:len(_SEG_MAGIC)] != _SEG_MAGIC:
                continue
            for _end, seq, cid, payload in _parse_records(
                    data[len(_SEG_MAGIC):], len(_SEG_MAGIC)):
                if seq > after_seq:
                    yield seq, cid, payload

    def find_crc(self, client_id: int) -> Optional[int]:
        """CRC-32 of the *newest* payload this ledger holds for a client, or
        ``None``. The disk half of the idempotent-ingest discipline: the
        in-memory ``applied`` map is an LRU over this — an evicted entry is
        recovered here (newest-segment-first scan), so bounding the map
        never breaks ``duplicate: true`` replay answers."""
        cid = int(client_id)
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
        for path in reversed(_list_segments(self.directory)):
            data = path.read_bytes()
            if data[:len(_SEG_MAGIC)] != _SEG_MAGIC:
                continue
            hit = None
            for _end, _seq, rec_cid, payload in _parse_records(
                    data[len(_SEG_MAGIC):], len(_SEG_MAGIC)):
                if rec_cid == cid:
                    hit = zlib.crc32(payload)   # later record wins
            if hit is not None:
                return hit
        return None

    # -- compaction ----------------------------------------------------------

    @property
    def _ckpt_path(self) -> pathlib.Path:
        return self.directory / _CKPT_NAME

    def _read_ckpt(self) -> Dict[str, Any]:
        try:
            return json.loads(self._ckpt_path.read_text())
        except (OSError, ValueError):
            return {}

    @property
    def base_seq(self) -> int:
        """Compaction floor: every record with ``seq ≤ base_seq`` is covered
        by :attr:`snapshot_ref` and may no longer exist on disk."""
        return int(self._read_ckpt().get("base_seq", 0))

    @property
    def snapshot_ref(self) -> Optional[str]:
        """Checkpoint directory that covers everything up to
        :attr:`base_seq` (a follower cold-starts there, then tails)."""
        ref = self._read_ckpt().get("snapshot")
        return None if ref is None else str(ref)

    def compact(self, snapshot_ref, base_seq: int) -> List[pathlib.Path]:
        """Drop sealed segments every record of which is ≤ ``base_seq``
        (i.e. covered by the snapshot at ``snapshot_ref``), and persist the
        (snapshot, base_seq) floor. The active segment is never deleted.
        Returns the deleted segment paths."""
        base_seq = int(base_seq)
        with self._lock:
            self.sync()
            segs = _list_segments(self.directory)
            deleted = []
            # a sealed segment's records all precede the next segment's start
            for path, nxt in zip(segs[:-1], segs[1:]):
                if _seg_start(nxt) - 1 <= base_seq:
                    path.unlink()
                    deleted.append(path)
            tmp = self._ckpt_path.with_suffix(".tmp")
            tmp.write_text(json.dumps(
                {"snapshot": None if snapshot_ref is None
                 else str(snapshot_ref),
                 "base_seq": max(base_seq, self.base_seq)}))
            os.replace(tmp, self._ckpt_path)
            return deleted


def last_seq_on_disk(directory) -> int:
    """Newest sequence number any reader can currently see under
    ``directory`` (scans the final segment only — the lag probe)."""
    directory = pathlib.Path(directory)
    segs = _list_segments(directory)
    for path in reversed(segs):
        data = path.read_bytes()
        if data[:len(_SEG_MAGIC)] != _SEG_MAGIC:
            continue
        last = 0
        for _end, seq, _cid, _p in _parse_records(
                data[len(_SEG_MAGIC):], len(_SEG_MAGIC)):
            last = seq
        if last:
            return last
        # empty (freshly rotated) segment — fall back one
    try:
        ckpt = json.loads((directory / _CKPT_NAME).read_text())
        return int(ckpt.get("base_seq", 0))
    except (OSError, ValueError):
        return 0


def compact_ledger_dir(directory, snapshot_ref,
                       base_seq: int) -> List[pathlib.Path]:
    """Out-of-process compaction: the same sealed-segment drop + checkpoint
    floor as :meth:`ReportLedger.compact`, but safe to run against a
    directory whose writer lives in ANOTHER process. Opening a second
    :class:`ReportLedger` would be wrong here — its open-time recovery
    physically truncates what it takes for a torn tail, racing the live
    writer's active segment. This helper only ever deletes *sealed*
    segments fully covered by ``base_seq`` and atomically rewrites the
    checkpoint file; the active (last) segment is never touched. The
    caller owns the safety of ``base_seq`` (e.g. a ``describe`` that
    reported the seq with ``pending == 0``). Returns deleted paths."""
    directory = pathlib.Path(directory)
    base_seq = int(base_seq)
    segs = _list_segments(directory)
    deleted = []
    for path, nxt in zip(segs[:-1], segs[1:]):
        if _seg_start(nxt) - 1 <= base_seq:
            try:
                path.unlink()
            except OSError:
                continue               # already gone (concurrent compactor)
            deleted.append(path)
    ckpt_path = directory / _CKPT_NAME
    try:
        prior = int(json.loads(ckpt_path.read_text()).get("base_seq", 0))
    except (OSError, ValueError):
        prior = 0
    tmp = ckpt_path.with_suffix(".tmp")
    tmp.write_text(json.dumps(
        {"snapshot": None if snapshot_ref is None else str(snapshot_ref),
         "base_seq": max(base_seq, prior)}))
    os.replace(tmp, ckpt_path)
    return deleted


class LedgerTailer:
    """Incremental read cursor over a :class:`ReportLedger` directory.

    Read-only and crash-tolerant: it never truncates — a torn or
    still-being-written record simply ends the poll (the writer's own
    open-time recovery, or the next append, resolves it). Each
    :meth:`poll` reads only bytes past the cursor, advancing across sealed
    segments on clean end-of-segment. ``position`` is the last sequence
    number delivered."""

    def __init__(self, directory, *, after_seq: int = 0):
        self.directory = pathlib.Path(directory)
        self.position = int(after_seq)
        self._seg: Optional[pathlib.Path] = None
        self._off = 0
        # True when the last poll() consumed every readable byte (parked at
        # the live tip); False when it parked at a torn/half-written record.
        # Snapshot of that instant — a later append makes it stale until
        # the next poll, so it is a fast-path hint, not a lag oracle.
        self.at_tip = False

    def _pick_segment(self) -> Optional[pathlib.Path]:
        """Newest segment that may contain ``position + 1`` (compacted-away
        prefixes fall forward to the oldest surviving segment)."""
        segs = _list_segments(self.directory)
        if not segs:
            return None
        pick = segs[0]
        for p in segs:
            if _seg_start(p) <= self.position + 1:
                pick = p
        return pick

    def poll(self) -> List[Tuple[int, int, bytes]]:
        """All records appended (and readable) since the last poll, as
        ``(seq, client_id, payload)`` tuples, oldest first."""
        out: List[Tuple[int, int, bytes]] = []
        while True:
            if self._seg is None:
                self._seg = self._pick_segment()
                if self._seg is None:
                    self.at_tip = True      # nothing on disk at all
                    return out
                self._off = len(_SEG_MAGIC)
            try:
                with self._seg.open("rb") as f:
                    f.seek(self._off)
                    buf = f.read()
            except OSError:
                self._seg = None            # compacted away — re-pick
                continue
            clean_end = self._off
            for end, seq, cid, payload in _parse_records(buf, self._off):
                clean_end = end
                if seq > self.position:
                    out.append((seq, cid, payload))
                    self.position = seq
            consumed_all = clean_end - self._off == len(buf)
            self._off = clean_end
            if not consumed_all:
                self.at_tip = False
                return out                  # live/torn tail — retry later
            # clean end-of-segment: advance iff a later segment exists
            nxt = [p for p in _list_segments(self.directory)
                   if _seg_start(p) > _seg_start(self._seg)]
            if not nxt:
                self.at_tip = True
                return out
            self._seg = nxt[0]
            self._off = len(_SEG_MAGIC)

    def lag(self) -> int:
        """Records appended but not yet delivered to this tailer."""
        return max(0, last_seq_on_disk(self.directory) - self.position)


# ---------------------------------------------------------------------------
# Warm standby
# ---------------------------------------------------------------------------


def _latest_snapshot(snapshot_dir) -> Optional[pathlib.Path]:
    d = pathlib.Path(snapshot_dir)
    if not d.is_dir():
        return None
    snaps = sorted(p for p in d.glob("snap-*")
                   if (p / "manifest.json").exists())
    return snaps[-1] if snaps else None


class WarmStandby:
    """A follower coordinator: snapshot cold-start + ledger tail + promote.

    Cold-start precedence: an explicitly passed ``coordinator`` > the
    newest snapshot under ``snapshot_dir`` > the ledger's own compaction
    ``snapshot_ref`` > an empty ``cls(**ctor_kw)``. From there the standby
    replays every ledger record through ``coordinator.submit`` — records
    the snapshot already covers are skipped by the coordinator's own
    duplicate-client guard *before any state moves*, which is what makes
    replay-from-anywhere exact: the result is bitwise the primary's fold
    sequence, not an approximation of it.

    ``start()`` tails in a background thread; :meth:`promote` stops the
    tail, drains the remaining suffix, refreshes the coordinator's ETag
    salt (tokens minted by the dead primary must never revalidate here)
    and returns the coordinator — ready for
    ``FederationService.restore_federation`` or, when the standby was
    hosted via ``FederationService.host_standby``, the wire ``promote``
    route.
    """

    def __init__(self, ledger_dir, *, snapshot_dir=None, coordinator=None,
                 cls: Type = AFLServer, ctor_kw: Optional[dict] = None,
                 from_state_kw: Optional[dict] = None,
                 poll_interval: float = 0.05):
        self.ledger_dir = pathlib.Path(ledger_dir)
        self.snapshot_dir = (None if snapshot_dir is None
                             else pathlib.Path(snapshot_dir))
        self.poll_interval = float(poll_interval)
        self.applied = 0                    # records folded from the ledger
        self.skipped = 0                    # duplicates / rejected replays
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._apply_lock = threading.RLock()
        self.coordinator = coordinator if coordinator is not None else \
            self._cold_start(cls, dict(ctor_kw or {}),
                             dict(from_state_kw or {}))
        # replay from the beginning of whatever the ledger still holds:
        # the seen-set guard makes the overlap with the snapshot a no-op
        self._tailer = LedgerTailer(self.ledger_dir)

    def _cold_start(self, cls, ctor_kw, from_state_kw):
        import repro.checkpoint as ckpt

        snap = (None if self.snapshot_dir is None
                else _latest_snapshot(self.snapshot_dir))
        if snap is None:
            # the ledger's own compaction floor names the snapshot that
            # covers the deleted prefix
            ref = self._ledger_ckpt().get("snapshot")
            if ref and pathlib.Path(ref).is_dir():
                snap = pathlib.Path(ref)
        if snap is not None:
            return ckpt.load_server(snap, cls, **from_state_kw)
        if not ctor_kw:
            raise E.BadRequest(
                "warm standby has no snapshot to cold-start from and no "
                "ctor_kw (dim/num_classes/...) to start empty")
        return cls(**ctor_kw)

    def _ledger_ckpt(self) -> Dict[str, Any]:
        try:
            return json.loads((self.ledger_dir / _CKPT_NAME).read_text())
        except (OSError, ValueError):
            return {}

    # -- replay --------------------------------------------------------------

    def _apply(self, payload: bytes) -> bool:
        """Fold one ledger record; duplicates and invalid replays skip —
        the same outcome the primary's worker produced for them. An async
        coordinator folds through its wrapped sync server (same state, no
        event loop needed on the replay path)."""
        try:
            report = ClientReport.from_bytes(payload)
            target = self.coordinator
            if inspect.iscoroutinefunction(getattr(target, "submit", None)):
                target = target.server
            target.submit(report)
            return True
        except (E.DuplicateClient, E.GammaMismatch, ValueError):
            return False

    def catch_up(self) -> int:
        """Drain everything currently readable from the ledger; returns the
        number of records newly folded."""
        folded = 0
        with self._apply_lock:
            while True:
                batch = self._tailer.poll()
                if not batch:
                    return folded
                for _seq, _cid, payload in batch:
                    if self._apply(payload):
                        folded += 1
                        self.applied += 1
                    else:
                        self.skipped += 1

    @property
    def position(self) -> int:
        """Ledger sequence number of the last record examined."""
        return self._tailer.position

    def lag(self) -> int:
        """Records durable in the ledger but not yet replayed here."""
        return self._tailer.lag()

    # -- the background tail -------------------------------------------------

    def start(self) -> "WarmStandby":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._run, daemon=True, name="afl-standby-tail")
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=max(5.0, 20 * self.poll_interval))
            self._thread = None

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                self.catch_up()
            except Exception:               # noqa: BLE001 — keep tailing
                pass
            self._stop.wait(self.poll_interval)

    def promote(self):
        """Standby → primary: stop tailing, drain the remaining ledger
        suffix, invalidate every token the old primary minted (fresh ETag
        salt), and hand the coordinator over. Bit-for-bit (f64) the
        never-crashed oracle: snapshot prefix bitwise (``gram_diag_raw``
        rider) + suffix folded in the primary's accept order."""
        self.stop()
        self.catch_up()
        refresh = getattr(self.coordinator, "new_etag_salt", None)
        if refresh is not None:
            refresh()
        return self.coordinator

    def __enter__(self) -> "WarmStandby":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


# ---------------------------------------------------------------------------
# Read replica
# ---------------------------------------------------------------------------


class WeightsReplica:
    """Read-only coordinator following the primary's epoch via the ledger.

    Satisfies the read half of the :class:`~repro.fl.api.Coordinator`
    protocol from its *own* cached factor — the solve-once /
    download-millions path never touches the primary's ingest lock. The
    mutating half (``submit`` / ``grow`` / ``shrink``) raises the typed
    ``read_only`` error, and a :class:`~repro.fl.service.FederationService`
    hosting a replica rejects the mutating routes before dispatch
    (``read_only = True`` is the autodetect hook).

    Staleness contract: with ``auto_refresh`` (default) every read first
    drains the ledger tail; if the replica still trails the primary by more
    than ``max_lag`` records (a torn tail it cannot read past, or
    ``auto_refresh=False`` between manual :meth:`refresh` calls) the read
    raises the retryable typed ``unavailable`` rather than serving a stale
    head. ETag semantics are instance-scoped exactly like every other
    coordinator: the replica's tokens are minted under its own salt, so a
    token from the primary never revalidates here and vice versa — a
    client switching endpoints re-downloads once, then caches against the
    replica."""

    read_only = True

    def __init__(self, ledger_dir, *, snapshot_dir=None,
                 cls: Type = AFLServer, ctor_kw: Optional[dict] = None,
                 from_state_kw: Optional[dict] = None, max_lag: int = 0,
                 auto_refresh: bool = True):
        self._standby = WarmStandby(ledger_dir, snapshot_dir=snapshot_dir,
                                    cls=cls, ctor_kw=ctor_kw,
                                    from_state_kw=from_state_kw)
        self.max_lag = int(max_lag)
        self.auto_refresh = bool(auto_refresh)
        self._standby.catch_up()

    # -- follow the primary --------------------------------------------------

    @property
    def _coord(self):
        return self._standby.coordinator

    def refresh(self) -> int:
        """Drain the ledger tail into the local aggregate; returns newly
        folded records."""
        return self._standby.catch_up()

    @property
    def position(self) -> int:
        return self._standby.position

    @property
    def lag(self) -> int:
        """Records the primary has durably accepted that this replica has
        not folded yet."""
        return self._standby.lag()

    def _ready(self) -> None:
        if self.auto_refresh:
            self._standby.catch_up()
            if self._standby._tailer.at_tip:
                return                      # drained to the live tip: lag 0
                                            # without the disk lag() scan
        lag = self.lag
        if lag > self.max_lag:
            raise E.Unavailable(
                f"read replica is {lag} records behind the primary "
                f"(max_lag={self.max_lag}) — catching up, retry")

    # -- metadata (never gated: a lagging replica still describes itself) ----

    @property
    def dim(self) -> int:
        return self._coord.dim

    @property
    def num_classes(self) -> int:
        return self._coord.num_classes

    @property
    def gamma(self) -> float:
        return self._coord.gamma

    @property
    def num_clients(self) -> int:
        return self._coord.num_clients

    @property
    def version(self) -> int:
        return self._coord.version

    @property
    def mesh_epoch(self) -> int:
        return int(getattr(self._coord, "mesh_epoch", 0))

    @property
    def pending(self) -> int:
        """For a replica, "pending" is its replication lag."""
        return self.lag

    # -- the read surface ----------------------------------------------------

    def solve(self, target_gamma: float = 0.0) -> np.ndarray:
        self._ready()
        return self._coord.solve(target_gamma)

    def solve_multi_gamma(self, gammas: Sequence[float]) -> list:
        self._ready()
        return self._coord.solve_multi_gamma(gammas)

    def sweep(self, gammas: Sequence[float], holdout) -> GammaSweep:
        self._ready()
        return self._coord.sweep(gammas, holdout)

    def weights(self, target_gamma: float = 0.0, *,
                if_etag: Optional[str] = None) -> VersionedWeights:
        self._ready()
        return self._coord.weights(target_gamma, if_etag=if_etag)

    def state(self) -> Dict[str, np.ndarray]:
        self._ready()
        return self._coord.state()

    def new_etag_salt(self) -> str:
        return self._coord.new_etag_salt()

    # -- the rejected mutating surface ---------------------------------------

    def _read_only(self, verb: str):
        raise E.ReadOnlyFederation(
            f"{verb} on a weights read replica — replicas follow the "
            "primary's ledger and never ingest; send writes to the primary")

    def submit(self, report) -> bool:
        self._read_only("submit")

    def submit_many(self, reports) -> None:
        self._read_only("submit")

    def grow(self, n: int = 1) -> int:
        self._read_only("grow")

    def shrink(self, n: int = 1) -> int:
        self._read_only("shrink")

    def close(self) -> None:
        self._standby.stop()


# ---------------------------------------------------------------------------
# The promotion watch loop (standbyd / serve --standby-of)
# ---------------------------------------------------------------------------


def watch_primary(standby: WarmStandby, is_alive: Callable[[], bool], *,
                  grace: int = 3, interval: float = 1.0,
                  stop: Optional[threading.Event] = None,
                  on_promote: Optional[Callable[[Any], None]] = None):
    """Tail the ledger while the primary answers; after ``grace``
    consecutive liveness failures, :meth:`WarmStandby.promote` and return
    the promoted coordinator (``on_promote`` then fires with it, e.g. to
    flip a hosting service's suspended latch — a second ``promote`` through
    the service is a harmless no-op). Returns ``None`` if ``stop`` was set
    before promotion was warranted."""
    stop = stop or threading.Event()
    standby.start()
    failures = 0
    while not stop.is_set():
        try:
            alive = bool(is_alive())
        except Exception:                   # noqa: BLE001 — a probe error IS a failure
            alive = False
        failures = 0 if alive else failures + 1
        if failures >= max(1, int(grace)):
            coordinator = standby.promote()
            if on_promote is not None:
                on_promote(coordinator)
            return coordinator
        stop.wait(float(interval))
    standby.stop()
    return None
