"""AFL server: incremental aggregation, partial participation, stragglers,
and secure (masked) aggregation.

The paper's §5 lists partial participation and stragglers as open problems
for AFL ("clients can only contribute after finishing local computations; the
AFL needs to wait for all the clients"). The AA law actually makes these
*easy*, and this module implements the consequences:

  * Sufficient statistics are additive ⇒ the server can aggregate clients
    **incrementally, in any order, at any time**. After any subset S has
    reported, ``solve()`` returns the weight that joint training on ∪S's
    data would produce — exactly, by Theorem 1. A straggler that reports
    later just adds its (C_k^r, Q_k) and the next solve is exact for the
    larger subset. No round structure, no re-training, no staleness.
  * The server never needs raw features, and with **pairwise masking**
    (SecAgg-style) it never even sees an individual client's statistics:
    clients u<v share a seed; u adds M_{uv}, v subtracts it. Masks cancel in
    the sum, and because AFL's aggregation IS a sum, masked aggregation is
    *bit-exact* — unlike gradient FL where masking must survive averaging
    weights by data size.

All server state is two matrices and a count — see :class:`AFLServer`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Sequence

import numpy as np

from repro.core import analytic as al

__all__ = ["ClientReport", "AFLServer", "masked_reports"]


@dataclasses.dataclass(frozen=True)
class ClientReport:
    """What one client uploads: regularized sufficient statistics.

    gram:   C_k^r = X_kᵀX_k + γI   (d, d)
    moment: Q_k   = X_kᵀY_k        (d, C)
    (Equivalent information to the paper's (Ŵ_k^r, C_k^r) upload —
    Q_k = C_k^r Ŵ_k^r — but numerically nicer to accumulate.)
    """

    client_id: int
    gram: np.ndarray
    moment: np.ndarray
    gamma: float


def make_report(client_id: int, x: np.ndarray, y_onehot: np.ndarray,
                gamma: float) -> ClientReport:
    x = np.asarray(x, np.float64)
    y = np.asarray(y_onehot, np.float64)
    d = x.shape[1]
    return ClientReport(client_id, x.T @ x + gamma * np.eye(d), x.T @ y, gamma)


class AFLServer:
    """Incremental AFL aggregation with RI restore at solve time.

    >>> server = AFLServer(dim=d, num_classes=c, gamma=1.0)
    >>> server.submit(report)              # any order, any time
    >>> w = server.solve()                 # exact joint weight over arrivals
    """

    def __init__(self, dim: int, num_classes: int, gamma: float = 1.0):
        self.dim = dim
        self.num_classes = num_classes
        self.gamma = gamma
        self._gram = np.zeros((dim, dim))
        self._moment = np.zeros((dim, num_classes))
        self._seen: set[int] = set()

    @property
    def num_clients(self) -> int:
        return len(self._seen)

    def submit(self, report: ClientReport) -> None:
        if report.client_id in self._seen:
            raise ValueError(f"client {report.client_id} already aggregated")
        if report.gamma != self.gamma:
            raise ValueError(
                f"client γ={report.gamma} != server γ={self.gamma}")
        self._gram += report.gram
        self._moment += report.moment
        self._seen.add(report.client_id)

    def submit_many(self, reports: Iterable[ClientReport]) -> None:
        for r in reports:
            self.submit(r)

    def solve(self, target_gamma: float = 0.0) -> np.ndarray:
        """Exact joint solution over all clients aggregated *so far*.

        RI restore (Thm 2): C_agg^r carries kγI for k = arrivals; remove it.
        Stragglers simply have not been added yet — calling solve() again
        after they report gives the exact larger-joint solution.
        """
        if not self._seen:
            raise ValueError("no clients aggregated")
        k = len(self._seen)
        c = self._gram - (k * self.gamma - target_gamma) * np.eye(self.dim)
        return al._sym_solve(c, self._moment)

    def state(self) -> Dict[str, np.ndarray]:
        """Serializable server state (see repro.checkpoint)."""
        return {
            "gram": self._gram.copy(),
            "moment": self._moment.copy(),
            "seen": np.array(sorted(self._seen), np.int64),
            "gamma": np.float64(self.gamma),
        }

    @classmethod
    def from_state(cls, state: Dict[str, np.ndarray],
                   num_classes: Optional[int] = None) -> "AFLServer":
        dim = state["gram"].shape[0]
        srv = cls(dim, num_classes or state["moment"].shape[1],
                  float(state["gamma"]))
        srv._gram = np.array(state["gram"])
        srv._moment = np.array(state["moment"])
        srv._seen = set(int(i) for i in state["seen"])
        return srv


def masked_reports(reports: Sequence[ClientReport],
                   seed: int = 0) -> list[ClientReport]:
    """SecAgg-style pairwise masking of the uploads.

    Every pair (u, v), u < v derives a shared mask from a common seed; u adds
    it, v subtracts it. Any single report is then statistically useless to
    the server, but Σ reports is unchanged — and since AFL aggregation IS
    that sum, the masked protocol is exact (tested to ~1e-9).
    """
    n = len(reports)
    masked_g = [r.gram.astype(np.float64).copy() for r in reports]
    masked_q = [r.moment.astype(np.float64).copy() for r in reports]
    for u in range(n):
        for v in range(u + 1, n):
            rng = np.random.default_rng(
                (seed, reports[u].client_id, reports[v].client_id))
            mg = rng.standard_normal(masked_g[u].shape)
            mq = rng.standard_normal(masked_q[u].shape)
            masked_g[u] += mg
            masked_g[v] -= mg
            masked_q[u] += mq
            masked_q[v] -= mq
    return [
        dataclasses.replace(r, gram=g, moment=q)
        for r, g, q in zip(reports, masked_g, masked_q)
    ]
