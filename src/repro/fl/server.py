"""AFL server: incremental aggregation, partial participation, stragglers,
and secure (masked) aggregation.

The paper's §5 lists partial participation and stragglers as open problems
for AFL ("clients can only contribute after finishing local computations; the
AFL needs to wait for all the clients"). The AA law actually makes these
*easy*, and this module implements the consequences:

  * Sufficient statistics are additive ⇒ the server can aggregate clients
    **incrementally, in any order, at any time**. After any subset S has
    reported, ``solve()`` returns the weight that joint training on ∪S's
    data would produce — exactly, by Theorem 1. A straggler that reports
    later just adds its (C_k^r, Q_k) and the next solve is exact for the
    larger subset. No round structure, no re-training, no staleness.
  * The server never needs raw features, and with **pairwise masking**
    (SecAgg-style) it never even sees an individual client's statistics:
    clients u<v share a seed; u adds M_{uv}, v subtracts it. Masks cancel in
    the sum, and because AFL's aggregation IS a sum, masked aggregation is
    *bit-exact* — unlike gradient FL where masking must survive averaging
    weights by data size.

All aggregation math routes through :class:`repro.core.engine.
AnalyticEngine` (``numpy_f64`` backend); the server itself owns only a
:class:`~repro.core.engine.SuffStats`, the set of seen client ids, and a
**cached Cholesky factorization**: the serving hot path polls ``solve()``
after every straggler arrival, and between arrivals the statistics are
unchanged — so the d³ factorization is computed once per (submission epoch,
target γ) and every further poll pays only the d²·C triangular solves.
Arrivals that carry a low-rank ``root`` of their Gram don't even end the
epoch: ``submit`` folds them into the cached factors as rank-n_k Cholesky
updates (engine ``factor_update``), and only rootless / high-rank arrivals
force a refactor. ``fl.async_server`` builds the event-loop serving story
on top of exactly this seam.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Sequence

import numpy as np

from repro.core.engine import AnalyticEngine, Factorization, SuffStats

__all__ = ["ClientReport", "AFLServer", "make_report", "masked_reports"]


@dataclasses.dataclass(frozen=True)
class ClientReport:
    """What one client uploads: regularized sufficient statistics.

    gram:   C_k^r = X_kᵀX_k + γI   (d, d)
    moment: Q_k   = X_kᵀY_k        (d, C)
    (Equivalent information to the paper's (Ŵ_k^r, C_k^r) upload —
    Q_k = C_k^r Ŵ_k^r — but numerically nicer to accumulate.)
    count: number of local samples (diagnostics only; 0 when unknown).
    root:  optional (n_k, d) square root of the RAW Gram, ``rootᵀroot =
           X_kᵀX_k`` (e.g. the R factor of QR(X_k)). It carries exactly the
           information already in ``gram`` — no extra privacy exposure — but
           lets the server fold the arrival into a cached Cholesky factor as
           a rank-n_k update instead of refactoring. ``None`` (unknown root,
           e.g. after masking) forces the refactor path.
    """

    client_id: int
    gram: np.ndarray
    moment: np.ndarray
    gamma: float
    count: float = 0.0
    root: Optional[np.ndarray] = None


def make_report(client_id: int, x: np.ndarray, y_onehot: np.ndarray,
                gamma: float) -> ClientReport:
    """One client's local stage → upload, via the engine's update path."""
    eng = AnalyticEngine("numpy_f64", gamma=gamma)
    stats = eng.client_stats(x, y_onehot)
    x2d = np.asarray(x, np.float64).reshape(-1, stats.dim)
    root = np.linalg.qr(x2d, mode="r") if x2d.shape[0] < stats.dim else None
    return ClientReport(client_id, eng.regularized_gram(stats), stats.moment,
                        gamma, count=float(stats.count), root=root)


class AFLServer:
    """Incremental AFL aggregation with RI restore at solve time.

    >>> server = AFLServer(dim=d, num_classes=c, gamma=1.0)
    >>> server.submit(report)              # any order, any time
    >>> w = server.solve()                 # exact joint weight over arrivals

    ``solve()`` factors the regularized aggregate once per submission epoch
    (and per distinct ``target_gamma``); repeated polls between arrivals
    reuse the cached factor. A ``submit`` whose report carries a low-rank
    ``root`` (n_k ≤ ``update_rank_budget``) folds the arrival into every
    cached factor as an O(n_k·d²) rank update; any other submit invalidates
    the cache and the next solve refactors.
    """

    def __init__(self, dim: int, num_classes: int, gamma: float = 1.0,
                 *, update_rank_budget: Optional[int] = None):
        self.dim = dim
        self.num_classes = num_classes
        self.gamma = gamma
        self.engine = AnalyticEngine("numpy_f64", gamma=gamma)
        # Rank-update crossover: past ~d/16 rows the k fused rank-1 sweeps
        # cost as much as the BLAS refactor (measured at d=2048 in
        # benchmarks/async_server_bench.py; small d always favors refactor).
        self.update_rank_budget = (
            max(1, dim // 16) if update_rank_budget is None
            else int(update_rank_budget))
        self._stats = self.engine.init(dim, num_classes)
        self._seen: set[int] = set()
        self._factor_cache: Dict[float, Factorization] = {}

    @property
    def num_clients(self) -> int:
        return len(self._seen)

    def submit(self, report: ClientReport) -> bool:
        """Merge one upload; returns True when the cached factors survived
        (rank-updated in place, or nothing was cached), False when the
        arrival invalidated them and the next solve will refactor."""
        if report.client_id in self._seen:
            raise ValueError(f"client {report.client_id} already aggregated")
        if report.gamma != self.gamma:
            raise ValueError(
                f"client γ={report.gamma} != server γ={self.gamma}")
        # Uploads carry the regularized C_k^r (paper form); the engine keeps
        # raw Grams with lazy per-client γ, so strip the γI on ingestion.
        raw = np.asarray(report.gram, np.float64) - self.gamma * np.eye(self.dim)
        upload = SuffStats(
            gram=raw,
            moment=np.asarray(report.moment, np.float64),
            count=float(report.count),
            clients=1.0,
        )
        self._stats = self.engine.merge(self._stats, upload)
        self._seen.add(report.client_id)
        if self._try_factor_update(report.root):
            return True
        self._factor_cache.clear()
        return False

    def _try_factor_update(self, root: Optional[np.ndarray]) -> bool:
        """Fold an arrival's low-rank root into every cached factor; False
        when the cache must be invalidated instead (no root, rank past the
        crossover, or a non-updatable pinv-fallback factor)."""
        if not self._factor_cache:
            return True                    # nothing cached — nothing to do
        if root is None:
            return False
        root = np.asarray(root, np.float64).reshape(-1, self.dim)
        if root.shape[0] > self.update_rank_budget:
            return False
        if not all(f.updatable for f in self._factor_cache.values()):
            return False
        self._factor_cache = {
            key: f.rank_update(root) for key, f in self._factor_cache.items()}
        return True

    def submit_many(self, reports: Iterable[ClientReport]) -> None:
        for r in reports:
            self.submit(r)

    def solve(self, target_gamma: float = 0.0) -> np.ndarray:
        """Exact joint solution over all clients aggregated *so far*.

        RI restore (Thm 2): the engine's lazy-γ bookkeeping means the kγI of
        the k arrivals is never materialized; only ``target_gamma`` enters
        the system. Stragglers simply have not been added yet — calling
        solve() again after they report gives the exact larger-joint
        solution (and re-factors, since the statistics changed).
        """
        if not self._seen:
            raise ValueError("no clients aggregated")
        key = float(target_gamma)
        fact = self._factor_cache.get(key)
        if fact is None:
            fact = self.engine.factor(self._stats, target_gamma=key)
            self._factor_cache[key] = fact
        return self.engine.factor_solve(fact, self._stats.moment)

    def solve_multi_gamma(self, gammas: Sequence[float]) -> list[np.ndarray]:
        """γ model sweep over the current aggregate: one eigendecomposition,
        one weight per candidate ridge (see engine.solve_multi_gamma)."""
        if not self._seen:
            raise ValueError("no clients aggregated")
        return self.engine.solve_multi_gamma(self._stats, gammas)

    def state(self) -> Dict[str, np.ndarray]:
        """Serializable server state (see repro.checkpoint). ``gram`` is the
        paper-form regularized aggregate C_agg^r = ΣC_k^r, kept for format
        stability across the raw-Gram refactor."""
        return {
            "gram": self.engine.regularized_gram(self._stats).copy(),
            "moment": self._stats.moment.copy(),
            "seen": np.array(sorted(self._seen), np.int64),
            "gamma": np.float64(self.gamma),
            "count": np.float64(self._stats.count),
        }

    @classmethod
    def from_state(cls, state: Dict[str, np.ndarray],
                   num_classes: Optional[int] = None) -> "AFLServer":
        dim = state["gram"].shape[0]
        srv = cls(dim, num_classes or state["moment"].shape[1],
                  float(state["gamma"]))
        seen = set(int(i) for i in state["seen"])
        k = len(seen)
        srv._stats = SuffStats(
            gram=np.array(state["gram"], np.float64) - k * srv.gamma * np.eye(dim),
            moment=np.array(state["moment"], np.float64),
            # older checkpoints predate the count field — restore as 0
            count=float(state.get("count", 0.0)),
            clients=float(k),
        )
        srv._seen = seen
        return srv


def masked_reports(reports: Sequence[ClientReport],
                   seed: int = 0) -> list[ClientReport]:
    """SecAgg-style pairwise masking of the uploads.

    Every pair (u, v), u < v derives a shared mask from a common seed; u adds
    it, v subtracts it. Any single report is then statistically useless to
    the server, but Σ reports is unchanged — and since AFL aggregation IS
    that sum, the masked protocol is exact (tested to ~1e-9).
    """
    n = len(reports)
    masked_g = [r.gram.astype(np.float64).copy() for r in reports]
    masked_q = [r.moment.astype(np.float64).copy() for r in reports]
    for u in range(n):
        for v in range(u + 1, n):
            rng = np.random.default_rng(
                (seed, reports[u].client_id, reports[v].client_id))
            mg = rng.standard_normal(masked_g[u].shape)
            mq = rng.standard_normal(masked_q[u].shape)
            masked_g[u] += mg
            masked_g[v] -= mg
            masked_q[u] += mq
            masked_q[v] -= mq
    return [
        # the mask is dense and full-rank, so a masked gram has no usable
        # low-rank root — drop it and let the server take the refactor path
        dataclasses.replace(r, gram=g, moment=q, root=None)
        for r, g, q in zip(reports, masked_g, masked_q)
    ]
