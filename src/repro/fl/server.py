"""DEPRECATED shim — the canonical FL surface moved to :mod:`repro.fl.api`.

Every name that used to live here (``ClientReport``, ``AFLServer``,
``make_report``, ``masked_reports``) is the *same object* re-exported from
``repro.fl.api``; importing it through this module emits a
``DeprecationWarning``. Update imports to ``repro.fl`` (or ``repro.fl.api``).
This shim is kept for one release after the api.py redesign and then removed.
"""

from __future__ import annotations

import warnings

from repro.fl import api as _api

__all__ = ["ClientReport", "AFLServer", "make_report", "masked_reports"]


def __getattr__(name: str):
    if name in __all__:
        warnings.warn(
            f"repro.fl.server.{name} is deprecated; import it from repro.fl "
            "(canonical home: repro.fl.api). This shim will be removed one "
            "release after the api redesign.",
            DeprecationWarning, stacklevel=2)
        return getattr(_api, name)
    raise AttributeError(
        f"module 'repro.fl.server' has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
