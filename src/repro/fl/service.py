"""FederationService: the transport-agnostic AFL serving surface.

AFL's single-round AA law reduces a whole federation to "clients POST one
report, then anyone may ask for the solved head" — so the serving API is
small enough to pin down completely. This module does exactly that, in three
layers that compose but never leak into each other:

  * :class:`FederationService` — wraps ANY :class:`~repro.fl.api.Coordinator`
    (sync :class:`~repro.fl.api.AFLServer`, event-loop
    :class:`~repro.fl.async_server.AsyncAFLServer`, mesh-sharded
    :class:`~repro.fl.api.ShardedCoordinator`) behind a routed bytes-in /
    bytes-out API: ``describe``, ``submit``, ``submit_stream`` (framed
    multi-report uploads with backpressure derived from the async queue's
    ``pending``), ``solve`` / ``solve_multi_gamma`` / ``sweep`` (the γ
    grid), ``weights`` (versioned solved-head download with an ETag-style
    staleness token), ``state`` (checkpoint), and ``personalized_solve``
    (client-specific target γ and/or a local-stats mixture). Failures are
    the typed taxonomy of :mod:`repro.fl.errors`, carried on the wire as
    stable codes.
  * Transports — :class:`InProcTransport` (same bytes, same envelope, no
    socket: the zero-copy default for tests) and :class:`HttpTransport` (a
    stdlib ``http.server`` loopback server via :func:`serve_http`, plus the
    ``http.client`` client side). Both move opaque byte envelopes; neither
    knows what a Gram matrix is.
  * :class:`RemoteCoordinator` — the client: speaks the service over bytes
    yet satisfies the :class:`~repro.fl.api.Coordinator` protocol, so
    ``run_afl``, ``launch/train.py`` and the examples can point at a URL
    instead of an in-process object with zero call-site changes. It passes
    the same conformance suite as the three local coordinators
    (``tests/test_coordinator_conformance.py``), which makes
    wire-equivalence — bit-for-bit at f64 — a permanent invariant.

Envelope format (shared by requests and responses)::

    b"AFLS" | u32 header_len | header JSON | array payload | blob

The header carries an array manifest (name/shape/dtype), the blob length,
and a CRC-32 of everything after the header, mirroring the
:class:`~repro.fl.api.ClientReport` wire rules: a flipped or truncated byte
is rejected, never silently folded into a federation.
"""

from __future__ import annotations

import asyncio
import hmac
import http.client
import http.server
import inspect
import json
import ssl
import struct
import threading
import urllib.parse
import zlib
from collections import OrderedDict
from typing import (Any, Dict, Iterable, List, Optional, Sequence, Tuple,
                    Union)

import numpy as np

from repro.core.engine import AnalyticEngine, SuffStats
from repro.fl import errors as E
from repro.fl.api import (ClientReport, GammaSweep, VersionedWeights,
                          _restore_stats)
from repro.fl.replication import ReportLedger, WarmStandby

__all__ = [
    "pack_message",
    "unpack_message",
    "frame_reports",
    "FederationService",
    "InProcTransport",
    "HttpTransport",
    "HttpFederationServer",
    "serve_http",
    "RemoteCoordinator",
]

# ---------------------------------------------------------------------------
# The byte envelope
# ---------------------------------------------------------------------------

_MAGIC = b"AFLS"
_ARRAY_DTYPES = {"float64": np.float64, "float32": np.float32,
                 "int64": np.int64}
_HOST_ENGINE = AnalyticEngine("numpy_f64")


def pack_message(header: Dict[str, Any],
                 arrays: Sequence[Tuple[str, np.ndarray]] = (),
                 blob: bytes = b"") -> bytes:
    """Serialize one service message: JSON header + named arrays + an
    optional opaque blob (e.g. a nested ClientReport payload)."""
    manifest, parts = [], []
    for name, arr in arrays:
        arr = np.asarray(arr)
        if not arr.flags.c_contiguous:
            # (not ascontiguousarray — that would promote 0-d scalars to 1-d)
            arr = np.ascontiguousarray(arr)
        if arr.dtype.name not in _ARRAY_DTYPES:
            raise ValueError(f"unsupported envelope dtype {arr.dtype.name!r} "
                             f"for array {name!r}")
        manifest.append({"name": str(name), "shape": list(arr.shape),
                         "dtype": arr.dtype.name})
        parts.append(arr.tobytes())
    payload = b"".join(parts) + bytes(blob)
    header = dict(header)
    header["arrays"] = manifest
    header["blob_len"] = len(blob)
    header["crc32"] = zlib.crc32(payload)
    hb = json.dumps(header, sort_keys=True).encode("utf-8")
    return _MAGIC + struct.pack("<I", len(hb)) + hb + payload


def unpack_message(data: bytes) -> Tuple[dict, Dict[str, np.ndarray], bytes]:
    """Parse + validate a service message → (header, {name: array}, blob).

    Raises :class:`~repro.fl.errors.BadRequest` for anything that is not a
    well-formed, checksum-clean envelope.
    """
    data = bytes(data)
    if len(data) < len(_MAGIC) + 4 or data[: len(_MAGIC)] != _MAGIC:
        raise E.BadRequest("not a federation service message (bad magic)")
    (hlen,) = struct.unpack("<I", data[len(_MAGIC): len(_MAGIC) + 4])
    body = len(_MAGIC) + 4
    if len(data) < body + hlen:
        raise E.BadRequest("truncated message header")
    try:
        header = json.loads(data[body: body + hlen].decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as exc:
        raise E.BadRequest(f"corrupt message header: {exc}") from None
    payload = data[body + hlen:]
    try:
        manifest = header["arrays"]
        blob_len = int(header["blob_len"])
        crc = int(header["crc32"])
        specs = [(str(a["name"]), tuple(int(s) for s in a["shape"]),
                  _ARRAY_DTYPES[a["dtype"]]) for a in manifest]
    except (KeyError, TypeError, ValueError) as exc:
        raise E.BadRequest(f"malformed message header: {exc}") from None
    if blob_len < 0 or any(s < 0 for _, shape, _ in specs for s in shape):
        raise E.BadRequest("malformed message header: negative sizes")
    sizes = [int(np.prod(shape, dtype=np.int64)) * np.dtype(dt).itemsize
             for _, shape, dt in specs]
    if len(payload) != sum(sizes) + blob_len:
        raise E.BadRequest(
            f"payload length {len(payload)} does not match header manifest")
    if zlib.crc32(payload) != crc:
        raise E.BadRequest("message payload failed its CRC-32 check")
    arrays, off = {}, 0
    for (name, shape, dt), nbytes in zip(specs, sizes):
        count = int(np.prod(shape, dtype=np.int64))
        # copy: frombuffer views are read-only and would pin the whole
        # response buffer — callers must get ordinary writable arrays,
        # exactly like an in-process coordinator returns
        arrays[name] = np.frombuffer(
            payload, dt, count, offset=off).reshape(shape).copy()
        off += nbytes
    return header, arrays, payload[off:]


def frame_reports(payloads: Iterable[bytes]) -> bytes:
    """Frame multiple report payloads into one ``submit_stream`` body:
    ``u32 count | (u32 len | payload)*``."""
    payloads = [bytes(p) for p in payloads]
    return struct.pack("<I", len(payloads)) + b"".join(
        struct.pack("<I", len(p)) + p for p in payloads)


def _unframe_reports(body: bytes) -> List[bytes]:
    body = bytes(body)
    if len(body) < 4:
        raise E.BadRequest("truncated stream body")
    (count,) = struct.unpack("<I", body[:4])
    frames, off = [], 4
    for _ in range(count):
        if len(body) < off + 4:
            raise E.BadRequest("truncated stream frame header")
        (n,) = struct.unpack("<I", body[off: off + 4])
        off += 4
        if len(body) < off + n:
            raise E.BadRequest("truncated stream frame")
        frames.append(body[off: off + n])
        off += n
    if off != len(body):
        raise E.BadRequest("trailing bytes after the last stream frame")
    return frames


def _decode_response(data: bytes) -> Tuple[dict, Dict[str, np.ndarray], bytes]:
    """Client-side decode: re-raise the typed error an error response
    carried, otherwise return (header, arrays, blob)."""
    header, arrays, blob = unpack_message(data)
    if not header.get("ok", False):
        raise E.from_code(header.get("error", "internal"),
                          header.get("message", "service error"))
    return header, arrays, blob


# ---------------------------------------------------------------------------
# One hosted federation: a coordinator + its concurrency discipline
# ---------------------------------------------------------------------------


class _AppliedMap:
    """Bounded idempotent-ingest map: client id → CRC-32 of the exact
    payload the service accepted.

    The unbounded dict grew one entry per client forever. With a
    :class:`~repro.fl.replication.ReportLedger` attached the map is a pure
    cache — an evicted entry is recoverable from disk
    (``ledger.find_crc``), so eviction never breaks the ``duplicate: true``
    replay answer. Without a ledger the LRU *is* the replay window: a
    retry arriving after ``maxsize`` newer clients degrades to the
    coordinator's ``duplicate_client`` 409 — the documented floor for
    ledger-less services. ``maxsize=None`` keeps the old unbounded
    behavior."""

    def __init__(self, maxsize: Optional[int] = None):
        self.maxsize = None if maxsize is None else max(1, int(maxsize))
        self._d: "OrderedDict[int, int]" = OrderedDict()

    def get(self, client_id: int) -> Optional[int]:
        crc = self._d.get(client_id)
        if crc is not None:
            self._d.move_to_end(client_id)
        return crc

    def set(self, client_id: int, crc: int) -> None:
        self._d[client_id] = crc
        self._d.move_to_end(client_id)
        if self.maxsize is not None:
            while len(self._d) > self.maxsize:
                self._d.popitem(last=False)

    def __len__(self) -> int:
        return len(self._d)


class _Flight:
    """One in-flight coalesced read: the leader computes, followers wait on
    the event and share the leader's encoded response (or its exception)."""

    __slots__ = ("token", "event", "result", "exc")

    def __init__(self, token: tuple):
        self.token = token
        self.event = threading.Event()
        self.result: Optional[bytes] = None
        self.exc: Optional[BaseException] = None


class _Federation:
    """Adapter making any coordinator callable from transport threads.

    Sync coordinators are serialized under one lock; an async coordinator
    gets a dedicated daemon event loop (started lazily, its worker task
    brought up via ``start()``) and every call crosses into it through
    ``run_coroutine_threadsafe`` — so exceptions, return values, and the
    coordinator's own internal locking behave exactly as in-process.
    """

    def __init__(self, coordinator, *,
                 applied_cache_size: Optional[int] = None,
                 ledger: Optional[ReportLedger] = None,
                 auth_token: Optional[str] = None):
        self.coordinator = coordinator
        # bearer token gating every route of this federation (None = open).
        # Checked before dispatch, so a bad token never touches state.
        self.auth_token = None if auth_token is None else str(auth_token)
        self.is_async = inspect.iscoroutinefunction(
            getattr(coordinator, "submit", None))
        self._lock = threading.RLock()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        # idempotent-ingest map: client id → CRC-32 of the exact report
        # payload the service accepted. A transport retry that re-delivers
        # the identical bytes answers success instead of duplicate_client.
        # LRU-bounded; with a ledger attached, evicted entries are
        # recovered from disk, so the bound costs nothing but a scan.
        self.applied = _AppliedMap(applied_cache_size)
        # durable submit ledger: every accepted submit/stream frame is
        # appended (and fsynced before the ack), so a warm standby tailing
        # the directory loses zero reports on failover
        self.ledger = ledger
        # a read replica never ingests: mutating routes answer the typed
        # read_only 403 before dispatch
        self.read_only = bool(getattr(coordinator, "read_only", False))
        # a hosted-but-not-yet-promoted warm standby (host_standby)
        self.standby: Optional[WarmStandby] = None
        self._adopt_ledger = False
        # failover latch: while True the federation answers 503 unavailable
        # (retryable) on every route — set when the coordinator dies,
        # cleared by FederationService.restore_federation or the promote
        # route (which flips a hosted standby live)
        self.suspended = False
        # single-flight read coalescing: concurrent identical read requests
        # (same route + body) at the same epoch share ONE computation and
        # ONE encoded response. Entries are valid only while read_token()
        # is unchanged — any epoch bump (submit, grow/shrink, promote; a
        # restore replaces the _Federation wholesale) changes the token, so
        # a stale head can never be served. coalesced_hits counts requests
        # answered without touching the coordinator.
        self.coalesce_lock = threading.Lock()
        self.read_cache: "OrderedDict[tuple, tuple]" = OrderedDict()
        self.inflight: Dict[tuple, "_Flight"] = {}
        self.coalesced_hits = 0

    def read_token(self) -> tuple:
        """Identity-plus-epoch fingerprint of everything a read-route
        response can depend on: the coordinator instance, its ETag salt
        (promotion mints a new one), the submission epoch, and the mesh
        epoch (grow/shrink bumps it without necessarily bumping version).
        Plain attribute reads — safe from transport threads."""
        c = self.coordinator
        salt = getattr(c, "_etag_salt", None)
        if salt is None:       # async wrapper / read replica: salt lives on
            inner = getattr(c, "server", None) or getattr(c, "_coord", None)
            salt = getattr(inner, "_etag_salt", None)
        return (id(c), salt, int(getattr(c, "version", -1)),
                int(getattr(c, "mesh_epoch", -1)))

    def start(self) -> "_Federation":
        if self.is_async and self._loop is None:
            self._loop = asyncio.new_event_loop()
            self._thread = threading.Thread(
                target=self._loop.run_forever, daemon=True,
                name="afl-federation-loop")
            self._thread.start()
            start = getattr(self.coordinator, "start", None)
            if start is not None:
                self._run(start())
        return self

    def _run(self, awaitable):
        return asyncio.run_coroutine_threadsafe(
            awaitable, self._loop).result()

    def call(self, name: str, *args, **kwargs):
        """Invoke a coordinator method, awaiting it when it is a coroutine."""
        method = getattr(self.coordinator, name)
        if self.is_async:
            out = method(*args, **kwargs)
            return self._run(out) if inspect.isawaitable(out) else out
        with self._lock:
            return method(*args, **kwargs)

    @property
    def pending(self) -> int:
        """Unapplied queued reports (0 for coordinators without a queue)."""
        return int(getattr(self.coordinator, "pending", 0))

    def close(self) -> None:
        if self.standby is not None:
            self.standby.stop()
            self.standby = None
        if self.ledger is not None:
            self.ledger.close()
            self.ledger = None
        if self._loop is not None:
            try:
                close = getattr(self.coordinator, "close", None)
                if close is not None:
                    self._run(close())
            finally:
                self._loop.call_soon_threadsafe(self._loop.stop)
                self._thread.join(timeout=5)
                self._loop.close()
                self._loop = None
                self._thread = None


# ---------------------------------------------------------------------------
# The service
# ---------------------------------------------------------------------------


class FederationService:
    """Routes byte envelopes to hosted coordinators (any kind, any count).

    >>> service = FederationService(AFLServer(dim=d, num_classes=c))
    >>> coord = RemoteCoordinator(service)            # in-proc transport
    >>> with serve_http(service) as srv:              # ...or over HTTP
    ...     coord = RemoteCoordinator(srv.url)

    ``handle(route, body, federation)`` is the single wire entrypoint both
    transports call; it never raises — every failure becomes an error
    envelope carrying a stable taxonomy code plus the HTTP status the
    transport should surface. ``max_report_bytes`` bounds any single report
    payload (checked before parsing); ``max_pending`` is the ingest
    high-watermark for queue-backed coordinators — once ``pending`` reaches
    it, submissions answer ``backpressure`` (HTTP 429, retryable) and the
    coordinator state stays untouched.
    """

    def __init__(self, coordinator=None, *, federation_id: str = "default",
                 max_report_bytes: int = 64 << 20,
                 max_pending: Optional[int] = None,
                 ledger_dir=None, applied_cache_size: int = 65536,
                 auth_token: Optional[str] = None):
        self.max_report_bytes = int(max_report_bytes)
        self.max_pending = None if max_pending is None else int(max_pending)
        self.applied_cache_size = (None if applied_cache_size is None
                                   else int(applied_cache_size))
        self._feds: Dict[str, _Federation] = {}
        if coordinator is not None:
            self.add_federation(
                federation_id, coordinator,
                ledger=(None if ledger_dir is None
                        else ReportLedger(ledger_dir)),
                auth_token=auth_token)

    # -- lifecycle / registry -----------------------------------------------

    def add_federation(self, federation_id: str, coordinator, *,
                       ledger: Optional[ReportLedger] = None,
                       auth_token: Optional[str] = None
                       ) -> "FederationService":
        """Host another coordinator under ``federation_id`` (async kinds get
        their worker loop brought up here). With a ``ledger``, every
        accepted submit/stream frame is appended and fsynced before the
        ack — the durable half of zero-loss failover. With ``auth_token``,
        every request must carry that bearer token or it answers the typed
        ``unauthorized`` 401 before touching any state."""
        self._feds[str(federation_id)] = _Federation(
            coordinator, applied_cache_size=self.applied_cache_size,
            ledger=ledger, auth_token=auth_token).start()
        return self

    def set_auth_token(self, token: Optional[str],
                       federation_id: str = "default") -> None:
        """Install (or clear, with ``None``) the bearer token gating a
        hosted federation — rotation without a restart."""
        self._fed(federation_id).auth_token = (
            None if token is None else str(token))

    def ledger(self, federation_id: str = "default"
               ) -> Optional[ReportLedger]:
        """The federation's live submit ledger (None when not configured) —
        e.g. to hand the in-process snapshot daemon for tick compaction."""
        return self._fed(federation_id).ledger

    def host_standby(self, federation_id: str, standby: WarmStandby,
                     *, adopt_ledger: bool = True,
                     auth_token: Optional[str] = None
                     ) -> "FederationService":
        """Host a warm standby: the federation answers retryable 503s while
        the standby tails the primary's ledger in the background; the
        ``promote`` route (or :meth:`promote_federation`) flips it live.
        With ``adopt_ledger`` the promoted primary keeps appending to the
        same ledger directory, so the failover chain can repeat."""
        fed = _Federation(standby.coordinator,
                          applied_cache_size=self.applied_cache_size,
                          auth_token=auth_token)
        fed.standby = standby.start()
        fed.suspended = True
        self._feds[str(federation_id)] = fed
        fed._adopt_ledger = bool(adopt_ledger)
        return self

    def promote_federation(self, federation_id: str = "default"):
        """Standby → primary: drain the ledger tail, refresh the ETag salt
        (tokens the dead primary minted never revalidate here), clear the
        suspended latch, and resume serving — with continued ledger appends
        when the standby was hosted with ``adopt_ledger``. Returns the
        promoted coordinator."""
        fed = self._fed(federation_id)
        if fed.standby is None:
            raise E.BadRequest(
                f"federation {federation_id!r} has no warm standby to "
                "promote (host one via host_standby)")
        standby = fed.standby
        fed.standby = None
        coordinator = standby.promote()
        if fed._adopt_ledger and fed.ledger is None:
            fed.ledger = ReportLedger(standby.ledger_dir)
        fed.suspended = False
        fed.start()                        # async kinds: bring the loop up
        return coordinator

    def suspend_federation(self, federation_id: str = "default"):
        """Take a federation out of service — the failover latch. Every
        subsequent request answers the retryable ``unavailable`` 503 until
        :meth:`restore_federation` installs a replacement coordinator.
        Returns the (possibly dead) coordinator for post-mortems."""
        fed = self._fed(federation_id)
        fed.suspended = True
        return fed.coordinator

    def restore_federation(self, federation_id: str,
                           coordinator) -> "FederationService":
        """Install a replacement coordinator (e.g. cold-started from the
        snapshot daemon's latest snapshot, or a promoted warm standby) and
        resume serving. The idempotent-ingest map AND the submit ledger
        carry over, so a client retrying a submit that straddled the
        outage still gets its idempotent answer."""
        old = self._fed(federation_id)
        applied, ledger = old.applied, old.ledger
        old.ledger = None                  # keep it open across the swap
        old.close()
        fed = _Federation(coordinator,
                          applied_cache_size=self.applied_cache_size,
                          ledger=ledger,
                          auth_token=old.auth_token).start()
        fed.applied = applied
        self._feds[str(federation_id)] = fed
        return self

    def coordinator(self, federation_id: str = "default"):
        """The backing coordinator object (in-proc introspection/tests)."""
        return self._fed(federation_id).coordinator

    def federation_ids(self) -> List[str]:
        return sorted(self._feds)

    def close(self) -> None:
        for fed in self._feds.values():
            fed.close()

    def __enter__(self) -> "FederationService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _fed(self, federation_id: str) -> _Federation:
        fed = self._feds.get(str(federation_id))
        if fed is None:
            raise E.UnknownFederation(
                f"no federation {federation_id!r} "
                f"(hosting: {self.federation_ids()})")
        return fed

    # -- the wire entrypoint -------------------------------------------------

    def handle(self, route: str, body: bytes = b"",
               federation: str = "default", *,
               token: Optional[str] = None) -> Tuple[bytes, int]:
        """Dispatch one request → (response envelope, HTTP status)."""
        try:
            handler = self._ROUTES.get(route)
            if handler is None:
                raise E.BadRequest(
                    f"unknown route {route!r} (one of {sorted(self._ROUTES)})")
            fed = self._fed(federation)
            # auth precedes EVERYTHING else (promote included): a bad
            # bearer token answers 401 with coordinator state untouched
            if fed.auth_token is not None and (
                    token is None
                    or not hmac.compare_digest(str(token), fed.auth_token)):
                raise E.Unauthorized(
                    f"federation {federation!r} requires a valid bearer "
                    "token")
            # promote is the one route that must work DURING the outage —
            # it is how a hosted standby ends it
            if fed.suspended and route != "promote":
                raise E.Unavailable(
                    f"federation {federation!r} is failing over — retry "
                    "after the replacement coordinator is installed")
            if fed.read_only and route in self._MUTATING_ROUTES:
                raise E.ReadOnlyFederation(
                    f"{route!r} on read-only federation {federation!r} — "
                    "replicas never ingest; send writes to the primary")
            if route in self._COALESCED_ROUTES:
                return self._coalesced(fed, route, handler, bytes(body)), 200
            return handler(self, fed, bytes(body)), 200
        except E.ServiceError as exc:
            return self._error(exc)
        except ValueError as exc:
            return self._error(E.BadRequest(str(exc)))
        except Exception as exc:                      # noqa: BLE001
            # never leak a stack trace onto the wire; "internal" decodes to
            # the bare ServiceError on the client
            err = E.ServiceError(f"{type(exc).__name__}: {exc}")
            return self._error(err)

    @staticmethod
    def _error(exc: E.ServiceError) -> Tuple[bytes, int]:
        return (pack_message({"ok": False, "error": exc.code,
                              "message": str(exc),
                              "retryable": exc.retryable}),
                exc.http_status)

    @staticmethod
    def _ok(header: Dict[str, Any],
            arrays: Sequence[Tuple[str, np.ndarray]] = (),
            blob: bytes = b"") -> bytes:
        return pack_message({"ok": True, **header}, arrays, blob=blob)

    # -- single-flight read coalescing ----------------------------------------

    _COALESCE_CACHE_MAX = 64

    def _coalesced(self, fed: _Federation, route: str, handler,
                   body: bytes) -> bytes:
        """Single-flight dispatch for read routes: identical concurrent
        requests (same route + request bytes — which carry the γ / grid /
        if_etag) at the same :meth:`_Federation.read_token` share ONE
        underlying computation and ONE encoded response; repeats within the
        same epoch answer from the per-federation response cache. The token
        captures instance + salt + version + mesh epoch, so every epoch
        bump invalidates implicitly — a stale head can never be served, and
        N pollers between arrivals cost one solve. Works identically over
        in-proc, HTTP, and mux: coalescing sits under ``handle``, above the
        transports. Errors propagate to every waiter and are never cached.
        """
        key = (route, body)
        token = fed.read_token()
        with fed.coalesce_lock:
            entry = fed.read_cache.get(key)
            if entry is not None:
                if entry[0] == token:
                    fed.read_cache.move_to_end(key)
                    fed.coalesced_hits += 1
                    return entry[1]
                del fed.read_cache[key]    # stale epoch — drop eagerly
            flight = fed.inflight.get(key)
            if flight is not None and flight.token == token:
                leader = None
            else:
                leader = flight = _Flight(token)
                fed.inflight[key] = flight
        if leader is None:
            # follower: wait for the leader's response (the leader's
            # ``finally`` always signals, even on error). If an epoch
            # bumped mid-flight the answer is still linearizable — it is
            # what a direct dispatch would have returned moments earlier.
            flight.event.wait()
            if flight.exc is not None:
                raise flight.exc
            with fed.coalesce_lock:
                fed.coalesced_hits += 1
            return flight.result
        try:
            resp = handler(self, fed, body)
        except BaseException as exc:
            flight.exc = exc
            raise
        else:
            flight.result = resp
            return resp
        finally:
            with fed.coalesce_lock:
                if fed.inflight.get(key) is flight:
                    del fed.inflight[key]
                # cache only when no arrival landed during the compute —
                # otherwise the NEXT request recomputes under its own token
                if flight.exc is None and fed.read_token() == flight.token:
                    fed.read_cache[key] = (flight.token, flight.result)
                    while len(fed.read_cache) > self._COALESCE_CACHE_MAX:
                        fed.read_cache.popitem(last=False)
            flight.event.set()

    # -- shared ingest helpers ----------------------------------------------

    def _parse_report(self, payload: bytes) -> ClientReport:
        if len(payload) > self.max_report_bytes:
            raise E.OversizedReport(
                f"report payload is {len(payload)} bytes "
                f"(limit {self.max_report_bytes})")
        try:
            return ClientReport.from_bytes(payload)
        except E.ServiceError:
            raise
        except ValueError as exc:
            raise E.CorruptReport(str(exc)) from None

    def _check_backpressure(self, fed: _Federation) -> None:
        if self.max_pending is not None and fed.pending >= self.max_pending:
            raise E.Backpressure(
                f"{fed.pending} reports pending ≥ "
                f"max_pending={self.max_pending}")

    def _replayed(self, fed: _Federation, report: ClientReport,
                  payload: bytes) -> Optional[int]:
        """The idempotency check: the CRC of this exact payload if the
        service already accepted it for this client id (a transport retry
        whose first attempt landed — answer success, apply nothing), else
        ``None``. A *different* payload under a known id falls through to
        the coordinator's duplicate_client rejection."""
        crc = zlib.crc32(payload)
        return crc if fed.applied.get(report.client_id) == crc else None

    @staticmethod
    def _client_known(fed: _Federation, client_id: int) -> bool:
        """Whether the coordinator has already folded this client — the
        cheap gate in front of a ledger disk scan (a brand-new client must
        never pay one)."""
        c = fed.coordinator
        seen = getattr(getattr(c, "server", c), "_seen", None)
        return seen is not None and int(client_id) in seen

    def _ledger_replayed(self, fed: _Federation, report: ClientReport,
                         payload: bytes) -> bool:
        """Disk half of the idempotency check, consulted only after the
        in-memory map missed (LRU eviction, or a service restarted /
        promoted onto the same ledger): ``True`` iff the ledger's newest
        record for this client is byte-identical to ``payload``. A hit is
        re-cached into the map."""
        if fed.ledger is None:
            return False
        crc = zlib.crc32(payload)
        if fed.ledger.find_crc(report.client_id) != crc:
            return False
        fed.applied.set(report.client_id, crc)
        return True

    @staticmethod
    def _request_header(body: bytes) -> Tuple[dict, Dict[str, np.ndarray],
                                              bytes]:
        if not body:
            return {}, {}, b""
        return unpack_message(body)

    # -- routes ---------------------------------------------------------------

    def _r_describe(self, fed: _Federation, body: bytes) -> bytes:
        c = fed.coordinator
        # ledger position is read BEFORE pending: a compactor may treat
        # ledger_seq as fully-applied only when the same describe reports
        # pending == 0 — with this ordering, any record appended after the
        # seq read either shows up as pending or carries a higher seq, so
        # compacting to ledger_seq can never drop an unapplied report
        ledger_seq = (None if fed.ledger is None
                      else int(fed.ledger.last_seq))
        info = {
            "kind": type(c).__name__,
            "dim": int(c.dim),
            "num_classes": int(c.num_classes),
            "gamma": float(c.gamma),
            "num_clients": int(c.num_clients),
            "version": int(c.version),
            "pending": fed.pending,
            "max_report_bytes": self.max_report_bytes,
            "auth_required": fed.auth_token is not None,
        }
        shards = getattr(c, "num_shards", None)
        if shards is not None:
            info["num_shards"] = int(shards)
            info["mesh_epoch"] = int(getattr(c, "mesh_epoch", 0))
        info["read_only"] = fed.read_only
        if fed.read_only:
            info["replica_lag"] = int(getattr(c, "lag", 0))
            info["mesh_epoch"] = int(getattr(c, "mesh_epoch", 0))
        if ledger_seq is not None:
            info["ledger_seq"] = ledger_seq
        # read-path observability: requests answered without recomputing
        info["coalesced_hits"] = int(fed.coalesced_hits)
        # ingest observability for batching coordinators (AsyncAFLServer):
        # live queue depth plus the fold counters a capacity planner needs
        # to size batch_max against arrival rate
        if getattr(c, "batches_folded", None) is not None:
            info["ingest"] = {
                "queue_depth": fed.pending,
                "batch_max": int(getattr(c, "batch_max", 1)),
                "last_batch": int(getattr(c, "last_batch", 0)),
                "batches_folded": int(c.batches_folded),
                "rejected_dropped": int(getattr(c, "rejected_dropped", 0)),
            }
        return self._ok(info)

    def _r_grow(self, fed: _Federation, body: bytes) -> bytes:
        return self._resize_route(fed, body, "grow")

    def _r_shrink(self, fed: _Federation, body: bytes) -> bytes:
        return self._resize_route(fed, body, "shrink")

    def _resize_route(self, fed: _Federation, body: bytes,
                      verb: str) -> bytes:
        """``grow``/``shrink`` the hosted mesh by ``n`` shards (header key
        ``n``, default 1). Only elastic coordinators support it; racing
        requests surface the coordinator's retryable backpressure."""
        header, _, _ = self._request_header(body)
        n = int(header.get("n", 1))
        c = fed.coordinator
        if getattr(c, verb, None) is None:
            raise E.BadRequest(
                f"{type(c).__name__} is not elastic — no {verb}()")
        epoch = fed.call(verb, n)
        return self._ok({"mesh_epoch": int(epoch),
                         "num_shards": int(c.num_shards),
                         "version": int(c.version)})

    def _duplicate_ok(self, fed: _Federation) -> bytes:
        c = fed.coordinator
        return self._ok({"folded": True, "duplicate": True,
                         "num_clients": int(c.num_clients),
                         "version": int(c.version)})

    def _r_submit(self, fed: _Federation, body: bytes) -> bytes:
        """Body = one raw :class:`ClientReport` payload → fold outcome.
        Idempotent: re-delivery of the identical payload (client id + CRC)
        answers success without touching the aggregate, so a transport may
        safely replay a submit whose response was lost — even across an
        LRU-evicted map entry or a promotion, via the ledger fallback.
        Accepted folds are appended to the ledger and fsynced BEFORE the
        ack: anything a client saw acknowledged survives to the standby."""
        report = self._parse_report(body)
        if self._replayed(fed, report, body) is not None:
            return self._duplicate_ok(fed)
        self._check_backpressure(fed)
        try:
            folded = fed.call("submit", report)
        except E.DuplicateClient:
            # the map missed (evicted / fresh promotion) but the
            # coordinator knows the client — identical bytes on disk mean
            # this is a replay, not a conflict
            if self._ledger_replayed(fed, report, body):
                return self._duplicate_ok(fed)
            raise
        fed.applied.set(report.client_id, zlib.crc32(body))
        if fed.ledger is not None:
            fed.ledger.append(body, report.client_id)
            fed.ledger.sync()              # durable before the ack
        c = fed.coordinator
        return self._ok({"folded": bool(folded), "duplicate": False,
                         "num_clients": int(c.num_clients),
                         "version": int(c.version)})

    def _r_submit_stream(self, fed: _Federation, body: bytes) -> bytes:
        """Framed multi-report upload; each frame is accepted/rejected
        independently, so one corrupt report in a batch cannot poison the
        rest. Queue-backed coordinators ingest fire-and-forget: every
        admissible frame in the stream crosses into the coordinator loop in
        ONE ``enqueue_many`` call (the transport answer is *queued*, not
        *folded*) — so a 64-frame stream costs one loop wakeup, not 64.
        Backpressure — the service watermark (projected over the frames
        already admitted from this stream) or the coordinator's own —
        rejects a frame without touching state."""
        frames = _unframe_reports(body)
        if fed.is_async:
            return self._stream_async(fed, frames)
        results: List[Dict[str, Any]] = []
        accepted = appended = 0
        for frame in frames:
            try:
                report = self._parse_report(frame)
                if self._replayed(fed, report, frame) is not None:
                    results.append({"ok": True, "duplicate": True})
                    accepted += 1
                    continue
                try:
                    folded = fed.call("submit", report)
                except E.DuplicateClient:
                    if self._ledger_replayed(fed, report, frame):
                        results.append({"ok": True, "duplicate": True})
                        accepted += 1
                        continue
                    raise
                results.append({"ok": True, "queued": False,
                                "folded": bool(folded)})
                fed.applied.set(report.client_id, zlib.crc32(frame))
                if fed.ledger is not None:
                    fed.ledger.append(frame, report.client_id)
                    appended += 1
                accepted += 1
            except E.ServiceError as exc:
                results.append({"ok": False, "error": exc.code,
                                "message": str(exc),
                                "retryable": exc.retryable})
            except ValueError as exc:
                results.append({"ok": False, "error": E.BadRequest.code,
                                "message": str(exc), "retryable": False})
        if appended:
            fed.ledger.sync()              # ONE fsync per stream batch
        return self._ok({"results": results, "accepted": accepted,
                         "pending": fed.pending,
                         "version": int(fed.coordinator.version)})

    def _stream_async(self, fed: _Federation,
                      frames: Sequence[bytes]) -> bytes:
        """Queue-backed half of ``submit_stream``: admit every valid frame
        first (parse, replay/idempotency, projected watermark), then hand
        the whole admissible batch to the coordinator in one
        ``enqueue_many`` crossing. Bookkeeping (idempotency map + ledger)
        happens only for frames the coordinator actually admitted — its own
        watermark may shave the tail, which answers retryable backpressure
        exactly as a per-frame enqueue would have."""
        results: List[Optional[Dict[str, Any]]] = []
        # provisionally admitted frames: (result slot, report, frame, crc)
        slots: List[Tuple[int, ClientReport, bytes, int]] = []
        # intra-stream duplicates ride on their original's admission:
        # result slot → index into ``slots`` they duplicate
        dup_of: List[Tuple[int, int]] = []
        batch_seen: Dict[int, Tuple[int, int]] = {}   # client → (crc, slot#)
        accepted = appended = 0
        for frame in frames:
            try:
                report = self._parse_report(frame)
                if self._replayed(fed, report, frame) is not None:
                    results.append({"ok": True, "duplicate": True})
                    accepted += 1
                    continue
                crc = zlib.crc32(frame)
                prior = batch_seen.get(report.client_id)
                if prior is not None and prior[0] == crc:
                    # identical bytes earlier in this very stream — final
                    # answer depends on whether that frame is admitted
                    dup_of.append((len(results), prior[1]))
                    results.append(None)
                    continue
                if self.max_pending is not None and (
                        fed.pending + len(slots) >= self.max_pending):
                    raise E.Backpressure(
                        f"{fed.pending + len(slots)} reports pending ≥ "
                        f"max_pending={self.max_pending}")
                # fire-and-forget: the fold outcome is unknown at ack time,
                # so the idempotency answer for an evicted map entry must
                # come from disk BEFORE re-enqueueing
                if (self._client_known(fed, report.client_id)
                        and self._ledger_replayed(fed, report, frame)):
                    results.append({"ok": True, "duplicate": True})
                    accepted += 1
                    continue
                batch_seen[report.client_id] = (crc, len(slots))
                slots.append((len(results), report, frame, crc))
                results.append(None)
            except E.ServiceError as exc:
                results.append({"ok": False, "error": exc.code,
                                "message": str(exc),
                                "retryable": exc.retryable})
            except ValueError as exc:
                results.append({"ok": False, "error": E.BadRequest.code,
                                "message": str(exc), "retryable": False})
        admitted = 0
        if slots:
            reports = [s[1] for s in slots]
            if getattr(fed.coordinator, "enqueue_many", None) is not None:
                admitted = int(fed.call("enqueue_many", reports))
            else:
                try:
                    for report in reports:
                        fed.call("enqueue", report)
                        admitted += 1
                except E.ServiceError:
                    pass                   # tail answers backpressure below
        shaved = {"ok": False, "error": E.Backpressure.code,
                  "message": "coordinator queue full — retry",
                  "retryable": True}
        for n, (idx, report, frame, crc) in enumerate(slots):
            if n < admitted:
                results[idx] = {"ok": True, "queued": True}
                fed.applied.set(report.client_id, crc)
                if fed.ledger is not None:
                    # queued frames are appended the moment they are
                    # admitted — a crash before the worker applies them
                    # still drains them into the standby (zero loss for
                    # fire-and-forget ingest)
                    fed.ledger.append(frame, report.client_id)
                    appended += 1
                accepted += 1
            else:
                results[idx] = dict(shaved)
        for idx, slot in dup_of:
            if slot < admitted:
                results[idx] = {"ok": True, "duplicate": True}
                accepted += 1
            else:
                results[idx] = dict(shaved)
        if appended:
            fed.ledger.sync()              # ONE fsync per stream batch
        return self._ok({"results": results, "accepted": accepted,
                         "pending": fed.pending,
                         "version": int(fed.coordinator.version)})

    def _r_solve(self, fed: _Federation, body: bytes) -> bytes:
        header, _, _ = self._request_header(body)
        tg = float(header.get("target_gamma", 0.0))
        w = fed.call("solve", tg)
        return self._ok(
            {"target_gamma": tg, "version": int(fed.coordinator.version)},
            [("weight", np.asarray(w, np.float64))])

    def _r_solve_multi_gamma(self, fed: _Federation, body: bytes) -> bytes:
        header, _, _ = self._request_header(body)
        gammas = [float(g) for g in header.get("gammas", ())]
        if not gammas:
            raise E.BadRequest("solve_multi_gamma requires a non-empty "
                               "'gammas' list")
        ws = fed.call("solve_multi_gamma", gammas)
        stacked = np.stack([np.asarray(w, np.float64) for w in ws])
        return self._ok(
            {"gammas": gammas, "version": int(fed.coordinator.version)},
            [("weights", stacked)])

    def _r_sweep(self, fed: _Federation, body: bytes) -> bytes:
        header, arrays, _ = self._request_header(body)
        gammas = [float(g) for g in header.get("gammas", ())]
        if not gammas or "x" not in arrays or "y" not in arrays:
            raise E.BadRequest("sweep requires 'gammas' plus holdout arrays "
                               "'x' and 'y'")
        sweep: GammaSweep = fed.call("sweep", gammas,
                                     (arrays["x"], arrays["y"]))
        best = sweep.gammas.index(sweep.best_gamma)
        return self._ok(
            {"gammas": list(sweep.gammas),
             "accuracies": list(sweep.accuracies),
             "best_gamma": float(sweep.best_gamma),
             "best_index": int(best),
             "version": int(fed.coordinator.version)},
            [("weights", np.stack([np.asarray(w, np.float64)
                                   for w in sweep.weights]))])

    def _r_weights(self, fed: _Federation, body: bytes) -> bytes:
        header, _, _ = self._request_header(body)
        tg = float(header.get("target_gamma", 0.0))
        if_etag = header.get("if_etag")
        vw: VersionedWeights = fed.call(
            "weights", tg,
            if_etag=None if if_etag is None else str(if_etag))
        meta = {"version": int(vw.version), "target_gamma": tg,
                "etag": vw.etag, "not_modified": vw.not_modified}
        if vw.not_modified:
            return self._ok(meta)
        return self._ok(meta, [("weight", np.asarray(vw.weight, np.float64))])

    def _r_state(self, fed: _Federation, body: bytes) -> bytes:
        state = fed.call("state")
        arrays = [(k, np.asarray(v)) for k, v in state.items()]
        return self._ok({"kind": type(fed.coordinator).__name__,
                         "version": int(fed.coordinator.version)}, arrays)

    def _r_personalized_solve(self, fed: _Federation, body: bytes) -> bytes:
        """Per-client head from the shared aggregate (ROADMAP's
        personalization item): a client-specific target γ, optionally mixed
        with the client's OWN local statistics — solve
        ``(C_agg + β·C_k + γ_c·I) W = Q_agg + β·Q_k`` with the client's
        report riding in the envelope blob. β > 0 tilts the shared head
        toward the client's local distribution; β = 0 (no report) is the
        pure per-γ personalization. The federation aggregate is read, never
        written, so personalization can not corrupt the shared state.
        """
        header, _, blob = self._request_header(body)
        tg = float(header.get("target_gamma", 0.0))
        c = fed.coordinator
        if c.num_clients == 0:
            raise E.EmptyFederation("no clients aggregated")
        if not blob:
            w = fed.call("solve", tg)
            return self._ok({"target_gamma": tg, "mix_weight": 0.0,
                             "version": int(c.version)},
                            [("weight", np.asarray(w, np.float64))])
        report = self._parse_report(blob)
        beta = float(header.get("mix_weight", 1.0))
        state = fed.call("state")
        dim = int(state["gram"].shape[0])
        stats, _seen = _restore_stats(state, float(state["gamma"]), dim)
        raw_k = (np.asarray(report.gram, np.float64)
                 - report.gamma * np.eye(dim))
        mixed = SuffStats(
            gram=stats.gram + beta * raw_k,
            moment=stats.moment + beta * np.asarray(report.moment,
                                                    np.float64),
            count=stats.count + beta * report.count,
            clients=stats.clients,
        )
        w = _HOST_ENGINE.solve(mixed, target_gamma=tg)
        return self._ok({"target_gamma": tg, "mix_weight": beta,
                         "version": int(c.version)},
                        [("weight", np.asarray(w, np.float64))])

    def _r_promote(self, fed: _Federation, body: bytes) -> bytes:
        """Flip a hosted warm standby live (see :meth:`promote_federation`).
        The one route the suspended latch does not gate."""
        # self-route through the public method: _fed lookup already done,
        # but promote_federation re-resolves by id — find ours
        fid = next(k for k, v in self._feds.items() if v is fed)
        coordinator = self.promote_federation(fid)
        return self._ok({"promoted": True,
                         "kind": type(coordinator).__name__,
                         "num_clients": int(coordinator.num_clients),
                         "version": int(coordinator.version)})

    _ROUTES = {
        "describe": _r_describe,
        "submit": _r_submit,
        "submit_stream": _r_submit_stream,
        "solve": _r_solve,
        "solve_multi_gamma": _r_solve_multi_gamma,
        "sweep": _r_sweep,
        "weights": _r_weights,
        "state": _r_state,
        "personalized_solve": _r_personalized_solve,
        "grow": _r_grow,
        "shrink": _r_shrink,
        "promote": _r_promote,
    }

    # routes that change federation state — rejected up front on a
    # read-only (replica) federation
    _MUTATING_ROUTES = frozenset(
        {"submit", "submit_stream", "grow", "shrink"})

    # pure read routes whose responses depend only on (request bytes, head
    # epoch) — safe to single-flight and cache per read_token. ``state`` and
    # ``describe`` are deliberately excluded: state is a snapshot/backup path
    # (cheap, rarely concurrent-identical) and describe reports live queue
    # depth that must not be frozen within an epoch.
    _COALESCED_ROUTES = frozenset(
        {"solve", "solve_multi_gamma", "sweep", "weights"})


# ---------------------------------------------------------------------------
# Transports
# ---------------------------------------------------------------------------


class InProcTransport:
    """Zero-copy loopback: the same byte envelopes, no socket. The default
    for tests — what crosses this transport is exactly what would cross
    HTTP, so in-proc coverage IS wire coverage."""

    def __init__(self, service: FederationService, *,
                 auth_token: Optional[str] = None):
        self._service = service
        self.auth_token = auth_token

    def request(self, route: str, body: bytes = b"",
                federation: str = "default") -> bytes:
        data, _status = self._service.handle(route, body, federation,
                                             token=self.auth_token)
        return data

    def close(self) -> None:
        pass


class HttpTransport:
    """Client side of the HTTP transport (stdlib ``http.client``).

    Connections are **kept alive and reused** (HTTP/1.1 persistent
    connections, one pooled connection per calling thread, so the transport
    stays trivially thread-safe without locking the socket; connections
    owned by dead threads are swept on the next pool access, so thread
    churn cannot leak sockets). At loopback latencies reuse is minor; over
    a WAN it removes a TCP (and eventually TLS) handshake round-trip from
    every submit/poll — the PR-4 ROADMAP rung. A pooled connection the
    server has since closed (idle timeout, restart) is detected on its next
    use and replaced with ONE transparent retry on a fresh connection —
    with replay discipline: a failure while *sending* or while *reading
    the response* retries on the fresh socket — replaying a ``submit``
    whose first attempt actually landed is safe because the service's
    ingest is idempotent (a re-delivered identical payload, keyed on
    client id + report CRC, answers success without double-applying,
    instead of surfacing a spurious ``duplicate_client`` 409). A *timeout*
    is never retried (the request may still be executing), and a failure
    on a *fresh* connection propagates — that is a real transport error.
    ``keep_alive=False`` restores the one-shot connection-per-request
    behavior.
    """

    def __init__(self, url: str, *, timeout: float = 60.0,
                 keep_alive: bool = True,
                 auth_token: Optional[str] = None,
                 ssl_context: Optional[ssl.SSLContext] = None,
                 cafile: Optional[str] = None):
        parts = urllib.parse.urlsplit(url)
        if parts.scheme not in ("http", "https"):
            raise ValueError(
                f"HttpTransport speaks http:// or https:// only, got {url!r}")
        self._tls = parts.scheme == "https"
        self._host = parts.hostname or "127.0.0.1"
        self._port = parts.port or (443 if self._tls else 80)
        self._prefix = parts.path.rstrip("/")
        self._timeout = float(timeout)
        self.keep_alive = bool(keep_alive)
        self.auth_token = auth_token
        self._ssl = (ssl_context if ssl_context is not None
                     else (ssl.create_default_context(cafile=cafile)
                           if self._tls else None))
        self._local = threading.local()
        self._pool: Dict[threading.Thread, http.client.HTTPConnection] = {}
        self._pool_lock = threading.Lock()

    def _connect(self) -> http.client.HTTPConnection:
        if self._tls:
            return http.client.HTTPSConnection(
                self._host, self._port, timeout=self._timeout,
                context=self._ssl)
        return http.client.HTTPConnection(self._host, self._port,
                                          timeout=self._timeout)

    def _pooled(self) -> Tuple[http.client.HTTPConnection, bool]:
        """This thread's live connection (reused=True), or a fresh one that
        joins the pool. Joining also sweeps connections whose owning thread
        has exited — their thread-local slot is gone, so without the sweep
        the sockets would stay open until close()."""
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            return conn, True
        conn = self._connect()
        self._local.conn = conn
        with self._pool_lock:
            for t in [t for t in self._pool if not t.is_alive()]:
                self._pool.pop(t).close()
            self._pool[threading.current_thread()] = conn
        return conn, False

    def _discard(self) -> None:
        conn = getattr(self._local, "conn", None)
        if conn is None:
            return
        self._local.conn = None
        with self._pool_lock:
            me = threading.current_thread()
            if self._pool.get(me) is conn:
                self._pool.pop(me)
        conn.close()

    def _path(self, route: str, federation: str) -> str:
        return (f"{self._prefix}/v1/"
                f"{urllib.parse.quote(federation, safe='')}/{route}")

    def request(self, route: str, body: bytes = b"",
                federation: str = "default") -> bytes:
        path = self._path(route, federation)
        headers = {"Content-Type": "application/octet-stream"}
        if self.auth_token is not None:
            headers["Authorization"] = f"Bearer {self.auth_token}"
        if not self.keep_alive:
            conn = self._connect()
            try:
                conn.request("POST", path, body=body, headers=headers)
                return conn.getresponse().read()
            finally:
                conn.close()
        while True:
            conn, reused = self._pooled()
            try:
                conn.request("POST", path, body=body, headers=headers)
                resp = conn.getresponse()
                data = resp.read()
                if resp.will_close:
                    self._discard()            # server opted out of reuse
                return data
            except (http.client.HTTPException, ConnectionError,
                    OSError) as exc:
                self._discard()
                if not reused or isinstance(exc, TimeoutError):
                    # fresh socket: a real failure. Timeout: the request
                    # may still be executing — replaying races it, so
                    # surface the error instead.
                    raise
                # stale kept-alive socket — retry once on a fresh one.
                # Safe even for submit: the service's idempotent ingest
                # (client id + CRC) makes a replayed landed request a no-op.

    def close(self) -> None:
        with self._pool_lock:
            pool, self._pool = dict(self._pool), {}
        for conn in pool.values():
            conn.close()
        self._local = threading.local()


class _HttpHandler(http.server.BaseHTTPRequestHandler):
    service: FederationService = None  # type: ignore[assignment]
    server_version = "AFLFederationService/1"
    protocol_version = "HTTP/1.1"
    # headers and body go out in separate writes; without TCP_NODELAY the
    # Nagle + delayed-ACK interaction costs ~40ms per response on loopback
    disable_nagle_algorithm = True

    def _respond(self, data: bytes, status: int) -> None:
        self.send_response(status)
        self.send_header("Content-Type", "application/octet-stream")
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def _bearer(self) -> Optional[str]:
        auth = self.headers.get("Authorization") or ""
        return auth[7:] if auth.startswith("Bearer ") else None

    def _route(self, body: bytes) -> Tuple[bytes, int]:
        parts = [p for p in self.path.split("?")[0].split("/") if p]
        if len(parts) != 3 or parts[0] != "v1":
            return FederationService._error(E.BadRequest(
                f"path {self.path!r} is not /v1/<federation>/<route>"))
        return self.service.handle(parts[2], body,
                                   urllib.parse.unquote(parts[1]),
                                   token=self._bearer())

    def do_POST(self) -> None:  # noqa: N802 (stdlib handler naming)
        length = int(self.headers.get("Content-Length") or 0)
        # refuse to even read a body past the request cap — backstop against
        # memory-ballooning uploads (8× single-report cap: stream batches)
        if length > 8 * self.service.max_report_bytes:
            self._respond(*FederationService._error(E.OversizedReport(
                f"request body is {length} bytes")))
            return
        body = self.rfile.read(length) if length else b""
        self._respond(*self._route(body))

    def do_GET(self) -> None:  # noqa: N802
        """GET works for the body-less reads (describe / weights / state) —
        curl-friendly introspection of a live federation."""
        self._respond(*self._route(b""))

    def log_message(self, *args) -> None:  # silence per-request stderr spam
        pass


class HttpFederationServer:
    """A threaded stdlib HTTP server hosting one :class:`FederationService`
    on loopback (or any interface). Context-manager friendly::

        with serve_http(FederationService(server)) as srv:
            coord = RemoteCoordinator(srv.url)
    """

    def __init__(self, service: FederationService, host: str = "127.0.0.1",
                 port: int = 0, *,
                 ssl_context: Optional[ssl.SSLContext] = None):
        handler = type("BoundHandler", (_HttpHandler,), {"service": service})
        self.service = service
        self._httpd = http.server.ThreadingHTTPServer((host, port), handler)
        self.host, self.port = self._httpd.server_address[:2]
        if ssl_context is not None:
            self._httpd.socket = ssl_context.wrap_socket(
                self._httpd.socket, server_side=True)
        self.url = (f"{'https' if ssl_context is not None else 'http'}"
                    f"://{self.host}:{self.port}")
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "HttpFederationServer":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._httpd.serve_forever, daemon=True,
                name="afl-http-server")
            self._thread.start()
        return self

    def close(self, *, close_service: bool = False) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread = None
        if close_service:
            self.service.close()

    def __enter__(self) -> "HttpFederationServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()


def serve_http(service: FederationService, host: str = "127.0.0.1",
               port: int = 0, *,
               ssl_context: Optional[ssl.SSLContext] = None
               ) -> HttpFederationServer:
    """Serve a federation over loopback HTTP (HTTPS with ``ssl_context``);
    returns the started server (``.url`` carries the ephemeral port when
    ``port=0``)."""
    return HttpFederationServer(service, host, port,
                                ssl_context=ssl_context).start()


def _transport_for_url(url: str, *, auth_token: Optional[str] = None,
                       ssl_context: Optional[ssl.SSLContext] = None,
                       cafile: Optional[str] = None):
    """URL scheme → transport: http/https → :class:`HttpTransport`,
    mux/muxs → :class:`~repro.fl.mux.MuxTransport` (imported lazily —
    mux builds on this module, not the other way around)."""
    scheme = urllib.parse.urlsplit(url).scheme
    if scheme in ("mux", "muxs"):
        from repro.fl.mux import MuxTransport

        return MuxTransport(url, auth_token=auth_token,
                            ssl_context=ssl_context, cafile=cafile)
    return HttpTransport(url, auth_token=auth_token,
                         ssl_context=ssl_context, cafile=cafile)


# ---------------------------------------------------------------------------
# The remote client
# ---------------------------------------------------------------------------


def promote_remote(transport: Union[str, FederationService, "InProcTransport",
                                    "HttpTransport"],
                   federation: str = "default", *,
                   auth_token: Optional[str] = None,
                   ssl_context: Optional[ssl.SSLContext] = None,
                   cafile: Optional[str] = None) -> dict:
    """Send the ``promote`` route to a standby service — the one request a
    suspended federation answers, so it cannot go through
    :class:`RemoteCoordinator` (whose constructor ``describe`` would 503
    during the outage). Returns the promote response header; a
    :class:`RemoteCoordinator` can be constructed normally afterwards."""
    own = False
    if isinstance(transport, str):
        transport, own = _transport_for_url(
            transport, auth_token=auth_token, ssl_context=ssl_context,
            cafile=cafile), True
    elif isinstance(transport, FederationService):
        transport = InProcTransport(transport, auth_token=auth_token)
    try:
        header, _, _ = _decode_response(
            transport.request("promote", b"", federation))
        return header
    finally:
        if own:
            transport.close()


class RemoteCoordinator:
    """A :class:`~repro.fl.api.Coordinator` whose backing state lives behind
    a transport.

    Construction accepts a URL string (``http(s)://`` →
    :class:`HttpTransport`, ``mux(s)://`` →
    :class:`~repro.fl.mux.MuxTransport`), a :class:`FederationService`
    (→ :class:`InProcTransport`), or any object with the transport
    ``request`` method. ``describe`` pins dim/classes/γ
    at construction; everything else is a wire round-trip, and every error
    re-raises as the same typed taxonomy exception an in-process coordinator
    would have thrown — which is why this class passes the local
    coordinators' conformance suite verbatim.

    The one deliberate divergence: ``sweep`` ships the holdout to the
    service and scores there (one round-trip for the whole γ grid) instead
    of downloading every candidate head.
    """

    def __init__(self,
                 transport: Union[str, FederationService, "InProcTransport",
                                  "HttpTransport"],
                 *, federation: str = "default",
                 auth_token: Optional[str] = None,
                 ssl_context: Optional[ssl.SSLContext] = None,
                 cafile: Optional[str] = None):
        if isinstance(transport, str):
            transport = _transport_for_url(
                transport, auth_token=auth_token, ssl_context=ssl_context,
                cafile=cafile)
        elif isinstance(transport, FederationService):
            transport = InProcTransport(transport, auth_token=auth_token)
        self._transport = transport
        self.federation = str(federation)
        info = self.describe()
        self.dim = int(info["dim"])
        self.num_classes = int(info["num_classes"])
        self.gamma = float(info["gamma"])
        self.kind = str(info.get("kind", "unknown"))

    # -- plumbing -----------------------------------------------------------

    def _request(self, route: str, header: Optional[dict] = None,
                 arrays: Sequence[Tuple[str, np.ndarray]] = (),
                 blob: bytes = b"", raw: Optional[bytes] = None):
        if raw is not None:
            body = bytes(raw)
        elif header is None and not arrays and not blob:
            body = b""
        else:
            body = pack_message(header or {}, arrays, blob=blob)
        return _decode_response(
            self._transport.request(route, body, self.federation))

    def close(self) -> None:
        self._transport.close()

    def __enter__(self) -> "RemoteCoordinator":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- protocol surface ---------------------------------------------------

    def describe(self) -> dict:
        header, _, _ = self._request("describe")
        return header

    @property
    def num_clients(self) -> int:
        return int(self.describe()["num_clients"])

    @property
    def version(self) -> int:
        return int(self.describe()["version"])

    @property
    def pending(self) -> int:
        return int(self.describe()["pending"])

    @property
    def num_shards(self) -> Optional[int]:
        """Shard count of an elastic remote, ``None`` for fixed kinds."""
        shards = self.describe().get("num_shards")
        return None if shards is None else int(shards)

    @property
    def mesh_epoch(self) -> int:
        return int(self.describe().get("mesh_epoch", 0))

    def grow(self, n: int = 1) -> int:
        """Admit ``n`` shards on the remote mesh → new mesh epoch."""
        header, _, _ = self._request("grow", {"n": int(n)})
        return int(header["mesh_epoch"])

    def shrink(self, n: int = 1) -> int:
        """Retire ``n`` shards on the remote mesh → new mesh epoch."""
        header, _, _ = self._request("shrink", {"n": int(n)})
        return int(header["mesh_epoch"])

    def submit(self, report: ClientReport) -> bool:
        return self.submit_bytes(report.to_bytes())

    def submit_bytes(self, payload: bytes) -> bool:
        """Submit an already-serialized report (skips the re-encode when the
        caller holds wire bytes — e.g. relaying a client upload)."""
        header, _, _ = self._request("submit", raw=payload)
        return bool(header["folded"])

    def submit_many(self, reports: Iterable[ClientReport]) -> None:
        """Sync semantics (stop at first rejection), matching
        :meth:`repro.fl.api.AFLServer.submit_many`; for fire-and-forget
        batching use :meth:`submit_stream`."""
        for report in reports:
            self.submit(report)

    def submit_stream(self, payloads: Iterable[bytes]) -> dict:
        """Upload many serialized reports in ONE framed request; returns the
        per-frame outcome dict (``results`` / ``accepted`` / ``pending`` /
        ``version``). Queue-backed federations ingest asynchronously —
        ``pending`` is the live backpressure signal."""
        header, _, _ = self._request("submit_stream",
                                     raw=frame_reports(payloads))
        return header

    def solve(self, target_gamma: float = 0.0) -> np.ndarray:
        _, arrays, _ = self._request(
            "solve", {"target_gamma": float(target_gamma)})
        return arrays["weight"]

    def solve_multi_gamma(self, gammas: Sequence[float]) -> List[np.ndarray]:
        _, arrays, _ = self._request(
            "solve_multi_gamma", {"gammas": [float(g) for g in gammas]})
        return list(arrays["weights"])

    def sweep(self, gammas: Sequence[float], holdout) -> GammaSweep:
        x, y = holdout
        ya = np.asarray(y)
        ya = (ya.astype(np.int64) if ya.dtype.kind in "iub"
              else ya.astype(np.float64))
        header, arrays, _ = self._request(
            "sweep", {"gammas": [float(g) for g in gammas]},
            [("x", np.asarray(x, np.float64)), ("y", ya)])
        weights = list(arrays["weights"])
        best = int(header["best_index"])
        return GammaSweep(tuple(float(g) for g in header["gammas"]), weights,
                          tuple(float(a) for a in header["accuracies"]),
                          float(header["best_gamma"]), weights[best])

    def weights(self, target_gamma: float = 0.0, *,
                if_etag: Optional[str] = None) -> VersionedWeights:
        req = {"target_gamma": float(target_gamma)}
        if if_etag is not None:
            req["if_etag"] = str(if_etag)
        header, arrays, _ = self._request("weights", req)
        return VersionedWeights(int(header["version"]),
                                float(header["target_gamma"]),
                                arrays.get("weight"),
                                str(header.get("etag", "")))

    def personalized_solve(self, target_gamma: float = 0.0, *,
                           report: Union[ClientReport, bytes, None] = None,
                           mix_weight: Optional[float] = None) -> np.ndarray:
        """Per-client head: client-specific target γ, optionally mixed with
        the client's own local statistics (``report`` + ``mix_weight`` β)."""
        req: Dict[str, Any] = {"target_gamma": float(target_gamma)}
        if mix_weight is not None:
            req["mix_weight"] = float(mix_weight)
        blob = b""
        if report is not None:
            blob = (bytes(report) if isinstance(report, (bytes, bytearray))
                    else report.to_bytes())
        _, arrays, _ = self._request("personalized_solve", req, blob=blob)
        return arrays["weight"]

    def state(self) -> Dict[str, np.ndarray]:
        """Download the federation checkpoint (the one shared coordinator
        state schema — restorable into any local coordinator kind)."""
        _, arrays, _ = self._request("state")
        return arrays
