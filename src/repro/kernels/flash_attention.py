"""Pallas TPU kernel: flash attention (causal / GQA / sliding-window).

The backbone-forward hot spot of the AFL local stage (and of the serving
path) is attention at long sequence length — prefill_32k makes the S² logits
matrix (32768² × heads) unmaterializable, so the kernel computes attention
with the online-softmax streaming recurrence, never leaving VMEM:

  grid = (B·Hq, Sq/bq, Skv/bk) — the kv axis is the innermost, sequential
  ("arbitrary") axis; (m, l, acc) f32 running statistics live in VMEM scratch
  across the kv sweep and the output tile is normalized + flushed on the last
  kv step. GQA maps each query head's grid slot onto its kv head via the
  BlockSpec index map (b·Hkv + h//group), so kv tiles are streamed once per
  query-head group member without a gather. Causal and sliding-window masks
  are evaluated from block-local iotas; kv blocks wholly outside the
  causal/window band are skipped with ``pl.when`` (no MXU work, no mask).

Block sizes default to (bq, bk) = (256, 512) with the 128-lane head dim —
MXU-aligned; the wrapper pads S/D up to block multiples and masks padded keys.

Validated in interpret mode against ``repro.kernels.ref.mha_ref`` over a
shape/dtype/window sweep (tests/test_kernels_attention.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 512
_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale, causal, window, q_offset, skv_valid, bq, bk,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * bq + q_offset      # absolute position of first query row
    kv_start = ik * bk

    # Block-level skip: entire kv block above the causal diagonal, or entirely
    # left of the sliding window, or entirely in key padding.
    relevant = kv_start < skv_valid
    if causal:
        relevant = jnp.logical_and(relevant, kv_start <= q_start + bq - 1)
    if window is not None:
        relevant = jnp.logical_and(relevant, kv_start + bk - 1 >= q_start - window + 1)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(                          # (bq, bk)
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < skv_valid
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, 0]                               # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = alpha * l_ref[:, 0] + jnp.sum(p, axis=-1)
        m_ref[:, 0] = m_new
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(ik == nk - 1)
    def _flush():
        l = l_ref[:, 0]
        norm = jnp.where(l > 0.0, 1.0 / jnp.where(l > 0.0, l, 1.0), 0.0)
        o_ref[0] = (acc_ref[...] * norm[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "scale", "q_offset", "block_q", "block_k", "interpret",
    ),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_offset: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention. Shapes as in ``ref.mha_ref`` (B, H, S, D)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    bq = min(block_q, _ceil_mult(sq, 8))
    bk = min(block_k, _ceil_mult(skv, 8))
    sq_p, skv_p, d_p = _ceil_mult(sq, bq), _ceil_mult(skv, bk), _ceil_mult(d, 128)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, d_p - d)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, d_p - d)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, d_p - d)))
    qp = qp.reshape(b * hq, sq_p, d_p)
    kp = kp.reshape(b * hkv, skv_p, d_p)
    vp = vp.reshape(b * hkv, skv_p, d_p)

    def kv_index(bh, iq_, ik_):
        return (bh // hq) * hkv + (bh % hq) // group, ik_, 0

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, q_offset=q_offset,
        skv_valid=skv, bq=bq, bk=bk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, sq_p // bq, skv_p // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d_p), lambda bh, iq_, ik_: (bh, iq_, 0)),
            pl.BlockSpec((1, bk, d_p), kv_index),
            pl.BlockSpec((1, bk, d_p), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d_p), lambda bh, iq_, ik_: (bh, iq_, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_p, d_p), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d_p), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qp, kp, vp)
    return out.reshape(b, hq, sq_p, d_p)[:, :, :sq, :d]


def _ceil_mult(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m
