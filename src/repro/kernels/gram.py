"""Pallas TPU kernel: fused Gram-statistics update  G = XᵀX,  Q = XᵀY.

This is the AFL-specific compute hot spot: every analytic train step folds a
batch of backbone embeddings ``X (N, d)`` and one-hot targets ``Y (N, C)``
into the sufficient statistics. d is the model width (up to 6144 here), so G
is up to 6144² and the update is a rank-N outer-product accumulation — an MXU
matmul with a long reduction dim.

TPU mapping:
  grid = (d/bi, d/bj, N/bn); the reduction dim (N) is the innermost,
  sequential ("arbitrary") grid axis, so the f32 VMEM scratch accumulator for
  an output tile survives across its reduction steps and is flushed once.
  X tiles arrive in VMEM twice per (i, j) step — once row-blocked for the i
  side, once for the j side — with 128-aligned (bn, bi/bj) blocks feeding the
  MXU via dot_general on the transposed left operand. Q = XᵀY is fused into
  the j == 0 column of the grid so X's i-side tile is reused from VMEM instead
  of re-streamed from HBM.

Validated on CPU in interpret mode against ``repro.kernels.ref.gram_ref``
(the pure-jnp oracle) over a shape/dtype sweep in tests/test_kernels_gram.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

DEFAULT_BLOCK_D = 128   # output tile side (MXU lane-aligned)
DEFAULT_BLOCK_N = 512   # reduction chunk (sublane multiple)


def _gram_kernel(xi_ref, xj_ref, y_ref, g_ref, q_ref, g_acc, q_acc):
    """One (i, j, n) grid step.

    xi_ref: (bn, bi)  rows of X for the output-row block i
    xj_ref: (bn, bj)  rows of X for the output-col block j
    y_ref:  (bn, C)   targets (same row chunk)
    g_ref:  (bi, bj)  output tile of G
    q_ref:  (bi, C)   output tile of Q (written by the j==0 column only)
    g_acc/q_acc: f32 VMEM scratch accumulators
    """
    j = pl.program_id(1)
    n = pl.program_id(2)
    n_steps = pl.num_programs(2)

    @pl.when(n == 0)
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)

    @pl.when(jnp.logical_and(n == 0, j == 0))
    def _init_q():
        q_acc[...] = jnp.zeros_like(q_acc)

    xi = xi_ref[...].astype(jnp.float32)
    xj = xj_ref[...].astype(jnp.float32)
    # (bi, bn) @ (bn, bj) on the MXU; contraction over the row chunk.
    g_acc[...] += jax.lax.dot_general(
        xi, xj, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(j == 0)
    def _q_update():
        y = y_ref[...].astype(jnp.float32)
        q_acc[...] += jax.lax.dot_general(
            xi, y, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(n == n_steps - 1)
    def _flush():
        g_ref[...] = g_acc[...].astype(g_ref.dtype)

    @pl.when(jnp.logical_and(n == n_steps - 1, j == 0))
    def _flush_q():
        q_ref[...] = q_acc[...].astype(q_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_d", "block_n", "interpret", "out_dtype")
)
def gram_update(
    x: jax.Array,
    y: jax.Array,
    *,
    block_d: int = DEFAULT_BLOCK_D,
    block_n: int = DEFAULT_BLOCK_N,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Compute (XᵀX, XᵀY) with the fused Pallas kernel.

    x: (N, d) embeddings (any float dtype; accumulation is f32).
    y: (N, C) targets.
    Shapes are padded up to block multiples here in the wrapper; zero rows
    contribute nothing to either product so padding is exact.
    """
    n, d = x.shape
    n2, c = y.shape
    assert n == n2, (n, n2)
    bd = min(block_d, _ceil_mult(d, 128))
    bn = min(block_n, _ceil_mult(n, 8))
    d_p = _ceil_mult(d, bd)
    n_p = _ceil_mult(n, bn)
    c_p = _ceil_mult(c, 128)
    if (d_p, n_p) != (d, n):
        x = jnp.pad(x, ((0, n_p - n), (0, d_p - d)))
    if (n_p, c_p) != (n, c):
        y = jnp.pad(y, ((0, n_p - n), (0, c_p - c)))

    grid = (d_p // bd, d_p // bd, n_p // bn)
    g, q = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, n: (n, i)),  # X rows, i-side
            pl.BlockSpec((bn, bd), lambda i, j, n: (n, j)),  # X rows, j-side
            pl.BlockSpec((bn, c_p), lambda i, j, n: (n, 0)),  # Y rows
        ],
        out_specs=[
            pl.BlockSpec((bd, bd), lambda i, j, n: (i, j)),
            pl.BlockSpec((bd, c_p), lambda i, j, n: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d_p, d_p), out_dtype),
            jax.ShapeDtypeStruct((d_p, c_p), out_dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bd, bd), jnp.float32),
            pltpu.VMEM((bd, c_p), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, x, y)
    return g[:d, :d], q[:d, :c]


def _ceil_mult(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m
