"""Public jit'd entry points for the Pallas kernels.

On CPU (this container) the kernels execute via the Pallas interpreter;
on TPU the same calls compile through Mosaic. ``repro.kernels.ref`` holds the
pure-jnp oracles used by the tests and by the models' default (portable) path.
"""

from __future__ import annotations

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import gram as _gram
from repro.kernels import solve as _solve

_ON_TPU = jax.default_backend() == "tpu"


def gram_update(x: jax.Array, y: jax.Array, **kw) -> tuple[jax.Array, jax.Array]:
    """Fused (XᵀX, XᵀY). Interpreted off-TPU, Mosaic-compiled on TPU."""
    kw.setdefault("interpret", not _ON_TPU)
    return _gram.gram_update(x, y, **kw)


def blocked_cholesky(a: jax.Array, **kw) -> jax.Array:
    """Batched blocked lower-Cholesky of SPD systems (m, d, d) → L."""
    kw.setdefault("interpret", not _ON_TPU)
    return _solve.blocked_cholesky(a, **kw)


def cholesky_solve(l: jax.Array, b: jax.Array, **kw) -> jax.Array:
    """Batched L·Lᵀ·x = b substitution against blocked_cholesky factors."""
    kw.setdefault("interpret", not _ON_TPU)
    return _solve.cholesky_solve(l, b, **kw)


def multi_gamma_solve(c: jax.Array, q: jax.Array, gammas: jax.Array,
                      **kw) -> jax.Array:
    """Fused γ-sweep: (C + γ_j I) W_j = Q for the whole grid in one call."""
    kw.setdefault("interpret", not _ON_TPU)
    return _solve.multi_gamma_solve(c, q, gammas, **kw)


STREAM_MIN_DIM = _solve.STREAM_MIN_DIM


def interpret_default() -> bool:
    """Whether Pallas calls should run interpreted on this backend."""
    return not _ON_TPU


def chol_rank_update(l: jax.Array, xs: jax.Array, **kw) -> jax.Array:
    """Fused rank-k Cholesky factor update L → chol(LLᵀ + xsᵀxs)."""
    kw.setdefault("interpret", not _ON_TPU)
    return _solve.chol_rank_update(l, xs, **kw)


def streamed_cholesky(a: jax.Array, **kw) -> jax.Array:
    """Single-system (d, d) lower Cholesky via HBM→VMEM panel streaming."""
    kw.setdefault("interpret", not _ON_TPU)
    return _solve.streamed_cholesky(a, **kw)


def streamed_cholesky_solve(l: jax.Array, b: jax.Array, **kw) -> jax.Array:
    """L·Lᵀ·x = b substitution against a streamed_cholesky factor."""
    kw.setdefault("interpret", not _ON_TPU)
    return _solve.streamed_cholesky_solve(l, b, **kw)


def flash_attention(q, k, v, **kw) -> jax.Array:
    """Causal/GQA/sliding-window flash attention."""
    kw.setdefault("interpret", not _ON_TPU)
    return _fa.flash_attention(q, k, v, **kw)
