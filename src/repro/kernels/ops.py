"""Public jit'd entry points for the Pallas kernels.

On CPU (this container) the kernels execute via the Pallas interpreter;
on TPU the same calls compile through Mosaic. ``repro.kernels.ref`` holds the
pure-jnp oracles used by the tests and by the models' default (portable) path.
"""

from __future__ import annotations

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import gram as _gram

_ON_TPU = jax.default_backend() == "tpu"


def gram_update(x: jax.Array, y: jax.Array, **kw) -> tuple[jax.Array, jax.Array]:
    """Fused (XᵀX, XᵀY). Interpreted off-TPU, Mosaic-compiled on TPU."""
    kw.setdefault("interpret", not _ON_TPU)
    return _gram.gram_update(x, y, **kw)


def flash_attention(q, k, v, **kw) -> jax.Array:
    """Causal/GQA/sliding-window flash attention."""
    kw.setdefault("interpret", not _ON_TPU)
    return _fa.flash_attention(q, k, v, **kw)
