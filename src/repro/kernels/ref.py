"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_ref(x: jax.Array, y: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels.gram.gram_update: (XᵀX, XᵀY) in f32."""
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    return xf.T @ xf, xf.T @ yf


def mha_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Oracle for kernels.flash_attention.flash_attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0 (GQA).
    ``q_offset``: absolute position of q[0] (decode: Skv - Sq).
    ``window``: sliding-window size — query at absolute position t attends to
    keys in [t - window + 1, t] (None = unbounded).
    Computation in f32 throughout.
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(b, hkv, group, sq, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf)
    q_pos = jnp.arange(sq)[:, None] + q_offset
    k_pos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    # Fully-masked rows (can happen with pathological windows) → zeros.
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)
    return out.reshape(b, hq, sq, d).astype(q.dtype)
