"""Pallas TPU kernels for the AFL aggregate solve: blocked Cholesky,
batched triangular solves, and the fused multi-γ sweep.

AFL's single round ends in ONE linear solve, ``(C_agg + γI) W = Q_agg``, plus
the RI-ablation γ-sweep that repeats it over a ridge grid — at d=2048 the
PR-3 sweep spent ~40% of wall time in the per-γ host loop (interpreter +
per-call BLAS dispatch + a fresh ``C + γI`` materialization each iteration).
These kernels move the whole factor→sweep pipeline into ``pallas_call``s:

  * :func:`blocked_cholesky` — a right-looking blocked Cholesky over a batch
    of SPD systems. Panels are unrolled at trace time so every trsm/syrk
    tile update is a static-shape MXU matmul at the true d³/3 flop count;
    only the ``block``-column micro-factorizations run as ``fori_loop``
    column sweeps (O(d) cheap sequential steps total, each touching one
    ``block``² tile batched over the whole system batch).
  * :func:`cholesky_solve` — the batched forward/backward substitution
    against those factors, blocked the same way (per-panel inverse diagonal
    blocks turn the substitution recurrences into matmuls).
  * :func:`multi_gamma_solve` — the fused sweep: ONE ``pallas_call`` whose
    grid walks γ-blocks; each step materializes ``C + γ_j I`` for its block
    of γs in registers/VMEM, factors all of them batched, and solves for
    ``W(γ_j)`` — no host loop, no per-γ dispatch, one ``C`` fetch per block.

Precision variants (the ``precision`` argument):

  * ``"native"`` — compute in the input dtype: f32 by default, or **native
    f64** end-to-end under ``jax_enable_x64`` (the 1e-10-vs-numpy parity
    configuration locked down by ``tests/test_solve_kernels.py``).
  * ``"f32_x2"`` — f32 storage with **emulated-f64 products**: every
    trsm/syrk/substitution matmul splits its operands into exact high/low
    12-bit-mantissa halves (Dekker splitting) and accumulates the three
    significant cross products, so the MXU contractions carry ~2× the f32
    mantissa. Remaining error is f32 accumulation + the scalar
    sqrt/reciprocal path — measured ~1 decade better than plain f32 on the
    d=2048 sweep (see ``benchmarks/solve_kernels_bench.py``).

On TPU the calls compile through Mosaic with the whole batched system
resident in VMEM — which bounds native occupancy to roughly d ≤ 1024 at f32
per core (d² · batch · 4 bytes against ~16 MB); past that, run one system
per grid step or shard the γ-grid across cores. HBM-tiled panels (the
``gram.py`` treatment) are the open next rung for d=6144 *single-system*
factorization; the serving path at that scale instead shards the Gram
itself (``repro.fl.api.ShardedCoordinator(tiled_gram=True)``). Off-TPU the
kernels execute in interpret mode (``repro.kernels.ops`` defaults) — which
is how this repo's CI exercises them, and fast enough to beat the host
per-γ loop ~3× at d=2048 (measured, ``results/bench/solve_kernels_bench.json``).

Rank-deficient systems (the γ=0 ablations) are NOT special-cased here: a
singular system yields NaNs, which callers (``AnalyticEngine``) detect and
route to the eigendecomposition/pinv host path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = [
    "blocked_cholesky",
    "cholesky_solve",
    "multi_gamma_solve",
    "panel_factor",
    "panel_tri_inv",
    "panel_trsm",
    "panel_update",
    "tile_cholesky_factor",
    "tile_cholesky_solve",
    "streamed_cholesky",
    "streamed_cholesky_solve",
    "chol_rank_update",
    "panel_width",
    "DEFAULT_BLOCK",
    "DEFAULT_GAMMA_BLOCK",
    "DEFAULT_STREAM_BLOCK",
    "STREAM_MIN_DIM",
]

DEFAULT_BLOCK = 128        # panel width: MXU-lane multiple, 2·d fori steps
DEFAULT_GAMMA_BLOCK = 8    # γs factored together per fused-sweep grid step
DEFAULT_BATCH_BLOCK = 8    # systems per grid step for the batched kernels
DEFAULT_STREAM_BLOCK = 256   # panel width for the HBM-streamed single-system path
DEFAULT_UPDATE_BLOCK = 256   # row/col tile edge for the streamed syrk grid
STREAM_MIN_DIM = 2048      # engine routes single systems this wide to streaming

_SPLIT = 4097.0            # 2^12 + 1: Dekker split constant for f32


# ---------------------------------------------------------------------------
# In-kernel building blocks (trace-time helpers on (batch, ·, ·) values)
# ---------------------------------------------------------------------------


def _split(a):
    """Dekker split: a == hi + lo with 12-bit-mantissa halves (exact in f32,
    so every pairwise product of halves is exact in f32)."""
    t = a * _SPLIT
    hi = t - (t - a)
    return hi, (a - hi)


def _make_mm(precision: str):
    """Batched tile matmul ``(b, n, k) @ (b, k, m)`` at the requested
    precision: native dtype, or the 3-product emulated-f64 split."""
    dims = (((2,), (1,)), ((0,), (0,)))

    def mm(a, b):
        return lax.dot_general(a, b, dims, preferred_element_type=a.dtype)

    if precision != "f32_x2":
        return mm

    def mm_x2(a, b):
        ah, al = _split(a)
        bh, bl = _split(b)
        hi = lax.dot_general(ah, bh, dims, preferred_element_type=a.dtype)
        mid = (lax.dot_general(ah, bl, dims, preferred_element_type=a.dtype)
               + lax.dot_general(al, bh, dims,
                                 preferred_element_type=a.dtype))
        return hi + mid

    return mm_x2


def _factor_tile(tile):
    """Unblocked Cholesky of a batch of SPD tiles ``(b, m, m)`` → lower L.

    A ``fori_loop`` column sweep with masked full-width updates, so every
    iteration has static shapes (VPU work on one tile, batched); the upper
    triangle is written as zeros. A non-PD tile yields NaNs (sqrt of a
    non-positive pivot) that propagate to the caller's fallback check.
    """
    m = tile.shape[-1]
    rows = jnp.arange(m)

    def body(j, s):
        pv = jnp.sqrt(s[:, j, j])
        col = s[:, :, j] / pv[:, None]
        below = rows[None, :] > j
        colm = jnp.where(below, col, jnp.zeros_like(col))
        s = s - colm[:, :, None] * colm[:, None, :]
        cj = jnp.where(rows[None, :] == j, pv[:, None], colm)
        return s.at[:, :, j].set(cj)

    return lax.fori_loop(0, m, body, tile)


def _tri_inv_tile(l):
    """Inverse of a batch of lower-triangular tiles ``(b, m, m)`` by forward
    substitution on the identity — turns panel trsm into one matmul."""
    m = l.shape[-1]
    rows = jnp.arange(m)
    eye = jnp.eye(m, dtype=l.dtype)

    def body(i, z):
        li = l[:, i, :]
        strict = jnp.where(rows[None, :] < i, li, jnp.zeros_like(li))
        acc = lax.dot_general(strict, z, (((1,), (1,)), ((0,), (0,))),
                              preferred_element_type=l.dtype)
        zi = (eye[i][None, :] - acc) / l[:, i, i][:, None]
        return z.at[:, i, :].set(zi)

    return lax.fori_loop(0, m, body, jnp.zeros_like(l))


def _t(a):
    return jnp.swapaxes(a, -1, -2)


def _factor_panels(a, block, mm):
    """Right-looking blocked Cholesky on a batch ``(b, d, d)``; panels are
    unrolled at trace time (static tile shapes, true d³/3 flops). Returns the
    lower factor and the per-panel inverse diagonal blocks (reused by the
    solve phase so substitution needs no extra column sweeps)."""
    d = a.shape[-1]
    if block >= d:
        # single panel: no trailing updates (a whole-array .at[].set would
        # also lower to a scatter Pallas refuses to capture)
        l = _factor_tile(a)
        return l, [_tri_inv_tile(l)]
    inv_blocks = []
    for o in range(0, d, block):
        l11 = _factor_tile(a[:, o:o + block, o:o + block])
        zinv = _tri_inv_tile(l11)
        inv_blocks.append(zinv)
        a = a.at[:, o:o + block, o:o + block].set(l11)
        if o + block < d:
            l21 = mm(a[:, o + block:, o:o + block], _t(zinv))
            a = a.at[:, o + block:, o:o + block].set(l21)
            a = a.at[:, o + block:, o + block:].add(-mm(l21, _t(l21)))
    # zero the (garbage) strict upper triangle so the output is a clean L
    d_idx = jnp.arange(d)
    lower = d_idx[:, None] >= d_idx[None, :]
    return jnp.where(lower[None], a, jnp.zeros_like(a)), inv_blocks


def _solve_panels(l, b, block, mm, inv_blocks=None):
    """Batched ``L Lᵀ x = b`` by blocked forward + backward substitution."""
    d = l.shape[-1]
    if inv_blocks is None:
        inv_blocks = [_tri_inv_tile(l[:, o:o + block, o:o + block])
                      for o in range(0, d, block)]
    if block >= d:
        inv = inv_blocks[0]
        return mm(_t(inv), mm(inv, b))
    panels = list(enumerate(range(0, d, block)))
    y = jnp.zeros_like(b)
    for k, o in panels:
        rhs = b[:, o:o + block]
        if o:
            rhs = rhs - mm(l[:, o:o + block, :o], y[:, :o])
        y = y.at[:, o:o + block].set(mm(inv_blocks[k], rhs))
    x = jnp.zeros_like(b)
    for k, o in reversed(panels):
        rhs = y[:, o:o + block]
        if o + block < d:
            rhs = rhs - mm(_t(l[:, o + block:, o:o + block]), x[:, o + block:])
        x = x.at[:, o:o + block].set(mm(_t(inv_blocks[k]), rhs))
    return x


def _ceil_mult(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _pad_spd(a, d_p):
    """Pad a batch of (d, d) systems to (d_p, d_p) with an identity tail —
    the padded block factors to I and never couples back (block diagonal)."""
    d = a.shape[-1]
    if d_p == d:
        return a
    pad = d_p - d
    a = jnp.pad(a, ((0, 0), (0, pad), (0, pad)))
    tail = jnp.arange(d_p) >= d
    eye_tail = jnp.where(tail[:, None] & tail[None, :] &
                         (jnp.arange(d_p)[:, None] == jnp.arange(d_p)[None, :]),
                         jnp.ones((d_p, d_p), a.dtype),
                         jnp.zeros((d_p, d_p), a.dtype))
    return a + eye_tail[None]


# ---------------------------------------------------------------------------
# pallas_call entry points
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("block", "precision", "interpret",
                                    "batch_block"))
def blocked_cholesky(a: jax.Array, *, block: int = DEFAULT_BLOCK,
                     precision: str = "native", interpret: bool = False,
                     batch_block: int = DEFAULT_BATCH_BLOCK) -> jax.Array:
    """Batched lower-Cholesky ``a (m, d, d) SPD → L`` via the blocked kernel.

    The grid walks batch blocks; each step factors ``batch_block`` systems
    together (one trace of the unrolled panel pipeline serves the whole
    batch). Returns clean lower factors; non-PD inputs yield NaNs.
    """
    m, d, _ = a.shape
    if m == 0:
        return jnp.zeros((0, d, d), a.dtype)
    mm = _make_mm(precision)
    bs = min(block, _ceil_mult(d, 8))
    d_p = _ceil_mult(d, bs)
    bb = min(batch_block, m)
    m_p = _ceil_mult(m, bb)
    a = _pad_spd(a, d_p)
    if m_p != m:
        # pad the batch with identity systems (factor = I, discarded)
        pad = jnp.broadcast_to(jnp.eye(d_p, dtype=a.dtype)[None],
                               (m_p - m, d_p, d_p))
        a = jnp.concatenate([a, pad], 0)

    def kernel(a_ref, l_ref):
        l, _ = _factor_panels(a_ref[...], bs, mm)
        l_ref[...] = l

    out = pl.pallas_call(
        kernel,
        grid=(m_p // bb,),
        in_specs=[pl.BlockSpec((bb, d_p, d_p), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bb, d_p, d_p), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m_p, d_p, d_p), a.dtype),
        interpret=interpret,
    )(a)
    return out[:m, :d, :d]


@functools.partial(jax.jit,
                   static_argnames=("block", "precision", "interpret",
                                    "batch_block"))
def cholesky_solve(l: jax.Array, b: jax.Array, *, block: int = DEFAULT_BLOCK,
                   precision: str = "native", interpret: bool = False,
                   batch_block: int = DEFAULT_BATCH_BLOCK) -> jax.Array:
    """Batched triangular solve ``L Lᵀ x = b`` for lower factors from
    :func:`blocked_cholesky` — ``l (m, d, d)``, ``b (m, d, c)`` → ``x``.

    Blocked forward/backward substitution: the per-panel diagonal blocks are
    inverted once (``fori`` column sweeps), after which both sweeps are pure
    tile matmuls — the repeated-solve hot path costs d²·c, not d³.
    """
    m, d, _ = l.shape
    c = b.shape[-1]
    if m == 0:
        return jnp.zeros((0, d, c), b.dtype)
    mm = _make_mm(precision)
    bs = min(block, _ceil_mult(d, 8))
    d_p = _ceil_mult(d, bs)
    c_p = _ceil_mult(c, 8)
    bb = min(batch_block, m)
    m_p = _ceil_mult(m, bb)
    if d_p != d:
        l = _pad_spd(l, d_p)       # identity tail: triangular and invertible
    if (d_p, c_p) != (d, c):
        b = jnp.pad(b, ((0, 0), (0, d_p - d), (0, c_p - c)))
    if m_p != m:
        pad_l = jnp.broadcast_to(jnp.eye(d_p, dtype=l.dtype)[None],
                                 (m_p - m, d_p, d_p))
        l = jnp.concatenate([l, pad_l], 0)
        b = jnp.concatenate(
            [b, jnp.zeros((m_p - m, d_p, c_p), b.dtype)], 0)

    def kernel(l_ref, b_ref, x_ref):
        x_ref[...] = _solve_panels(l_ref[...], b_ref[...], bs, mm)

    out = pl.pallas_call(
        kernel,
        grid=(m_p // bb,),
        in_specs=[
            pl.BlockSpec((bb, d_p, d_p), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, d_p, c_p), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, d_p, c_p), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m_p, d_p, c_p), b.dtype),
        interpret=interpret,
    )(l, b)
    return out[:m, :d, :c]


@functools.partial(jax.jit,
                   static_argnames=("block", "gamma_block", "precision",
                                    "interpret"))
def multi_gamma_solve(c: jax.Array, q: jax.Array, gammas: jax.Array, *,
                      block: int = DEFAULT_BLOCK,
                      gamma_block: int = DEFAULT_GAMMA_BLOCK,
                      precision: str = "native",
                      interpret: bool = False) -> jax.Array:
    """The fused γ-sweep: solve ``(C + γ_j I) W_j = Q`` for a whole γ grid.

    One ``pallas_call`` whose grid walks γ-blocks: each step broadcasts C
    once, shifts the diagonal by its block of γs, factors all of them as one
    batched blocked Cholesky, and runs the batched substitution — replacing
    the per-γ host loop (allocate ``C + γI`` → LAPACK → dispatch, per γ)
    with a single device program. Returns ``(n_gammas, d, c)``; γs whose
    system is singular come back as NaNs (caller falls back to the
    eigendecomposition path).
    """
    d = c.shape[-1]
    n_cls = q.shape[-1]
    n_g = gammas.shape[0]
    if n_g == 0:
        return jnp.zeros((0, d, n_cls), c.dtype)
    mm = _make_mm(precision)
    bs = min(block, _ceil_mult(d, 8))
    d_p = _ceil_mult(d, bs)
    c_p = _ceil_mult(n_cls, 8)
    bg = min(gamma_block, n_g)
    n_gp = _ceil_mult(n_g, bg)
    if d_p != d:
        c = _pad_spd(c[None], d_p)[0]
    if (d_p, c_p) != (d, n_cls):
        q = jnp.pad(q, ((0, d_p - d), (0, c_p - n_cls)))
    if n_gp != n_g:
        gammas = jnp.concatenate(
            [gammas, jnp.broadcast_to(gammas[-1], (n_gp - n_g,))])
    gammas = gammas.astype(c.dtype).reshape(n_gp // bg, bg)

    def kernel(c_ref, q_ref, g_ref, w_ref):
        cc = c_ref[...]
        g = g_ref[...][0]                                   # (bg,)
        diag = jnp.arange(d_p)
        eye = (diag[:, None] == diag[None, :]).astype(cc.dtype)
        a = cc[None] + g[:, None, None] * eye[None]
        l, inv_blocks = _factor_panels(a, bs, mm)
        qb = jnp.broadcast_to(q_ref[...][None], (bg, d_p, c_p))
        w_ref[...] = _solve_panels(l, qb, bs, mm,
                                   inv_blocks=inv_blocks)[None]

    out = pl.pallas_call(
        kernel,
        grid=(n_gp // bg,),
        in_specs=[
            pl.BlockSpec((d_p, d_p), lambda i: (0, 0)),
            pl.BlockSpec((d_p, c_p), lambda i: (0, 0)),
            pl.BlockSpec((1, bg), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bg, d_p, c_p), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_gp // bg, bg, d_p, c_p), c.dtype),
        interpret=interpret,
    )(c, q, gammas)
    return out.reshape(n_gp, d_p, c_p)[:n_g, :d, :n_cls]


# ---------------------------------------------------------------------------
# Tile-parallel / HBM-streamed single-system path
#
# The kernels above keep the whole batched system resident in VMEM, which
# caps Mosaic-native occupancy near d≈1024 at f32. The path below factors a
# SINGLE wide system as a sequence of panel-sized pallas_calls: the (b, b)
# diagonal micro-factorization, the (r, b) panel trsm, and the streamed
# trailing syrk whose 2-D grid walks (row, col) tiles of the trailing
# submatrix — each grid step touches one VMEM-sized tile, so pallas's
# automatic grid pipelining double-buffers the HBM→VMEM panel traffic and a
# d≥2048 system factors Mosaic-native.
#
# The same trace-time routine also runs tile-PARALLEL: each mesh shard holds
# one (r, d) row tile of the global Gram, and the per-panel communication is
# abstracted behind two callbacks (``gather`` and ``psum``). The panel owner
# is a *static* shard index (panel width divides the tile rows), so the
# schedule per panel is: every shard offers its candidate diagonal block,
# one all-gather-of-a-panel replicates the true block, every shard factors
# it redundantly (b³ — cheap) and applies trsm/syrk to its own rows. No
# device ever materializes the full (d, d) system — peak per-device live
# bytes stay at the (r, d) tile plus one (d, b) panel column. With ONE shard
# and identity callbacks the very same trace is the local streamed kernel,
# which is what makes the distributed path bit-for-bit testable against
# :func:`streamed_cholesky`.
# ---------------------------------------------------------------------------

_DIMS_NN = (((1,), (0,)), ((), ()))    # a @ b
_DIMS_NT = (((1,), (1,)), ((), ()))    # a @ bᵀ
_DIMS_TN = (((0,), (0,)), ((), ()))    # aᵀ @ b


def _make_mm2(precision: str, dims):
    """Unbatched 2-D tile matmul at the requested precision (see _make_mm)."""

    def mm(a, b):
        return lax.dot_general(a, b, dims, preferred_element_type=a.dtype)

    if precision != "f32_x2":
        return mm

    def mm_x2(a, b):
        ah, al = _split(a)
        bh, bl = _split(b)
        hi = lax.dot_general(ah, bh, dims, preferred_element_type=a.dtype)
        mid = (lax.dot_general(ah, bl, dims, preferred_element_type=a.dtype)
               + lax.dot_general(al, bh, dims,
                                 preferred_element_type=a.dtype))
        return hi + mid

    return mm_x2


def panel_width(rows: int, cap: int = DEFAULT_STREAM_BLOCK) -> int:
    """Largest panel width ≤ ``cap`` that divides ``rows`` — panels must tile
    the shard rows exactly so every panel has a single static owner shard."""
    b = min(cap, rows)
    while rows % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("interpret",))
def panel_factor(diag: jax.Array, *, interpret: bool = False):
    """Factor one (b, b) SPD diagonal block → ``(L, inv(L))`` in VMEM.

    Both outputs come from one pallas_call so the trsm-ready inverse rides
    along with the factor; a non-PD block yields NaNs (caller fallback).
    """
    b = diag.shape[-1]

    def kernel(d_ref, l_ref, z_ref):
        l = _factor_tile(d_ref[...][None])
        l_ref[...] = l[0]
        z_ref[...] = _tri_inv_tile(l)[0]

    return pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct((b, b), diag.dtype),
                   jax.ShapeDtypeStruct((b, b), diag.dtype)),
        interpret=interpret,
    )(diag)


@functools.partial(jax.jit, static_argnames=("interpret",))
def panel_tri_inv(l: jax.Array, *, interpret: bool = False) -> jax.Array:
    """inv(L) of one (b, b) lower-triangular block (solve-only callers that
    hold a factor but not the inverses from :func:`panel_factor`)."""
    b = l.shape[-1]

    def kernel(l_ref, z_ref):
        z_ref[...] = _tri_inv_tile(l_ref[...][None])[0]

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, b), l.dtype),
        interpret=interpret,
    )(l)


@functools.partial(jax.jit,
                   static_argnames=("precision", "interpret", "row_block"))
def panel_trsm(raw: jax.Array, zinv: jax.Array, *, precision: str = "native",
               interpret: bool = False,
               row_block: int = DEFAULT_UPDATE_BLOCK) -> jax.Array:
    """Panel trsm ``raw (r, b) @ inv(L_D)ᵀ`` — the grid streams row blocks of
    the local column slab through VMEM against the replicated (b, b) inverse."""
    r, b = raw.shape
    rb = panel_width(r, row_block)
    mm = _make_mm2(precision, _DIMS_NT)

    def kernel(a_ref, z_ref, o_ref):
        o_ref[...] = mm(a_ref[...], z_ref[...])

    return pl.pallas_call(
        kernel,
        grid=(r // rb,),
        in_specs=[
            pl.BlockSpec((rb, b), lambda i: (i, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, b), raw.dtype),
        interpret=interpret,
    )(raw, zinv)


@functools.partial(jax.jit,
                   static_argnames=("precision", "interpret", "row_block",
                                    "col_block"))
def panel_update(trail: jax.Array, lp: jax.Array, pt: jax.Array, *,
                 precision: str = "native", interpret: bool = False,
                 row_block: int = DEFAULT_UPDATE_BLOCK,
                 col_block: int = DEFAULT_UPDATE_BLOCK) -> jax.Array:
    """Streamed trailing syrk ``trail (r, w) − lp (r, b) @ pt (w, b)ᵀ``.

    The 2-D grid walks (row, col) VMEM tiles of the trailing submatrix, so
    per-step residency is rb·cb + (rb + cb)·b elements regardless of d —
    this is the kernel that keeps the right-looking update HBM-streamed.
    """
    r, w = trail.shape
    b = lp.shape[-1]
    rb = panel_width(r, row_block)
    cb = panel_width(w, col_block)
    mm = _make_mm2(precision, _DIMS_NT)

    def kernel(t_ref, l_ref, p_ref, o_ref):
        o_ref[...] = t_ref[...] - mm(l_ref[...], p_ref[...])

    return pl.pallas_call(
        kernel,
        grid=(r // rb, w // cb),
        in_specs=[
            pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
            pl.BlockSpec((rb, b), lambda i, j: (i, 0)),
            pl.BlockSpec((cb, b), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, w), trail.dtype),
        interpret=interpret,
    )(trail, lp, pt)


def tile_cholesky_factor(tile, *, shard, n_shards: int, gather, block: int,
                         precision: str = "native", interpret: bool = False,
                         use_kernel: bool = True):
    """Blocked right-looking Cholesky of a row-tiled global system.

    ``tile`` is this shard's ``(r, d)`` row slab of the global SPD system
    (``d = n_shards · r``); ``shard`` is the shard's linear index (a traced
    ``axis_index`` under shard_map, or a plain 0 for the local streamed
    path) and ``gather(x) → (n_shards, …)`` stacks a per-shard value in
    shard order (``lax.all_gather`` on the mesh; ``x[None]`` locally).
    ``block`` must divide ``r`` (see :func:`panel_width`) so each panel has
    one static owner shard. Returns this shard's rows of the clean lower
    factor plus the replicated per-panel inverse diagonal blocks.

    Per panel: every shard offers its candidate (b, b) diagonal slice, the
    gather replicates the owner's true one, every shard factors it
    redundantly (b³ flops — far below the gather latency it would trade
    against) and applies trsm to its local column slab; one more panel
    gather assembles the (d, b) L-column every shard needs for its streamed
    trailing syrk. Peak live bytes per shard: the (r, d) tile + one (d, b)
    panel — never the (d, d) system.
    """
    r, d_p = tile.shape
    b = block
    mm_nt = _make_mm2(precision, _DIMS_NT)
    rows_g = shard * r + jnp.arange(r)          # global row ids of this tile
    work = tile
    zs = []
    for p in range(d_p // b):
        o = p * b
        own = o // r                    # static: panel lives on one shard
        lo = o - own * r                # static owner-local row offset
        diag = gather(work[lo:lo + b, o:o + b])[own]
        if use_kernel:
            l_d, z = panel_factor(diag, interpret=interpret)
        else:
            l_d = _factor_tile(diag[None])[0]
            z = _tri_inv_tile(l_d[None])[0]
        zs.append(z)
        if use_kernel:
            colv = panel_trsm(work[:, o:o + b], z, precision=precision,
                              interpret=interpret)
        else:
            colv = mm_nt(work[:, o:o + b], z)
        below = rows_g >= o + b
        in_diag = (rows_g >= o) & (rows_g < o + b)
        ld_full = jnp.zeros((r, b), work.dtype).at[lo:lo + b].set(l_d)
        col = jnp.where(below[:, None], colv,
                        jnp.where(in_diag[:, None], ld_full,
                                  jnp.zeros_like(colv)))
        work = work.at[:, o:o + b].set(col)
        w_tr = d_p - o - b
        if w_tr:
            lcol = gather(col).reshape(n_shards * r, b)
            pt = lcol[o + b:]
            lp = jnp.where(below[:, None], col, jnp.zeros_like(col))
            if use_kernel:
                trail = panel_update(work[:, o + b:], lp, pt,
                                     precision=precision, interpret=interpret)
            else:
                trail = work[:, o + b:] - mm_nt(lp, pt)
            work = work.at[:, o + b:].set(trail)
    return work, zs


def tile_cholesky_solve(tile_l, q_tile, zs=None, *, shard, n_shards: int,
                        gather, psum, block: int, precision: str = "native",
                        interpret: bool = False, use_kernel: bool = True):
    """``L Lᵀ x = q`` against a row-tiled factor from
    :func:`tile_cholesky_factor`; returns the replicated ``(d, C)`` solution.

    ``q_tile`` is this shard's rows of the right-hand side; ``psum`` reduces
    a per-shard value over the mesh (identity locally). Forward sweep: the
    panel owner forms its (b, C) block from its own L rows and the psum
    broadcasts it; backward sweep: every shard contributes its local rows'
    partial product and the psum assembles the replicated update. Per-panel
    traffic is (b, C) — never the system.
    """
    r, d_p = tile_l.shape
    cdim = q_tile.shape[-1]
    b = block
    mm_nn = _make_mm2(precision, _DIMS_NN)
    mm_tn = _make_mm2(precision, _DIMS_TN)
    rows_g = shard * r + jnp.arange(r)
    panels = list(range(d_p // b))
    if zs is None:
        zs = []
        for p in panels:
            o = p * b
            own, lo = o // r, o - (o // r) * r
            diagl = gather(tile_l[lo:lo + b, o:o + b])[own]
            if use_kernel:
                zs.append(panel_tri_inv(diagl, interpret=interpret))
            else:
                zs.append(_tri_inv_tile(diagl[None])[0])
    y = jnp.zeros((d_p, cdim), q_tile.dtype)
    for p in panels:
        o = p * b
        own, lo = o // r, o - (o // r) * r
        rhs = q_tile[lo:lo + b]
        if o:
            rhs = rhs - mm_nn(tile_l[lo:lo + b, :o], y[:o])
        y_p = mm_nn(zs[p], rhs)
        y_p = jnp.where(jnp.asarray(shard == own), y_p, jnp.zeros_like(y_p))
        y = y.at[o:o + b].set(psum(y_p))
    x = jnp.zeros((d_p, cdim), q_tile.dtype)
    for p in reversed(panels):
        o = p * b
        below = rows_g >= o + b
        lp = jnp.where(below[:, None], tile_l[:, o:o + b],
                       jnp.zeros((r, b), tile_l.dtype))
        start = jnp.asarray(shard * r)
        xs_local = lax.dynamic_slice(
            x, (start, jnp.zeros_like(start)), (r, cdim))
        total = psum(mm_tn(lp, xs_local))
        x = x.at[o:o + b].set(mm_tn(zs[p], y[o:o + b] - total))
    return x


@functools.partial(jax.jit,
                   static_argnames=("block", "precision", "interpret"))
def streamed_cholesky(a: jax.Array, *, block: int = DEFAULT_STREAM_BLOCK,
                      precision: str = "native",
                      interpret: bool = False) -> jax.Array:
    """Single-system lower Cholesky ``a (d, d) SPD → L`` via panel streaming.

    The degenerate one-shard instance of :func:`tile_cholesky_factor`: the
    whole system stays in HBM and only panel-sized tiles transit VMEM, so a
    d≥2048 system factors Mosaic-native where :func:`blocked_cholesky`'s
    whole-resident batch kernel cannot. Non-divisible d is padded with an
    identity tail and sliced back.
    """
    d = a.shape[-1]
    bs = min(block, _ceil_mult(d, 8))
    d_p = _ceil_mult(d, bs)
    ap = _pad_spd(a[None], d_p)[0]
    l, _ = tile_cholesky_factor(
        ap, shard=0, n_shards=1, gather=lambda v: v[None], block=bs,
        precision=precision, interpret=interpret)
    return l[:d, :d]


@functools.partial(jax.jit,
                   static_argnames=("block", "precision", "interpret"))
def streamed_cholesky_solve(l: jax.Array, b: jax.Array, *,
                            block: int = DEFAULT_STREAM_BLOCK,
                            precision: str = "native",
                            interpret: bool = False) -> jax.Array:
    """``L Lᵀ x = b`` against a :func:`streamed_cholesky` factor —
    ``l (d, d)`` lower, ``b (d, c)`` → ``x (d, c)``."""
    d = l.shape[-1]
    bs = min(block, _ceil_mult(d, 8))
    d_p = _ceil_mult(d, bs)
    lp = _pad_spd(l[None], d_p)[0]
    bp = jnp.pad(b, ((0, d_p - d), (0, 0))) if d_p != d else b
    x = tile_cholesky_solve(
        lp, bp, None, shard=0, n_shards=1, gather=lambda v: v[None],
        psum=lambda v: v, block=bs, precision=precision, interpret=interpret)
    return x[:d]


# ---------------------------------------------------------------------------
# Fused rank-k Cholesky update (the batched-ingest fold)
# ---------------------------------------------------------------------------


def _rank_update_kernel(l_ref, xt_ref, o_ref):
    """Householder column sweep folding ``xtᵀ`` rows into a lower factor.

    Whole-resident: L (d_p, d_p) and the stacked update tail xt (d_p, k_p)
    live in VMEM for the entire sweep — one kernel launch for the whole
    rank-k update instead of k rank-1 sweeps (or a host-driven loop). Each
    column step annihilates all k update entries with a single
    (k+1)-reflection; masked full-width updates keep every iteration
    static-shape under ``fori_loop``. Zero update rows (s == 0 — including
    every identity-tail padding column) reduce to r = |a| with vanishing
    corrections, so padding needs no masking of its own.
    """
    dp = l_ref.shape[-1]
    rows = jnp.arange(dp)

    def body(i, carry):
        l, xt = carry
        w = xt[i, :]
        s = jnp.sum(w * w)
        s_ = jnp.where(s > 0, s, 1.0)      # w == 0 ⇒ t == 0, updates vanish
        a = l[i, i]
        r = jnp.sqrt(a * a + s)
        amr = -s / (r + a)                 # a − r without cancellation
        beta = (r + a) / (r * s_)          # 2 / uᵀu for u = [a−r; w]
        below = rows > i
        col = l[:, i]
        t = amr * col + xt @ w
        new_col = jnp.where(below, col - (beta * amr) * t, col)
        new_col = jnp.where(rows == i, r, new_col)
        l = l.at[:, i].set(new_col)
        xt = jnp.where(below[:, None],
                       xt - (beta * t)[:, None] * w[None, :], xt)
        return l, xt

    l, _ = lax.fori_loop(0, dp, body, (l_ref[...], xt_ref[...]))
    o_ref[...] = l


@functools.partial(jax.jit, static_argnames=("interpret",))
def chol_rank_update(l: jax.Array, xs: jax.Array, *,
                     interpret: bool = False) -> jax.Array:
    """Fused rank-k Cholesky update: ``L (d, d)`` lower with ``A = LLᵀ``,
    update rows ``xs (k, d)`` → ``chol(A + xsᵀxs)`` in ONE ``pallas_call``.

    This is the micro-batch ingest fold's device path: a whole batch of
    client roots, stacked, folds into the cached factor in a single kernel
    launch — versus the non-kernel jax path's per-column ``fori_loop``
    dispatched from ``jit`` (same flops, k× the launch/carry overhead when
    applied per report). The update is positive (a Gram delta), so the
    sweep cannot break down; non-finite inputs surface as NaNs, which
    ``AnalyticEngine.factor_update`` detects and routes to a full refactor.
    Whole-resident in VMEM like :func:`blocked_cholesky` — same d ≲ 1024
    f32 bound; wider serving systems refactor via the streamed path anyway.
    """
    d = l.shape[-1]
    k = xs.shape[0]
    if k == 0:
        return l
    bs = min(DEFAULT_BLOCK, _ceil_mult(d, 8))
    d_p = _ceil_mult(d, bs)
    k_p = _ceil_mult(k, 8)
    lp = _pad_spd(l[None], d_p)[0]
    xt = jnp.pad(xs.T.astype(l.dtype), ((0, d_p - d), (0, k_p - k)))
    out = pl.pallas_call(
        _rank_update_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((d_p, d_p), lambda i: (0, 0)),
                  pl.BlockSpec((d_p, k_p), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((d_p, d_p), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d_p, d_p), l.dtype),
        interpret=interpret,
    )(lp, xt)
    return out[:d, :d]
