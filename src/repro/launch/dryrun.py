import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

This is the no-hardware proof that the distribution config is coherent: for
each combination we build abstract inputs (ShapeDtypeStruct — no allocation),
jit the step with explicit in/out shardings, ``.lower().compile()`` on the
production mesh, and record ``memory_analysis()`` / ``cost_analysis()`` plus
the collective bytes parsed from the partitioned HLO. ``benchmarks/roofline``
turns the emitted JSON into the §Roofline table.

The two lines above MUST stay first: jax locks the device count on first
backend init, and the production meshes need 512 host placeholder devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      --arch all --shape all --mesh both --out results/dryrun [--variant v]

Steps lowered per shape kind (see launch/steps.py):
  train_4k               analytic_train_step  (forward + Gram update; the
                         paper's gradient-free local stage — no backward)
  prefill_32k            prefill_step
  decode_32k, long_500k  serve_step (1 new token against a full-length cache)

Variants (--variant, default "baseline"):
  baseline    paper-faithful mapping (full-length masked cache for decode)
  ring        §Perf: ring-buffer KV cache capped at the attention window for
              windowed long-context decode (memory-term hillclimb)
  gradfl      lowers the gradient-FL baseline local step instead of the
              analytic step for train shapes (the paper's comparison arm)
"""

import argparse
import dataclasses
import json
import pathlib
import time
from typing import Any, Optional

import functools

import jax
import jax.numpy as jnp

from repro.config import INPUT_SHAPES, InputShape, ModelConfig
from repro.core import act
from repro.configs.registry import get_config, list_archs
from repro.core import streaming
from repro.launch import hlo_analysis as HLO
from repro.launch import mesh as M
from repro.launch import sharding as SH
from repro.launch import steps as ST
from repro.launch.inputs import input_specs
from repro.models import transformer as T


# long_500k policy per DESIGN.md §Arch-applicability: native sub-quadratic
# archs run as-is; dense/moe/vlm run an explicit sliding-window variant;
# seamless (enc-dec) is the one documented skip.
LONG_WINDOW = 4096
LONG_NATIVE = {"zamba2_7b", "xlstm_350m"}
LONG_SKIP = {"seamless_m4t_medium"}


def resolve_config(arch: str, shape: InputShape, variant: str) -> Optional[ModelConfig]:
    cfg = get_config(arch)
    cfg = dataclasses.replace(cfg, dtype="bfloat16")  # v5e target dtype
    if "pad" in variant:
        # §Perf head-padding: round head counts up to the TP width so the
        # (B,S,H,hd) reshape lands on shard boundaries and GSPMD stops
        # re-gathering q/k/v every layer. Exact for frozen backbones when
        # the padded heads' wo rows are zero (they are never trained).
        tp = 16
        pad = lambda h: -(-h // tp) * tp if h % tp else h
        cfg = dataclasses.replace(
            cfg, num_heads=pad(cfg.num_heads),
            num_kv_heads=pad(cfg.num_kv_heads),
            head_dim=cfg.resolved_head_dim)
    if shape.name == "long_500k":
        if arch in LONG_SKIP:
            return None
        if arch not in LONG_NATIVE:
            # sliding-window variant: every layer windowed (gemma3's global
            # layers included — recorded as a variant, not the 128k-native cfg)
            cfg = dataclasses.replace(cfg, window=LONG_WINDOW, global_every=0)
    return cfg


# ------------------------------------------------------------------ lowering
def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: T.init_params(jax.random.key(0), cfg))


def sharded_bytes(shapes_tree, shardings_tree) -> int:
    """Static per-device residency of a (ShapeDtypeStruct, NamedSharding) tree."""
    import math
    total = 0
    for leaf, sh in zip(jax.tree.leaves(shapes_tree),
                        jax.tree.leaves(shardings_tree,
                                        is_leaf=lambda x: hasattr(x, "shard_shape"))):
        total += math.prod(sh.shard_shape(leaf.shape)) * leaf.dtype.itemsize
    return total


def _with_policy(step, mesh, variant: str = "baseline"):
    """Install the activation-sharding policy for the trace of ``step``."""

    @functools.wraps(step)
    def wrapped(*args):
        with act.activation_policy(
                mesh, M.batch_axes(mesh), M.model_axes(mesh),
                flash_surrogate=variant.startswith("flash")):
            return step(*args)

    return wrapped


def attention_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Analytic MXU FLOPs (global) of every attention instance — the cost the
    Pallas flash kernel performs when the ``flash`` variant stands it in.

    4·B·H·Sq·Skv_eff·hd per instance (QKᵀ + PV, 2 FLOPs/MAC each); causal
    self-attention halves Skv_eff; sliding windows cap it at the window.
    """
    import numpy as np

    b, S = shape.global_batch, shape.seq_len
    hd, h = cfg.resolved_head_dim, cfg.num_heads
    decode = shape.kind == "decode"
    sq = 1 if decode else S

    def inst(skv, *, causal=True, window=0) -> float:
        eff = float(skv)
        if causal and not decode and sq == skv:
            eff = eff / 2.0
        if window and window < eff:
            eff = float(window)
        return 4.0 * b * h * sq * eff * hd

    total = 0.0
    if cfg.arch_type in ("dense", "moe"):
        windows = np.asarray(T.layer_meta(cfg, cfg.num_layers)[0])
        for w in windows:
            total += inst(S, window=int(w))
    elif cfg.arch_type == "hybrid":
        n_groups = cfg.num_layers // cfg.shared_attn_every
        for _ in range(n_groups):
            total += inst(S, window=cfg.window)
    elif cfg.arch_type == "encdec":
        enc_len = cfg.encoder_seq if decode else min(cfg.encoder_seq, S)
        total += cfg.num_layers * inst(S)                       # dec self
        total += cfg.num_layers * inst(enc_len, causal=False)   # cross
        if not decode:  # encoder runs in train/prefill only
            total += cfg.encoder_layers * (
                4.0 * b * h * enc_len * enc_len * hd)
    # xlstm: no attention
    return total


def build_lowerable(cfg: ModelConfig, shape: InputShape, mesh, variant: str):
    """Returns (jitted_fn, abstract_args) ready for .lower(*args)."""
    p_shape = abstract_params(cfg)
    p_sh = SH.param_shardings(p_shape, mesh)
    specs = input_specs(cfg, shape, dtype=jnp.bfloat16)
    b_sh = SH.batch_shardings(cfg, specs, mesh)
    repl = SH.replicated(mesh)

    if shape.kind == "train":
        if variant == "gradfl":
            step = _with_policy(ST.make_fedavg_train_step(cfg), mesh, variant)
            head = jax.ShapeDtypeStruct((cfg.d_model, cfg.num_classes), jnp.float32)
            fn = jax.jit(step, in_shardings=(p_sh, repl, b_sh),
                         out_shardings=(repl, repl))
            static = {"params": sharded_bytes(p_shape, p_sh),
                      "batch": sharded_bytes(specs, b_sh)}
            return fn, (p_shape, head, specs), static
        step = _with_policy(ST.make_analytic_train_step(cfg), mesh, variant)
        st_shape = jax.eval_shape(
            lambda: streaming.init_state(cfg.d_model, cfg.num_classes))
        st_sh = SH.state_shardings(mesh)
        fn = jax.jit(step, in_shardings=(p_sh, st_sh, b_sh), out_shardings=st_sh,
                     donate_argnums=(1,))
        static = {"params": sharded_bytes(p_shape, p_sh),
                  "batch": sharded_bytes(specs, b_sh)}
        return fn, (p_shape, st_shape, specs), static

    if shape.kind == "prefill":
        step = _with_policy(ST.make_prefill_step(cfg, shape.seq_len), mesh, variant)
        logits_sh = SH.batch_shardings(
            cfg, {"logits": jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.vocab_size), jnp.bfloat16)}, mesh)["logits"]
        c_shape = jax.eval_shape(
            lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len))
        c_sh = SH.cache_shardings(cfg, c_shape, shape, mesh)
        fn = jax.jit(step, in_shardings=(p_sh, b_sh),
                     out_shardings=(logits_sh, c_sh))
        static = {"params": sharded_bytes(p_shape, p_sh),
                  "cache": sharded_bytes(c_shape, c_sh),
                  "batch": sharded_bytes(specs, b_sh)}
        return fn, (p_shape, specs), static

    # decode: one token against a seq_len-long cache
    cache_len = shape.seq_len
    if "ring" in variant and cfg.window:
        cache_len = min(cache_len, cfg.window)
    step = _with_policy(ST.make_serve_step(cfg), mesh, variant)
    c_shape = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, cache_len))
    c_sh = SH.cache_shardings(cfg, c_shape, shape, mesh)
    tok_sh = SH.batch_shardings(cfg, specs, mesh)
    logits_sh = SH.batch_shardings(
        cfg, {"logits": jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.vocab_size), jnp.bfloat16)}, mesh)["logits"]
    fn = jax.jit(step, in_shardings=(p_sh, c_sh, tok_sh["token"], repl),
                 out_shardings=(logits_sh, c_sh), donate_argnums=(1,))
    tok = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    static = {"params": sharded_bytes(p_shape, p_sh),
              "cache": sharded_bytes(c_shape, c_sh)}
    return fn, (p_shape, c_shape, tok, pos), static


def run_one(arch: str, shape_name: str, multi_pod: bool, variant: str) -> dict:
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    rec: dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variant": variant, "ok": False,
    }
    cfg = resolve_config(arch, shape, variant)
    if cfg is None:
        rec["skipped"] = "long_500k inapplicable (see DESIGN.md §Arch-applicability)"
        return rec
    mesh = M.make_production_mesh(multi_pod=multi_pod)
    chips = M.num_chips(mesh)
    try:
        fn, args, static = build_lowerable(cfg, shape, mesh, variant)
        t0 = time.time()
        lowered = fn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    except Exception as e:  # a failure here is a sharding bug — surface it
        rec["error"] = f"{type(e).__name__}: {e}"
        return rec

    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    # Loop-aware analysis: XLA's own cost_analysis counts scan bodies once
    # (64-layer stacks under-report ~64×); hlo_analysis re-walks the HLO with
    # known_trip_count multipliers. The xla_cost_* fields keep the raw
    # single-iteration numbers for reference.
    cap = 2 if cfg.dtype == "bfloat16" else 0
    cost = HLO.analyze(hlo, collective_width_cap=cap)
    attn_flops_global = 0.0
    if variant.startswith("flash"):
        attn_flops_global = attention_flops(cfg, shape)
        cost.flops += attn_flops_global / chips
    coll = dict(cost.collective_bytes)
    coll["count"] = cost.collective_count
    coll["total"] = cost.collective_total
    flops_dev = cost.flops
    bytes_dev = cost.bytes_accessed
    rec.update(
        ok=True,
        chips=chips,
        lower_s=round(t1 - t0, 2),
        compile_s=round(t2 - t1, 2),
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        attn_flops_global=attn_flops_global,
        xla_cost_flops_once=float(ca.get("flops", 0.0)),
        xla_cost_bytes_once=float(ca.get("bytes accessed", 0.0)),
        unknown_trip_whiles=cost.unknown_trip_whiles,
        flops_global=flops_dev * chips,
        bytes_global=bytes_dev * chips,
        collectives=coll,
        memory=dict(
            argument_bytes_per_device=ma.argument_size_in_bytes,
            output_bytes_per_device=ma.output_size_in_bytes,
            temp_bytes_per_device=ma.temp_size_in_bytes,
            alias_bytes_per_device=ma.alias_size_in_bytes,
            peak_bytes_per_device=(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
            # Static residency under the declared shardings. The CPU
            # stand-in backend legalizes bf16 dot operands by hoisting
            # whole-buffer f32 converts out of loops, inflating
            # temp_bytes ~2-3x vs the TPU target; this is the honest
            # params+cache footprint (see EXPERIMENTS.md §Dry-run).
            static_bytes_per_device={k: int(v) for k, v in static.items()},
        ),
        roofline=M.Roofline(
            flops=flops_dev * chips,
            hbm_bytes=bytes_dev * chips,
            collective_bytes=coll["total"] * chips,
            chips=chips,
        ).as_dict(),
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all",
                    choices=["all", *INPUT_SHAPES.keys()])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                tag = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
                if args.variant != "baseline":
                    tag += f"__{args.variant}"
                rec = run_one(arch, shape_name, multi, args.variant)
                (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
                if rec.get("skipped"):
                    print(f"[skip] {tag}: {rec['skipped']}", flush=True)
                elif rec["ok"]:
                    r = rec["roofline"]
                    print(
                        f"[ ok ] {tag}: compile={rec['compile_s']}s "
                        f"peak/dev={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
                        f"compute={r['compute_s']*1e3:.2f}ms "
                        f"memory={r['memory_s']*1e3:.2f}ms "
                        f"coll={r['collective_s']*1e3:.2f}ms → {r['dominant']}",
                        flush=True)
                else:
                    n_fail += 1
                    print(f"[FAIL] {tag}: {rec.get('error')}", flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} combination(s) failed")


if __name__ == "__main__":
    main()
