"""Loop-aware cost analysis of compiled (partitioned) HLO text.

``compiled.cost_analysis()`` counts every ``while`` body ONCE — a 64-layer
scanned transformer under-reports FLOPs/bytes/collectives by ~64×. XLA does
record ``backend_config={"known_trip_count":{"n":...}}`` on each while after
optimization, so this module re-walks the HLO text with loop multipliers:

  flops        2 · out_elems · contracted_elems per ``dot`` (the MXU work;
               elementwise FLOPs are ignored, standard for MFU accounting),
               multiplied by the product of enclosing trip counts.
  bytes        HloCostAnalysis-style bytes-accessed: Σ (operand + result)
               bytes per materializing op at fusion granularity — fusion ops
               count their boundary buffers only, mirroring what a fused
               kernel actually reads/writes against HBM.
  collectives  operand bytes per all-reduce / all-gather / reduce-scatter /
               all-to-all / collective-permute, × multiplier — per-device
               traffic (shapes in the partitioned module are per-device).

All counts are per-device; multiply by mesh size for global totals.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
          "pred": 1, "token": 0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# ops that move no data (views / metadata)
_FREE = {"bitcast", "get-tuple-element", "tuple", "parameter", "constant",
         "iota", "after-all", "partition-id", "replica-id"}
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],{}]+))\s+"
    r"([\w\-]+)\((.*)$")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s+->")
_TRIP_RE = re.compile(r'known_trip_count[^}]*"n"\s*:\s*"(\d+)"')


def type_bytes(type_str: str, width_cap: int = 0) -> int:
    """Bytes of an HLO type string; ``width_cap`` (if >0) caps the per-element
    width — used to count collectives at the model's compute dtype, since the
    CPU stand-in backend legalizes bf16 collectives/dots to f32 (a TPU build
    moves them at bf16)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        width = _BYTES[dt]
        if width_cap and width > width_cap:
            width = width_cap
        total += n * width
    return total


def _shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str  # everything after the opening '('

    def operands(self) -> List[str]:
        depth, out, cur = 0, [], ""
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    out.append(cur)
                    break
                depth -= 1
            cur += ch
        args = "".join(out)
        return re.findall(r"%([\w.\-]+)", args)

    def attr(self, key: str) -> Optional[str]:
        m = re.search(rf"{key}=%([\w.\-]+)", self.rest)
        return m.group(1) if m else None

    def trip_count(self) -> int:
        m = _TRIP_RE.search(self.rest)
        return int(m.group(1)) if m else 1


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]          # param name -> type string
    instrs: List[Instr]

    def symbol(self, name: str) -> Optional[str]:
        if name in self.params:
            return self.params[name]
        for ins in self.instrs:
            if ins.name == name:
                return ins.type_str
        return None


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        if cur is None:
            h = _HEADER_RE.match(line)
            if h and line.rstrip().endswith("{"):
                params = {}
                for pm in re.finditer(r"([\w.\-]+)\s*:\s*((?:\([^)]*\))|[\w\[\],{}]+)",
                                      h.group(3)):
                    params[pm.group(1)] = pm.group(2)
                cur = Computation(h.group(2), params, [])
                if h.group(1):
                    entry_name = h.group(2)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if im:
            cur.instrs.append(Instr(im.group(2), im.group(3), im.group(4),
                                    im.group(5)))
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_dims = _shape_dims(ins.type_str) or []
    out_elems = math.prod(out_dims) if out_dims else 1
    ops = ins.operands()
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    if m and ops:
        lhs_type = comp.symbol(ops[0])
        lhs_dims = _shape_dims(lhs_type) if lhs_type else None
        if lhs_dims:
            for idx in m.group(1).split(","):
                if idx:
                    contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def _conv_flops(comp: Computation, ins: Instr) -> float:
    """convolution: 2 · out_elems · (kernel spatial · in_channels)."""
    out_dims = _shape_dims(ins.type_str) or []
    out_elems = math.prod(out_dims) if out_dims else 1
    ops = ins.operands()
    if len(ops) < 2:
        return 0.0
    k_type = comp.symbol(ops[1])
    k_dims = _shape_dims(k_type) if k_type else None
    if not k_dims:
        return 0.0
    # kernel = spatial… x in_ch x out_ch (dnums vary; product/out_ch is robust)
    out_ch = k_dims[-1] if k_dims else 1
    return 2.0 * out_elems * (math.prod(k_dims) / max(out_ch, 1))


def _instr_bytes(comp: Computation, ins: Instr) -> float:
    """HloCostAnalysis-style bytes accessed for one materializing op.

    Slicing ops touch only the slice, not the whole operand (a dynamic-slice
    of one layer's weights inside a 64-iteration scan reads L× less than the
    stacked buffer); DUS updates in place.
    """
    op = ins.op
    out_b = type_bytes(ins.type_str)
    if op in ("dynamic-slice", "slice", "gather"):
        return 2.0 * out_b
    if op in ("dynamic-update-slice", "scatter"):
        ops_ = ins.operands()
        upd = type_bytes(comp.symbol(ops_[1]) or "") if len(ops_) > 1 else out_b
        return 2.0 * upd
    if op == "broadcast":
        ops_ = ins.operands()
        src = type_bytes(comp.symbol(ops_[0]) or "") if ops_ else 0
        return out_b + src
    return out_b + sum(type_bytes(comp.symbol(o) or "") for o in ins.operands())


def _fusion_bytes(comps: Dict[str, "Computation"], comp: Computation,
                  ins: Instr) -> float:
    """Boundary traffic of a fusion, modelling the TPU target:

    - an operand consumed *only through slice ops* counts as the sliced
      bytes, not the whole buffer (scan bodies slice stacked weights);
    - an operand that is the *target* of a dynamic-update-slice is updated
      in place: traffic = 2 × update bytes, and the fusion output (which
      aliases it) is not counted — this is how XLA buffer-assigns scan ys;
    - a pure dtype-conversion fusion costs nothing: those are the CPU
      stand-in backend's bf16→f32 legalization of loop carries, which does
      not exist on TPU where bf16 is compute-native.
    """
    callee = comps.get(ins.attr("calls") or "")
    operand_names = ins.operands()
    if callee is None:
        return float(type_bytes(ins.type_str)) + sum(
            type_bytes(comp.symbol(o) or "") for o in operand_names)
    ops_set = {u.op for u in callee.instrs}
    if ops_set <= {"convert", "parameter", "bitcast", "copy", "constant"}:
        return 0.0  # bf16 legalization artifact (see docstring)
    params = list(callee.params)
    by_name = {u.name: u for u in callee.instrs}

    def origin(name: str) -> str:
        """Walk back through dtype/layout no-ops to the originating value."""
        seen = set()
        while name in by_name and name not in seen:
            seen.add(name)
            u = by_name[name]
            if u.op in ("convert", "bitcast", "copy") and u.operands():
                name = u.operands()[0]
            else:
                break
        return name

    # uses of each param, looking through convert/bitcast/copy chains
    uses: Dict[str, List[Instr]] = {p: [] for p in params}
    for u in callee.instrs:
        if u.op in ("convert", "bitcast", "copy"):
            continue
        for o in u.operands():
            og = origin(o)
            if og in uses:
                uses[og].append(u)

    inplace: Dict[str, float] = {}
    aliased_output = False
    for u in callee.instrs:
        if u.op == "dynamic-update-slice":
            uops = u.operands()
            tgt = origin(uops[0]) if uops else ""
            if tgt in params:
                upd = (type_bytes(callee.symbol(uops[1]) or "")
                       if len(uops) > 1 else 0)
                inplace[tgt] = 2.0 * upd
                aliased_output = True
    total = 0.0 if aliased_output else float(type_bytes(ins.type_str))
    for i, oname in enumerate(operand_names):
        if i >= len(params):
            total += type_bytes(comp.symbol(oname) or "")
            continue
        pname = params[i]
        if pname in inplace:
            total += inplace[pname]
            continue
        puses = uses.get(pname, [])
        if puses and all(u.op in ("dynamic-slice", "slice") for u in puses):
            total += sum(type_bytes(u.type_str) for u in puses)
            continue
        total += type_bytes(comp.symbol(oname) or "")
    return total


def _is_carry_copy(comp: Computation, ins: Instr) -> bool:
    """A ``copy`` (inside a loop body) whose source resolves to a loop
    parameter: XLA-CPU copy-insertion double-buffering the carried state.
    The TPU buffer assigner aliases the carry in place (standard decode-loop
    behaviour), so these bytes are tracked separately, not as HBM traffic."""
    by_name = {u.name: u for u in comp.instrs}
    name = ins.operands()[0] if ins.operands() else ""
    seen = set()
    while name in by_name and name not in seen:
        seen.add(name)
        u = by_name[name]
        if u.op in ("convert", "bitcast", "copy", "get-tuple-element") and u.operands():
            name = u.operands()[0]
        elif u.op == "parameter":
            return True
        else:
            return False
    return name in comp.params


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    carry_copy_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    collective_count: int = 0
    unknown_trip_whiles: int = 0

    @property
    def collective_total(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(text: str, collective_width_cap: int = 0) -> Cost:
    comps = parse_module(text)
    cost = Cost()
    if "__entry__" not in comps:
        return cost
    # (computation, multiplier, fusion_context, loop_body)
    stack: List[Tuple[str, float, bool, bool]] = [
        (comps["__entry__"].name, 1.0, False, False)]
    seen_guard = 0
    while stack:
        cname, mult, in_fusion, in_loop = stack.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        seen_guard += 1
        if seen_guard > 100_000:  # malformed module safety valve
            break
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                trip = ins.trip_count()
                if trip == 1 and "known_trip_count" not in ins.rest:
                    cost.unknown_trip_whiles += 1
                body, cond = ins.attr("body"), ins.attr("condition")
                if body:
                    stack.append((body, mult * trip, in_fusion, True))
                if cond:
                    stack.append((cond, mult * trip, in_fusion, True))
                continue
            if op == "fusion":
                callee = ins.attr("calls")
                if callee:
                    stack.append((callee, mult, True, in_loop))
                if not in_fusion:
                    cost.bytes_accessed += mult * _fusion_bytes(comps, comp, ins)
                continue
            if op == "conditional" or op == "call":
                for key in ("true_computation", "false_computation",
                            "branch_computations", "to_apply"):
                    callee = ins.attr(key)
                    if callee:
                        stack.append((callee, mult, in_fusion, in_loop))
                continue
            if op == "dot":
                cost.flops += mult * _dot_flops(comp, ins)
            elif op == "convolution":
                cost.flops += mult * _conv_flops(comp, ins)
            if op in COLLECTIVES:
                cap = collective_width_cap
                if op in ("all-gather", "all-reduce", "collective-permute"):
                    # traffic each device receives == the result
                    b = type_bytes(ins.type_str, cap)
                else:  # reduce-scatter / all-to-all: what each device sends
                    b = sum(type_bytes(comp.symbol(o) or "", cap)
                            for o in ins.operands())
                    if b == 0:
                        b = type_bytes(ins.type_str, cap)
                cost.collective_bytes[op] += mult * b
                cost.collective_count += 1
            if not in_fusion and op not in _FREE:
                b = mult * _instr_bytes(comp, ins)
                if op == "copy" and in_loop and _is_carry_copy(comp, ins):
                    cost.carry_copy_bytes += b
                else:
                    cost.bytes_accessed += b
    return cost


def peak_aval_bytes(fn, *args, **kwargs) -> Tuple[int, str]:
    """Largest single intermediate array (bytes) anywhere in ``fn``'s jaxpr.

    Recurses through every sub-jaxpr an equation carries (pjit bodies,
    shard_map bodies, scan/while/cond branches, pallas grids), so values
    inside a ``shard_map`` are counted at their PER-DEVICE shapes — which is
    exactly what the distributed-factor bench needs to assert that no shard
    ever materializes the full (d, d) system: the gather-then-factor
    collective shows a (d, d) transient here, the tile-parallel path tops
    out at its (d/shards, d) row tile. A static upper bound on per-device
    live bytes, not a simulation of XLA's buffer assignment (rematerialization
    can only shrink it). Returns ``(bytes, shape_str)`` for the peak value.
    """
    import jax
    import numpy as np

    core = jax.core

    def aval_bytes(v):
        aval = getattr(v, "aval", None)
        if aval is None or not hasattr(aval, "shape"):
            return 0, ""
        n = int(np.prod(aval.shape)) if aval.shape else 1
        return n * np.dtype(aval.dtype).itemsize, str(aval)

    def is_jaxpr(x):
        return isinstance(x, (core.Jaxpr, core.ClosedJaxpr))

    def walk(jaxpr):
        if isinstance(jaxpr, core.ClosedJaxpr):
            jaxpr = jaxpr.jaxpr
        # equation outputs only: the caller's (sharded, resident) inputs are
        # not transients of the solve
        best = (0, "")
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                best = max(best, aval_bytes(v))
            for sub in jax.tree_util.tree_leaves(
                    eqn.params, is_leaf=is_jaxpr):
                if is_jaxpr(sub):
                    best = max(best, walk(sub))
        return best

    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return walk(closed)
