"""Input specs (ShapeDtypeStruct stand-ins) and concrete sample batches.

``input_specs(cfg, shape, kind)`` returns abstract inputs for .lower() —
weak-type-correct, shardable, no device allocation. ``sample_batch`` builds
the small concrete analogue for smoke tests / examples.

Modality stubs (the one sanctioned carve-out): VLM archs get pre-computed
patch embeddings (anyres tiling → cfg.prefix_tokens patches); audio enc-dec
archs get pre-computed frame embeddings for the encoder. Both are float
features of width d_model — the frontends themselves are out of scope.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import InputShape, ModelConfig


def _token_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text-token count so that prefix + tokens == seq_len total positions."""
    if cfg.prefix_tokens:
        return max(1, seq_len - cfg.prefix_tokens)
    return seq_len


def train_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.float32) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, _token_len(cfg, s)), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b,), jnp.int32),
    }
    if cfg.prefix_tokens:
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.prefix_tokens, cfg.d_model), dtype)
    if cfg.encoder_layers:
        specs["enc_feats"] = jax.ShapeDtypeStruct(
            (b, min(cfg.encoder_seq, s), cfg.d_model), dtype)
    return specs


def prefill_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.float32):
    specs = train_specs(cfg, shape, dtype)
    del specs["labels"]
    return specs


def decode_specs(cfg: ModelConfig, shape: InputShape):
    b = shape.global_batch
    return {
        "token": jax.ShapeDtypeStruct((b,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.float32):
    if shape.kind == "train":
        return train_specs(cfg, shape, dtype)
    if shape.kind == "prefill":
        return prefill_specs(cfg, shape, dtype)
    return decode_specs(cfg, shape)


def sample_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                 with_labels: bool = True) -> Dict[str, Any]:
    """Concrete random batch matching train_specs (small sizes, CPU)."""
    rng = np.random.default_rng(seed)
    out: Dict[str, Any] = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, _token_len(cfg, seq))), jnp.int32)
    }
    if with_labels:
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.num_classes, (batch,)), jnp.int32)
    if cfg.prefix_tokens:
        out["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.prefix_tokens, cfg.d_model)) * 0.1,
            jnp.float32)
    if cfg.encoder_layers:
        out["enc_feats"] = jnp.asarray(
            rng.standard_normal((batch, min(cfg.encoder_seq, seq), cfg.d_model)) * 0.1,
            jnp.float32)
    return out
