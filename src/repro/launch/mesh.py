"""Production mesh construction + TPU v5e hardware model.

Functions only (no module-level jax device access) so importing this module
never initializes the backend — ``dryrun.py`` must set XLA_FLAGS before the
first jax call, and smoke tests must keep seeing the real single CPU device.

Mesh layout (target: TPU v5e pods, 256 chips each):
  single-pod : (16, 16)    axes ('data', 'model')
  multi-pod  : (2, 16, 16) axes ('pod', 'data', 'model')

The 'pod'+'data' axes together form the *federation* axes for AFL: each shard
group along them plays a client cohort; the single aggregation round is one
all-reduce over them. 'model' carries tensor parallelism for the backbone.
"""

from __future__ import annotations

import dataclasses

import jax

# ----------------------------------------------------------------- hardware
# TPU v5e (target; this container lowers on CPU stand-in devices).
PEAK_FLOPS_BF16 = 197e12      # per chip, FLOP/s
HBM_BW = 819e9                # per chip, B/s
ICI_BW = 50e9                 # per link, B/s (~ per-chip collective bandwidth)
HBM_BYTES = 16 * 2**30        # 16 GiB per chip

SINGLE_POD_SHAPE = (16, 16)
SINGLE_POD_AXES = ("data", "model")
MULTI_POD_SHAPE = (2, 16, 16)
MULTI_POD_AXES = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate mesh over whatever devices exist (CPU smoke/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def batch_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Axes that shard the batch / act as AFL federation axes."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def model_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("model",) if a in mesh.shape)


def num_chips(mesh: jax.sharding.Mesh) -> int:
    return mesh.devices.size


@dataclasses.dataclass(frozen=True)
class Roofline:
    """Three-term roofline for one compiled step on this mesh."""

    flops: float
    hbm_bytes: float
    collective_bytes: float
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }
