"""Serving launcher: batched prefill + decode against any assigned arch.

Drives the inference path the decode input-shapes exercise: prefill a batch
of prompts, then autoregressively decode with the per-family cache (KV for
dense/moe, SSM/conv state for mamba, recurrent state for xLSTM, cross-attn
memory for enc-dec). Greedy sampling — the request semantics, batching and
cache plumbing are the point, not the sampler.

Usage (CPU example — reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3_12b \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.launch import mesh as M
from repro.launch import steps as ST
from repro.launch.inputs import sample_batch
from repro.models import transformer as T


def serve(cfg, batch: int, prompt_len: int, gen: int, seed: int = 0):
    """Returns (tokens (B, prompt+gen), prefill_s, decode_s)."""
    max_seq = prompt_len + gen
    params = T.init_params(jax.random.key(seed), cfg)
    prefill = jax.jit(ST.make_prefill_step(cfg, max_seq))
    decode = jax.jit(ST.make_serve_step(cfg))

    b = sample_batch(cfg, batch, prompt_len, seed=seed, with_labels=False)
    t0 = time.perf_counter()
    logits, cache = prefill(params, b)
    logits.block_until_ready()
    t1 = time.perf_counter()

    toks = [jnp.argmax(logits, -1).astype(jnp.int32)]
    for i in range(gen - 1):
        pos = prompt_len + i
        logits, cache = decode(params, cache, toks[-1], jnp.asarray(pos, jnp.int32))
        toks.append(jnp.argmax(logits, -1).astype(jnp.int32))
    jax.block_until_ready(toks[-1])
    t2 = time.perf_counter()

    out = np.concatenate(
        [np.asarray(b["tokens"]), np.stack([np.asarray(t) for t in toks], 1)], 1)
    return out, t1 - t0, t2 - t1


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    out, prefill_s, decode_s = serve(cfg, args.batch, args.prompt_len, args.gen)
    n_new = args.batch * args.gen
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {prefill_s*1e3:.1f}ms   decode: {decode_s*1e3:.1f}ms "
          f"({n_new/decode_s:.1f} tok/s)")
    print("first sequence tail:", out[0, -min(8, out.shape[1]):].tolist())


if __name__ == "__main__":
    main()
