"""Serving launcher: LLM decode OR a live AFL federation endpoint.

Two serving workloads share this entrypoint:

* **LLM decode** (default): batched prefill + autoregressive decode against
  any assigned arch with the per-family cache (KV for dense/moe, SSM/conv
  state for mamba, recurrent state for xLSTM, cross-attn memory for
  enc-dec). Greedy sampling — the request semantics, batching and cache
  plumbing are the point, not the sampler.

* **Federation serving** (``--federation``): bring up a
  :class:`~repro.fl.service.FederationService` over loopback HTTP — any
  coordinator kind behind it — and serve submit/solve/weights/state/
  personalized_solve until interrupted. Remote clients point
  :class:`~repro.fl.service.RemoteCoordinator` (or ``launch/train.py
  --server-url``) at the printed URL.

Usage (CPU examples — reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3_12b \
      --batch 4 --prompt-len 32 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --federation --dim 256 \
      --classes 50 --gamma 1.0 --port 8790 --coordinator async
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.launch import mesh as M
from repro.launch import steps as ST
from repro.launch.inputs import sample_batch
from repro.models import transformer as T


def serve(cfg, batch: int, prompt_len: int, gen: int, seed: int = 0):
    """Returns (tokens (B, prompt+gen), prefill_s, decode_s)."""
    max_seq = prompt_len + gen
    params = T.init_params(jax.random.key(seed), cfg)
    prefill = jax.jit(ST.make_prefill_step(cfg, max_seq))
    decode = jax.jit(ST.make_serve_step(cfg))

    b = sample_batch(cfg, batch, prompt_len, seed=seed, with_labels=False)
    t0 = time.perf_counter()
    logits, cache = prefill(params, b)
    logits.block_until_ready()
    t1 = time.perf_counter()

    toks = [jnp.argmax(logits, -1).astype(jnp.int32)]
    for i in range(gen - 1):
        pos = prompt_len + i
        logits, cache = decode(params, cache, toks[-1], jnp.asarray(pos, jnp.int32))
        toks.append(jnp.argmax(logits, -1).astype(jnp.int32))
    jax.block_until_ready(toks[-1])
    t2 = time.perf_counter()

    out = np.concatenate(
        [np.asarray(b["tokens"]), np.stack([np.asarray(t) for t in toks], 1)], 1)
    return out, t1 - t0, t2 - t1


def _make_endpoint(service, args):
    """Start the configured transport server: ``--mux`` → the multiplexed
    mux protocol, default → HTTP; either one TLS-wrapped when
    ``--tls-cert/--tls-key`` are given (plus required client certificates
    with ``--tls-client-ca``)."""
    from repro.fl.service import serve_http

    ssl_ctx = None
    if args.tls_cert:
        from repro.fl.mux import server_ssl_context

        if not args.tls_key:
            raise SystemExit("--tls-cert requires --tls-key")
        ssl_ctx = server_ssl_context(args.tls_cert, args.tls_key,
                                     client_ca=args.tls_client_ca)
    if args.mux:
        from repro.fl.mux import serve_mux

        return serve_mux(service, args.host, args.port, ssl_context=ssl_ctx)
    return serve_http(service, args.host, args.port, ssl_context=ssl_ctx)


def serve_federation(args) -> None:
    """Host a FederationService over HTTP or mux until interrupted."""
    from repro.fl import AFLServer, AsyncAFLServer, ShardedCoordinator
    from repro.fl.service import FederationService

    shard_kw = dict(num_shards=args.shards, tiled_gram=args.tiled)
    cls_kw = {
        "sync": (AFLServer, {}),
        "async": (AsyncAFLServer, {"max_pending": args.max_pending}),
        "sharded": (ShardedCoordinator, shard_kw),
    }[args.coordinator]
    kinds = {
        "sync": lambda: AFLServer(args.dim, args.classes, gamma=args.gamma),
        "async": lambda: AsyncAFLServer(args.dim, args.classes,
                                        gamma=args.gamma,
                                        max_pending=args.max_pending),
        "sharded": lambda: ShardedCoordinator(args.dim, args.classes,
                                              gamma=args.gamma, **shard_kw),
    }

    if args.standby_of or args.replica:
        serve_role(args, cls_kw)
        return

    if args.restore_from:
        import repro.checkpoint as ckpt

        coordinator = ckpt.load_server(args.restore_from, cls_kw[0],
                                       **cls_kw[1])
        print(f"restored {args.coordinator} coordinator from "
              f"{args.restore_from} ({coordinator.num_clients} clients)")
    else:
        coordinator = kinds[args.coordinator]()
    service = FederationService(coordinator, max_pending=args.max_pending,
                                ledger_dir=args.ledger_dir,
                                auth_token=args.auth_token)
    with service, _make_endpoint(service, args) as srv:
        print(f"federation up: {srv.url}  "
              f"(coordinator={args.coordinator} d={args.dim} "
              f"C={args.classes} γ={args.gamma:g})")
        if args.tls_cert:
            print(f"  TLS: {args.tls_cert}"
                  + (f" (client certs required: {args.tls_client_ca})"
                     if args.tls_client_ca else ""))
        if args.auth_token:
            print("  auth: bearer token required on every request")
        if args.ledger_dir:
            print(f"  ledger: {args.ledger_dir} "
                  "(every accepted submit, CRC-framed)")
        if args.mux:
            print(f"  point RemoteCoordinator at {srv.url} "
                  "(many clients per connection — interleaved streams)")
        else:
            print(f"  submit:  POST {srv.url}/v1/default/submit  "
                  "(ClientReport.to_bytes payload)")
            print(f"  weights: GET  {srv.url}/v1/default/weights")
        daemon = None
        if args.snapshot_dir:
            from repro.checkpoint import SnapshotDaemon

            # in-proc pull (the service object, not the URL): no TLS /
            # token round-trips, and the live ledger object rides along so
            # successful ticks compact what each snapshot now covers
            daemon = SnapshotDaemon(
                service, directory=args.snapshot_dir,
                interval=args.snapshot_every, keep=args.snapshot_keep,
                ledger=service.ledger() if args.ledger_dir else None,
                auth_token=args.auth_token)
            daemon.start()
            print(f"  snapshots: {args.snapshot_dir} "
                  f"every {args.snapshot_every:g}s "
                  f"(keep {args.snapshot_keep}"
                  + (", ledger compacted per tick)" if args.ledger_dir
                     else ")"))
        print("ctrl-c to stop")
        try:
            import threading

            threading.Event().wait()
        except KeyboardInterrupt:
            print("shutting down")
        finally:
            if daemon is not None:
                daemon.stop()


def serve_role(args, cls_kw) -> None:
    """Host a warm standby (``--standby-of URL``) or a read-only weights
    replica (``--replica``), both following ``--ledger-dir``."""
    from repro.fl import WarmStandby, WeightsReplica, watch_primary
    from repro.fl.service import FederationService

    if not args.ledger_dir:
        raise SystemExit("--standby-of/--replica require --ledger-dir "
                         "(the primary's ledger, on shared storage)")
    cls, kw = cls_kw
    # Bootstrap kwargs: with no snapshot yet, the follower starts an EMPTY
    # coordinator of the configured shape and replays the whole ledger.
    boot_kw = dict(dim=args.dim, num_classes=args.classes,
                   gamma=args.gamma, **kw)
    if args.replica:
        replica = WeightsReplica(args.ledger_dir,
                                 snapshot_dir=args.snapshot_dir,
                                 cls=cls, ctor_kw=boot_kw, from_state_kw=kw)
        service = FederationService(replica, auth_token=args.auth_token)
        with service, _make_endpoint(service, args) as srv:
            print(f"weights replica up: {srv.url} "
                  f"(position={replica.position}, reads only — "
                  "writes get HTTP 403 read_only)")
            print("ctrl-c to stop")
            try:
                import threading

                threading.Event().wait()
            except KeyboardInterrupt:
                print("shutting down")
        return

    standby = WarmStandby(args.ledger_dir, snapshot_dir=args.snapshot_dir,
                          cls=cls, ctor_kw=boot_kw, from_state_kw=kw)
    service = FederationService()
    service.host_standby("default", standby, auth_token=args.auth_token)
    with service, _make_endpoint(service, args) as srv:
        print(f"warm standby up: {srv.url} "
              f"(tailing {args.ledger_dir}, watching {args.standby_of}; "
              "503 until promoted)")

        def _alive() -> bool:
            from repro.fl.mux import probe_alive

            return probe_alive(args.standby_of, cafile=args.watch_cafile,
                               auth_token=args.auth_token)

        watch_primary(standby, _alive, grace=args.grace,
                      interval=args.watch_every,
                      on_promote=lambda c: service.promote_federation())
        print(f"PROMOTED: primary missed {args.grace} liveness checks — "
              f"now serving writes at {srv.url} "
              f"({standby.coordinator.num_clients} clients, zero loss)")
        try:
            import threading

            threading.Event().wait()
        except KeyboardInterrupt:
            print("shutting down")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None,
                    help="LLM serving arch (required unless --federation)")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    fed = ap.add_argument_group("federation serving")
    fed.add_argument("--federation", action="store_true",
                     help="serve an AFL FederationService over HTTP instead "
                          "of LLM decode")
    fed.add_argument("--dim", type=int, default=256)
    fed.add_argument("--classes", type=int, default=50)
    fed.add_argument("--gamma", type=float, default=1.0)
    fed.add_argument("--coordinator", default="sync",
                     choices=["sync", "async", "sharded"])
    fed.add_argument("--host", default="127.0.0.1")
    fed.add_argument("--port", type=int, default=8790)
    fed.add_argument("--mux", action="store_true",
                     help="serve the multiplexed mux protocol instead of "
                          "HTTP (many interleaved streams per connection)")
    fed.add_argument("--tls-cert", default=None,
                     help="TLS server certificate PEM (enables TLS; see "
                          "repro.fl.mux.generate_self_signed_cert)")
    fed.add_argument("--tls-key", default=None,
                     help="TLS server private key PEM (with --tls-cert)")
    fed.add_argument("--tls-client-ca", default=None,
                     help="require client certificates signed by this CA "
                          "PEM (mutual TLS)")
    fed.add_argument("--auth-token", default=None,
                     help="bearer token every request must carry "
                          "(typed 401 unauthorized otherwise)")
    fed.add_argument("--max-pending", type=int, default=None,
                     help="ingest high-watermark (HTTP 429 past it)")
    fed.add_argument("--shards", type=int, default=None,
                     help="sharded coordinator: shard count (default: one "
                          "per device); grow/shrink at runtime via the "
                          "grow/shrink routes")
    fed.add_argument("--tiled", action="store_true",
                     help="sharded coordinator: row-tiled global Gram "
                          "(one tile per device)")
    fed.add_argument("--restore-from", default=None,
                     help="cold-start the coordinator from this checkpoint "
                          "directory (e.g. a snapshotd snap-*)")
    fed.add_argument("--snapshot-dir", default=None,
                     help="run an in-process snapshot daemon writing here")
    fed.add_argument("--snapshot-every", type=float, default=30.0,
                     help="snapshot interval seconds (with --snapshot-dir)")
    fed.add_argument("--snapshot-keep", type=int, default=5,
                     help="snapshots retained (with --snapshot-dir)")
    rep = ap.add_argument_group("replication (ledger / standby / replica)")
    rep.add_argument("--ledger-dir", default=None,
                     help="durable submit ledger directory: every accepted "
                          "submit is appended + fsynced before the ack")
    rep.add_argument("--standby-of", default=None, metavar="URL",
                     help="run as a warm standby of the primary at URL: "
                          "tail --ledger-dir, serve 503s, promote after "
                          "--grace failed liveness probes")
    rep.add_argument("--replica", action="store_true",
                     help="run as a read-only weights replica following "
                          "--ledger-dir (writes answer HTTP 403 read_only)")
    rep.add_argument("--grace", type=int, default=3,
                     help="standby: failed probes before promotion")
    rep.add_argument("--watch-every", type=float, default=1.0,
                     help="standby: seconds between liveness probes")
    rep.add_argument("--watch-cafile", default=None,
                     help="standby: CA PEM for probing a TLS primary "
                          "(muxs:// or https:// --standby-of URL)")
    args = ap.parse_args()

    if args.federation:
        serve_federation(args)
        return
    if args.arch is None:
        ap.error("--arch is required for LLM serving "
                 "(or pass --federation)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    out, prefill_s, decode_s = serve(cfg, args.batch, args.prompt_len, args.gen)
    n_new = args.batch * args.gen
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {prefill_s*1e3:.1f}ms   decode: {decode_s*1e3:.1f}ms "
          f"({n_new/decode_s:.1f} tok/s)")
    print("first sequence tail:", out[0, -min(8, out.shape[1]):].tolist())


if __name__ == "__main__":
    main()
