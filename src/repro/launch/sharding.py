"""Logical→mesh sharding rules (MaxText-style FSDP + tensor parallelism).

Parameter rules are keyed by leaf *name* and describe the trailing dims of the
leaf; any extra leading dims (layer stacks, MoE groups) are replicated (None).
Every assignment is divisibility-guarded: an axis that does not divide the dim
is dropped rather than producing an invalid sharding, so the same rules serve
all ten architectures (36-head minicpm and 8-expert grok included).

Logical axes:
  fsdp  = ('pod', 'data')  — weight d_model dim, batch dim
  tp    = ('model',)       — heads / ff / vocab dim
"""

from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import InputShape, ModelConfig
from repro.core.streaming import AnalyticState
from repro.launch.mesh import batch_axes, model_axes

# name → spec template for the *trailing* dims ("fsdp" / "tp" / None).
# 2-entry templates apply to matrices, 1-entry to vectors.
_COL = ("fsdp", "tp")      # d_model → features   (column parallel)
_ROW = ("tp", "fsdp")      # features → d_model   (row parallel)
PARAM_RULES: dict[str, tuple] = {
    # attention / mlp (layers.py)
    "wq": _COL, "wk": _COL, "wv": _COL, "w_up": _COL, "w_gate": _COL,
    "wo": _ROW, "w_down": _ROW,
    # embeddings / heads (transformer.py)
    "embed": ("tp", "fsdp"),           # vocab over tp, d_model over fsdp
    "lm_head": _COL,                   # (d_model, vocab)
    "mm_proj": _COL, "enc_proj": _COL,
    # MoE (moe.py) — (E, d_in, d_out) leaves: E replicated (left-pad), the
    # matrices tensor-parallel. router (d_model, E): E is tiny → fsdp only.
    "router": ("fsdp", None),
    # Mamba2 (ssm.py)
    "in_proj": _COL, "out_proj": _ROW,
    "conv_w": (None, None),            # (d_conv, conv_dim) — small, replicate
    # xLSTM (xlstm.py)
    "up": _COL, "qkv": _COL, "if_proj": _COL, "wx": _COL, "down": _ROW,
    "r": (None, None, None, None),     # per-head recurrent kernels, replicate
}


def _axes_for(label, mesh: Mesh):
    if label == "fsdp":
        return batch_axes(mesh)
    if label == "tp":
        return model_axes(mesh)
    return ()


def _guard(dim: int, axes: Sequence[str], mesh: Mesh) -> Optional[tuple]:
    """Return the axis tuple if it divides ``dim``, else None (replicate)."""
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if total > 1 and dim % total == 0:
        return tuple(axes)
    return None


def _leaf_spec(name: str, shape: tuple, mesh: Mesh) -> P:
    rule = PARAM_RULES.get(name)
    if rule is None or len(shape) < len(rule):
        return P()
    pad = len(shape) - len(rule)
    entries: list = [None] * pad
    for dim, label in zip(shape[pad:], rule):
        axes = _axes_for(label, mesh)
        entries.append(_guard(dim, axes, mesh))
    return P(*entries)


def param_specs(params_shape: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree matching a param (ShapeDtypeStruct) tree."""

    def spec(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = entry.key
                break
        return _leaf_spec(name or "", leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def param_shardings(params_shape: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params_shape, mesh))


# ------------------------------------------------------------------- batches
def batch_specs(cfg: ModelConfig, specs: dict, mesh: Mesh) -> dict:
    """Shard every batch input along its leading (batch) dim."""
    baxes = batch_axes(mesh)

    def one(leaf):
        if leaf.ndim == 0:
            return P()
        ax = _guard(leaf.shape[0], baxes, mesh)
        return P(ax, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(one, specs)


def batch_shardings(cfg: ModelConfig, specs: dict, mesh: Mesh) -> dict:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), batch_specs(cfg, specs, mesh))


# ----------------------------------------------------------- analytic state
def state_specs(mesh: Mesh) -> AnalyticState:
    """AFL sufficient statistics are replicated: the batch-sharded Gram
    contraction reduces over the federation axes, and GSPMD realises that
    reduction as the paper's one aggregation all-reduce."""
    return AnalyticState(gram=P(), moment=P(), count=P())


def state_shardings(mesh: Mesh) -> AnalyticState:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs(mesh))


# -------------------------------------------------------------------- caches
def cache_specs(cfg: ModelConfig, cache_shape: Any, shape: InputShape,
                mesh: Mesh) -> Any:
    """Decode-cache sharding.

    Per leaf: the dim equal to the global batch shards over the federation
    axes; the *last* dim (head_dim for KV caches) shards over 'model' — the
    per-token dynamic-update-slice then stays shard-local, whereas sharding
    the sequence dim makes GSPMD rewrite the whole cache behind a masked
    select every step (§Perf decode iteration 2, refuted layout). Only when
    the batch cannot use the federation axes (long_500k B=1) does the
    sequence dim shard — over those unused axes — so a 500k-token cache still
    spreads across the pod.
    """
    baxes = batch_axes(mesh)
    maxes = model_axes(mesh)
    b = shape.global_batch

    def one(leaf):
        nd = leaf.ndim
        entries: list = [None] * nd
        used_batch = False
        for i, d in enumerate(leaf.shape):
            if d == b and _guard(d, baxes, mesh):
                entries[i] = _guard(d, baxes, mesh)
                used_batch = True
                break
        # head/feature dim: the last dim, over 'model'
        if nd >= 2 and entries[-1] is None:
            entries[-1] = _guard(leaf.shape[-1], maxes, mesh)
        # sequence dim: only the federation axes the batch left unused
        if not used_batch:
            cand = [
                (d, i) for i, d in enumerate(leaf.shape)
                if entries[i] is None and d >= 1024
            ]
            if cand:
                d, i = max(cand)
                entries[i] = _guard(d, baxes, mesh)
        return P(*entries)

    return jax.tree.map(one, cache_shape)


def cache_shardings(cfg: ModelConfig, cache_shape: Any, shape: InputShape,
                    mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        cache_specs(cfg, cache_shape, shape, mesh))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
