"""Step functions: analytic train (paper), gradient baseline, prefill, decode.

These are the units the launchers jit/lower. The *analytic* train step is the
paper's local stage: a frozen-backbone forward + streaming Gram update —
gradient-free (AFL's point). The gradient step exists for the FedAvg/FedProx
baselines the paper compares against (head-only SGD, backbone frozen, paper
Supp. E) and optionally full-backbone training for the generic train driver.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.streaming import AnalyticState, update_state
from repro.models import transformer as T


def make_analytic_train_step(cfg: ModelConfig, *, use_kernel: bool = False) -> Callable:
    """(params, AnalyticState, batch) → AnalyticState.

    batch: tokens (B, S) int32, labels (B,) int32 in [0, num_classes);
    plus prefix_embeds / enc_feats for VLM / audio archs.
    """

    def step(params, state: AnalyticState, batch) -> AnalyticState:
        hidden = T.forward(params, cfg, batch)
        emb = T.pool(hidden)                                    # (B, D)
        y = jax.nn.one_hot(batch["labels"], cfg.num_classes, dtype=jnp.float32)
        return update_state(state, emb, y, use_kernel=use_kernel)

    return step


def head_loss(head: jax.Array, emb: jax.Array, labels: jax.Array) -> jax.Array:
    logits = emb.astype(jnp.float32) @ head
    logp = jax.nn.log_softmax(logits, -1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def head_sgd_step(head: jax.Array, emb: jax.Array, labels: jax.Array,
                  lr: float = 0.05) -> jax.Array:
    """One SGD step on the linear head over precomputed embeddings."""
    grad = jax.grad(head_loss)(head, emb, labels)
    return head - lr * grad


def make_fedavg_train_step(cfg: ModelConfig, lr: float = 0.05) -> Callable:
    """Gradient-FL baseline local step: SGD on the classification head with a
    frozen backbone (paper Supp. E: batch 64, SGD lr 0.05).

    (params, head (D,C), batch) → (head', loss)
    """

    def step(params, head, batch):
        hidden = T.forward(params, cfg, batch)
        emb = T.pool(hidden)
        loss, grad = jax.value_and_grad(head_loss)(head, emb, batch["labels"])
        return head - lr * grad, loss

    return step


def make_full_train_step(cfg: ModelConfig, lr: float = 1e-3) -> Callable:
    """Generic end-to-end LM training step (next-token CE over the backbone) —
    the non-FL training driver (examples/train_100m.py). SGD w/ provided lr
    (schedules composed by the caller via repro.optim)."""

    def loss_fn(params, batch):
        hidden = T.forward(params, cfg, batch)
        logits = T.lm_logits(params, cfg, hidden)
        tokens = batch["tokens"]
        if cfg.prefix_tokens:
            logits = logits[:, cfg.prefix_tokens :]
        tgt = tokens[:, 1:]
        logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)
        return jnp.mean(nll)

    def step(params, batch, lr_t=lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params = jax.tree.map(lambda p, g: p - lr_t * g.astype(p.dtype), params, grads)
        return params, loss

    return step


def make_prefill_step(cfg: ModelConfig, max_seq: int) -> Callable:
    """(params, batch) → (last-token vocab logits (B, V), cache)."""

    def step(params, batch):
        hidden, cache = T.prefill(params, cfg, batch, max_seq)
        logits = T.lm_logits(params, cfg, hidden[:, -1:])
        return logits[:, 0], cache

    return step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """One decode step: (params, cache, token (B,), pos) → (logits (B,V), cache)."""

    def step(params, cache, token, pos):
        hidden, cache = T.decode_step(params, cfg, token, cache, pos)
        logits = T.lm_logits(params, cfg, hidden)
        return logits[:, 0], cache

    return step
