"""Training launcher: AFL analytic training of a backbone+head, end to end.

Runs the paper's pipeline on real devices (the host mesh on CPU; the
production mesh on TPU): frozen-backbone forward → streaming Gram statistics
per federation shard → ONE ``federated_solve`` collective → linear head.
Optionally runs the gradient-FL baseline (head SGD + periodic averaging) on
the same data for comparison, and a full-backbone LM pre-training mode
(``--mode lm``) for the generic train driver.

Usage (CPU example — reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_32b --reduced \
      --samples 2048 --seq 64 --classes 16 --batch 64
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.configs.registry import get_config
from repro.core import act
from repro.data import synthetic as D
from repro.fl.api import AFLClient, AFLServer, ShardedCoordinator
from repro.launch import mesh as M
from repro.launch import sharding as SH
from repro.launch import steps as ST
from repro.launch.inputs import sample_batch
from repro.models import transformer as T
from repro.optim import wsd_schedule


def _batches(ds: D.Dataset, batch: int):
    n = (len(ds) // batch) * batch
    for i in range(0, n, batch):
        yield ds.x[i:i + batch], ds.y[i:i + batch]


def _embed_fn(params, cfg, mesh):
    """jitted frozen-backbone embedding: tokens (B,S) → (B,D) f32."""

    def fwd(params, tokens):
        with act.activation_policy(mesh, M.batch_axes(mesh), M.model_axes(mesh)):
            hidden = T.forward(params, cfg, {"tokens": tokens})
            return T.pool(hidden).astype(jnp.float32)

    return jax.jit(fwd)


def run_analytic(cfg, mesh, train_ds, test_ds, fl: FLConfig, batch: int,
                 use_kernel: bool = False, server_url: str = ""):
    """AFL on-device: one epoch of forwards, one aggregation collective.

    Drives the canonical API end to end: an :class:`~repro.fl.api.AFLClient`
    (jax-backend engine; ``use_kernel=True`` folds batches with the Pallas
    Gram kernel) accumulates the local stage, its
    :class:`~repro.fl.api.ClientReport` is submitted to a coordinator —
    a :class:`~repro.fl.service.RemoteCoordinator` when ``server_url``
    points at a live :class:`~repro.fl.service.FederationService` (e.g.
    ``launch/serve.py --federation``), else
    :class:`~repro.fl.api.ShardedCoordinator` when the mesh has >1
    federation shard (one psum collective), plain
    :class:`~repro.fl.api.AFLServer` otherwise.
    """
    params = T.init_params(jax.random.key(0), cfg)
    embed = _embed_fn(params, cfg, mesh)
    client = AFLClient(0, gamma=fl.gamma, backend="jax",
                       use_kernel=use_kernel)
    t0 = time.perf_counter()
    for toks, labels in _batches(train_ds, batch):
        emb = embed(params, jnp.asarray(toks))
        y = jax.nn.one_hot(jnp.asarray(labels), cfg.num_classes)
        client.update(emb, y)
    # single-round aggregation: with >1 devices the sharded coordinator runs
    # the one all-reduce; on one device it degenerates to the plain solve.
    naxes = M.batch_axes(mesh)
    n_shards = 1
    for a in naxes:
        n_shards *= mesh.shape[a]
    if server_url:
        from repro.fl.service import RemoteCoordinator

        coord = RemoteCoordinator(server_url)
        if coord.dim != cfg.d_model:
            raise ValueError(f"remote federation dim={coord.dim} != model "
                             f"d_model={cfg.d_model}")
    elif n_shards > 1:
        coord = ShardedCoordinator(cfg.d_model, cfg.num_classes,
                                   gamma=fl.gamma, mesh=mesh,
                                   axis_names=naxes)
    else:
        coord = AFLServer(cfg.d_model, cfg.num_classes, gamma=fl.gamma)
    coord.submit(client.report())
    w = coord.solve(target_gamma=0.0)
    train_s = time.perf_counter() - t0
    # evaluate
    correct = total = 0
    for toks, labels in _batches(test_ds, batch):
        emb = embed(params, jnp.asarray(toks))
        pred = np.argmax(np.asarray(emb) @ np.asarray(w), -1)
        correct += int((pred == labels).sum())
        total += len(labels)
    return float(correct / max(total, 1)), train_s


def run_gradient(cfg, mesh, train_ds, test_ds, fl: FLConfig, batch: int,
                 rounds: int, lr: float = 0.05):
    """Head-only gradient FL baseline on the same frozen features."""
    params = T.init_params(jax.random.key(0), cfg)
    embed = _embed_fn(params, cfg, mesh)
    step = jax.jit(
        lambda h, e, l: ST.head_sgd_step(h, e, l, lr))
    head = jnp.zeros((cfg.d_model, cfg.num_classes), jnp.float32)
    t0 = time.perf_counter()
    for _ in range(rounds):
        for toks, labels in _batches(train_ds, batch):
            emb = embed(params, jnp.asarray(toks))
            head = step(head, emb, jnp.asarray(labels))
    train_s = time.perf_counter() - t0
    correct = total = 0
    for toks, labels in _batches(test_ds, batch):
        emb = embed(params, jnp.asarray(toks))
        pred = np.argmax(np.asarray(emb) @ np.asarray(head), -1)
        correct += int((pred == labels).sum())
        total += len(labels)
    return float(correct / max(total, 1)), train_s


def run_lm(cfg, mesh, steps: int, batch: int, seq: int, base_lr: float = 3e-3):
    """Generic LM pre-training driver (WSD schedule, minicpm-style)."""
    params = T.init_params(jax.random.key(0), cfg)
    train_step = jax.jit(ST.make_full_train_step(cfg))
    sched = wsd_schedule(base_lr, warmup=max(steps // 10, 1), total=steps)
    losses = []
    for i in range(steps):
        b = sample_batch(cfg, batch, seq, seed=i)
        params, loss = train_step(params, b, sched(i))
        losses.append(float(loss))
    return losses


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mode", default="analytic",
                    choices=["analytic", "gradient", "lm"])
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--samples", type=int, default=2048)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--classes", type=int, default=16)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=5, help="gradient-FL rounds")
    ap.add_argument("--steps", type=int, default=50, help="lm steps")
    ap.add_argument("--gamma", type=float, default=1.0)
    ap.add_argument("--kernel", action="store_true",
                    help="fold Gram batches with the Pallas kernel")
    ap.add_argument("--server-url", default="",
                    help="submit to a FederationService at this URL instead "
                         "of aggregating in-process (see launch/serve.py "
                         "--federation)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(num_classes=args.classes)
    mesh = M.make_host_mesh()
    print(f"arch={cfg.name} reduced={args.reduced} mesh={dict(mesh.shape)}")

    if args.mode == "lm":
        losses = run_lm(cfg, mesh, args.steps, args.batch, args.seq)
        print(f"lm: step0 loss={losses[0]:.4f} → step{len(losses)-1} "
              f"loss={losses[-1]:.4f}")
        return

    ds = D.token_classification(
        n=args.samples, seq=args.seq, vocab=cfg.vocab_size,
        num_classes=args.classes, seed=0)
    train_ds, test_ds = D.train_test_split(ds, 0.25, seed=0)
    fl = FLConfig(gamma=args.gamma)
    if args.mode == "analytic":
        acc, dt = run_analytic(cfg, mesh, train_ds, test_ds, fl, args.batch,
                               use_kernel=args.kernel,
                               server_url=args.server_url)
        where = f" via {args.server_url}" if args.server_url else ""
        print(f"AFL analytic: acc={acc:.4f} train_time={dt:.2f}s (one epoch, "
              f"single aggregation{where})")
    else:
        acc, dt = run_gradient(cfg, mesh, train_ds, test_ds, fl, args.batch,
                               args.rounds)
        print(f"gradient FL baseline: acc={acc:.4f} train_time={dt:.2f}s "
              f"({args.rounds} rounds)")


if __name__ == "__main__":
    main()
