"""Shared neural building blocks (pure-functional, params = pytrees).

Conventions:
  * params are nested dicts of jnp arrays; init_* builds them, apply fns use them.
  * activations (B, S, D); attention heads (B, H, S, hd).
  * per-layer *dynamic* metadata (window size, rope theta) is passed as traced
    scalars so heterogeneous stacks (gemma3 local/global) scan with a uniform
    body — `window <= 0` means "no window" and is encoded as a huge window.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import act

BIG_WINDOW = 1 << 30


# ---------------------------------------------------------------- init utils
def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def init_norm(d, dtype, with_bias=False):
    p = {"scale": jnp.ones((d,), dtype)}
    if with_bias:
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p, x, eps, kind="rms"):
    xf = x.astype(jnp.float32)
    if kind == "layer":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------------- rope
def apply_rope(x, positions, theta):
    """x: (B, H, S, D); positions: (B, S) or (S,); theta: python or traced scalar."""
    d = x.shape[-1]
    half = d // 2
    freq_exp = jnp.arange(half, dtype=jnp.float32) / half
    inv_freq = jnp.asarray(theta, jnp.float32) ** -freq_exp          # (half,)
    pos = jnp.asarray(positions, jnp.float32)
    if pos.ndim == 1:
        pos = pos[None]
    angles = pos[:, None, :, None] * inv_freq[None, None, None, :]  # (B,1,S,half)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ attention
@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False


def init_attention(key, dims: AttnDims, dtype):
    ks = jax.random.split(key, 4)
    h, hk, hd, d = dims.num_heads, dims.num_kv_heads, dims.head_dim, dims.d_model
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, hk * hd, dtype),
        "wv": dense_init(ks[2], d, hk * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype, scale=1.0 / math.sqrt(h * hd)),
    }
    if dims.qk_norm:
        p["q_norm"] = init_norm(hd, dtype)
        p["k_norm"] = init_norm(hd, dtype)
    return p


def _heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd).transpose(0, 2, 1, 3)


def qkv_project(p, dims: AttnDims, x, positions, theta, eps=1e-6):
    """Project + (optional) qk-norm + rope. Returns q (B,H,S,hd), k/v (B,Hk,S,hd)."""
    q = _heads(x @ p["wq"], dims.num_heads, dims.head_dim)
    k = _heads(x @ p["wk"], dims.num_kv_heads, dims.head_dim)
    v = _heads(x @ p["wv"], dims.num_kv_heads, dims.head_dim)
    if dims.qk_norm:
        q = norm_apply(p["q_norm"], q, eps)
        k = norm_apply(p["k_norm"], k, eps)
    if theta is not None:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def sdpa(
    q, k, v, *, causal=True, window=None, q_offset=0, softcap=0.0,
    q_chunk=256, kv_chunk=1024,
):
    """Scaled dot-product attention, pure-jnp flash-style (online softmax,
    lax.scan over q- and kv-chunks) so prefill-length logits never materialize.

    This is the portable mirror of kernels/flash_attention.py (used on CPU and
    for dry-run lowering; the Pallas kernel replaces it on TPU).
    window: None | python int | traced scalar (<=0 or >=S means no window).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = d ** -0.5
    if act.flash_surrogate_active():
        # Dry-run stand-in for the Pallas flash kernel (kernels/
        # flash_attention.py, validated in interpret mode): reads q, k, v
        # once and writes (b,hq,sq,d) — the kernel's exact HBM boundary.
        # Internal logits/softmax stay in VMEM on TPU, so they must NOT
        # appear as HBM traffic here; MXU FLOPs are added analytically by
        # launch/dryrun.attention_flops.
        kv = (jnp.mean(k, axis=2, keepdims=True)
              + jnp.mean(v, axis=2, keepdims=True)) * scale   # (b,hkv,1,d)
        kv = jnp.broadcast_to(kv[:, :, None], (b, hkv, group, 1, d))
        return q + kv.reshape(b, hq, 1, d).astype(q.dtype)
    # q/k/v stay in their storage dtype (bf16 cache on TPU); contractions
    # request an f32 accumulator instead (MXU-native), and the softmax scale
    # is applied to the f32 logits. Mixed-dtype einsums would promote the
    # cache operand to f32 — and XLA then hoists a full-precision copy of
    # the whole stacked KV cache out of the layer scan: 2× cache memory.
    qg = q.reshape(b, hkv, group, sq, d)
    win = jnp.asarray(BIG_WINDOW if window is None else window, jnp.int32)
    win = jnp.where(win <= 0, BIG_WINDOW, win)

    if sq * skv <= 1 << 22:  # small: direct path
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                            preferred_element_type=jnp.float32) * scale
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(skv)[None, :]
        mask = kpos > qpos - win
        if causal:
            mask &= kpos <= qpos
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, -1)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", probs.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.reshape(b, hq, sq, d).astype(q.dtype)

    # chunked two-level online-softmax path
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)
    sq_p, skv_p = -(-sq // qc) * qc, -(-skv // kc) * kc
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    kfp = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    vfp = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    nq, nk = sq_p // qc, skv_p // kc
    qs = jnp.moveaxis(qg.reshape(b, hkv, group, nq, qc, d), 3, 0)   # (nq,b,hkv,g,qc,d)
    ks = jnp.moveaxis(kfp.reshape(b, hkv, nk, kc, d), 2, 0)         # (nk,b,hkv,kc,d)
    vs = jnp.moveaxis(vfp.reshape(b, hkv, nk, kc, d), 2, 0)

    def q_step(_, qi_blk):
        qi, q_blk = qi_blk

        def kv_step(carry, kv):
            m, l, acc, ki = carry
            k_blk, v_blk = kv
            s = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            qpos = qi * qc + jnp.arange(qc)[:, None] + q_offset
            kpos = ki * kc + jnp.arange(kc)[None, :]
            mask = (kpos > qpos - win) & (kpos < skv)
            if causal:
                mask &= kpos <= qpos
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new, ki + 1), None

        init = (
            jnp.full((b, hkv, group, qc), -1e30, jnp.float32),
            jnp.zeros((b, hkv, group, qc), jnp.float32),
            jnp.zeros((b, hkv, group, qc, d), jnp.float32),
            jnp.zeros((), jnp.int32),
        )
        (m, l, acc, _), _ = jax.lax.scan(kv_step, init, (ks, vs))
        l = jnp.where(l > 0, l, 1.0)
        return None, acc / l[..., None]

    _, out = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    out = jnp.moveaxis(out, 0, 3).reshape(b, hkv, group, sq_p, d)[:, :, :, :sq]
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def attn_out(p, ctx):
    """ctx: (B, H, S, hd) → (B, S, D)."""
    b, h, s, hd = ctx.shape
    return ctx.transpose(0, 2, 1, 3).reshape(b, s, h * hd) @ p["wo"]


# ----------------------------------------------------------------------- MLP
def init_mlp(key, d_model, d_ff, activation, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], d_model, d_ff, dtype),
        "w_down": dense_init(ks[1], d_ff, d_model, dtype),
    }
    if activation == "swiglu":
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp_apply(p, x, activation):
    if activation == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif activation == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["w_up"]))
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]
