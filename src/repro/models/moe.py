"""Mixture-of-Experts layer (top-k router, capacity-based grouped dispatch).

Dispatch uses the classic one-hot combine tensors, but over token *groups* so
the dispatch einsums stay linear in total tokens (cost ≈ k·cf·g per token,
negligible vs the expert FLOPs — see DESIGN.md). Experts are laid out on a
leading E dim so the expert weights shard over the mesh
(E → expert-parallel submesh when enabled, else tensor-parallel inner dims).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.core import act

from repro.config import MoEConfig
from repro.models import layers as L


def init_moe(key, d_model, d_ff, moe: MoEConfig, activation, dtype):
    ks = jax.random.split(key, 4)
    e = moe.num_experts

    def ed(k, d_in, d_out):
        flat = L.dense_init(k, d_in, e * d_out, dtype)
        return flat.reshape(d_in, e, d_out).transpose(1, 0, 2)  # (E, d_in, d_out)

    p = {
        "router": L.dense_init(ks[0], d_model, e, dtype),
        "w_up": ed(ks[1], d_model, d_ff),
        "w_down": ed(ks[2], d_ff, d_model),
    }
    if activation == "swiglu":
        p["w_gate"] = ed(ks[3], d_model, d_ff)
    return p


def _expert_hidden(p, h_in, activation):
    """(n, E, cap, d) → (n, E, cap, f): up/gate projection + nonlinearity."""
    up = jnp.einsum("necd,edf->necf", h_in, p["w_up"])
    if activation == "swiglu":
        gate = jnp.einsum("necd,edf->necf", h_in, p["w_gate"])
        return jax.nn.silu(gate) * up
    if activation == "relu2":
        return jnp.square(jax.nn.relu(up))
    return jax.nn.gelu(up)


def _scatter_dispatch(groups, slot, e, cap):
    """Scatter token vectors into expert-capacity slots (§Perf granite iter 1).

    groups (ng, g, d), slot (ng, g, k) flat indices into [0, e·cap] (e·cap =
    the drop bin). Replaces the one-hot dispatch einsum, whose (g × E × cap)
    cross tensors cost ~e/k× the dispatched-token bytes (granite, 40 experts
    top-8: ≈1 PB-scale intermediates at train_4k). Slots are unique per
    (group, expert, position) by cumsum construction, so the scatter-add is
    collision-free and exactly equals the einsum dispatch.
    """
    ng, g, d = groups.shape
    k = slot.shape[-1]
    src = jnp.broadcast_to(groups[:, :, None, :], (ng, g, k, d))
    src = src.reshape(ng, g * k, d)
    flat = slot.reshape(ng, g * k)
    buf = jnp.zeros((ng, e * cap + 1, d), groups.dtype)
    buf = buf.at[jnp.arange(ng)[:, None], flat].add(src)
    return buf[:, : e * cap].reshape(ng, e, cap, d)


def _gather_combine(out_e, slot, weight):
    """Inverse of _scatter_dispatch: gather each token's expert output and
    weight by its router prob. out_e (ng, e, cap, d); slot/weight (ng, g, k)."""
    ng, e, cap, d = out_e.shape
    g, k = slot.shape[1], slot.shape[2]
    flat = out_e.reshape(ng, e * cap, d)
    flat = jnp.concatenate([flat, jnp.zeros((ng, 1, d), flat.dtype)], axis=1)
    gath = jnp.take_along_axis(
        flat, slot.reshape(ng, g * k)[..., None], axis=1)
    gath = gath.reshape(ng, g, k, d)
    return jnp.sum(gath * weight[..., None], axis=2)


def _ffn_dense(p, groups, slot, weight, e, cap, activation):
    """Single-program expert FFN (GSPMD chooses the collectives).

    NOTE (§Perf grok iteration 1, refuted): constraining hidden to f-sharded
    and/or out_e to d-sharded here makes GSPMD reshard the dispatched tensors
    and collective traffic explodes ~6×. GSPMD's unconstrained placement
    (partial-sum all-reduce of out_e in dispatched-token space, 2.5× token
    volume at capacity 1.25 × top-2) is the best this path expresses; the
    combine-before-reduce placement needs _ffn_shard_map.
    """
    h_in = _scatter_dispatch(groups, slot, e, cap)
    hidden = _expert_hidden(p, h_in, activation)
    out_e = jnp.einsum("necf,efd->necd", hidden, p["w_down"])
    return _gather_combine(out_e, slot, weight)


def _shard_map_ok(ng: int, d_ff: int) -> bool:
    """Use the explicit shard_map FFN when the policy is active and the
    group/feature dims divide the federation/model axes.
    REPRO_MOE_FFN=dense forces the GSPMD path (perf A/B)."""
    import os
    if os.environ.get("REPRO_MOE_FFN") == "dense":
        return False
    pol = act._POLICY.get()
    if pol is None:
        return False
    import math as _math
    fsdp = _math.prod(pol["mesh"].shape[a] for a in pol["batch"])
    tp = _math.prod(pol["mesh"].shape[a] for a in pol["model"])
    return tp > 1 and ng % max(fsdp, 1) == 0 and d_ff % tp == 0


def _ffn_shard_map(p, groups, slot, weight, e, cap, activation):
    """Expert FFN with an explicit collective schedule (§Perf grok iter 2):

    tokens stay sharded over the federation axes; expert weights enter
    d_ff-sharded over 'model'; dispatch/FFN/combine are local; the combine
    runs on the *partial* (f-shard) expert outputs — linearity lets it
    commute with the f-reduction — and ONE psum in token space (ng·g·d)
    finishes the layer. vs the dense path's all-reduce in dispatched-token
    space this moves 1/(top_k·capacity_factor) of the bytes (grok: 2.5×).
    """
    from jax.sharding import PartitionSpec as P

    pol = act._POLICY.get()
    mesh, fsdp, tp = pol["mesh"], pol["batch"], pol["model"]
    tok_spec = P(fsdp)  # ng dim; g/k/d replicated
    wcol = P(None, None, tp)   # (E, d, f): f over model
    wrow = P(None, tp, None)   # (E, f, d)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(
            {k: (wrow if k == "w_down" else wcol)
             for k in ("w_up", "w_down", *(("w_gate",) if "w_gate" in p else ()))},
            tok_spec, tok_spec, tok_spec,
        ),
        out_specs=tok_spec,
    )
    def ffn(weights, groups_l, slot_l, weight_l):
        h_in = _scatter_dispatch(groups_l, slot_l, e, cap)
        hidden = _expert_hidden(weights, h_in, activation)
        out_partial = jnp.einsum("necf,efd->necd", hidden, weights["w_down"])
        out_l = _gather_combine(out_partial, slot_l, weight_l)
        return jax.lax.psum(out_l, tp)

    weights = {k: p[k] for k in ("w_up", "w_down", "w_gate") if k in p}
    return ffn(weights, groups, slot, weight)


def moe_apply(p, x, moe: MoEConfig, activation):
    """x: (B, S, D) → (B, S, D); also returns the router aux loss (load-balance)."""
    b, s, d = x.shape
    e, k = moe.num_experts, moe.top_k
    tokens = x.reshape(-1, d)
    t = tokens.shape[0]
    g = min(moe.group_size, t)
    ng = -(-t // g)
    pad = ng * g - t
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    groups = tokens.reshape(ng, g, d)

    logits = groups @ p["router"]                       # (ng, g, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    top_p, top_i = jax.lax.top_k(probs, k)              # (ng, g, k)
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)

    cap = int(max(1, round(g * k / e * moe.capacity_factor)))
    # one-hot expert assignment (ng, g, k, E) — position of each (token, k)
    # within its expert queue via cumsum over the flattened (g·k) order
    assign = jax.nn.one_hot(top_i, e, dtype=jnp.float32)
    pos = jnp.cumsum(assign.reshape(ng, g * k, e), axis=1).reshape(ng, g, k, e)
    pos = pos * assign - 1.0
    pos_sel = jnp.max(pos, axis=-1)                 # (ng, g, k): own-expert pos
    keep = (pos_sel >= 0) & (pos_sel < cap)
    # flat slot index into (E·cap); dropped tokens land in the overflow bin
    slot = top_i * cap + pos_sel.astype(jnp.int32)
    slot = jnp.where(keep, slot, e * cap)
    weight = jnp.where(keep, top_p, 0.0)            # (ng, g, k)

    # n=group, g=token-in-group, e=expert, c=capacity slot, d/f=features
    if _shard_map_ok(ng, p["w_up"].shape[-1]):
        out = _ffn_shard_map(p, groups, slot, weight, e, cap, activation)
    else:
        out = _ffn_dense(p, groups, slot, weight, e, cap, activation)
    out = out.reshape(-1, d)[:t].reshape(b, s, d).astype(x.dtype)

    # load-balance aux (Switch-style): E * Σ_e f_e · P_e
    frac_tokens = jnp.mean(assign.sum(2), axis=(0, 1)) / k
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out, aux
