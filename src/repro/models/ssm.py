"""Mamba2 (SSD) mixer — chunked scan for training/prefill, O(1) decode step.

TPU adaptation: the GPU reference implementation leans on fused CUDA scans;
here the state-space recurrence is re-blocked into the chunkwise-parallel SSD
form — intra-chunk terms are dense (MXU) matmuls, the inter-chunk carry is a
short ``lax.scan`` over S/chunk steps. Chunk length defaults to 128 so the
(c × c) decay matrices stay VMEM-resident under the production shardings.

State layout for decode: conv cache (B, conv_dim, d_conv-1) + SSD state
(B, heads, d_state, d_head).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.config import SSMConfig
from repro.models import layers as L


def dims(d_model: int, cfg: SSMConfig):
    d_inner = cfg.expand * d_model
    nh = cfg.num_heads or max(1, d_inner // 64)
    dh = d_inner // nh
    conv_dim = d_inner + 2 * cfg.d_state
    return d_inner, nh, dh, conv_dim


def init_mamba(key, d_model, cfg: SSMConfig, dtype):
    d_inner, nh, dh, conv_dim = dims(d_model, cfg)
    ks = jax.random.split(key, 5)
    proj_out = 2 * d_inner + 2 * cfg.d_state + nh  # z, x, B, C, dt
    return {
        "in_proj": L.dense_init(ks[0], d_model, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, conv_dim), jnp.float32)
                   / math.sqrt(cfg.d_conv)).astype(dtype),
        "a_log": jnp.zeros((nh,), jnp.float32),          # A = -exp(a_log) = -1
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_proj": L.dense_init(ks[2], d_inner, d_model, dtype),
    }


def _split_proj(proj, d_inner, d_state, nh):
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner: 2 * d_inner + 2 * d_state]
    dt = proj[..., 2 * d_inner + 2 * d_state:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, cache=None):
    """Depthwise causal conv over time. xbc (B, S, C); conv_w (K, C)."""
    k = conv_w.shape[0]
    if cache is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = cache  # (B, K-1, C)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i: i + xbc.shape[1]] * conv_w[i][None, None] for i in range(k))
    new_cache = xp[:, -(k - 1):] if k > 1 else pad[:, :0]
    return jax.nn.silu(out), new_cache


def mamba_apply(p, x, cfg: SSMConfig, *, init_state=None, return_state=False):
    """Full-sequence (train/prefill) chunked SSD. x: (B, S, D)."""
    b, s, d_model = x.shape
    d_inner, nh, dh, conv_dim = dims(d_model, cfg)
    ds = cfg.d_state
    z, xbc, dt_raw = _split_proj(x @ p["in_proj"], d_inner, ds, nh)
    xbc, conv_cache = _causal_conv(xbc, p["conv_w"],
                                   None if init_state is None else init_state["conv"])
    xs = xbc[..., :d_inner].reshape(b, s, nh, dh).astype(jnp.float32)
    bmat = xbc[..., d_inner: d_inner + ds].astype(jnp.float32)       # (B,S,ds)
    cmat = xbc[..., d_inner + ds:].astype(jnp.float32)               # (B,S,ds)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    neg_a = jnp.exp(p["a_log"])                                      # (nh,)
    log_g = -dt * neg_a                                              # log decay ≤ 0

    c = min(cfg.chunk, s)
    nc = -(-s // c)
    pad = nc * c - s
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        log_g = jnp.pad(log_g, ((0, 0), (0, pad), (0, 0)))
    ch = lambda a: a.reshape((b, nc, c) + a.shape[2:])
    xs_c, b_c, c_c, dt_c, g_c = map(ch, (xs, bmat, cmat, dt, log_g))

    gcum = jnp.cumsum(g_c, axis=2)                                   # (B,nc,c,nh)
    gtot = gcum[:, :, -1]                                            # (B,nc,nh)
    xw = xs_c * dt_c[..., None]                                      # dt-weighted x

    # intra-chunk: y_t += C_t · Σ_{s≤t} exp(gcum_t − gcum_s) B_s xw_s
    decay = jnp.exp(gcum[:, :, :, None] - gcum[:, :, None, :])       # (B,nc,t,s,nh)
    causal = jnp.tril(jnp.ones((c, c), bool))
    decay = jnp.where(causal[None, None, :, :, None], decay, 0.0)
    scores = jnp.einsum("bnte,bnse->bnts", c_c, b_c)                 # C_t·B_s
    y_intra = jnp.einsum("bnts,bntsh,bnshd->bnthd", scores, decay, xw)

    # chunk boundary states: S_chunk = Σ_s exp(gtot − gcum_s) B_s ⊗ xw_s
    w_state = jnp.exp(gtot[:, :, None] - gcum)                       # (B,nc,c,nh)
    chunk_states = jnp.einsum("bnsh,bnse,bnshd->bnhed", w_state, b_c, xw)

    # inter-chunk carry
    def carry(h, inp):
        st, g = inp                                                   # g (B,nh)
        h_new = h * jnp.exp(g)[..., None, None] + st
        return h_new, h                                               # emit h_prev

    h0 = (jnp.zeros((b, nh, ds, dh), jnp.float32) if init_state is None
          else init_state["ssd"].astype(jnp.float32))
    h_last, h_prevs = jax.lax.scan(
        carry, h0,
        (chunk_states.transpose(1, 0, 2, 3, 4), gtot.transpose(1, 0, 2)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                        # (B,nc,nh,ds,dh)
    y_inter = jnp.einsum("bnth,bnte,bnhed->bnthd",
                         jnp.exp(gcum), c_c, h_prevs)
    y = (y_intra + y_inter).reshape(b, nc * c, nh, dh)[:, :s]
    y = y + xs.reshape(b, nc * c, nh, dh)[:, :s] * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_inner).astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if return_state:
        return out, {"conv": conv_cache, "ssd": h_last.astype(jnp.float32)}
    return out


def mamba_decode(p, x, state, cfg: SSMConfig):
    """Single-token step. x: (B, 1, D); state: {'conv','ssd'}."""
    b, _, d_model = x.shape
    d_inner, nh, dh, _ = dims(d_model, cfg)
    ds = cfg.d_state
    z, xbc, dt_raw = _split_proj(x @ p["in_proj"], d_inner, ds, nh)
    xbc, conv_cache = _causal_conv(xbc, p["conv_w"], state["conv"])
    xs = xbc[:, 0, :d_inner].reshape(b, nh, dh).astype(jnp.float32)
    bvec = xbc[:, 0, d_inner: d_inner + ds].astype(jnp.float32)
    cvec = xbc[:, 0, d_inner + ds:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    g = jnp.exp(-dt * jnp.exp(p["a_log"]))                                 # decay
    xw = xs * dt[..., None]
    h = state["ssd"] * g[..., None, None] + jnp.einsum("be,bhd->bhed", bvec, xw)
    y = jnp.einsum("be,bhed->bhd", cvec, h) + xs * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, d_inner).astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"], {"conv": conv_cache, "ssd": h}


def init_mamba_state(batch, d_model, cfg: SSMConfig, dtype=jnp.float32):
    d_inner, nh, dh, conv_dim = dims(d_model, cfg)
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
        "ssd": jnp.zeros((batch, nh, cfg.d_state, dh), jnp.float32),
    }
