"""Backbone assembly for all assigned architecture families.

Every family compiles to O(1)-size HLO via lax.scan over (groups of) layers
with stacked parameters; heterogeneous stacks (gemma3 local/global windows,
zamba2 shared-attention cadence, xLSTM mLSTM/sLSTM ratio) are expressed either
as per-layer *traced* metadata (window/theta arrays scanned alongside params)
or as grouped two-level scans, never as per-layer unrolled HLO.

Public entry points (uniform across families):
  init_params(key, cfg)                  → param pytree
  forward(params, cfg, batch)            → final hidden states (B, S, D)
  pool(hidden)                           → (B, D) embedding for the AFL head
  lm_logits(params, cfg, hidden)         → (B, S, vocab)
  init_cache(cfg, batch, max_seq)        → decode cache pytree
  prefill(params, cfg, batch, max_seq)   → (hidden, cache)
  decode_step(params, cfg, tok, cache, pos) → (hidden (B,1,D), cache)

``batch`` is a dict: tokens (B, S) int32 and, for VLM/audio archs, the
modality stub: prefix_embeds (B, P, D) (llava patches, consumed as prefix
tokens) or enc_feats (B, S_enc, D) (seamless audio frames → encoder input).
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core import act
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X

Params = Dict[str, Any]


# ------------------------------------------------------------ per-layer meta
def layer_meta(cfg: ModelConfig, n_layers: int):
    """(window, theta) per layer as arrays scanned with the params.

    window==0 encodes "full attention" (sdpa maps <=0 to unbounded).
    """
    idx = np.arange(n_layers)
    if cfg.window and cfg.global_every:
        is_global = (idx % cfg.global_every) == (cfg.global_every - 1)
    elif cfg.window:
        is_global = np.zeros(n_layers, bool)
    else:
        is_global = np.ones(n_layers, bool)
    window = np.where(is_global, 0, cfg.window).astype(np.int32)
    theta_g = cfg.rope_theta_global or cfg.rope_theta
    theta = np.where(is_global, theta_g, cfg.rope_theta).astype(np.float32)
    return jnp.asarray(window), jnp.asarray(theta)


def _attn_dims(cfg: ModelConfig) -> L.AttnDims:
    return L.AttnDims(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        qk_norm=cfg.qk_norm,
    )


# ----------------------------------------------------------- dense/moe block
def _init_block(key, cfg: ModelConfig, cross_attn: bool = False):
    ks = jax.random.split(key, 6)
    dt = cfg.param_dtype
    p = {
        "ln1": L.init_norm(cfg.d_model, dt, cfg.norm == "layer"),
        "attn": L.init_attention(ks[0], _attn_dims(cfg), dt),
        "ln2": L.init_norm(cfg.d_model, dt, cfg.norm == "layer"),
    }
    if cfg.moe is not None:
        p["moe"] = M.init_moe(ks[1], cfg.d_model, cfg.d_ff, cfg.moe, cfg.activation, dt)
    elif cfg.d_ff:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation, dt)
    if cross_attn:
        p["ln_x"] = L.init_norm(cfg.d_model, dt, cfg.norm == "layer")
        p["xattn"] = L.init_attention(ks[2], _attn_dims(cfg), dt)
    return p


def _block_ffn(p, cfg: ModelConfig, x):
    x = act.constrain_bsd(x)
    h = L.norm_apply(p["ln2"], x, cfg.norm_eps, cfg.norm)
    if cfg.moe is not None:
        out, _aux = M.moe_apply(p["moe"], h, cfg.moe, cfg.activation)
    elif cfg.d_ff:
        out = L.mlp_apply(p["mlp"], h, cfg.activation)
    else:
        out = jnp.zeros_like(x)
    return x + out


def _block_fwd(p, cfg: ModelConfig, x, positions, window, theta,
               *, causal=True, kv_cache=None, pos=None, memory_kv=None):
    """One attention block. Returns (x, new_kv or computed kv)."""
    dims = _attn_dims(cfg)
    x = act.constrain_bsd(x)
    h = L.norm_apply(p["ln1"], x, cfg.norm_eps, cfg.norm)
    q, k, v = L.qkv_project(p["attn"], dims, h, positions, theta, cfg.norm_eps)
    q = act.constrain_heads(q)
    k = act.constrain_heads(k)
    v = act.constrain_heads(v)
    if kv_cache is None:
        attn = L.sdpa(q, k, v, causal=causal, window=window,
                      softcap=cfg.logit_softcap)
        new_kv = (k, v)
        q_offset = 0
    else:
        ck, cv = kv_cache
        clen = ck.shape[2]
        # Ring-buffer semantics (§Perf long_500k): when the allocated cache
        # is shorter than the context, slot = pos % clen keeps exactly the
        # last clen positions (keys stored rope'd at absolute positions, so
        # dot products are position-correct). The sliding-window mask is
        # then enforced *by the ring itself* — disable it (a slot-index
        # window mask would wrongly evict wrapped slots) and let causality
        # (slot <= pos) mask the not-yet-written slots while pos < clen.
        slot = jax.lax.rem(jnp.asarray(pos, jnp.int32), jnp.int32(clen))
        win = jnp.asarray(window, jnp.int32)
        win = jnp.where((win > 0) & (clen <= win), 0, win)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, slot, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, slot, 0))
        attn = L.sdpa(q, ck, cv, causal=True, window=win, q_offset=pos,
                      softcap=cfg.logit_softcap)
        new_kv = (ck, cv)
    x = x + L.attn_out(p["attn"], attn)
    if memory_kv is not None:  # cross attention (enc-dec)
        hx = L.norm_apply(p["ln_x"], x, cfg.norm_eps, cfg.norm)
        qx, _, _ = L.qkv_project(p["xattn"], dims, hx, positions, None)
        mk, mv = memory_kv
        xattn = L.sdpa(qx, mk, mv, causal=False, window=None)
        x = x + L.attn_out(p["xattn"], xattn)
    return _block_ffn(p, cfg, x), new_kv


def _memory_kv(p, cfg: ModelConfig, memory):
    """Cross-attention K/V from encoder memory (per decoder layer)."""
    dims = _attn_dims(cfg)
    _, mk, mv = L.qkv_project(p["xattn"], dims, memory, None, None)
    return mk, mv


# ------------------------------------------------------------ embedding etc.
def _init_common(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    dt = cfg.param_dtype
    p = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model), jnp.float32)
                  * 0.02).astype(dt),
        "final_norm": L.init_norm(cfg.d_model, dt, cfg.norm == "layer"),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(ks[1], cfg.d_model, cfg.vocab_size, dt)
    if cfg.prefix_tokens:
        p["mm_proj"] = L.dense_init(ks[2], cfg.d_model, cfg.d_model, dt)
    if cfg.encoder_layers:
        p["enc_proj"] = L.dense_init(ks[3], cfg.d_model, cfg.d_model, dt)
    return p


def embed_inputs(params: Params, cfg: ModelConfig, batch):
    """tokens (+ optional VLM prefix) → (x (B,S,D), positions (B,S))."""
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.prefix_tokens:
        prefix = batch["prefix_embeds"].astype(x.dtype) @ params["mm_proj"]
        x = jnp.concatenate([prefix, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return act.constrain_bsd(x), positions


def pool(hidden: jax.Array) -> jax.Array:
    """Sequence-mean embedding for the AFL analytic head."""
    return jnp.mean(hidden, axis=1)


def lm_logits(params: Params, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    table = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return hidden @ table


# =====================================================================
# family: dense / moe (uniform stack, single scan)
# =====================================================================
def _init_dense(key, cfg: ModelConfig):
    p = _init_common(key, cfg)
    keys = jax.random.split(jax.random.fold_in(key, 1), cfg.num_layers)
    p["layers"] = jax.vmap(lambda k: _init_block(k, cfg))(keys)
    return p


def _dense_forward(params, cfg, x, positions, causal=True):
    window, theta = layer_meta(cfg, cfg.num_layers)

    def body(h, xs):
        lp, w, th = xs
        h, _ = _block_fwd(lp, cfg, h, positions, w, th, causal=causal)
        return h, None

    x, _ = jax.lax.scan(body, x, (params["layers"], window, theta))
    return L.norm_apply(params["final_norm"], x, cfg.norm_eps, cfg.norm)


def _dense_prefill(params, cfg, x, positions, max_seq):
    window, theta = layer_meta(cfg, cfg.num_layers)
    b, s, _ = x.shape
    hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def body(h, xs):
        lp, w, th = xs
        h, (k, v) = _block_fwd(lp, cfg, h, positions, w, th)
        pad = max_seq - s
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return h, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], window, theta))
    cache = {"k": ks, "v": vs}  # (L, B, Hk, max_seq, hd)
    return L.norm_apply(params["final_norm"], x, cfg.norm_eps, cfg.norm), cache


def _dense_decode(params, cfg, x, cache, pos):
    """One-token decode, cache as fori_loop carry (§Perf decode iteration).

    Threading the cache through scan *ys* rewrites every layer's full cache
    slice per token (~2× cache bytes/step); carrying the stacked cache and
    dynamic-update-slicing ONE token at (layer, ·, ·, slot, ·) leaves the
    write O(1) and the read just the layer's K/V (needed by attention anyway).
    Ring semantics as in _block_fwd: slot = pos % cache_len.
    """
    window, theta = layer_meta(cfg, cfg.num_layers)
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    dims = _attn_dims(cfg)
    clen = cache["k"].shape[3]
    slot = jax.lax.rem(jnp.asarray(pos, jnp.int32), jnp.int32(clen))

    def body(i, carry):
        h, ck_all, cv_all = carry
        lp = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            params["layers"])
        w, th = window[i], theta[i]
        hn = L.norm_apply(lp["ln1"], act.constrain_bsd(h), cfg.norm_eps, cfg.norm)
        q, k, v = L.qkv_project(lp["attn"], dims, hn, positions, th,
                                cfg.norm_eps)
        ck_all = jax.lax.dynamic_update_slice(
            ck_all, k[None].astype(ck_all.dtype), (i, 0, 0, slot, 0))
        cv_all = jax.lax.dynamic_update_slice(
            cv_all, v[None].astype(cv_all.dtype), (i, 0, 0, slot, 0))
        ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
        win = jnp.where((w > 0) & (clen <= w), 0, w)
        attn = L.sdpa(q, ck, cv, causal=True, window=win, q_offset=pos,
                      softcap=cfg.logit_softcap)
        h = h + L.attn_out(lp["attn"], attn)
        h = _block_ffn(lp, cfg, h)
        return h, ck_all, cv_all

    x, ks, vs = jax.lax.fori_loop(
        0, cfg.num_layers, body, (x, cache["k"], cache["v"]))
    x = L.norm_apply(params["final_norm"], x, cfg.norm_eps, cfg.norm)
    return x, {"k": ks, "v": vs}


def _dense_cache(cfg, batch, max_seq, dtype):
    hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, hk, max_seq, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# =====================================================================
# family: hybrid (zamba2) — groups of (G-1 mamba + 1 shared attn) + tail
# =====================================================================
def _hybrid_split(cfg: ModelConfig):
    g = cfg.shared_attn_every
    n_groups = cfg.num_layers // g
    tail = cfg.num_layers - n_groups * g
    return g, n_groups, tail


def _init_mamba_layer(key, cfg: ModelConfig):
    dt = cfg.param_dtype
    return {
        "ln": L.init_norm(cfg.d_model, dt),
        "mixer": S.init_mamba(key, cfg.d_model, cfg.ssm, dt),
    }


def _init_hybrid(key, cfg: ModelConfig):
    p = _init_common(key, cfg)
    g, n_groups, tail = _hybrid_split(cfg)
    kg, kt, ka = jax.random.split(jax.random.fold_in(key, 2), 3)
    if n_groups:
        keys = jax.random.split(kg, (n_groups, g - 1))
        p["mamba_groups"] = jax.vmap(jax.vmap(
            lambda k: _init_mamba_layer(k, cfg)))(keys)
    if tail:
        keys_t = jax.random.split(kt, tail)
        p["mamba_tail"] = jax.vmap(lambda k: _init_mamba_layer(k, cfg))(keys_t)
    p["shared_attn"] = _init_block(ka, cfg)
    return p


def _mamba_layer_fwd(lp, cfg, h, state=None):
    h = act.constrain_bsd(h)
    hin = L.norm_apply(lp["ln"], h, cfg.norm_eps, cfg.norm)
    if state is None:
        return h + S.mamba_apply(lp["mixer"], hin, cfg.ssm), None
    out, new_state = (
        S.mamba_decode(lp["mixer"], hin, state, cfg.ssm)
        if hin.shape[1] == 1
        else S.mamba_apply(lp["mixer"], hin, cfg.ssm,
                           init_state=state, return_state=True)
    )
    return h + out, new_state


def _hybrid_forward(params, cfg, x, positions):
    g, n_groups, tail = _hybrid_split(cfg)

    def mamba_body(h, lp):
        h, _ = _mamba_layer_fwd(lp, cfg, h)
        return h, None

    def group_body(h, gp):
        h, _ = jax.lax.scan(mamba_body, h, gp)
        h, _ = _block_fwd(params["shared_attn"], cfg, h, positions,
                          cfg.window or 0, cfg.rope_theta)
        return h, None

    if n_groups:
        x, _ = jax.lax.scan(group_body, x, params["mamba_groups"])
    if tail:
        x, _ = jax.lax.scan(mamba_body, x, params["mamba_tail"])
    return L.norm_apply(params["final_norm"], x, cfg.norm_eps, cfg.norm)


def _hybrid_cache(cfg, batch, max_seq, dtype):
    g, n_groups, tail = _hybrid_split(cfg)
    hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    one = S.init_mamba_state(batch, cfg.d_model, cfg.ssm, dtype)
    cache = {}
    if n_groups:
        cache["mamba_groups"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_groups, g - 1) + a.shape).copy(), one
        )
        cache["attn"] = {
            "k": jnp.zeros((n_groups, batch, hk, max_seq, hd), dtype),
            "v": jnp.zeros((n_groups, batch, hk, max_seq, hd), dtype),
        }
    if tail:
        cache["mamba_tail"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (tail,) + a.shape).copy(), one
        )
    return cache


def _hybrid_step(params, cfg, x, positions, cache, pos, max_seq):
    """Shared path for prefill (S>1) and decode (S=1) with state carry."""
    g, n_groups, tail = _hybrid_split(cfg)
    s = x.shape[1]

    def mamba_body(h, xs):
        lp, st = xs
        h, new_st = _mamba_layer_fwd(lp, cfg, h, state=st)
        return h, new_st

    new_cache = dict(cache)
    if n_groups:
        def group_body(h, xs):
            gp, gst, ck, cv = xs
            h, new_gst = jax.lax.scan(mamba_body, h, (gp, gst))
            if s > 1:  # prefill: write kv at [0, s)
                h, (k, v) = _block_fwd(params["shared_attn"], cfg, h, positions,
                                       cfg.window or 0, cfg.rope_theta)
                pad = max_seq - s
                nk = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(ck.dtype)
                nv = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(cv.dtype)
            else:
                h, (nk, nv) = _block_fwd(params["shared_attn"], cfg, h, positions,
                                         cfg.window or 0, cfg.rope_theta,
                                         kv_cache=(ck, cv), pos=pos)
            return h, (new_gst, nk, nv)

        x, (gst, ks, vs) = jax.lax.scan(
            group_body, x,
            (params["mamba_groups"], cache["mamba_groups"],
             cache["attn"]["k"], cache["attn"]["v"]),
        )
        new_cache["mamba_groups"] = gst
        new_cache["attn"] = {"k": ks, "v": vs}
    if tail:
        x, tst = jax.lax.scan(mamba_body, x, (params["mamba_tail"], cache["mamba_tail"]))
        new_cache["mamba_tail"] = tst
    return L.norm_apply(params["final_norm"], x, cfg.norm_eps, cfg.norm), new_cache


# =====================================================================
# family: xlstm — groups of (N-1 mLSTM + 1 sLSTM)
# =====================================================================
def _xlstm_split(cfg: ModelConfig):
    g = cfg.slstm_every
    n_groups = cfg.num_layers // g
    tail = cfg.num_layers - n_groups * g
    return g, n_groups, tail


def _init_xlstm(key, cfg: ModelConfig):
    p = _init_common(key, cfg)
    g, n_groups, tail = _xlstm_split(cfg)
    dt = cfg.param_dtype
    km, ks_, kt = jax.random.split(jax.random.fold_in(key, 3), 3)

    def init_m(k):
        return {"ln": L.init_norm(cfg.d_model, dt),
                "mixer": X.init_mlstm(k, cfg.d_model, cfg.num_heads, dt)}

    def init_s(k):
        return {"ln": L.init_norm(cfg.d_model, dt),
                "mixer": X.init_slstm(k, cfg.d_model, cfg.num_heads, dt)}

    if n_groups:
        keys = jax.random.split(km, (n_groups, g - 1))
        p["mlstm_groups"] = jax.vmap(jax.vmap(init_m))(keys)
        p["slstm"] = jax.vmap(init_s)(jax.random.split(ks_, n_groups))
    if tail:
        p["mlstm_tail"] = jax.vmap(init_m)(jax.random.split(kt, tail))
    return p


def _xlstm_run(params, cfg, x, states=None):
    """states=None → plain forward; else threads and returns states."""
    g, n_groups, tail = _xlstm_split(cfg)
    want_state = states is not None

    def m_body(h, xs):
        lp, st = xs if want_state else (xs, None)
        h = act.constrain_bsd(h)
        hin = L.norm_apply(lp["ln"], h, cfg.norm_eps, cfg.norm)
        if want_state:
            out, nst = X.mlstm_apply(lp["mixer"], hin, cfg.num_heads,
                                     init_state=st, return_state=True)
            return h + out, nst
        return h + X.mlstm_apply(lp["mixer"], hin, cfg.num_heads), None

    def group_body(h, xs):
        if want_state:
            gp, sp, gst, sst = xs
            h, new_gst = jax.lax.scan(m_body, h, (gp, gst))
            hin = L.norm_apply(sp["ln"], h, cfg.norm_eps, cfg.norm)
            out, new_sst = X.slstm_apply(sp["mixer"], hin, cfg.num_heads,
                                         init_state=sst, return_state=True)
            return h + out, (new_gst, new_sst)
        gp, sp = xs
        h, _ = jax.lax.scan(m_body, h, gp)
        hin = L.norm_apply(sp["ln"], h, cfg.norm_eps, cfg.norm)
        return h + X.slstm_apply(sp["mixer"], hin, cfg.num_heads), None

    new_states: dict = {} if want_state else None
    if n_groups:
        if want_state:
            x, (gst, sst) = jax.lax.scan(
                group_body, x,
                (params["mlstm_groups"], params["slstm"],
                 states["mlstm_groups"], states["slstm"]),
            )
            new_states["mlstm_groups"], new_states["slstm"] = gst, sst
        else:
            x, _ = jax.lax.scan(group_body, x, (params["mlstm_groups"], params["slstm"]))
    if tail:
        if want_state:
            x, tst = jax.lax.scan(m_body, x, (params["mlstm_tail"], states["mlstm_tail"]))
            new_states["mlstm_tail"] = tst
        else:
            x, _ = jax.lax.scan(m_body, x, params["mlstm_tail"])
    x = L.norm_apply(params["final_norm"], x, cfg.norm_eps, cfg.norm)
    return (x, new_states) if want_state else x


def _xlstm_cache(cfg, batch, max_seq, dtype):
    g, n_groups, tail = _xlstm_split(cfg)
    m_one = X.init_mlstm_state(batch, cfg.d_model, cfg.num_heads)
    s_one = X.init_slstm_state(batch, cfg.d_model, cfg.num_heads)
    cache = {}
    tile = lambda tree, dims: jax.tree.map(
        lambda a: jnp.broadcast_to(a, dims + a.shape).copy(), tree)
    if n_groups:
        cache["mlstm_groups"] = tile(m_one, (n_groups, g - 1))
        cache["slstm"] = tile(s_one, (n_groups,))
    if tail:
        cache["mlstm_tail"] = tile(m_one, (tail,))
    return cache


# =====================================================================
# family: encdec (seamless) — encoder + cross-attending decoder
# =====================================================================
def _init_encdec(key, cfg: ModelConfig):
    p = _init_common(key, cfg)
    ke, kd = jax.random.split(jax.random.fold_in(key, 4))
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    p["enc_layers"] = jax.vmap(lambda k: _init_block(k, cfg))(enc_keys)
    p["enc_norm"] = L.init_norm(cfg.d_model, cfg.param_dtype, cfg.norm == "layer")
    dec_keys = jax.random.split(kd, cfg.num_layers)
    p["layers"] = jax.vmap(lambda k: _init_block(k, cfg, cross_attn=True))(dec_keys)
    return p


def _encode(params, cfg, enc_feats):
    x = enc_feats.astype(cfg.param_dtype) @ params["enc_proj"]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(h, lp):
        h, _ = _block_fwd(lp, cfg, h, positions, 0, cfg.rope_theta, causal=False)
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.norm_apply(params["enc_norm"], x, cfg.norm_eps, cfg.norm)


def _encdec_forward(params, cfg, batch):
    memory = _encode(params, cfg, batch["enc_feats"])
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(h, lp):
        mkv = _memory_kv(lp, cfg, memory)
        h, _ = _block_fwd(lp, cfg, h, positions, 0, cfg.rope_theta, memory_kv=mkv)
        return h, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.norm_apply(params["final_norm"], x, cfg.norm_eps, cfg.norm)


def _encdec_cache(cfg, batch, max_seq, dtype):
    hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    self_shape = (cfg.num_layers, batch, hk, max_seq, hd)
    cross_shape = (cfg.num_layers, batch, hk, cfg.encoder_seq, hd)
    return {
        "k": jnp.zeros(self_shape, dtype),
        "v": jnp.zeros(self_shape, dtype),
        "xk": jnp.zeros(cross_shape, dtype),
        "xv": jnp.zeros(cross_shape, dtype),
    }


def _encdec_prefill(params, cfg, batch, max_seq):
    memory = _encode(params, cfg, batch["enc_feats"])
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(h, lp):
        mkv = _memory_kv(lp, cfg, memory)
        h, (k, v) = _block_fwd(lp, cfg, h, positions, 0, cfg.rope_theta,
                               memory_kv=mkv)
        pad = max_seq - s
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return h, (k, v, mkv[0], mkv[1])

    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["layers"])
    cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs}
    return L.norm_apply(params["final_norm"], x, cfg.norm_eps, cfg.norm), cache


def _encdec_decode(params, cfg, x, cache, pos):
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)

    def body(h, xs):
        lp, ck, cv, xk, xv = xs
        h, (nk, nv) = _block_fwd(lp, cfg, h, positions, 0, cfg.rope_theta,
                                 kv_cache=(ck, cv), pos=pos, memory_kv=(xk, xv))
        return h, (nk, nv)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    x = L.norm_apply(params["final_norm"], x, cfg.norm_eps, cfg.norm)
    return x, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"]}


# =====================================================================
# public dispatch
# =====================================================================
def init_params(key, cfg: ModelConfig) -> Params:
    fam = cfg.arch_type
    if fam in ("dense", "moe"):
        return _init_dense(key, cfg)
    if fam == "hybrid":
        return _init_hybrid(key, cfg)
    if fam == "xlstm":
        return _init_xlstm(key, cfg)
    if fam == "encdec":
        return _init_encdec(key, cfg)
    raise ValueError(f"unknown arch_type {fam!r}")


def forward(params: Params, cfg: ModelConfig, batch) -> jax.Array:
    fam = cfg.arch_type
    if fam == "encdec":
        return _encdec_forward(params, cfg, batch)
    x, positions = embed_inputs(params, cfg, batch)
    if fam in ("dense", "moe"):
        return _dense_forward(params, cfg, x, positions)
    if fam == "hybrid":
        return _hybrid_forward(params, cfg, x, positions)
    if fam == "xlstm":
        return _xlstm_run(params, cfg, x)
    raise ValueError(fam)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> Any:
    dtype = dtype or cfg.param_dtype
    fam = cfg.arch_type
    if fam in ("dense", "moe"):
        return _dense_cache(cfg, batch, max_seq, dtype)
    if fam == "hybrid":
        return _hybrid_cache(cfg, batch, max_seq, dtype)
    if fam == "xlstm":
        return _xlstm_cache(cfg, batch, max_seq, dtype)
    if fam == "encdec":
        return _encdec_cache(cfg, batch, max_seq, dtype)
    raise ValueError(fam)


def prefill(params: Params, cfg: ModelConfig, batch, max_seq: int):
    fam = cfg.arch_type
    if fam == "encdec":
        return _encdec_prefill(params, cfg, batch, max_seq)
    x, positions = embed_inputs(params, cfg, batch)
    if fam in ("dense", "moe"):
        return _dense_prefill(params, cfg, x, positions, max_seq)
    if fam == "hybrid":
        cache = _hybrid_cache(cfg, x.shape[0], max_seq, cfg.param_dtype)
        return _hybrid_step(params, cfg, x, positions, cache, None, max_seq)
    if fam == "xlstm":
        cache = _xlstm_cache(cfg, x.shape[0], max_seq, cfg.param_dtype)
        return _xlstm_run(params, cfg, x, states=cache)
    raise ValueError(fam)


def decode_step(params: Params, cfg: ModelConfig, token, cache, pos):
    """token: (B,) int32; pos: traced scalar position. → ((B,1,D), cache)."""
    x = jnp.take(params["embed"], token[:, None], axis=0)
    fam = cfg.arch_type
    if fam in ("dense", "moe"):
        return _dense_decode(params, cfg, x, cache, pos)
    if fam == "hybrid":
        b = x.shape[0]
        positions = jnp.full((b, 1), pos, jnp.int32)
        return _hybrid_step(params, cfg, x, positions, cache, pos,
                            cache["attn"]["k"].shape[3] if "attn" in cache else 0)
    if fam == "xlstm":
        return _xlstm_run(params, cfg, x, states=cache)
    if fam == "encdec":
        return _encdec_decode(params, cfg, x, cache, pos)
    raise ValueError(fam)
