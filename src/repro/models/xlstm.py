"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory, exp gating).

mLSTM is linear-attention-like and has no hidden-to-gate recurrence, so its
training/prefill form here is a ``lax.scan`` over time with stabilized
exponential gating (chunkwise-parallelization is a recorded §Perf candidate);
decode is the same single-step recurrence. sLSTM has true recurrent gate
connections (R · h_{t-1}) and is inherently sequential — scan over time.

Per the assigned config (d_ff=0) the blocks are projection-only: an up
projection (factor 2), the recurrent mixer, and a down projection; no separate
FFN stack. State layouts:
  mLSTM: C (B, H, dh, dh), n (B, H, dh), m (B, H)
  sLSTM: c, n, h (B, H, dh), m (B, H)
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L

UP_FACTOR = 2


def _inner(d_model, num_heads):
    d_inner = UP_FACTOR * d_model
    dh = d_inner // num_heads
    return d_inner, dh


# ------------------------------------------------------------------- mLSTM
def init_mlstm(key, d_model, num_heads, dtype):
    d_inner, dh = _inner(d_model, num_heads)
    ks = jax.random.split(key, 4)
    return {
        "up": L.dense_init(ks[0], d_model, 2 * d_inner, dtype),   # [x_in, gate]
        "qkv": L.dense_init(ks[1], d_inner, 3 * d_inner, dtype),
        "if_proj": L.dense_init(ks[2], d_inner, 2 * num_heads, dtype),
        "down": L.dense_init(ks[3], d_inner, d_model, dtype),
    }


def _mlstm_step(carry, inp):
    c_mat, n_vec, m = carry                     # (B,H,dh,dh), (B,H,dh), (B,H)
    q, k, v, i_raw, f_raw = inp                 # (B,H,dh) ×3, (B,H) ×2
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c_mat = f_g[..., None, None] * c_mat + i_g[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n_vec = f_g[..., None] * n_vec + i_g[..., None] * k
    denom = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", n_vec, q)), jnp.exp(-m_new)
    )
    h = jnp.einsum("bhd,bhde->bhe", q, c_mat) / denom[..., None]
    return (c_mat, n_vec, m_new), h


def mlstm_apply(p, x, num_heads, *, init_state=None, return_state=False):
    """x: (B, S, D) → (B, S, D)."""
    b, s, d_model = x.shape
    d_inner, dh = _inner(d_model, num_heads)
    up = x @ p["up"]
    x_in, gate = up[..., :d_inner], up[..., d_inner:]
    qkv = (x_in @ p["qkv"]).astype(jnp.float32)
    q, k, v = jnp.split(qkv.reshape(b, s, 3, num_heads, dh), 3, axis=2)
    q, k, v = (a[:, :, 0].transpose(1, 0, 2, 3) for a in (q, k, v))  # (S,B,H,dh)
    k = k / math.sqrt(dh)
    if_g = (x_in @ p["if_proj"]).astype(jnp.float32).reshape(b, s, 2, num_heads)
    i_raw = if_g[:, :, 0].transpose(1, 0, 2)                         # (S,B,H)
    f_raw = if_g[:, :, 1].transpose(1, 0, 2)

    if init_state is None:
        state = (
            jnp.zeros((b, num_heads, dh, dh), jnp.float32),
            jnp.zeros((b, num_heads, dh), jnp.float32),
            jnp.full((b, num_heads), -1e30, jnp.float32),
        )
    else:
        state = (init_state["c"], init_state["n"], init_state["m"])
    state, hs = jax.lax.scan(_mlstm_step, state, (q, k, v, i_raw, f_raw))
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, d_inner)
    out = (h.astype(x.dtype) * jax.nn.silu(gate)) @ p["down"]
    if return_state:
        return out, {"c": state[0], "n": state[1], "m": state[2]}
    return out


def mlstm_decode(p, x, state, num_heads):
    out, new_state = mlstm_apply(
        p, x, num_heads, init_state=state, return_state=True
    )
    return out, new_state


def init_mlstm_state(batch, d_model, num_heads):
    d_inner, dh = _inner(d_model, num_heads)
    return {
        "c": jnp.zeros((batch, num_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, num_heads, dh), jnp.float32),
        "m": jnp.full((batch, num_heads), -1e30, jnp.float32),
    }


# ------------------------------------------------------------------- sLSTM
def init_slstm(key, d_model, num_heads, dtype):
    d_inner, dh = _inner(d_model, num_heads)
    ks = jax.random.split(key, 4)
    return {
        "up": L.dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "wx": L.dense_init(ks[1], d_inner, 4 * d_inner, dtype),      # z,i,f,o
        # block-diagonal (per-head) recurrent kernel for the 4 gates
        "r": (jax.random.normal(ks[2], (4, num_heads, dh, dh), jnp.float32)
              / math.sqrt(dh)).astype(dtype),
        "down": L.dense_init(ks[3], d_inner, d_model, dtype),
    }


def _slstm_step(p_r, carry, inp, num_heads, dh):
    c, n, h, m = carry                               # (B,H,dh)×3, (B,H)
    wx_t = inp                                        # (B, 4, H, dh)
    rec = jnp.einsum("ghde,bhd->bghe", p_r.astype(jnp.float32), h)
    pre = wx_t + rec                                  # (B,4,H,dh)
    z = jnp.tanh(pre[:, 0])
    i_raw = pre[:, 1].mean(-1)                        # scalar gates per head
    f_raw = pre[:, 2].mean(-1)
    o = jax.nn.sigmoid(pre[:, 3])
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c = f_g[..., None] * c + i_g[..., None] * z
    n = f_g[..., None] * n + i_g[..., None]
    h_new = o * c / jnp.maximum(n, 1e-6)
    return (c, n, h_new, m_new), h_new


def slstm_apply(p, x, num_heads, *, init_state=None, return_state=False):
    b, s, d_model = x.shape
    d_inner, dh = _inner(d_model, num_heads)
    up = x @ p["up"]
    x_in, gate = up[..., :d_inner], up[..., d_inner:]
    wx = (x_in @ p["wx"]).astype(jnp.float32).reshape(b, s, 4, num_heads, dh)
    wx = wx.transpose(1, 0, 2, 3, 4)                  # (S,B,4,H,dh)
    if init_state is None:
        zeros = jnp.zeros((b, num_heads, dh), jnp.float32)
        state = (zeros, zeros, zeros, jnp.full((b, num_heads), -1e30, jnp.float32))
    else:
        state = (init_state["c"], init_state["n"], init_state["h"], init_state["m"])
    step = lambda carry, inp: _slstm_step(p["r"], carry, inp, num_heads, dh)
    state, hs = jax.lax.scan(step, state, wx)
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, d_inner)
    out = (h.astype(x.dtype) * jax.nn.silu(gate)) @ p["down"]
    if return_state:
        return out, {"c": state[0], "n": state[1], "h": state[2], "m": state[3]}
    return out


def slstm_decode(p, x, state, num_heads):
    return slstm_apply(p, x, num_heads, init_state=state, return_state=True)


def init_slstm_state(batch, d_model, num_heads):
    d_inner, dh = _inner(d_model, num_heads)
    zeros = jnp.zeros((batch, num_heads, dh), jnp.float32)
    return {"c": zeros, "n": zeros, "h": zeros,
            "m": jnp.full((batch, num_heads), -1e30, jnp.float32)}
