"""Optimizers & schedules for the gradient baselines and the LM driver.

AFL itself is gradient-free (that is the paper's point) — this package exists
for the comparison arms: head-SGD federated baselines (paper Supp. E) and the
generic backbone pre-training driver (WSD schedule, per minicpm
[arXiv:2404.06395], the schedule its config cites).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["sgd", "momentum_sgd", "wsd_schedule", "cosine_schedule"]


def sgd(lr: float) -> Callable:
    """params, grads → params. Plain SGD (paper Supp. E uses lr=0.05)."""

    def update(params, grads, lr_t=lr):
        return jax.tree.map(lambda p, g: p - lr_t * g.astype(p.dtype),
                            params, grads)

    return update


def momentum_sgd(lr: float, beta: float = 0.9):
    """Returns (init_fn, update_fn) with velocity state."""

    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(params, grads, vel, lr_t=lr):
        vel = jax.tree.map(lambda v, g: beta * v + g.astype(v.dtype), vel, grads)
        params = jax.tree.map(lambda p, v: p - lr_t * v.astype(p.dtype),
                              params, vel)
        return params, vel

    return init, update


def wsd_schedule(base_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1, floor: float = 0.0) -> Callable:
    """Warmup-Stable-Decay (minicpm): linear warmup → flat → 1-sqrt decay."""
    decay_steps = max(int(total * decay_frac), 1)
    stable_end = total - decay_steps

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        frac = jnp.clip((step - stable_end) / decay_steps, 0.0, 1.0)
        decay = base_lr * (1.0 - (1.0 - floor) * jnp.sqrt(frac))
        return jnp.where(step < stable_end, warm, decay)

    return lr


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)

    return lr
