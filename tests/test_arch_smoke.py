"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(2-ish layers, d_model ≤ 512, ≤ 4 experts), run one forward pass AND one
analytic train step on CPU, assert output shapes and absence of NaNs. Also
covers one decode step per arch (serve path) and the gradient-baseline step.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.core.streaming import init_state
from repro.launch import steps as St
from repro.launch.inputs import sample_batch
from repro.models import transformer as T

ARCHS = list_archs()


@pytest.fixture(scope="module")
def setups():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            params = T.init_params(jax.random.key(0), cfg)
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, setups):
    cfg, params = setups(arch)
    b, s = 2, 32
    batch = sample_batch(cfg, b, s)
    hidden = T.forward(params, cfg, batch)
    total = s if not cfg.prefix_tokens else (s - cfg.prefix_tokens) + cfg.prefix_tokens
    assert hidden.shape == (b, total, cfg.d_model)
    assert bool(jnp.isfinite(hidden).all()), "NaN/Inf in forward output"


@pytest.mark.parametrize("arch", ARCHS)
def test_analytic_train_step(arch, setups):
    """One paper-style local training step: forward + Gram update."""
    cfg, params = setups(arch)
    b, s = 2, 32
    batch = sample_batch(cfg, b, s)
    step = jax.jit(St.make_analytic_train_step(cfg))
    state = step(params, init_state(cfg.d_model, cfg.num_classes), batch)
    assert state.gram.shape == (cfg.d_model, cfg.d_model)
    assert state.moment.shape == (cfg.d_model, cfg.num_classes)
    assert int(state.count) == b
    for leaf in (state.gram, state.moment):
        assert bool(jnp.isfinite(leaf).all())
    # Gram must be symmetric PSD by construction
    assert bool(jnp.allclose(state.gram, state.gram.T, atol=1e-4))


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_decode_step(arch, setups):
    cfg, params = setups(arch)
    b, s, max_seq = 2, 16, 24
    batch = sample_batch(cfg, b, s, with_labels=False)
    logits, cache = St.make_prefill_step(cfg, max_seq)(params, batch)
    assert logits.shape == (b, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.asarray(s if not cfg.prefix_tokens else s, jnp.int32)
    logits2, cache = St.make_serve_step(cfg)(params, cache, tok, pos)
    assert logits2.shape == (b, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ["minicpm_2b", "grok1_314b", "zamba2_7b", "xlstm_350m"])
def test_gradient_baseline_step(arch, setups):
    """FedAvg-style head-SGD step decreases loss on repeated application."""
    cfg, params = setups(arch)
    batch = sample_batch(cfg, 4, 16)
    step = jax.jit(St.make_fedavg_train_step(cfg, lr=0.5))
    head = jnp.zeros((cfg.d_model, cfg.num_classes), jnp.float32)
    losses = []
    for _ in range(5):
        head, loss = step(params, head, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_full_configs_match_assignment():
    """The FULL configs carry the exact published numbers."""
    expect = {
        "minicpm_2b": (40, 2304, 36, 36, 5760, 122753),
        "qwen3_32b": (64, 5120, 64, 8, 25600, 151936),
        "gemma3_12b": (48, 3840, 16, 8, 15360, 262144),
        "grok1_314b": (64, 6144, 48, 8, 32768, 131072),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
        "llava_next_mistral_7b": (32, 4096, 32, 8, 14336, 32000),
        "granite_moe_3b_a800m": (32, 1536, 24, 8, 512, 49155),
        "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206),
        "nemotron4_15b": (32, 6144, 48, 8, 24576, 256000),
        "xlstm_350m": (24, 1024, 4, 4, 0, 50304),
    }
    for arch, (nl, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == (nl, d, h, kv, ff, v), (arch, got)
    grok = get_config("grok1_314b")
    assert grok.moe.num_experts == 8 and grok.moe.top_k == 2
    granite = get_config("granite_moe_3b_a800m")
    assert granite.moe.num_experts == 40 and granite.moe.top_k == 8
    assert get_config("zamba2_7b").ssm.d_state == 64
    assert get_config("qwen3_32b").qk_norm
    assert get_config("gemma3_12b").global_every == 6  # 5 local : 1 global
    assert get_config("nemotron4_15b").activation == "relu2"
    assert get_config("seamless_m4t_medium").encoder_layers == 12


def test_reduced_configs_within_limits():
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        assert cfg.num_layers <= 4
        assert cfg.d_model <= 512
        if cfg.moe:
            assert cfg.moe.num_experts <= 4
