"""AsyncAFLServer: the event-loop serving path must be *numerically
invisible* — concurrent submit/solve interleavings, rank-updated factors,
and deferred refactors all land on exactly the weights the synchronous
server produces from the same reports."""

import asyncio
import dataclasses

import numpy as np
import pytest

from repro.core import analytic as al
from repro.fl import AFLServer, AsyncAFLServer, make_report, masked_reports

D, C, GAMMA = 24, 5, 1.0


def _reports(n_clients=10, rows_each=6, seed=0):
    """Small per-client batches (rows ≪ d) so roots ride along."""
    rng = np.random.default_rng(seed)
    n = n_clients * rows_each
    x = rng.standard_normal((n, D))
    y = np.eye(C)[rng.integers(0, C, n)]
    reps = [make_report(k, x[k * rows_each:(k + 1) * rows_each],
                        y[k * rows_each:(k + 1) * rows_each], GAMMA)
            for k in range(n_clients)]
    return x, y, reps


def test_concurrent_interleaving_matches_sequential():
    """Producers submitting concurrently with a consumer polling solve():
    every intermediate poll returns finite weights, and once drained the
    async weights == the sequential AFLServer's on the same reports."""
    x, y, reps = _reports(n_clients=12)

    async def scenario():
        # explicit budget: at this tiny d the default (perf crossover d//16)
        # would refuse every fold and the update path would go untested
        async with AsyncAFLServer(D, C, gamma=GAMMA,
                                  update_rank_budget=6) as srv:
            async def producer(chunk):
                for r in chunk:
                    await srv.submit(r)
                    await asyncio.sleep(0)      # interleave with the consumer

            async def consumer():
                polls = []
                while srv.num_clients < 12:
                    if srv.num_clients:
                        polls.append(await srv.solve())
                    await asyncio.sleep(0)
                return polls

            _, _, polls = await asyncio.gather(
                producer(reps[:6]), producer(reps[6:]), consumer())
            await srv.join()
            return await srv.solve(), polls, srv.updates

    w_async, polls, updates = asyncio.run(scenario())
    assert all(np.all(np.isfinite(p)) for p in polls)
    assert updates > 0                        # the rank-update path ran

    seq = AFLServer(D, C, gamma=GAMMA)
    seq.submit_many(reps)
    np.testing.assert_allclose(w_async, seq.solve(), rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(w_async, al.ridge_solve(x, y, 0.0),
                               rtol=1e-7, atol=1e-9)


def test_masked_reports_bit_exact_vs_sync_path():
    """A masked cohort through the async path aggregates to EXACTLY the
    sync server's statistics (same reports, same order ⇒ same float adds),
    and both match the unmasked joint solution."""
    x, y, reps = _reports(n_clients=8, seed=3)
    masked = masked_reports(reps, seed=7)
    assert all(r.root is None for r in masked)   # masking kills the roots

    async def scenario():
        async with AsyncAFLServer(D, C, gamma=GAMMA) as srv:
            await srv.submit_many(masked)
            await srv.join()
            return srv.server._stats, await srv.solve()

    stats_async, w_async = asyncio.run(scenario())
    sync = AFLServer(D, C, gamma=GAMMA)
    sync.submit_many(masked)
    np.testing.assert_array_equal(stats_async.gram, sync._stats.gram)
    np.testing.assert_array_equal(stats_async.moment, sync._stats.moment)
    np.testing.assert_array_equal(w_async, sync.solve())
    np.testing.assert_allclose(w_async, al.ridge_solve(x, y, 0.0),
                               rtol=1e-6, atol=1e-7)


def test_deferred_refactor_policy_stays_exact():
    """A tiny refactor_rank forces frequent deferrals; correctness must not
    depend on which path each arrival took."""
    x, y, reps = _reports(n_clients=12, seed=5)

    async def scenario():
        async with AsyncAFLServer(D, C, gamma=GAMMA, update_rank_budget=6,
                                  refactor_rank=8) as srv:
            for r in reps:
                await srv.submit(r)
                await srv.join()
                await srv.solve()             # keep a live factor in play
            return await srv.solve(), srv.updates, srv.deferred_refactors

    w, updates, deferred = asyncio.run(scenario())
    assert deferred > 0 and updates > 0       # both paths exercised
    np.testing.assert_allclose(w, al.ridge_solve(x, y, 0.0),
                               rtol=1e-7, atol=1e-9)


def test_solve_multi_gamma_served_concurrently():
    x, y, reps = _reports(n_clients=6, seed=8)

    async def scenario():
        async with AsyncAFLServer(D, C, gamma=GAMMA) as srv:
            await srv.submit_many(reps)
            await srv.join()
            sweep, w0 = await asyncio.gather(
                srv.solve_multi_gamma([0.0, 0.1, 1.0]), srv.solve())
            return sweep, w0

    sweep, w0 = asyncio.run(scenario())
    np.testing.assert_allclose(sweep[0], w0, rtol=1e-7, atol=1e-8)

    sync = AFLServer(D, C, gamma=GAMMA)
    sync.submit_many(reps)
    for w_a, w_s in zip(sweep, sync.solve_multi_gamma([0.0, 0.1, 1.0])):
        np.testing.assert_allclose(w_a, w_s, rtol=1e-10, atol=1e-12)


def test_bad_uploads_rejected_without_killing_worker():
    """``enqueue`` is fire-and-forget: rejections land in ``rejected`` and
    the worker survives; an awaited ``submit`` raises like the sync server,
    also without killing the loop."""
    _, _, reps = _reports(n_clients=4, seed=9)

    async def scenario():
        async with AsyncAFLServer(D, C, gamma=GAMMA) as srv:
            await srv.submit_many(reps)
            await srv.enqueue(reps[0])                      # duplicate id
            await srv.enqueue(dataclasses.replace(reps[1], client_id=77,
                                                  gamma=2.0))  # γ mismatch
            await srv.enqueue(dataclasses.replace(
                reps[2], client_id=[78]))   # malformed: unhashable id
            await srv.join()
            with pytest.raises(ValueError):
                await srv.submit(reps[0])   # awaited duplicate raises
            return srv.num_clients, srv.rejected, await srv.solve()

    n, rejected, w = asyncio.run(scenario())
    assert n == 4
    assert len(rejected) == 4
    assert np.all(np.isfinite(w))


def test_submit_returns_the_sync_fold_outcome():
    """API-drift regression: ``await async.submit(r)`` resolves to exactly
    the bool the sync server returns for the same arrival sequence (with the
    deferred-refactor policy opened wide so the paths are comparable)."""
    _, _, reps = _reports(n_clients=10, seed=11)
    masked = masked_reports(reps[5:], seed=1)   # root=None → cache kills
    sequence = reps[:5] + masked

    sync = AFLServer(D, C, gamma=GAMMA, update_rank_budget=6)
    sync_outcomes = []
    for r in sequence:
        sync_outcomes.append(sync.submit(r))
        sync.solve()                            # keep a live factor in play

    async def scenario():
        async with AsyncAFLServer(D, C, gamma=GAMMA, update_rank_budget=6,
                                  refactor_rank=10**6,
                                  error_budget=1.0) as srv:
            outcomes = []
            for r in sequence:
                outcomes.append(await srv.submit(r))
                await srv.solve()
            return outcomes

    async_outcomes = asyncio.run(scenario())
    assert async_outcomes == sync_outcomes
    assert True in async_outcomes and False in async_outcomes


def test_solve_before_any_arrival_raises():
    async def scenario():
        async with AsyncAFLServer(D, C, gamma=GAMMA) as srv:
            with pytest.raises(ValueError):
                await srv.solve()

    asyncio.run(scenario())
