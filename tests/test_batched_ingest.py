"""Batched ingest: micro-batch fold, pipelined uploads, read coalescing.

Three claims, each load-bearing for the ingest fast path:

  * **Micro-batch fold ≡ sequential submits, bit-for-bit at f64.** The
    batched paths (:meth:`AFLServer.submit_batch`, the
    :class:`AsyncAFLServer` worker draining its queue) must perform the
    exact sequential operation schedule — grouped Householder sweep,
    strict-left-fold merge — so a federation cannot tell whether its
    uploads arrived one at a time or sixty-four at once. Pinned here
    deterministically for the hard edges (mid-batch rejection, rank-budget
    overflow, empty and rank-0 roots) and, when ``hypothesis`` is installed
    (requirements-dev.txt), over randomized batch schedules.
  * **Pipelined ``submit_many`` / bounded rejection history.** The async
    uploader enqueues the whole iterable before awaiting, preserves
    stop-at-first-rejection, and the rejected log is a bounded deque with a
    drop counter instead of an unbounded list.
  * **Single-flight read coalescing.** Concurrent identical reads share ONE
    computation and ONE encoded response; repeats within an epoch answer
    from cache; any epoch bump invalidates; errors propagate to every
    waiter and are never cached.
"""

import asyncio
import threading

import numpy as np
import pytest

from repro.core.engine import AnalyticEngine
from repro.fl import (AFLServer, AsyncAFLServer, ClientReport,
                      FederationService, InProcTransport, RemoteCoordinator,
                      SubmitAborted, make_report)
from repro.fl import errors as E
from repro.fl.service import frame_reports, pack_message, unpack_message

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="property tests need hypothesis (requirements-dev.txt)")

DIM, C, GAMMA = 16, 3, 1.0


def _report(client_id, rows=4, seed=None, gamma=GAMMA, root=True):
    """One upload; ``rows=0`` exercises the empty (rank-0) root edge."""
    rng = np.random.default_rng(client_id if seed is None else seed)
    x = rng.standard_normal((rows, DIM))
    y = np.eye(C)[rng.integers(0, C, rows)] if rows else np.zeros((0, C))
    rep = make_report(client_id, x, y, gamma)
    if not root:
        rep = ClientReport(rep.client_id, rep.gram, rep.moment,
                           rep.gamma, rep.count, None)
    return rep


def _assert_same_state(a: AFLServer, b: AFLServer):
    """Bit-for-bit: aggregate, identity sets, caches, and solved heads."""
    np.testing.assert_array_equal(np.asarray(a._stats.gram),
                                  np.asarray(b._stats.gram))
    np.testing.assert_array_equal(np.asarray(a._stats.moment),
                                  np.asarray(b._stats.moment))
    assert float(a._stats.count) == float(b._stats.count)
    assert float(a._stats.clients) == float(b._stats.clients)
    assert a._seen == b._seen
    assert a.version == b.version
    assert set(a._factor_cache) == set(b._factor_cache)
    for key in a._factor_cache:
        ha, hb = a._factor_cache[key].handle, b._factor_cache[key].handle
        np.testing.assert_array_equal(np.asarray(ha), np.asarray(hb))
    assert (a._sweep_cache is None) == (b._sweep_cache is None)
    if a._sweep_cache is not None:
        np.testing.assert_array_equal(a._sweep_cache.u, b._sweep_cache.u)
    np.testing.assert_array_equal(a.solve(0.5), b.solve(0.5))
    for wa, wb in zip(a.solve_multi_gamma([0.0, GAMMA]),
                      b.solve_multi_gamma([0.0, GAMMA])):
        np.testing.assert_array_equal(np.asarray(wa), np.asarray(wb))


def _seeded_pair(n=6, warm=True):
    """Two identical servers, optionally with factor + sweep caches warm
    (so the batch paths exercise the incremental-update machinery, not just
    the cold fold)."""
    a, b = AFLServer(DIM, C, gamma=GAMMA), AFLServer(DIM, C, gamma=GAMMA)
    for i in range(n):
        rep = _report(1000 + i, rows=6)
        a.submit(rep)
        b.submit(rep)
    if warm:
        for srv in (a, b):
            srv.solve(0.5)
            srv.solve_multi_gamma([0.0, GAMMA])
    return a, b


def _sequential_oracle(srv: AFLServer, reports):
    """Per-report submits, collecting the exact flag-or-exception per slot —
    the reference schedule submit_batch must reproduce bit-for-bit."""
    out = []
    for rep in reports:
        try:
            out.append(srv.submit(rep))
        except Exception as exc:                       # noqa: BLE001
            out.append(exc)
    return out


class TestSubmitBatchBitForBit:
    def test_plain_batch_matches_sequential(self):
        a, b = _seeded_pair()
        reports = [_report(i, rows=1 + (i % 3)) for i in range(8)]
        flags = a.submit_batch(reports)
        ref = _sequential_oracle(b, reports)
        assert flags == ref
        _assert_same_state(a, b)

    def test_mid_batch_rejections_reject_alone(self):
        """A duplicate id, a γ mismatch, and an intra-batch duplicate each
        reject their own slot; everything around them folds as if the bad
        reports were never sent."""
        a, b = _seeded_pair()
        good = _report(7)
        reports = [_report(1, rows=2),
                   _report(1000, rows=3),              # pre-seeded id
                   _report(5, gamma=GAMMA + 1.0),      # γ mismatch
                   good,
                   _report(good.client_id, seed=99),   # intra-batch dup
                   _report(9, rows=2)]
        flags = a.submit_batch(reports)
        ref = _sequential_oracle(b, reports)
        assert [type(f) for f in flags] == [type(r) for r in ref]
        assert flags[1].__class__ is E.DuplicateClient
        assert flags[2].__class__ is E.GammaMismatch
        assert flags[4].__class__ is E.DuplicateClient
        _assert_same_state(a, b)

    def test_rank_budget_overflow_and_rootless_reports(self):
        """Roots past the update budget (and absent roots) kill / bypass the
        incremental caches exactly as sequential submits do."""
        a, b = _seeded_pair()
        reports = [_report(1, rows=1),
                   _report(2, rows=6),                 # > d//16 budget
                   _report(3, rows=1, root=False),     # no root → refactor
                   _report(4, rows=1)]
        flags = a.submit_batch(reports)
        assert flags == _sequential_oracle(b, reports)
        _assert_same_state(a, b)

    def test_empty_batch_and_empty_roots(self):
        a, b = _seeded_pair()
        assert a.submit_batch([]) == []
        _assert_same_state(a, b)
        reports = [_report(1, rows=0), _report(2, rows=2)]
        flags = a.submit_batch(reports)
        assert flags == _sequential_oracle(b, reports)
        _assert_same_state(a, b)

    def test_cold_server_batch(self):
        """First-ever contact arriving as a batch: the seeding refactor path
        (rank-deficient pinv fallback included) matches sequential."""
        a, b = AFLServer(DIM, C, gamma=GAMMA), AFLServer(DIM, C, gamma=GAMMA)
        reports = [_report(i, rows=3) for i in range(4)]   # 12 < d rows
        flags = a.submit_batch(reports)
        assert flags == _sequential_oracle(b, reports)
        _assert_same_state(a, b)


if HAVE_HYPOTHESIS:
    class TestSubmitBatchProperty:
        """Randomized schedules: any batch split of any report sequence —
        duplicates, γ mismatches, rank-0 and missing roots included —
        leaves the server bit-for-bit where sequential submits leave its
        twin."""

        @settings(max_examples=25, deadline=None)
        @given(st.data())
        def test_batched_equals_sequential(self, data):
            specs = data.draw(st.lists(
                st.tuples(st.integers(0, 5),       # client id (collisions!)
                          st.integers(0, 6),       # rows (0 = empty root)
                          st.booleans(),           # carry root?
                          st.booleans()),          # γ mismatch?
                min_size=1, max_size=10))
            reports = [
                _report(cid, rows=rows, seed=i, root=root,
                        gamma=GAMMA + (0.5 if bad_gamma else 0.0))
                for i, (cid, rows, root, bad_gamma) in enumerate(specs)]
            a, b = _seeded_pair(n=4)
            # arbitrary batch split of the same sequence
            cut = data.draw(st.integers(0, len(reports)))
            flags = (a.submit_batch(reports[:cut])
                     + a.submit_batch(reports[cut:]))
            assert flags == _sequential_oracle(b, reports)
            _assert_same_state(a, b)
else:
    class TestSubmitBatchProperty:
        @needs_hypothesis
        def test_batched_equals_sequential(self):
            """Placeholder so the skip is visible in the test report."""


class TestAsyncBatchedFold:
    def test_worker_folds_batches_bit_for_bit(self):
        """Reports pipelined through the async queue fold in real batches
        (batch counters prove it) and the end state is bit-for-bit the
        sequential sync fold."""
        oracle = AFLServer(DIM, C, gamma=GAMMA)
        reports = [_report(i, rows=2) for i in range(20)]
        for rep in reports:
            oracle.submit(rep)

        async def body():
            async with AsyncAFLServer(DIM, C, gamma=GAMMA,
                                      batch_max=8) as srv:
                await srv.submit_many(reports)
                w = await srv.solve(0.5)
                return srv, w

        srv, w = asyncio.run(body())
        np.testing.assert_array_equal(w, oracle.solve(0.5))
        _assert_same_state(srv.server, oracle)
        assert srv.batches_folded >= 1
        assert 1 <= srv.last_batch <= 8
        # pipelining produced real multi-report folds, not 20 singletons
        assert srv.batches_folded < len(reports)

    def test_submit_many_stops_at_first_rejection(self):
        """Pipelined submit_many preserves stop-at-first-rejection: the bad
        report's error surfaces, reports after it in the SAME call are
        aborted (not folded), state matches the sync server that stopped at
        the same place."""
        oracle = AFLServer(DIM, C, gamma=GAMMA)
        good = [_report(i) for i in range(3)]
        bad = _report(1, seed=77)                      # duplicate of good[1]
        tail = [_report(10), _report(11)]
        for rep in good:
            oracle.submit(rep)

        async def body():
            async with AsyncAFLServer(DIM, C, gamma=GAMMA) as srv:
                with pytest.raises(E.DuplicateClient):
                    await srv.submit_many(good + [bad] + tail)
                await srv.join()
                return srv

        srv = asyncio.run(body())
        _assert_same_state(srv.server, oracle)
        assert srv.server.num_clients == len(good)

    def test_aborted_reports_are_retryable(self):
        """Reports behind a rejection are aborted (SubmitAborted), never
        half-folded — retrying them afterwards succeeds."""
        async def body():
            async with AsyncAFLServer(DIM, C, gamma=GAMMA) as srv:
                await srv.submit(_report(0))
                with pytest.raises(E.DuplicateClient):
                    await srv.submit_many([_report(0, seed=5), _report(1)])
                assert srv.server.num_clients == 1     # tail NOT folded
                assert isinstance(await srv.submit(_report(1)), bool)
                return srv.server.num_clients

        assert asyncio.run(body()) == 2
        assert issubclass(SubmitAborted, RuntimeError)

    def test_rejected_deque_is_bounded(self):
        async def body():
            async with AsyncAFLServer(DIM, C, gamma=GAMMA,
                                      rejected_max=3) as srv:
                await srv.submit(_report(0))
                for seed in range(5):
                    with pytest.raises(E.DuplicateClient):
                        await srv.submit(_report(0, seed=100 + seed))
                return len(srv.rejected), srv.rejected_dropped

        kept, dropped = asyncio.run(body())
        assert kept == 3
        assert dropped == 2

    def test_enqueue_many_respects_watermark(self):
        async def body():
            srv = AsyncAFLServer(DIM, C, gamma=GAMMA, max_pending=4)
            # worker NOT started: the queue only fills
            admitted = await srv.enqueue_many(
                [_report(i) for i in range(10)])
            return admitted, srv.pending

        admitted, pending = asyncio.run(body())
        assert admitted == 4
        assert pending == 4


def _service_with(server, **kw):
    svc = FederationService(server, **kw)
    return svc, InProcTransport(svc)


class TestReadCoalescing:
    def _loaded_service(self):
        srv = AFLServer(DIM, C, gamma=GAMMA)
        svc, t = _service_with(srv)
        for i in range(4):
            svc.handle("submit", _report(i, rows=6).to_bytes())
        return srv, svc, t

    def test_concurrent_identical_reads_share_one_solve(self):
        srv, svc, t = self._loaded_service()
        fed = svc._fed("default")
        calls, release = [], threading.Event()
        orig = srv.solve

        def slow_solve(tg=0.0):
            calls.append(tg)
            release.wait(2.0)
            return orig(tg)

        srv.solve = slow_solve
        body = pack_message({"target_gamma": 0.5})
        outs = [None] * 8

        def go(i):
            outs[i] = t.request("solve", body)

        threads = [threading.Thread(target=go, args=(i,)) for i in range(8)]
        for th in threads:
            th.start()
        while not calls:                               # leader reached solve
            pass
        release.set()
        for th in threads:
            th.join()
        assert len(calls) == 1                         # ONE computation
        assert all(o == outs[0] for o in outs)         # ONE encoded response
        assert fed.coalesced_hits == 7
        # repeat within the same epoch: answered from cache
        assert t.request("solve", body) == outs[0]
        assert len(calls) == 1
        assert fed.coalesced_hits == 8

    def test_epoch_bump_invalidates(self):
        srv, svc, t = self._loaded_service()
        body = pack_message({"target_gamma": 0.5})
        first = t.request("solve", body)
        svc.handle("submit", _report(50, rows=6).to_bytes())
        second = t.request("solve", body)
        assert second != first
        header, arrays, _ = unpack_message(second)
        np.testing.assert_array_equal(arrays["weight"], srv.solve(0.5))

    def test_distinct_requests_do_not_coalesce(self):
        _, svc, t = self._loaded_service()
        fed = svc._fed("default")
        a = t.request("solve", pack_message({"target_gamma": 0.25}))
        b = t.request("solve", pack_message({"target_gamma": 0.75}))
        assert a != b
        assert fed.coalesced_hits == 0

    def test_etags_stay_correct_across_epoch_bump(self):
        """The weights route through coalescing: a cached fresh-ETag answer
        must never survive a submit."""
        srv, svc, t = self._loaded_service()
        rc = RemoteCoordinator(t)
        w1 = rc.weights(0.5)
        assert rc.weights(0.5, if_etag=w1.etag).etag == w1.etag
        svc.handle("submit", _report(60, rows=6).to_bytes())
        w2 = rc.weights(0.5, if_etag=w1.etag)
        assert w2.etag != w1.etag
        np.testing.assert_array_equal(w2.weight, srv.solve(0.5))

    def test_errors_propagate_and_are_not_cached(self):
        srv, svc, t = self._loaded_service()

        boom = [True]
        orig = srv.solve

        def flaky(tg=0.0):
            if boom[0]:
                raise RuntimeError("transient")
            return orig(tg)

        srv.solve = flaky
        body = pack_message({"target_gamma": 0.5})
        resp = t.request("solve", body)
        assert unpack_message(resp)[0]["ok"] is False
        boom[0] = False
        header, arrays, _ = unpack_message(t.request("solve", body))
        assert header["ok"] is True                    # error was not cached
        np.testing.assert_array_equal(arrays["weight"], orig(0.5))

    def test_describe_reports_ingest_and_coalescing_counters(self):
        async_reports = [_report(i) for i in range(6)]

        srv = AsyncAFLServer(DIM, C, gamma=GAMMA, batch_max=4)
        svc, t = _service_with(srv)
        frames = frame_reports(r.to_bytes() for r in async_reports)
        header, _, _ = unpack_message(t.request("submit_stream", frames))
        assert header["accepted"] == len(async_reports)
        deadline = 50
        while svc._fed("default").pending and deadline:
            import time
            time.sleep(0.05)
            deadline -= 1
        t.request("solve", pack_message({"target_gamma": 0.5}))
        t.request("solve", pack_message({"target_gamma": 0.5}))
        info, _, _ = unpack_message(t.request("describe"))
        assert info["coalesced_hits"] >= 1
        ingest = info["ingest"]
        assert ingest["batches_folded"] >= 1
        assert 1 <= ingest["last_batch"] <= 4
        assert ingest["batch_max"] == 4
        assert ingest["queue_depth"] == 0
        assert ingest["rejected_dropped"] == 0
        svc.close()


class TestStreamBatchedEnqueue:
    def _frames(self, reports):
        return frame_reports(r.to_bytes() for r in reports)

    def test_stream_admits_in_one_crossing_and_folds(self):
        srv = AsyncAFLServer(DIM, C, gamma=GAMMA, batch_max=16)
        svc, t = _service_with(srv)
        oracle = AFLServer(DIM, C, gamma=GAMMA)
        reports = [_report(i, rows=2) for i in range(12)]
        for r in reports:
            oracle.submit(r)
        header, _, _ = unpack_message(
            t.request("submit_stream", self._frames(reports)))
        assert header["accepted"] == len(reports)
        assert all(r["ok"] and r.get("queued") for r in header["results"])
        # drain, then compare bit-for-bit with the sequential oracle
        import time
        for _ in range(100):
            if not svc._fed("default").pending:
                break
            time.sleep(0.05)
        _assert_same_state(srv.server, oracle)
        svc.close()

    def test_stream_backpressure_shaves_the_tail(self):
        srv = AsyncAFLServer(DIM, C, gamma=GAMMA)
        svc, t = _service_with(srv, max_pending=3)
        # stall the worker so admitted frames stay queued
        reports = [_report(i) for i in range(6)]
        header, _, _ = unpack_message(
            t.request("submit_stream", self._frames(reports)))
        oks = [r["ok"] for r in header["results"]]
        assert oks == [True] * 3 + [False] * 3
        assert all(r["error"] == E.Backpressure.code
                   and r["retryable"] for r in header["results"][3:])
        assert header["accepted"] == 3
        svc.close()

    def test_intra_stream_duplicate_answers_idempotently(self):
        srv = AsyncAFLServer(DIM, C, gamma=GAMMA)
        svc, t = _service_with(srv)
        rep = _report(0)
        header, _, _ = unpack_message(
            t.request("submit_stream", self._frames([rep, rep])))
        assert header["results"][0] == {"ok": True, "queued": True}
        assert header["results"][1] == {"ok": True, "duplicate": True}
        assert header["accepted"] == 2
        svc.close()


class TestEngineBatchPrimitives:
    """The engine-layer primitives under the fold, pinned directly."""

    def test_merge_many_is_left_fold(self):
        eng = AnalyticEngine("numpy_f64", gamma=GAMMA)
        rng = np.random.default_rng(0)
        stats = eng.init(DIM, C)
        uploads = []
        for i in range(5):
            x = rng.standard_normal((4, DIM))
            y = np.eye(C)[rng.integers(0, C, 4)]
            uploads.append(eng.client_stats(x, y))
        seq = stats
        for u in uploads:
            seq = eng.merge(seq, u)
        batched = eng.merge_many(stats, uploads)
        np.testing.assert_array_equal(batched.gram, seq.gram)
        np.testing.assert_array_equal(batched.moment, seq.moment)
        assert float(batched.count) == float(seq.count)
        assert float(batched.clients) == float(seq.clients)

    def test_rank_update_many_matches_sequential(self):
        eng = AnalyticEngine("numpy_f64", gamma=GAMMA)
        rng = np.random.default_rng(1)
        x = rng.standard_normal((4 * DIM, DIM))
        stats = eng.client_stats(x, np.eye(C)[rng.integers(0, C, 4 * DIM)])
        f = eng.factor(stats, target_gamma=0.5)
        roots = [rng.standard_normal((k, DIM)) for k in (1, 3, 2)]
        seq = f
        for r in roots:
            seq = seq.rank_update(r)
        grouped = f.rank_update_many(roots)
        np.testing.assert_array_equal(np.asarray(grouped.handle),
                                      np.asarray(seq.handle))

    def test_rank_update_many_with_empty_groups(self):
        eng = AnalyticEngine("numpy_f64", gamma=GAMMA)
        rng = np.random.default_rng(2)
        x = rng.standard_normal((4 * DIM, DIM))
        stats = eng.client_stats(x, np.eye(C)[rng.integers(0, C, 4 * DIM)])
        f = eng.factor(stats, target_gamma=0.5)
        roots = [np.zeros((0, DIM)), rng.standard_normal((2, DIM)),
                 np.zeros((0, DIM))]
        grouped = f.rank_update_many(roots)
        seq = f.rank_update(roots[1])
        np.testing.assert_array_equal(np.asarray(grouped.handle),
                                      np.asarray(seq.handle))
