"""tools/bench_gate.py: baseline pass, regression fail, smoke tolerance,
failure propagation, suite isolation, and the metrics/modules fallback."""

import importlib.util
import json
import pathlib

import pytest

_GATE = pathlib.Path(__file__).resolve().parents[1] / "tools" / "bench_gate.py"
spec = importlib.util.spec_from_file_location("bench_gate", _GATE)
bench_gate = importlib.util.module_from_spec(spec)
spec.loader.exec_module(bench_gate)


def _entry(sha, suite="quick", metrics=None, modules=None, failures=(),
           env=None):
    return {
        "sha": sha, "suite": suite, "recorded_at": "2026-08-08T00:00:00",
        "env": env or {"JAX_ENABLE_X64": "1"},
        "modules": modules or {"solve_kernels_bench": 10.0},
        "metrics": metrics if metrics is not None else {},
        "failures": list(failures),
    }


def _write(tmp_path, entries):
    path = tmp_path / "BENCH_solve.json"
    path.write_text(json.dumps(entries))
    return str(path)


def test_first_entry_is_baseline(tmp_path, capsys):
    path = _write(tmp_path, [_entry("aaa", metrics={"m.bench.dist_s": 1.0})])
    assert bench_gate.main(["--path", path]) == 0
    assert "baseline" in capsys.readouterr().out


def test_same_sha_reruns_do_not_self_compare(tmp_path):
    path = _write(tmp_path, [
        _entry("aaa", metrics={"m.b.dist_s": 1.0}),
        _entry("aaa", metrics={"m.b.dist_s": 9.0}),
    ])
    assert bench_gate.main(["--path", path]) == 0


def test_regression_beyond_threshold_fails(tmp_path, capsys):
    path = _write(tmp_path, [
        _entry("aaa", metrics={"m.b.dist_s": 1.0}),
        _entry("bbb", metrics={"m.b.dist_s": 1.4}),
    ])
    assert bench_gate.main(["--path", path]) == 1
    assert "REGRESSED" in capsys.readouterr().out


def test_within_threshold_passes(tmp_path):
    path = _write(tmp_path, [
        _entry("aaa", metrics={"m.b.dist_s": 1.0}),
        _entry("bbb", metrics={"m.b.dist_s": 1.2}),
    ])
    assert bench_gate.main(["--path", path]) == 0


def test_smoke_tolerance_is_loose(tmp_path):
    entries = [
        _entry("aaa", metrics={"m.b.dist_s": 1.0}),
        _entry("bbb", metrics={"m.b.dist_s": 2.5}),
    ]
    path = _write(tmp_path, entries)
    assert bench_gate.main(["--path", path]) == 1          # 150% > 25%
    assert bench_gate.main(["--path", path, "--smoke"]) == 0   # < 200%
    entries[-1]["metrics"]["m.b.dist_s"] = 3.5
    path = _write(tmp_path, entries)
    assert bench_gate.main(["--path", path, "--smoke"]) == 1   # > 3x


def test_tiny_metrics_never_gate(tmp_path):
    """Sub-50ms walls are dispatch jitter, not kernel regressions."""
    path = _write(tmp_path, [
        _entry("aaa", metrics={"m.b.tiny_s": 0.004}),
        _entry("bbb", metrics={"m.b.tiny_s": 0.02}),
    ])
    assert bench_gate.main(["--path", path]) == 0


def test_recorded_failures_fail_the_gate(tmp_path):
    path = _write(tmp_path, [_entry("aaa", failures=["solve_kernels_bench"])])
    assert bench_gate.main(["--path", path]) == 1


def test_suites_are_isolated(tmp_path):
    """A full-suite entry never gates against a quick-suite ancestor."""
    path = _write(tmp_path, [
        _entry("aaa", suite="quick", metrics={"m.b.dist_s": 1.0}),
        _entry("bbb", suite="full", metrics={"m.b.dist_s": 50.0}),
    ])
    assert bench_gate.main(["--path", path]) == 0
    assert bench_gate.main(["--path", path, "--suite", "quick"]) == 0


def test_added_and_removed_metrics_do_not_gate(tmp_path, capsys):
    path = _write(tmp_path, [
        _entry("aaa", metrics={"m.b.gone_s": 1.0, "m.b.kept_s": 1.0}),
        _entry("bbb", metrics={"m.b.kept_s": 1.1, "m.b.new_s": 9.0}),
    ])
    assert bench_gate.main(["--path", path]) == 0
    out = capsys.readouterr().out
    assert "new" in out and "gone" in out


def test_modules_fallback_when_no_metrics(tmp_path):
    path = _write(tmp_path, [
        _entry("aaa", modules={"solve_kernels_bench": 10.0}),
        _entry("bbb", modules={"solve_kernels_bench": 20.0}),
    ])
    assert bench_gate.main(["--path", path]) == 1


def test_missing_file_raises(tmp_path):
    with pytest.raises(SystemExit):
        bench_gate.main(["--path", str(tmp_path / "nope.json")])
