"""One conformance suite for every AFL coordinator.

The :class:`repro.fl.api.Coordinator` protocol pins down the surface that
sync (:class:`AFLServer`), async (:class:`AsyncAFLServer`), sharded
(:class:`ShardedCoordinator`) and remote (:class:`RemoteCoordinator` over a
real loopback-HTTP :class:`FederationService`) implementations share: submit
fold outcomes, exact subset solves, the multi-γ sweep, the γ cross-validation
endpoint, versioned weights, and one checkpoint schema. Each test body is
written once against the protocol and parameterized over all four kinds;
async methods are awaited through a dispatch helper, so drift between the
implementations (the original ``AsyncAFLServer.submit → None`` bug) can no
longer hide — and because the remote kind runs the same matrix over actual
HTTP bytes, wire-equivalence is a permanent invariant, not a demo.

Also here: the canonical :class:`ClientReport` wire-format round-trip
(lossless f64, documented-tolerance compressed-f32 roots, corrupt-payload
rejection), the remote-vs-in-proc bit-for-bit f64 check, the f64-on-device
parity run (jax x64 backend vs numpy_f64 at 1e-12 through the AFLClient →
coordinator path, in a subprocess so x64 stays scoped), the 1e-6
sharded-vs-sync solve check on that same x64 path, and the K=1000
``fig2_clients`` run through the sharded backend.
"""

import asyncio
import contextlib
import functools
import inspect
import os
import subprocess
import sys
import tempfile
import textwrap

import numpy as np
import pytest

from repro.core import analytic as al
from repro.fl import (AFLClient, AFLServer, AsyncAFLServer, ClientReport,
                      Coordinator, FederationService, GammaSweep,
                      RemoteCoordinator, ShardedCoordinator, VersionedWeights,
                      generate_self_signed_cert, make_report, masked_reports,
                      serve_http, serve_mux, server_ssl_context)

DIM, C, GAMMA = 24, 5, 1.0
KINDS = ["sync", "async", "sharded", "remote", "mux"]
# device (f32) arithmetic for the in-process sharded solve; the 1e-6/1e-12
# claims are made on the x64 subprocess path below. The remote and mux kinds
# front an AFLServer over f64-lossless wire bytes, so they inherit sync
# tolerances — mux additionally rides TLS + bearer auth, proving the secured
# transport is still bit-identical.
TOL = {"sync": dict(rtol=1e-8, atol=1e-10),
       "async": dict(rtol=1e-8, atol=1e-10),
       "sharded": dict(rtol=1e-3, atol=2e-3),
       "remote": dict(rtol=1e-8, atol=1e-10),
       "mux": dict(rtol=1e-8, atol=1e-10)}

_MUX_TOKEN = "conformance-suite-token"


@functools.lru_cache(maxsize=1)
def _tls_files():
    """One self-signed keypair for the whole module (openssl run is ~1s)."""
    directory = tempfile.mkdtemp(prefix="afl-mux-tls-")
    cert, key = generate_self_signed_cert(directory)
    return str(cert), str(key)


def _reports(n_clients=10, rows_each=8, seed=0):
    rng = np.random.default_rng(seed)
    n = n_clients * rows_each
    x = rng.standard_normal((n, DIM))
    y = np.eye(C)[rng.integers(0, C, n)]
    reps = [make_report(k, x[k * rows_each:(k + 1) * rows_each],
                        y[k * rows_each:(k + 1) * rows_each], GAMMA)
            for k in range(n_clients)]
    return x, y, reps


async def _call(result):
    """Protocol dispatch: await coroutine results, pass sync ones through."""
    return await result if inspect.isawaitable(result) else result


@contextlib.asynccontextmanager
async def _serve_remote(server):
    """A RemoteCoordinator speaking REAL loopback-HTTP bytes to ``server``."""
    with serve_http(FederationService(server)) as http:
        coord = RemoteCoordinator(http.url)
        try:
            yield coord
        finally:
            coord.close()


@contextlib.asynccontextmanager
async def _serve_mux(server):
    """A RemoteCoordinator speaking the multiplexed binary framing over a
    REAL loopback TLS socket, bearer-token auth enforced per request — the
    hardest transport configuration runs the same matrix as everything
    else."""
    cert, key = _tls_files()
    service = FederationService(server, auth_token=_MUX_TOKEN)
    with serve_mux(service, ssl_context=server_ssl_context(cert, key)) as srv:
        coord = RemoteCoordinator(srv.url, auth_token=_MUX_TOKEN,
                                  cafile=cert)
        try:
            yield coord
        finally:
            coord.close()


@contextlib.asynccontextmanager
async def _make(kind, **kw):
    if kind == "sync":
        yield AFLServer(DIM, C, gamma=GAMMA, **kw)
    elif kind == "sharded":
        yield ShardedCoordinator(DIM, C, gamma=GAMMA)
    elif kind == "remote":
        async with _serve_remote(AFLServer(DIM, C, gamma=GAMMA, **kw)) as rc:
            yield rc
    elif kind == "mux":
        async with _serve_mux(AFLServer(DIM, C, gamma=GAMMA, **kw)) as rc:
            yield rc
    else:
        async with AsyncAFLServer(DIM, C, gamma=GAMMA, **kw) as srv:
            yield srv


@contextlib.asynccontextmanager
async def _restore(kind, state):
    if kind == "sync":
        yield AFLServer.from_state(state)
    elif kind == "sharded":
        yield ShardedCoordinator.from_state(state)
    elif kind == "remote":
        async with _serve_remote(AFLServer.from_state(state)) as rc:
            yield rc
    elif kind == "mux":
        async with _serve_mux(AFLServer.from_state(state)) as rc:
            yield rc
    else:
        async with AsyncAFLServer.from_state(state) as srv:
            yield srv


@pytest.fixture(params=KINDS)
def kind(request):
    return request.param


class TestCoordinatorConformance:
    def test_satisfies_protocol(self, kind):
        async def body():
            async with _make(kind) as coord:
                assert isinstance(coord, Coordinator)
                assert (coord.dim, coord.num_classes, coord.gamma) == \
                    (DIM, C, GAMMA)
                assert coord.num_clients == 0

        asyncio.run(body())

    def test_submit_outcome_and_solve_matches_joint(self, kind):
        x, y, reps = _reports()

        async def body():
            async with _make(kind) as coord:
                outcomes = [await _call(coord.submit(r)) for r in reps]
                assert all(isinstance(o, bool) for o in outcomes)
                assert coord.num_clients == len(reps)
                return await _call(coord.solve())

        w = asyncio.run(body())
        np.testing.assert_allclose(w, al.ridge_solve(x, y, 0.0), **TOL[kind])

    def test_submit_many_and_partial_subsets(self, kind):
        x, y, reps = _reports()

        async def body():
            async with _make(kind) as coord:
                await _call(coord.submit_many(reps[:6]))
                w_sub = await _call(coord.solve())
                await _call(coord.submit_many(reps[6:]))
                return w_sub, await _call(coord.solve())

        w_sub, w_all = asyncio.run(body())
        n6 = 6 * 8
        np.testing.assert_allclose(
            w_sub, al.ridge_solve(x[:n6], y[:n6], 0.0), **TOL[kind])
        np.testing.assert_allclose(w_all, al.ridge_solve(x, y, 0.0),
                                   **TOL[kind])

    def test_batched_ingest_is_bit_for_bit_with_sequential(self, kind):
        """Micro-batched ingest (``submit_batch`` on the sync server, the
        pipelined ``submit_many`` everywhere else) must be indistinguishable
        from a client that uploaded the same reports one at a time — at f64
        that means *bit-for-bit*: the batched fold performs the exact
        sequential operation schedule, not merely an equivalent one. The
        sharded kind accumulates on an f32 device mesh, so it keeps its
        usual tolerance."""
        _, _, reps = _reports(n_clients=12, rows_each=6, seed=11)
        oracle = AFLServer(DIM, C, gamma=GAMMA)
        for r in reps:
            oracle.submit(r)
        w_ref = np.asarray(oracle.solve())
        sweep_ref = [np.asarray(w)
                     for w in oracle.solve_multi_gamma([0.0, 0.5, GAMMA])]

        async def body():
            async with _make(kind) as coord:
                if kind == "sync":
                    flags = coord.submit_batch(reps)
                    assert all(f is True for f in flags)
                else:
                    await _call(coord.submit_many(reps))
                w = await _call(coord.solve())
                ws = await _call(coord.solve_multi_gamma([0.0, 0.5, GAMMA]))
                assert coord.num_clients == len(reps)
                return np.asarray(w), [np.asarray(v) for v in ws]

        w, ws = asyncio.run(body())
        if kind == "sharded":
            np.testing.assert_allclose(w, w_ref, **TOL[kind])
            return
        np.testing.assert_array_equal(w, w_ref)
        for got, ref in zip(ws, sweep_ref):
            np.testing.assert_array_equal(got, ref)

    def test_duplicate_and_gamma_mismatch_raise(self, kind):
        """A CONFLICTING duplicate (same client id, different statistics)
        raises on every kind. Byte-identical resubmission is deliberately
        NOT probed here: the remote kind answers it idempotently (a retried
        delivery is success, not an error — see TestIdempotentIngest in
        test_service.py), while in-process kinds still raise."""
        _, _, reps = _reports(n_clients=3)
        conflict = make_report(reps[0].client_id, np.ones((4, DIM)),
                               np.eye(C)[np.zeros(4, int)], GAMMA)

        async def body():
            async with _make(kind) as coord:
                await _call(coord.submit(reps[0]))
                with pytest.raises(ValueError):
                    await _call(coord.submit(conflict))
                bad = make_report(99, np.zeros((4, DIM)), np.zeros((4, C)),
                                  gamma=2.0)
                with pytest.raises(ValueError):
                    await _call(coord.submit(bad))
                assert coord.num_clients == 1

        asyncio.run(body())

    def test_submit_many_stops_at_first_rejection(self, kind):
        """Post-exception state is interchangeable across kinds: reports
        after the rejected one are NOT aggregated."""
        _, _, reps = _reports(n_clients=4)
        conflict = make_report(reps[0].client_id, np.ones((4, DIM)),
                               np.eye(C)[np.zeros(4, int)], GAMMA)

        async def body():
            async with _make(kind) as coord:
                await _call(coord.submit(reps[0]))
                with pytest.raises(ValueError):
                    await _call(coord.submit_many(
                        [reps[1], conflict, reps[2], reps[3]]))
                assert coord.num_clients == 2      # reps[2:] never applied
                await _call(coord.submit_many(reps[2:]))
                assert coord.num_clients == 4

        asyncio.run(body())

    def test_empty_client_upload_is_exact_noop(self, kind):
        """An empty client (0 rows, γI gram, rank-0 root) must fold with
        outcome True and leave the solution unchanged."""
        x, y, reps = _reports()
        empty = make_report(999, np.zeros((0, DIM)), np.zeros((0, C)), GAMMA)
        assert empty.root is not None and empty.root.shape == (0, DIM)

        async def body():
            async with _make(kind) as coord:
                await _call(coord.submit_many(reps))
                w0 = await _call(coord.solve())     # prime any factor cache
                assert await _call(coord.submit(empty)) is True
                return w0, await _call(coord.solve())

        w0, w1 = asyncio.run(body())
        np.testing.assert_allclose(w1, w0, rtol=1e-9,
                                   atol=1e-6 if kind == "sharded" else 1e-12)

    def test_solve_before_any_arrival_raises(self, kind):
        async def body():
            async with _make(kind) as coord:
                with pytest.raises(ValueError):
                    await _call(coord.solve())
                with pytest.raises(ValueError):
                    await _call(coord.solve_multi_gamma([0.0, 1.0]))

        asyncio.run(body())

    def test_multi_gamma_consistent_with_single_solves(self, kind):
        _, _, reps = _reports()
        gammas = [0.0, 0.1, 1.0]

        async def body():
            async with _make(kind) as coord:
                await _call(coord.submit_many(reps))
                sweep = await _call(coord.solve_multi_gamma(gammas))
                singles = [await _call(coord.solve(g)) for g in gammas]
                return sweep, singles

        sweep, singles = asyncio.run(body())
        assert len(sweep) == len(gammas)
        for w_sweep, w_single in zip(sweep, singles):
            np.testing.assert_allclose(w_sweep, w_single, rtol=1e-6,
                                       atol=2e-3 if kind == "sharded"
                                       else 1e-8)

    def test_sweep_scores_holdout_and_picks_best(self, kind):
        x, y, reps = _reports()
        labels = np.argmax(y, -1)
        gammas = [0.0, 1.0, 10.0]

        async def body():
            async with _make(kind) as coord:
                await _call(coord.submit_many(reps))
                return await _call(coord.sweep(gammas, (x, labels)))

        sweep = asyncio.run(body())
        assert isinstance(sweep, GammaSweep)
        assert sweep.gammas == tuple(gammas)
        assert len(sweep.accuracies) == len(gammas) == len(sweep.weights)
        assert sweep.best_gamma in gammas
        assert sweep.best_accuracy == max(sweep.accuracies)
        i = sweep.gammas.index(sweep.best_gamma)
        np.testing.assert_array_equal(sweep.best_weight, sweep.weights[i])

    def test_state_roundtrip_same_kind(self, kind):
        _, _, reps = _reports()

        async def body():
            async with _make(kind) as coord:
                await _call(coord.submit_many(reps[:7]))
                state = await _call(coord.state())
                w0 = await _call(coord.solve())
                async with _restore(kind, state) as back:
                    assert back.num_clients == 7
                    w1 = await _call(back.solve())
                    # dedup survives the round trip…
                    with pytest.raises(ValueError):
                        await _call(back.submit(reps[0]))
                    # …and aggregation resumes
                    await _call(back.submit_many(reps[7:]))
                    w_all = await _call(back.solve())
                return w0, w1, w_all

        w0, w1, w_all = asyncio.run(body())
        np.testing.assert_allclose(w1, w0, rtol=1e-6,
                                   atol=1e-4 if kind == "sharded" else 1e-10)
        x, y, _ = _reports()
        np.testing.assert_allclose(w_all, al.ridge_solve(x, y, 0.0),
                                   **TOL[kind])

    def test_state_interchangeable_across_kinds(self, kind):
        """One checkpoint schema: state written by any kind restores into a
        plain AFLServer (and vice versa) with the same solution."""
        _, _, reps = _reports()

        async def body():
            async with _make(kind) as coord:
                await _call(coord.submit_many(reps))
                return await _call(coord.state()), await _call(coord.solve())

        state, w = asyncio.run(body())
        srv = AFLServer.from_state(state)
        assert srv.num_clients == len(reps)
        np.testing.assert_allclose(srv.solve(), w, rtol=1e-5,
                                   atol=2e-3 if kind == "sharded" else 1e-10)

    def test_masked_cohort_aggregates_exactly(self, kind):
        x, y, reps = _reports(seed=3)
        masked = masked_reports(reps, seed=7)

        async def body():
            async with _make(kind) as coord:
                await _call(coord.submit_many(masked))
                return await _call(coord.solve())

        w = asyncio.run(body())
        loose = dict(rtol=1e-3, atol=2e-3) if kind == "sharded" \
            else dict(rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(w, al.ridge_solve(x, y, 0.0), **loose)

    def test_weights_are_versioned_with_staleness_token(self, kind):
        """``weights`` is the download endpoint: a VersionedWeights equal to
        solve(), whose etag token changes on submit and short-circuits
        (weight=None) when the caller is already current. The token is
        γ-bound: a token minted for one target γ never revalidates a
        download of another."""
        _, _, reps = _reports()

        async def body():
            async with _make(kind) as coord:
                await _call(coord.submit_many(reps[:5]))
                vw = await _call(coord.weights())
                cached = await _call(coord.weights(if_etag=vw.etag))
                other_gamma = await _call(coord.weights(1.0,
                                                        if_etag=vw.etag))
                await _call(coord.submit(reps[5]))
                fresh = await _call(coord.weights(if_etag=vw.etag))
                w_now = await _call(coord.solve())
                return vw, cached, other_gamma, fresh, w_now

        vw, cached, other_gamma, fresh, w_now = asyncio.run(body())
        assert isinstance(vw, VersionedWeights)
        assert vw.weight is not None and not vw.not_modified and vw.etag
        assert cached.not_modified and cached.etag == vw.etag
        # same epoch, different γ: MUST download (γ=0 head is not the γ=1)
        assert not other_gamma.not_modified
        assert other_gamma.etag != vw.etag
        assert fresh.etag != vw.etag and not fresh.not_modified
        assert fresh.version != vw.version
        np.testing.assert_allclose(fresh.weight, w_now, rtol=1e-9,
                                   atol=1e-6 if kind == "sharded" else 1e-12)


class TestShardedPlacement:
    def test_round_robin_spreads_clients(self):
        _, _, reps = _reports()
        coord = ShardedCoordinator(DIM, C, gamma=GAMMA)
        coord.submit_many(reps)
        counted = sum(float(s.clients) for s in coord._shards)
        assert counted == len(reps)
        # each shard's Gram is PSD and they sum to the aggregate
        agg = sum(np.asarray(s.gram) for s in coord._shards)
        srv = AFLServer(DIM, C, gamma=GAMMA)
        srv.submit_many(reps)
        np.testing.assert_allclose(agg, srv._stats.gram, rtol=1e-12,
                                   atol=1e-9)


class TestClientReportWire:
    def test_f64_roundtrip_is_lossless(self):
        _, _, reps = _reports(n_clients=2, rows_each=6)   # rows < d → root
        r = reps[0]
        assert r.root is not None
        back = ClientReport.from_bytes(r.to_bytes())
        assert (back.client_id, back.gamma, back.count) == \
            (r.client_id, r.gamma, r.count)
        np.testing.assert_array_equal(back.gram, r.gram)
        np.testing.assert_array_equal(back.moment, r.moment)
        np.testing.assert_array_equal(back.root, r.root)

    def test_rootless_report_roundtrip(self):
        _, _, reps = _reports(n_clients=2)
        r = masked_reports(reps, seed=0)[0]
        assert r.root is None
        back = ClientReport.from_bytes(r.to_bytes())
        assert back.root is None
        np.testing.assert_array_equal(back.gram, r.gram)

    def test_f32_wire_within_documented_tolerance(self):
        x, y, reps = _reports(n_clients=4, rows_each=6)
        r = reps[0]
        back = ClientReport.from_bytes(r.to_bytes(dtype=np.float32))
        np.testing.assert_allclose(back.gram, r.gram, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(back.moment, r.moment, rtol=1e-5,
                                   atol=1e-5)

    def test_compressed_f32_root_tolerance(self):
        """compress_root=True keeps gram/moment exact; the folded rootᵀ·root
        deviates ≲1e-6 relative (the documented rank-update tolerance)."""
        x, y, reps = _reports(n_clients=4, rows_each=6)
        r = reps[1]
        back = ClientReport.from_bytes(r.to_bytes(compress_root=True))
        np.testing.assert_array_equal(back.gram, r.gram)     # f64: exact
        np.testing.assert_array_equal(back.moment, r.moment)
        scale = np.abs(r.root.T @ r.root).max()
        err = np.abs(back.root.T @ back.root - r.root.T @ r.root).max()
        assert err <= 1e-6 * max(scale, 1.0)
        # the solve through a compressed-root rank update stays within tol
        srv = AFLServer(DIM, C, gamma=GAMMA, update_rank_budget=8)
        srv.submit_many(reps[:1] + reps[2:])
        srv.solve()
        srv.submit(back)
        np.testing.assert_allclose(srv.solve(), al.ridge_solve(x, y, 0.0),
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("mutate", [
        lambda b: b[:-1],                                  # truncated
        lambda b: b"XXXX" + b[4:],                         # bad magic
        lambda b: b[:len(b) // 2] +
        bytes([b[len(b) // 2] ^ 0xFF]) + b[len(b) // 2 + 1:],  # bit flip
        lambda b: b + b"\x00" * 8,                         # trailing junk
        lambda b: b"AFLR\xff\xff\xff\x7f",                 # absurd header len
    ])
    def test_corrupt_payloads_rejected(self, mutate):
        _, _, reps = _reports(n_clients=1, rows_each=6)
        wire = reps[0].to_bytes()
        with pytest.raises(ValueError):
            ClientReport.from_bytes(mutate(wire))

    def test_nonfinite_statistics_rejected(self):
        import dataclasses
        _, _, reps = _reports(n_clients=1, rows_each=6)
        bad_gram = dataclasses.replace(reps[0],
                                       gram=np.full((DIM, DIM), np.nan))
        with pytest.raises(ValueError):
            ClientReport.from_bytes(bad_gram.to_bytes())
        # a NaN root with clean gram/moment would silently poison every
        # cached factor through rank_update — ingest must reject it too
        bad_root = dataclasses.replace(
            reps[0], root=np.full_like(reps[0].root, np.inf))
        with pytest.raises(ValueError):
            ClientReport.from_bytes(bad_root.to_bytes())

    def test_unknown_schema_version_rejected(self):
        wire = bytearray(_reports(n_clients=1)[2][0].to_bytes())
        # bump the version field inside the JSON header
        idx = wire.find(b'"version": 1')
        assert idx > 0
        wire[idx: idx + len(b'"version": 1')] = b'"version": 9'
        with pytest.raises(ValueError):
            ClientReport.from_bytes(bytes(wire))


class TestRemoteWireEquivalence:
    """The acceptance bar for the serving redesign: a federation driven over
    loopback HTTP produces the SAME f64 bits as the in-proc coordinator."""

    def test_remote_solved_head_bit_for_bit_at_f64(self):
        x, y, reps = _reports()
        inproc = AFLServer(DIM, C, gamma=GAMMA)
        inproc.submit_many(reps)

        async def body():
            async with _make("remote") as coord:
                outcomes = [await _call(coord.submit(r)) for r in reps]
                assert all(isinstance(o, bool) for o in outcomes)
                return (await _call(coord.solve()),
                        await _call(coord.solve(0.5)),
                        await _call(coord.solve_multi_gamma([0.0, 0.1, 1.0])))

        w0, w_half, multi = asyncio.run(body())
        # f64 wire encoding is lossless and the backing math is identical —
        # equality here is exact, not approximate
        np.testing.assert_array_equal(w0, inproc.solve())
        np.testing.assert_array_equal(w_half, inproc.solve(0.5))
        for w_remote, w_local in zip(multi,
                                     inproc.solve_multi_gamma([0.0, 0.1, 1.0])):
            np.testing.assert_array_equal(w_remote, w_local)

    def test_mux_tls_auth_solved_head_bit_for_bit_at_f64(self):
        """Same bar for the multiplexed transport, in its hardest config:
        TLS socket + bearer auth, and the bits still match exactly."""
        x, y, reps = _reports()
        inproc = AFLServer(DIM, C, gamma=GAMMA)
        inproc.submit_many(reps)

        async def body():
            async with _make("mux") as coord:
                for r in reps:
                    await _call(coord.submit(r))
                return (await _call(coord.solve()),
                        await _call(coord.solve(0.5)),
                        await _call(coord.solve_multi_gamma([0.0, 0.1, 1.0])))

        w0, w_half, multi = asyncio.run(body())
        np.testing.assert_array_equal(w0, inproc.solve())
        np.testing.assert_array_equal(w_half, inproc.solve(0.5))
        for w_mux, w_local in zip(multi,
                                  inproc.solve_multi_gamma([0.0, 0.1, 1.0])):
            np.testing.assert_array_equal(w_mux, w_local)

    def test_remote_shim_module_is_gone(self):
        """The repro.fl.server deprecation window (PR 3) is closed."""
        with pytest.raises(ModuleNotFoundError):
            import repro.fl.server  # noqa: F401


# ---------------------------------------------------------------------------
# x64 path: f64-on-device parity + the 1e-6 sharded-vs-sync guarantee
# ---------------------------------------------------------------------------

_X64_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_ENABLE_X64"] = "1"
    import numpy as np
    import jax.numpy as jnp
    from repro.core import analytic as al
    from repro.fl import AFLClient, AFLServer, ClientReport, ShardedCoordinator

    rng = np.random.default_rng(0)
    d, c, k, per = 32, 5, 64, 8
    x = rng.standard_normal((k, per, d))
    y = np.eye(c)[rng.integers(0, c, (k, per))]

    sync = AFLServer(d, c, gamma=1.0)
    shard = ShardedCoordinator(d, c, gamma=1.0)
    assert shard.num_shards == 8
    for i in range(k):
        # f64-on-device local stage vs the host-f64 reference: the wire
        # reports must agree to 1e-12
        r_jax = AFLClient(i, gamma=1.0, backend="jax",
                          dtype=jnp.float64).local_stage(
                              jnp.asarray(x[i]), jnp.asarray(y[i]))
        r_np = AFLClient(i, gamma=1.0).local_stage(x[i], y[i])
        assert np.abs(r_jax.gram - r_np.gram).max() < 1e-12
        assert np.abs(r_jax.moment - r_np.moment).max() < 1e-12
        sync.submit(r_np)
        shard.submit(ClientReport.from_bytes(r_jax.to_bytes()))

    for tg in (0.0, 0.5):
        w_sync, w_shard = sync.solve(tg), shard.solve(tg)
        err = np.abs(w_shard - w_sync).max()
        assert err < 1e-6, f"sharded-vs-sync at target {tg}: {err}"
    # end-to-end f64 parity through the coordinator path
    flat_x = x.reshape(-1, d); flat_y = y.reshape(-1, c)
    w_ref = al.ridge_solve(flat_x, flat_y, 0.0)
    assert np.abs(sync.solve() - w_ref).max() < 1e-12
    assert np.abs(shard.solve() - w_ref).max() < 1e-9
    for w_a, w_b in zip(sync.solve_multi_gamma([0.0, 0.1, 1.0]),
                        shard.solve_multi_gamma([0.0, 0.1, 1.0])):
        assert np.abs(w_a - w_b).max() < 1e-9
    print("OK")
    """
)


def test_x64_f64_parity_and_sharded_matches_sync_1e6():
    """jax_enable_x64 in a subprocess (x64 is process-global): the jax-f64
    AFLClient matches numpy_f64 at 1e-12, and the 8-shard device solve
    matches the sync server at 1e-6 (measured ~1e-13)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", _X64_SUBPROC], capture_output=True, text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert res.returncode == 0, res.stderr
    assert "OK" in res.stdout


# ---------------------------------------------------------------------------
# K=1000 through the sharded backend (the ROADMAP 1000-client item)
# ---------------------------------------------------------------------------

def test_fig2_k1000_goes_through_sharded_coordinator():
    from benchmarks.common import feature_data
    from benchmarks.fig2_clients import afl_sharded
    from repro.config import FLConfig

    train, test = feature_data()
    fl = FLConfig(num_clients=1000, partition="niid1", alpha=0.1)
    acc, coord = afl_sharded(train, test, fl)
    assert isinstance(coord, ShardedCoordinator)
    assert coord.num_clients == 1000
    # client-number invariance survives the sharded device solve (f32 here,
    # so compare accuracies rather than weights)
    from repro.fl import afl
    ref = afl.run_afl(train, test, fl)
    assert abs(acc - ref.accuracy) < 0.02
