"""Unit + property tests for the AFL analytic core (Theorems 1 & 2).

These validate the paper's central mathematical claims:
  * AA law exactness (Thm 1): pairwise aggregation == joint training.
  * Invariance to data partitioning: any split, any order, any K.
  * RI process (Thm 2): regularization is a removable intermediary.
  * Table A.1 analogue: deviation ~1e-10 with RI even when N_k < d.
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import analytic as al


def make_data(rng, n, d, c):
    x = rng.standard_normal((n, d))
    labels = rng.integers(0, c, size=n)
    y = np.eye(c)[labels]
    return x, y


def split(rng, x, y, k, uneven=True):
    """Random (optionally uneven) partition of rows into k non-empty chunks."""
    n = x.shape[0]
    perm = rng.permutation(n)
    if uneven:
        cuts = np.sort(rng.choice(np.arange(1, n), size=k - 1, replace=False))
    else:
        cuts = np.arange(1, k) * (n // k)
    parts = np.split(perm, cuts)
    return [(x[p], y[p]) for p in parts]


class TestRidgeSolve:
    def test_matches_normal_equations(self):
        rng = np.random.default_rng(0)
        x, y = make_data(rng, 200, 32, 5)
        w = al.ridge_solve(x, y, 0.5)
        np.testing.assert_allclose(
            (x.T @ x + 0.5 * np.eye(32)) @ w, x.T @ y, atol=1e-9
        )

    def test_gamma_zero_full_rank_is_pinv(self):
        rng = np.random.default_rng(1)
        x, y = make_data(rng, 100, 16, 4)
        np.testing.assert_allclose(
            al.ridge_solve(x, y, 0.0), np.linalg.pinv(x) @ y, atol=1e-8
        )

    def test_rank_deficient_gamma_zero_falls_back(self):
        rng = np.random.default_rng(2)
        x, y = make_data(rng, 8, 16, 4)  # N < d
        w = al.ridge_solve(x, y, 0.0)
        assert np.all(np.isfinite(w))


class TestAALaw:
    """Theorem 1: exact two-client aggregation."""

    def test_two_client_exact(self):
        rng = np.random.default_rng(3)
        x, y = make_data(rng, 300, 24, 6)
        w_joint = al.ridge_solve(x, y, 0.0)
        (xu, yu), (xv, yv) = split(rng, x, y, 2)
        w_u, w_v = al.ridge_solve(xu, yu, 0.0), al.ridge_solve(xv, yv, 0.0)
        cu, cv = xu.T @ xu, xv.T @ xv
        w_merged, c_merged = al.aa_merge(w_u, cu, w_v, cv)
        np.testing.assert_allclose(w_merged, w_joint, atol=1e-8)
        np.testing.assert_allclose(c_merged, x.T @ x, atol=1e-8)

    def test_pairwise_equals_sufficient_stats(self):
        rng = np.random.default_rng(4)
        x, y = make_data(rng, 400, 16, 4)
        updates = [al.local_stage(xi, yi, 1.0) for xi, yi in split(rng, x, y, 5)]
        w_pair, c_pair = al.aggregate_pairwise(updates)
        w_stat, c_stat = al.aggregate_sufficient_stats(updates)
        np.testing.assert_allclose(w_pair, w_stat, atol=1e-8)
        np.testing.assert_allclose(c_pair, c_stat, atol=1e-8)


class TestRIProcess:
    """Theorem 2: the regularization intermediary is exactly removable."""

    @pytest.mark.parametrize("gamma", [0.1, 1.0, 10.0, 100.0])
    def test_ri_restores_joint_solution(self, gamma):
        rng = np.random.default_rng(5)
        x, y = make_data(rng, 500, 32, 8)
        w_joint = al.ridge_solve(x, y, 0.0)
        updates = [al.local_stage(xi, yi, gamma) for xi, yi in split(rng, x, y, 10)]
        w = al.afl_aggregate(updates, use_ri=True)
        np.testing.assert_allclose(w, w_joint, atol=1e-7)

    def test_without_ri_biased(self):
        rng = np.random.default_rng(6)
        x, y = make_data(rng, 500, 32, 8)
        w_joint = al.ridge_solve(x, y, 0.0)
        updates = [al.local_stage(xi, yi, 100.0) for xi, yi in split(rng, x, y, 10)]
        w = al.afl_aggregate(updates, use_ri=False)
        assert np.abs(w - w_joint).max() > 1e-3  # accumulated Kγ bias

    def test_theorem2_identity(self):
        """eq (14): Ŵ^r_agg == (C^r_agg)^{-1} C_agg Ŵ_agg."""
        rng = np.random.default_rng(7)
        x, y = make_data(rng, 300, 16, 4)
        gamma, k = 2.0, 4
        updates = [al.local_stage(xi, yi, gamma) for xi, yi in split(rng, x, y, k)]
        w_r, c_r = al.aggregate_sufficient_stats(updates)
        c_agg = c_r - k * gamma * np.eye(16)
        w_agg = al.ridge_solve(x, y, 0.0)
        np.testing.assert_allclose(
            w_r, np.linalg.solve(c_r, c_agg @ w_agg), atol=1e-8
        )

    def test_rank_deficient_clients(self):
        """Table A.1 regime: N_k < d per client; RI keeps exactness."""
        rng = np.random.default_rng(8)
        d = 64
        x, y = make_data(rng, 40 * 16, d, 10)  # 40 clients x 16 samples, 16 < 64
        w_joint = al.ridge_solve(x, y, 0.0)
        parts = split(rng, x, y, 40, uneven=False)
        updates = [al.local_stage(xi, yi, 1.0) for xi, yi in parts]
        w = al.afl_aggregate(updates, use_ri=True)
        assert np.abs(w - w_joint).max() < 1e-7


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.integers(2, 12),
    gamma=st.floats(0.01, 50.0),
    d=st.integers(4, 48),
    c=st.integers(2, 10),
)
def test_property_partition_invariance(seed, k, gamma, d, c):
    """AFL invariant: ANY partition into ANY number of clients with ANY γ
    aggregates (with RI) to the joint solution — the paper's headline claim."""
    rng = np.random.default_rng(seed)
    n = max(4 * d, k + 1)
    x, y = make_data(rng, n, d, c)
    w_joint = al.ridge_solve(x, y, 0.0)
    updates = [al.local_stage(xi, yi, gamma) for xi, yi in split(rng, x, y, k)]
    w = al.afl_aggregate(updates, use_ri=True)
    scale = max(1.0, np.abs(w_joint).max())
    assert np.abs(w - w_joint).max() / scale < 1e-6


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(2, 8))
def test_property_order_invariance(seed, k):
    """Aggregation order never matters (paper §3.2: clients may be sampled
    randomly)."""
    rng = np.random.default_rng(seed)
    x, y = make_data(rng, 200, 16, 4)
    updates = [al.local_stage(xi, yi, 1.0) for xi, yi in split(rng, x, y, k)]
    w_fwd, _ = al.aggregate_pairwise(updates)
    order = rng.permutation(k)
    w_perm, _ = al.aggregate_pairwise([updates[i] for i in order])
    np.testing.assert_allclose(w_fwd, w_perm, atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_client_number_invariance(seed):
    """Same data split into 2 vs 7 vs 13 clients → identical aggregate."""
    rng = np.random.default_rng(seed)
    x, y = make_data(rng, 260, 20, 5)
    results = []
    for k in (2, 7, 13):
        updates = [al.local_stage(xi, yi, 1.0) for xi, yi in split(rng, x, y, k)]
        results.append(al.afl_aggregate(updates, use_ri=True))
    np.testing.assert_allclose(results[0], results[1], atol=1e-7)
    np.testing.assert_allclose(results[0], results[2], atol=1e-7)


def test_mismatched_gamma_rejected():
    rng = np.random.default_rng(9)
    x, y = make_data(rng, 100, 8, 3)
    parts = split(rng, x, y, 2)
    ups = [al.local_stage(*parts[0], 1.0), al.local_stage(*parts[1], 2.0)]
    with pytest.raises(ValueError):
        al.afl_aggregate(ups)
