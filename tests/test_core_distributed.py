"""Tests for the shard_map/psum aggregation path.

The multi-device case runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main pytest
process keeps a single-device view (required by the smoke tests).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analytic as al
from repro.core import streaming
from repro.core.distributed import make_federated_solve


def test_single_device_mesh_matches_host():
    """Mechanics on a 1-device mesh: device solve == host f64 solve (f32 tol)."""
    rng = np.random.default_rng(0)
    n, d, c = 256, 32, 7
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = np.eye(c, dtype=np.float32)[rng.integers(0, c, n)]
    mesh = jax.make_mesh((1,), ("data",))
    st = streaming.update_state(streaming.init_state(d, c), jnp.asarray(x), jnp.asarray(y))
    stacked = jax.tree.map(lambda a: a[None], st)
    w_dev = make_federated_solve(mesh, axis_names=("data",), gamma=1.0, target_gamma=0.05)(stacked)
    w_host = al.ridge_solve(x.astype(np.float64), y.astype(np.float64), 0.05)
    np.testing.assert_allclose(np.asarray(w_dev), w_host, atol=2e-3)


def test_streaming_equals_batch():
    rng = np.random.default_rng(1)
    x = rng.standard_normal((300, 16)).astype(np.float32)
    y = np.eye(4, dtype=np.float32)[rng.integers(0, 4, 300)]
    st = streaming.init_state(16, 4)
    for i in range(0, 300, 64):
        st = streaming.update_state(st, jnp.asarray(x[i : i + 64]), jnp.asarray(y[i : i + 64]))
    np.testing.assert_allclose(np.asarray(st.gram), x.T @ x, rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st.moment), x.T @ y, rtol=2e-4, atol=2e-3)
    assert int(st.count) == 300


_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import analytic as al, streaming
    from repro.core.distributed import make_federated_solve

    rng = np.random.default_rng(42)
    d, c, per = 24, 5, 40   # per-client N=40 > d: full rank per shard
    xs = rng.standard_normal((8, per, d)).astype(np.float32)
    ys = np.eye(c, dtype=np.float32)[rng.integers(0, c, (8, per))]

    # Per-shard states, stacked on a leading federation dim.
    states = [
        streaming.update_state(streaming.init_state(d, c), jnp.asarray(xs[i]), jnp.asarray(ys[i]))
        for i in range(8)
    ]
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *states)
    mesh = jax.make_mesh((8,), ("data",))
    w_dev = make_federated_solve(mesh, axis_names=("data",), gamma=1.0, target_gamma=0.0)(stacked)

    # Host reference: literal paper Algorithm 1 over the 8 "clients".
    ups = [al.local_stage(xs[i].astype(np.float64), ys[i].astype(np.float64), 1.0) for i in range(8)]
    w_host = al.afl_aggregate(ups, use_ri=True, pairwise=True)
    err = np.abs(np.asarray(w_dev) - w_host).max()
    assert err < 5e-3, f"device/host mismatch: {err}"
    print("OK", err)
    """
)


def test_multidevice_psum_matches_pairwise_aa_law():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert res.returncode == 0, res.stderr
    assert "OK" in res.stdout
