"""Tile-parallel distributed Cholesky: panel-edge cases, the bit-for-bit
local≡distributed contract, padded dims, the γ=0 fallback, and the x64
8-device ≤1e-10 parity bar (subprocess).

The distributed factor (``make_tiled_federated_solve(distributed_factor=
True)``) and the local streamed kernel (:func:`repro.kernels.solve.
streamed_cholesky`) are ONE trace-time routine parameterized by the mesh
collectives — with one shard the collectives are value-identities, so the
two paths must agree bit-for-bit, which is what pins the distributed
schedule to the locally-testable kernel.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.core.distributed import make_tiled_federated_solve  # noqa: E402
from repro.fl.api import ShardedCoordinator, make_report  # noqa: E402
from repro.kernels.solve import (  # noqa: E402
    panel_width, streamed_cholesky, streamed_cholesky_solve)
from repro.launch.hlo_analysis import peak_aval_bytes  # noqa: E402


def _spd(rng, d, ridge=0.5, dtype=np.float32):
    x = rng.standard_normal((d + 32, d)).astype(dtype)
    a = x.T @ x
    a[np.arange(d), np.arange(d)] += dtype(ridge)
    return a


def _rel(a, b):
    return np.abs(np.asarray(a, np.float64) - b).max() / max(
        1.0, np.abs(b).max())


class TestPanelWidth:
    def test_divides_and_caps(self):
        assert panel_width(1024, 256) == 256
        assert panel_width(878, 256) == 2          # 2·439: no nice divisor
        assert panel_width(880, 256) == 220
        assert panel_width(8, 256) == 8
        for rows in (8, 24, 130, 256, 880):
            b = panel_width(rows, 64)
            assert rows % b == 0 and b <= 64


class TestStreamedKernel:
    """The one-shard instance: HBM-streamed panel factor + substitution."""

    @pytest.mark.parametrize("d", [64, 130, 256])
    def test_factor_and_solve_parity(self, d):
        # d=130 exercises the non-divisible panel count (identity-tail pad)
        rng = np.random.default_rng(d)
        a = _spd(rng, d)
        b = rng.standard_normal((d, 7)).astype(np.float32)
        l = streamed_cholesky(jnp.asarray(a), block=64, interpret=True)
        ref_l = np.linalg.cholesky(a.astype(np.float64))
        assert _rel(l, ref_l) < 1e-4
        # clean lower factor: strict upper triangle is exactly zero
        lu = np.triu(np.asarray(l), 1)
        assert not lu.any()
        x = streamed_cholesky_solve(l, jnp.asarray(b), block=64,
                                    interpret=True)
        ref_x = np.linalg.solve(a.astype(np.float64), b.astype(np.float64))
        assert _rel(x, ref_x) < 1e-4

    def test_non_pd_yields_nan(self):
        rng = np.random.default_rng(0)
        x = rng.standard_normal((3, 64)).astype(np.float32)   # rank 3
        a = x.T @ x
        l = streamed_cholesky(jnp.asarray(a), block=16, interpret=True)
        assert not np.isfinite(np.asarray(l)).all()


class TestDistributedFactor:
    """shard_map path on however many devices this host exposes."""

    def _mesh(self, n=1):
        return Mesh(np.array(jax.devices()[:n]), ("data",))

    def test_single_shard_bit_for_bit(self):
        """1-device distributed ≡ local streamed kernel, bitwise."""
        rng = np.random.default_rng(7)
        d, gamma, block = 256, 0.5, 64
        g = _spd(rng, d, ridge=0.0)
        q = rng.standard_normal((d, 9)).astype(np.float32)
        a = g.copy()
        a[np.arange(d), np.arange(d)] += np.float32(gamma)
        fn = make_tiled_federated_solve(
            self._mesh(), target_gamma=gamma, distributed_factor=True,
            dim=d, block=block)
        w_dist = np.asarray(fn(jnp.asarray(g[None]), jnp.asarray(q[None])))
        l = streamed_cholesky(jnp.asarray(a), block=block, interpret=True)
        w_loc = np.asarray(streamed_cholesky_solve(
            l, jnp.asarray(q), block=block, interpret=True))
        np.testing.assert_array_equal(w_dist, w_loc)

    def test_padded_dim_matches_host(self):
        """dim not divisible by the tile rows: pad rows carry a unit
        diagonal and are sliced off the result."""
        rng = np.random.default_rng(8)
        d, d_p = 120, 128
        g = _spd(rng, d, ridge=0.0)
        q = rng.standard_normal((d, 5)).astype(np.float32)
        gp = np.zeros((d_p, d_p), np.float32)
        gp[:d, :d] = g
        qp = np.zeros((d_p, 5), np.float32)
        qp[:d] = q
        fn = make_tiled_federated_solve(
            self._mesh(), target_gamma=0.5, distributed_factor=True,
            dim=d, block=32)
        w = np.asarray(fn(jnp.asarray(gp[None]), jnp.asarray(qp[None])))
        assert w.shape == (d, 5)
        ref = np.linalg.solve(g.astype(np.float64) + 0.5 * np.eye(d),
                              q.astype(np.float64))
        assert _rel(w, ref) < 1e-4

    def test_never_materializes_full_system(self):
        """The acceptance invariant, statically: the gather-then-factor
        collective shows a (d, d) per-device transient in its jaxpr; the
        distributed factor tops out at the (d/shards, d) row tile."""
        d, c = 256, 3
        n = len(jax.devices())
        mesh = Mesh(np.array(jax.devices()), ("data",))
        rows = d // n
        gt = jnp.zeros((n, rows, d))
        mt = jnp.zeros((n, rows, c))
        full = d * d * gt.dtype.itemsize
        fn_g = make_tiled_federated_solve(mesh, target_gamma=0.5, dim=d)
        fn_d = make_tiled_federated_solve(mesh, target_gamma=0.5, dim=d,
                                          distributed_factor=True, block=64)
        peak_g, _ = peak_aval_bytes(fn_g, gt, mt)
        peak_d, shape_d = peak_aval_bytes(fn_d, gt, mt)
        assert peak_g >= full
        if n > 1:
            assert peak_d < full, shape_d
        assert peak_d <= rows * d * gt.dtype.itemsize + 1, shape_d


class TestCoordinatorDistributed:
    def _reports(self, dim, c, k, rng):
        return [make_report(i, rng.standard_normal((3 * dim, dim)),
                            np.eye(c)[rng.integers(0, c, 3 * dim)], 1.0)
                for i in range(k)]

    @pytest.mark.parametrize("dim", [32, 30])
    def test_tiled_solve_matches_host(self, dim):
        rng = np.random.default_rng(dim)
        coord = ShardedCoordinator(dim, 4, gamma=1.0, tiled_gram=True)
        assert coord.distributed_factor
        coord.submit_many(self._reports(dim, 4, 3, rng))
        w = coord.solve(0.3)
        m = coord._merged()
        ref = np.linalg.solve(m.gram + 0.3 * np.eye(dim), m.moment)
        assert w.shape == (dim, 4)
        assert _rel(w, ref) < 1e-4

    def test_rank_deficient_gamma0_falls_back_to_pinv(self):
        """γ=0 on rank-deficient statistics: the distributed Cholesky
        surfaces NaNs, the coordinator reroutes to the host pinv path."""
        rng = np.random.default_rng(5)
        dim, c = 24, 3
        coord = ShardedCoordinator(dim, c, gamma=0.5, tiled_gram=True)
        x = rng.standard_normal((2, dim))              # rank 2 << dim
        y = np.eye(c)[rng.integers(0, c, 2)]
        coord.submit(make_report(0, x, y, 0.5))
        w = coord.solve(0.0)
        assert np.isfinite(w).all()
        m = coord._merged()
        ref = np.linalg.pinv(m.gram) @ m.moment
        assert np.allclose(w, ref, atol=1e-6)

    def test_state_roundtrip_padded(self):
        rng = np.random.default_rng(6)
        coord = ShardedCoordinator(30, 4, gamma=1.0, tiled_gram=True)
        coord.submit_many(self._reports(30, 4, 2, rng))
        back = ShardedCoordinator.from_state(coord.state(), 4,
                                             tiled_gram=True)
        np.testing.assert_allclose(back.solve(0.2), coord.solve(0.2),
                                   rtol=1e-4, atol=1e-5)

    def test_gather_path_still_available(self):
        rng = np.random.default_rng(9)
        coord = ShardedCoordinator(32, 4, gamma=1.0, tiled_gram=True,
                                   distributed_factor=False)
        coord.submit_many(self._reports(32, 4, 2, rng))
        ref = ShardedCoordinator(32, 4, gamma=1.0, tiled_gram=True)
        ref.submit_many(self._reports(32, 4, 2,
                                      np.random.default_rng(9)))
        np.testing.assert_allclose(coord.solve(0.1), ref.solve(0.1),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# x64 subprocess: ≤1e-10 vs numpy_f64 at d=2048 on an 8-device mesh
# ---------------------------------------------------------------------------

_X64_DIST_PARITY = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_ENABLE_X64"] = "1"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.distributed import make_tiled_federated_solve

    rng = np.random.default_rng(0)
    mesh = Mesh(np.array(jax.devices()), ("data",))

    def run(d, d_p, gamma, block=None):
        n, c = 8, 6
        r = d_p // n
        x = rng.standard_normal((d + 64, d))
        g = x.T @ x
        q = rng.standard_normal((d, c))
        gp = np.zeros((d_p, d_p)); gp[:d, :d] = g
        qp = np.zeros((d_p, c)); qp[:d] = q
        gt = np.stack([gp[i*r:(i+1)*r] for i in range(n)])
        mt = np.stack([qp[i*r:(i+1)*r] for i in range(n)])
        fn = make_tiled_federated_solve(
            mesh, target_gamma=gamma, distributed_factor=True, dim=d,
            block=block)
        w = np.asarray(fn(jnp.asarray(gt), jnp.asarray(mt)))
        ref = np.linalg.solve(g + gamma * np.eye(d), q)
        rel = np.abs(w - ref).max() / max(1.0, np.abs(ref).max())
        assert w.dtype == np.float64
        assert rel < 1e-10, (d, rel)
        print(d, rel)

    run(2048, 2048, 0.5)          # the headline f64 parity bar
    run(150, 152, 0.5, block=8)   # padded dim through the device path
    print("OK")
    """
)


def test_x64_distributed_parity_8dev():
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), env.get("PYTHONPATH", "")])
    res = subprocess.run(
        [sys.executable, "-c", _X64_DIST_PARITY], capture_output=True,
        text=True, env=env, cwd=root,
    )
    assert res.returncode == 0, res.stderr
    assert "OK" in res.stdout
