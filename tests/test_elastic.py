"""Elastic federation: exact resharding, live grow/shrink, failover.

The AA law makes sufficient statistics additive, so moving mass between
shards — or changing the shard count entirely — is *exact*, not
approximate. This file locks that down:

  * shard-count-changing ``from_state`` round-trips (sync ↔ sharded ↔
    tiled), bit-for-bit on the host paths thanks to the disjoint row-block
    restore split and the ``gram_diag_raw`` checkpoint rider;
  * live ``grow``/``shrink`` under the mesh-epoch guard (racing requests
    get retryable backpressure, never a wrong answer);
  * the snapshot daemon (versioned checkpoint-over-wire pulls, retention,
    outage survival);
  * the failover drill: kill the coordinator mid-stream, restore from the
    latest snapshot, clients only ever observe typed retryable errors, and
    the final head is bit-for-bit identical to an uninterrupted run at f64.

The multi-device (8-way mesh, x64, ≤1e-10 vs the sync oracle) parity case
runs in a subprocess, as everywhere else in this suite.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.fl import (AFLServer, AsyncAFLServer, FederationService,
                      RemoteCoordinator, ShardedCoordinator, make_report,
                      serve_http)
from repro.fl import errors as E
from repro.checkpoint import SnapshotDaemon

DIM, C, GAMMA = 24, 5, 1.0


def _reports(n=8, rows=10, seed=0, start_id=0):
    rng = np.random.default_rng(seed)
    return [make_report(start_id + k, rng.standard_normal((rows, DIM)),
                        np.eye(C)[rng.integers(0, C, rows)], GAMMA)
            for k in range(n)]


def _oracle(reports):
    srv = AFLServer(DIM, C, gamma=GAMMA)
    srv.submit_many(reports)
    return srv


# ---------------------------------------------------------------------------
# Shard-count-changing restore
# ---------------------------------------------------------------------------


class TestReshardingRestore:
    def test_restore_is_bitwise_identical_across_shard_counts(self):
        """The disjoint row-block split makes the shard sum reproduce the
        aggregate bitwise (0 + x = x), so the restored *device* solve is
        bit-for-bit the same on any shard count — even at f32 device
        precision."""
        base = ShardedCoordinator(DIM, C, gamma=GAMMA, num_shards=4)
        base.submit_many(_reports(7))
        state = base.state()
        solves = []
        for n in (1, 2, 3, 5, 7):
            coord = ShardedCoordinator.from_state(state, num_shards=n)
            assert coord.num_shards == n
            assert sum(coord.occupancy()) == 7
            solves.append(np.asarray(coord.solve(0.25), np.float64))
        for w in solves[1:]:
            np.testing.assert_array_equal(solves[0], w)

    def test_restored_aggregate_matches_sync_oracle_bitwise(self):
        """state() → from_state(num_shards=n) → state() reproduces the sync
        server's aggregate bit-for-bit (host f64 path): the shard-0 dump
        bug would instead have produced the right numbers with wrecked
        occupancy, and without gram_diag_raw the diagonal would lose its
        last ulp to the +kγ−kγ round trip."""
        reports = _reports(6)
        oracle = _oracle(reports)
        ref_state = oracle.state()
        for n in (2, 5):
            coord = ShardedCoordinator.from_state(ref_state, num_shards=n)
            back = coord.state()
            np.testing.assert_array_equal(back["gram"], ref_state["gram"])
            np.testing.assert_array_equal(back["moment"],
                                          ref_state["moment"])
            np.testing.assert_array_equal(back["seen"], ref_state["seen"])
            # host-engine solve (the f64 path) is therefore bit-identical
            np.testing.assert_array_equal(
                coord.solve_multi_gamma([0.3])[0],
                oracle.solve_multi_gamma([0.3])[0])

    def test_cross_kind_roundtrip_sync_sharded_tiled_async(self):
        reports = _reports(6)
        oracle = _oracle(reports)
        state = oracle.state()
        sharded = ShardedCoordinator.from_state(state, num_shards=3)
        tiled = ShardedCoordinator.from_state(sharded.state(),
                                              tiled_gram=True)
        back = AFLServer.from_state(tiled.state())
        np.testing.assert_array_equal(back.solve(0.1), oracle.solve(0.1))
        # async adopts the same schema (validation included)
        asrv = AsyncAFLServer.from_state(back.state())
        np.testing.assert_array_equal(asrv.server.solve(0.1),
                                      oracle.solve(0.1))

    def test_occupancy_folds_and_survives_roundtrip(self):
        base = ShardedCoordinator(DIM, C, gamma=GAMMA, num_shards=4,
                                  placement="round_robin")
        base.submit_many(_reports(6))
        assert base.occupancy() == [2, 2, 1, 1]
        # same count: occupancy carries over verbatim
        same = ShardedCoordinator.from_state(base.state(), num_shards=4)
        assert same.occupancy() == [2, 2, 1, 1]
        # shrink: old shard i folds onto i % n
        two = ShardedCoordinator.from_state(base.state(), num_shards=2)
        assert two.occupancy() == [3, 3]
        # grow: folded counts keep every client accounted for
        six = ShardedCoordinator.from_state(base.state(), num_shards=6)
        assert sum(six.occupancy()) == 6

    def test_tiled_checkpoint_occupancy_falls_back_to_even_split(self):
        """Tiled checkpoints record resident Gram rows in shard_clients,
        not client counts — the restore must not mistake rows for
        occupancy."""
        tiled = ShardedCoordinator(DIM, C, gamma=GAMMA, tiled_gram=True)
        tiled.submit_many(_reports(5))
        coord = ShardedCoordinator.from_state(tiled.state(), num_shards=2)
        assert sum(coord.occupancy()) == 5
        assert max(coord.occupancy()) - min(coord.occupancy()) <= 1

    def test_padded_tile_plan(self):
        """Indivisible dims pad up to the next tile multiple; a plan that
        would pad by a full tile is rejected up front."""
        assert ShardedCoordinator._plan_tile_rows(30, 4) == 8   # pad 2
        assert ShardedCoordinator._plan_tile_rows(30, 8) == 4   # pad 2
        with pytest.raises(E.BadRequest):
            ShardedCoordinator._plan_tile_rows(8, 7)            # pad ≥ tile


class TestStateValidation:
    @pytest.mark.parametrize("cls", [AFLServer, ShardedCoordinator,
                                     AsyncAFLServer])
    def test_contradictory_num_classes_raises_typed_bad_request(self, cls):
        state = _oracle(_reports(3)).state()
        with pytest.raises(E.BadRequest):
            cls.from_state(state, num_classes=C + 2)
        # the matching value still restores
        coord = cls.from_state(state, num_classes=C)
        assert coord.num_classes == C

    def test_malformed_checkpoints_rejected_up_front(self):
        state = _oracle(_reports(2)).state()
        bad = dict(state)
        bad["moment"] = state["moment"][:-1]               # row mismatch
        with pytest.raises(E.BadRequest):
            AFLServer.from_state(bad)
        with pytest.raises(E.BadRequest):
            AFLServer.from_state({"gamma": state["gamma"]})  # missing keys

    def test_legacy_checkpoint_without_diag_rider_still_restores(self):
        """Checkpoints written before gram_diag_raw restore to ≤1e-10 (the
        regularized round trip costs at most the diagonal's last ulp)."""
        oracle = _oracle(_reports(4))
        state = dict(oracle.state())
        state.pop("gram_diag_raw")
        back = AFLServer.from_state(state)
        np.testing.assert_allclose(back.solve(0.2), oracle.solve(0.2),
                                   rtol=1e-12, atol=1e-10)


# ---------------------------------------------------------------------------
# Live grow/shrink under the epoch guard
# ---------------------------------------------------------------------------


class TestLiveResize:
    def test_grow_admits_empty_shards_and_placement_fills_them(self):
        coord = ShardedCoordinator(DIM, C, gamma=GAMMA, num_shards=2)
        coord.submit_many(_reports(4))
        w0 = coord.solve_multi_gamma([0.25])[0]
        assert coord.grow(2) == 1 and coord.num_shards == 4
        assert coord.occupancy() == [2, 2, 0, 0]
        # growth is exact: empty shards add nothing
        np.testing.assert_array_equal(coord.solve_multi_gamma([0.25])[0], w0)
        coord.submit_many(_reports(2, start_id=100, seed=9))
        assert coord.occupancy() == [2, 2, 1, 1]   # new shards fill first

    def test_shrink_folds_retired_shards_exactly(self):
        coord = ShardedCoordinator(DIM, C, gamma=GAMMA, num_shards=5)
        coord.submit_many(_reports(7))
        before = coord.state()
        assert coord.shrink(3) == 1 and coord.num_shards == 2
        after = coord.state()
        np.testing.assert_allclose(after["gram"], before["gram"],
                                   rtol=1e-12, atol=1e-9)
        np.testing.assert_array_equal(after["seen"], before["seen"])
        assert sum(coord.occupancy()) == 7

    def test_resize_bounds_raise_typed_bad_request(self):
        coord = ShardedCoordinator(DIM, C, gamma=GAMMA, num_shards=2)
        with pytest.raises(E.BadRequest):
            coord.grow(0)
        with pytest.raises(E.BadRequest):
            coord.shrink(2)                        # nothing would survive
        with pytest.raises(E.BadRequest):
            coord.shrink(5)
        assert coord.num_shards == 2 and coord.mesh_epoch == 0

    def test_rejected_resize_leaves_coordinator_untouched(self):
        """Validation precedes mutation: a grow the mesh cannot back (tiled
        needs one device per tile) must not corrupt the tiles."""
        coord = ShardedCoordinator(DIM, C, gamma=GAMMA, tiled_gram=True)
        coord.submit_many(_reports(3))
        w0 = coord.solve_multi_gamma([0.1])[0]
        with pytest.raises(E.BadRequest):
            coord.grow(64)                         # no such devices
        assert coord.mesh_epoch == 0
        np.testing.assert_array_equal(coord.solve_multi_gamma([0.1])[0], w0)

    def test_inflight_requests_get_retryable_backpressure_mid_resize(self):
        coord = ShardedCoordinator(DIM, C, gamma=GAMMA, num_shards=2)
        coord.submit_many(_reports(2))
        coord._resizing = True                     # freeze mid-migration
        for call in (lambda: coord.submit(_reports(1, start_id=50)[0]),
                     lambda: coord.solve(0.1),
                     lambda: coord.solve_multi_gamma([0.1]),
                     coord.state, coord.rebalance):
            with pytest.raises(E.Backpressure) as exc:
                call()
            assert exc.value.retryable
        coord._resizing = False
        assert coord.num_clients == 2              # nothing landed

    def test_wire_grow_shrink_and_describe(self):
        svc = FederationService(
            ShardedCoordinator(DIM, C, gamma=GAMMA, num_shards=2))
        rc = RemoteCoordinator(svc)
        rc.submit_many(_reports(3))
        info = rc.describe()
        assert info["num_shards"] == 2 and info["mesh_epoch"] == 0
        assert rc.grow(1) == 1 and rc.num_shards == 3
        assert rc.shrink(2) == 2 and rc.num_shards == 1
        # non-elastic kinds answer a typed bad_request
        rc2 = RemoteCoordinator(FederationService(
            AFLServer(DIM, C, gamma=GAMMA)))
        assert rc2.num_shards is None
        with pytest.raises(E.BadRequest):
            rc2.grow(1)


# ---------------------------------------------------------------------------
# Snapshot daemon
# ---------------------------------------------------------------------------


class TestSnapshotDaemon:
    def test_versioned_snapshots_idempotent_and_pruned(self, tmp_path):
        svc = FederationService(AFLServer(DIM, C, gamma=GAMMA))
        rc = RemoteCoordinator(svc)
        d = SnapshotDaemon(svc, directory=tmp_path, keep=2)
        rc.submit_many(_reports(3))
        path = d.snapshot_once()
        assert path is not None and path.name == "snap-000000000003-000000"
        assert d.snapshot_once() is None           # same state: no-op
        for extra in range(2):
            rc.submit(_reports(1, start_id=10 + extra, seed=extra + 3)[0])
            d.snapshot_once()
        assert len(d.snapshots()) == 2             # retention pruned v3
        assert d.latest_version == 5

    def test_epoch_keyed_snapshots_catch_resharding(self, tmp_path):
        """Regression: `snap-{clients}` alone skipped a fresh snapshot when
        a grow/shrink changed the state without admitting a client — the
        key now carries the mesh epoch, and idempotence is by state digest,
        so a same-count same-epoch pull with different state (γ drift,
        rebalance) is re-snapshotted in place rather than skipped."""
        coord = ShardedCoordinator(DIM, C, gamma=GAMMA, num_shards=2)
        svc = FederationService(coord)
        RemoteCoordinator(svc).submit_many(_reports(3))
        d = SnapshotDaemon(svc, directory=tmp_path, keep=10)
        first = d.snapshot_once()
        assert first.name == "snap-000000000003-000000"
        coord.grow(1)                              # state changed, count not
        second = d.snapshot_once()
        assert second is not None                  # the old bug: None here
        assert second.name == "snap-000000000003-000001"
        assert d.latest() == second and d.latest_version == 3
        # same count + epoch + state → true no-op
        assert d.snapshot_once() is None
        # a restore from latest sees the post-grow mesh
        restored = d.restore(ShardedCoordinator, num_shards=3)
        np.testing.assert_array_equal(restored.solve(0.2), coord.solve(0.2))

    def test_restore_cold_starts_any_kind_on_any_shard_count(self, tmp_path):
        reports = _reports(5)
        oracle = _oracle(reports)
        svc = FederationService(AFLServer(DIM, C, gamma=GAMMA))
        RemoteCoordinator(svc).submit_many(reports)
        d = SnapshotDaemon(svc, directory=tmp_path)
        d.snapshot_once()
        same = d.restore()                         # AFLServer default
        np.testing.assert_array_equal(same.solve(0.2), oracle.solve(0.2))
        resharded = d.restore(ShardedCoordinator, num_shards=3)
        assert resharded.num_shards == 3
        np.testing.assert_array_equal(resharded.solve_multi_gamma([0.2])[0],
                                      oracle.solve_multi_gamma([0.2])[0])
        with pytest.raises(FileNotFoundError):
            SnapshotDaemon(svc, directory=tmp_path / "empty").restore()

    def test_daemon_survives_outage_and_keeps_snapshots(self, tmp_path):
        import time

        svc = FederationService(AFLServer(DIM, C, gamma=GAMMA))
        with serve_http(svc) as http:
            rc = RemoteCoordinator(http.url)
            rc.submit_many(_reports(4))
            d = SnapshotDaemon(http.url, directory=tmp_path, interval=0.02)
            with d:
                assert d.wait_for_version(4, timeout=10.0)
            rc.close()
        # service is gone: pulls fail, snapshots stay, errors are recorded
        d2 = SnapshotDaemon(http.url, directory=tmp_path, interval=0.02)
        with d2:
            time.sleep(0.1)
        assert d2.errors and d2.latest_version == 4


# ---------------------------------------------------------------------------
# The failover drill
# ---------------------------------------------------------------------------


def _drill(service, transport, tmp_path, replacement_cls, **restore_kw):
    """Kill → snapshot-restore → resume. Clients only ever observe typed
    retryable errors; returns (final head, uninterrupted-oracle head)."""
    reports = _reports(16)
    rc = RemoteCoordinator(transport)
    rc.submit_many(reports[:10])
    daemon = SnapshotDaemon(transport, directory=tmp_path)
    daemon.snapshot_once()
    assert daemon.latest_version == 10

    service.suspend_federation()                   # the coordinator "dies"
    outage_errors = []
    for r in reports[10:]:
        with pytest.raises(E.ServiceError) as exc:
            rc.submit(r)
        outage_errors.append(exc.value)
    with pytest.raises(E.ServiceError) as exc:
        rc.solve(0.25)
    outage_errors.append(exc.value)
    assert all(isinstance(e, E.Unavailable) and e.retryable
               for e in outage_errors)             # typed, retryable, only

    service.restore_federation(
        "default", daemon.restore(replacement_cls, **restore_kw))
    for r in reports[10:]:                         # clients back off + retry
        rc.submit(r)
    # a retry straddling the outage stays idempotent (ledger carried over)
    _, _, _ = rc._request("submit", raw=reports[3].to_bytes())
    assert rc.num_clients == 16
    return np.asarray(rc.solve(0.25), np.float64), \
        np.asarray(_oracle(reports).solve(0.25), np.float64)


class TestFailoverDrill:
    def test_inproc_drill_final_head_bitwise_vs_uninterrupted(self, tmp_path):
        svc = FederationService(AFLServer(DIM, C, gamma=GAMMA))
        final, ref = _drill(svc, svc, tmp_path, AFLServer)
        np.testing.assert_array_equal(final, ref)

    def test_http_drill_with_resharded_replacement(self, tmp_path):
        """Over real loopback HTTP, restoring into a DIFFERENT kind and
        shard count. The restore itself is bit-exact; post-outage arrivals
        then merge into different shards than the oracle's sequential fold,
        so the head matches to f64 reassociation roundoff (≪ 1e-12), and
        the f32 device solve the wire serves stays within device
        precision."""
        svc = FederationService(AFLServer(DIM, C, gamma=GAMMA))
        with serve_http(svc) as http:
            final, ref = _drill(svc, http.url, tmp_path,
                                ShardedCoordinator, num_shards=2)
            coord = svc.coordinator()
            np.testing.assert_allclose(
                coord.solve_multi_gamma([0.25])[0], ref,
                rtol=1e-12, atol=1e-12)
            assert np.abs(final - ref).max() < 1e-4


# ---------------------------------------------------------------------------
# x64 subprocess: ≤1e-10 vs the sync oracle on an 8-device mesh,
# grow / shrink / indivisible-dim pad — the acceptance bar
# ---------------------------------------------------------------------------

_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from repro.fl.api import AFLServer, ShardedCoordinator, make_report

    d, c, g = 30, 5, 1.0          # 30 rows: indivisible by 4 and 8 (pad 2)
    rng = np.random.default_rng(7)
    reports = [make_report(k, rng.standard_normal((40, d)),
                           np.eye(c)[rng.integers(0, c, 40)], g)
               for k in range(12)]
    oracle = AFLServer(d, c, gamma=g)
    oracle.submit_many(reports)
    w_ref = oracle.solve(0.3)

    base = ShardedCoordinator(d, c, gamma=g, num_shards=4)
    base.submit_many(reports)
    state = base.state()

    for label, kw in [
        ("shrink-nontiled", dict(num_shards=2)),
        ("grow-nontiled", dict(num_shards=8)),
        ("tiled-pad-4", dict(num_shards=4, tiled_gram=True)),
        ("tiled-pad-8", dict(num_shards=8, tiled_gram=True,
                             distributed_factor=False)),
    ]:
        coord = ShardedCoordinator.from_state(state, **kw)
        err = np.abs(np.asarray(coord.solve(0.3), np.float64)
                     - w_ref).max()
        assert err < 1e-10, f"{label}: {err}"
        print(label, err)

    # live mesh growth/shrink, tiled: re-tile the global Gram in place
    t = ShardedCoordinator.from_state(state, num_shards=4, tiled_gram=True)
    assert t.grow(4) == 1 and t.num_shards == 8
    err = np.abs(np.asarray(t.solve(0.3), np.float64) - w_ref).max()
    assert err < 1e-10, f"tiled grow: {err}"
    assert t.shrink(6) == 2 and t.num_shards == 2
    err = np.abs(np.asarray(t.solve(0.3), np.float64) - w_ref).max()
    assert err < 1e-10, f"tiled shrink: {err}"

    # logical shards beyond the mesh: 12 accumulators on 8 devices
    wide = ShardedCoordinator.from_state(state, num_shards=12)
    err = np.abs(np.asarray(wide.solve(0.3), np.float64) - w_ref).max()
    assert err < 1e-10, f"wide: {err}"
    print("OK")
    """
)


def test_elastic_restore_8device_x64_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert res.returncode == 0, res.stderr
    assert "OK" in res.stdout
