"""Cross-backend parity + engine semantics tests.

The acceptance bar for the sufficient-statistics engine: the numpy-f64,
jax-f32, and Pallas-kernel paths compute the SAME statistics and the SAME
solutions on the same data, and every consumer-facing behavior (lazy γ,
RI restore, factor caching, multi-γ sweep) matches the paper math.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import analytic as al
from repro.core.engine import AnalyticEngine, SuffStats


def _data(seed=0, n=512, d=48, c=7, k=4):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = np.eye(c, dtype=np.float32)[rng.integers(0, c, n)]
    bounds = np.linspace(0, n, k + 1).astype(int)
    shards = [(x[a:b], y[a:b]) for a, b in zip(bounds, bounds[1:])]
    return x, y, shards


def _aggregate(engine, shards):
    stats = None
    for xs, ys in shards:
        s = engine.client_stats(xs, ys)
        stats = s if stats is None else engine.merge(stats, s)
    return stats


class TestCrossBackendParity:
    """numpy-f64 vs jax-f32 vs jax+Pallas-kernel agree on the same data."""

    def test_stats_and_solve_agree(self):
        x, y, shards = _data()
        engines = {
            "numpy_f64": AnalyticEngine("numpy_f64", gamma=1.0),
            "jax": AnalyticEngine("jax", gamma=1.0),
            "jax_kernel": AnalyticEngine("jax", gamma=1.0, use_kernel=True),
        }
        stats = {k: _aggregate(e, shards) for k, e in engines.items()}
        ref = stats["numpy_f64"]
        for name in ("jax", "jax_kernel"):
            s = stats[name]
            np.testing.assert_allclose(
                np.asarray(s.gram), ref.gram, rtol=2e-4, atol=2e-3)
            np.testing.assert_allclose(
                np.asarray(s.moment), ref.moment, rtol=2e-4, atol=2e-3)
            assert float(s.count) == float(ref.count) == len(x)
            assert float(s.clients) == float(ref.clients) == len(shards)
        # the solves agree across all three paths (f32 tolerance)
        w = {k: np.asarray(engines[k].solve(s, target_gamma=0.05))
             for k, s in stats.items()}
        np.testing.assert_allclose(w["jax"], w["numpy_f64"], atol=2e-3)
        np.testing.assert_allclose(w["jax_kernel"], w["numpy_f64"], atol=2e-3)

    def test_engine_matches_paper_literal_host_path(self):
        """Engine RI solve == literal Algorithm 1 (pairwise AA + RI restore)."""
        x, y, shards = _data(seed=1)
        eng = AnalyticEngine("numpy_f64", gamma=1.0)
        w_eng = eng.solve(_aggregate(eng, shards))
        ups = [al.local_stage(xs.astype(np.float64), ys.astype(np.float64), 1.0)
               for xs, ys in shards]
        w_lit = al.afl_aggregate(ups, use_ri=True, pairwise=True)
        np.testing.assert_allclose(w_eng, w_lit, rtol=1e-7, atol=1e-8)

    def test_engine_matches_federated_solve(self):
        """Covers the multidevice triage case in-process: the device
        federated_solve path == host engine on identical shard data."""
        from repro.core import streaming
        from repro.core.distributed import make_federated_solve

        x, y, shards = _data(seed=2, d=24, c=5)
        states = [streaming.update_state(
            streaming.init_state(24, 5), jnp.asarray(xs), jnp.asarray(ys))
            for xs, ys in shards]
        stacked = jax.tree.map(lambda *l: jnp.stack(l), *states)
        mesh = jax.make_mesh((1,), ("data",))
        w_dev = make_federated_solve(mesh, axis_names=("data",), gamma=1.0,
                                     target_gamma=0.05)(stacked)
        eng = AnalyticEngine("numpy_f64", gamma=1.0)
        w_host = eng.solve(_aggregate(eng, shards), target_gamma=0.05)
        np.testing.assert_allclose(np.asarray(w_dev), w_host, atol=2e-3)


class TestGammaBookkeeping:
    def test_lazy_gamma_equals_materialized(self):
        """raw-Gram + lazy kγ == the paper's per-client C_k^r accumulation."""
        x, y, shards = _data(seed=3)
        gamma = 2.5
        eng = AnalyticEngine("numpy_f64", gamma=gamma)
        stats = _aggregate(eng, shards)
        c_r = eng.regularized_gram(stats)
        expect = sum(xs.astype(np.float64).T @ xs.astype(np.float64)
                     + gamma * np.eye(48) for xs, ys in shards)
        np.testing.assert_allclose(c_r, expect, rtol=1e-10, atol=1e-8)

    def test_no_ri_solve_matches_biased_aggregate(self):
        x, y, shards = _data(seed=4)
        gamma = 10.0
        eng = AnalyticEngine("numpy_f64", gamma=gamma)
        stats = _aggregate(eng, shards)
        w_biased = eng.solve(stats, use_ri=False)
        ups = [al.local_stage(xs.astype(np.float64), ys.astype(np.float64), gamma)
               for xs, ys in shards]
        w_ref = al.afl_aggregate(ups, use_ri=False)
        np.testing.assert_allclose(w_biased, w_ref, rtol=1e-7, atol=1e-8)

    def test_ri_restore_explicit_form(self):
        """engine.ri_restore on regularized aggregates == joint solution."""
        x, y, shards = _data(seed=5)
        gamma = 1.0
        eng = AnalyticEngine("numpy_f64", gamma=gamma)
        ups = [al.local_stage(xs.astype(np.float64), ys.astype(np.float64), gamma)
               for xs, ys in shards]
        w_r, c_r = al.aggregate_sufficient_stats(ups)
        w = eng.ri_restore(w_r, c_r, len(ups), gamma)
        w_joint = al.ridge_solve(x.astype(np.float64), y.astype(np.float64), 0.0)
        np.testing.assert_allclose(w, w_joint, rtol=1e-6, atol=1e-7)


class TestFactorCaching:
    def test_factor_solve_equals_solve(self):
        x, y, shards = _data(seed=6)
        eng = AnalyticEngine("numpy_f64", gamma=1.0)
        stats = _aggregate(eng, shards)
        f = eng.factor(stats, target_gamma=0.1)
        np.testing.assert_allclose(
            eng.factor_solve(f, stats.moment),
            eng.solve(stats, target_gamma=0.1),
            rtol=1e-12, atol=1e-12)

    def test_server_cache_reused_and_invalidated(self):
        from repro.fl import AFLServer, make_report

        rng = np.random.default_rng(7)
        d, c = 16, 3
        xs = rng.standard_normal((6, 40, d))
        ys = np.eye(c)[rng.integers(0, c, (6, 40))]
        reps = [make_report(i, xs[i], ys[i], 1.0) for i in range(6)]
        srv = AFLServer(d, c, gamma=1.0)
        srv.submit_many(reps[:4])
        w1 = srv.solve()
        assert srv._factor_cache                      # factored once
        fact = srv._factor_cache[0.0]
        w2 = srv.solve()
        assert srv._factor_cache[0.0] is fact         # reused, not refactored
        np.testing.assert_array_equal(w1, w2)
        srv.submit(reps[4])                           # straggler arrives
        assert not srv._factor_cache                  # cache invalidated
        w3 = srv.solve()
        x_flat = xs[:5].reshape(-1, d)
        y_flat = ys[:5].reshape(-1, c)
        w_ref = al.ridge_solve(x_flat, y_flat, 0.0)
        np.testing.assert_allclose(w3, w_ref, rtol=1e-8, atol=1e-9)


class TestRankUpdate:
    """Rank-k Cholesky updates: the refactor-free serving seam."""

    @staticmethod
    def _stats_pair(eng, seed=0, n0=300, d=40, c=5, k=6):
        rng = np.random.default_rng(seed)
        x0 = rng.standard_normal((n0, d))
        y0 = np.eye(c)[rng.integers(0, c, n0)]
        xk = rng.standard_normal((k, d))
        yk = np.eye(c)[rng.integers(0, c, k)]
        s0 = eng.client_stats(x0, y0)
        s1 = eng.merge(s0, eng.client_stats(xk, yk))
        return s0, s1, xk

    def test_numpy_update_equals_refactor(self):
        eng = AnalyticEngine("numpy_f64", gamma=1.0)
        s0, s1, xk = self._stats_pair(eng)
        f0 = eng.factor(s0, target_gamma=0.1)
        f_upd = f0.rank_update(xk)
        f_ref = eng.factor(s1, target_gamma=0.1)
        np.testing.assert_allclose(f_upd.handle, f_ref.handle,
                                   rtol=1e-10, atol=1e-10)

    def test_jax_update_equals_refactor(self):
        eng = AnalyticEngine("jax", gamma=1.0)
        s0, s1, xk = self._stats_pair(eng, d=24, c=4)
        f0 = eng.factor(s0, target_gamma=0.1)
        f_upd = eng.factor_update(f0, s1, xk, target_gamma=0.1, max_rank=8)
        f_ref = eng.factor(s1, target_gamma=0.1)
        np.testing.assert_allclose(
            np.asarray(eng.factor_solve(f_upd, s1.moment)),
            np.asarray(eng.factor_solve(f_ref, s1.moment)),
            rtol=1e-4, atol=1e-4)

    def test_chained_updates_track_refactor(self):
        """Several sequential arrivals folded one by one == one refactor."""
        eng = AnalyticEngine("numpy_f64", gamma=1.0)
        rng = np.random.default_rng(3)
        d, c = 32, 4
        stats = eng.client_stats(rng.standard_normal((100, d)),
                                 np.eye(c)[rng.integers(0, c, 100)])
        f = eng.factor(stats, target_gamma=0.05)
        for _ in range(10):
            xk = rng.standard_normal((5, d))
            yk = np.eye(c)[rng.integers(0, c, 5)]
            stats = eng.merge(stats, eng.client_stats(xk, yk))
            # small test dims sit below the perf crossover — force the
            # update path, it's the numerics under test here
            f = eng.factor_update(f, stats, xk, target_gamma=0.05, max_rank=8)
        f_ref = eng.factor(stats, target_gamma=0.05)
        np.testing.assert_allclose(
            eng.factor_solve(f, stats.moment),
            eng.factor_solve(f_ref, stats.moment), rtol=1e-9, atol=1e-11)

    def test_high_rank_delta_falls_back_to_refactor(self):
        eng = AnalyticEngine("numpy_f64", gamma=1.0)
        s0, s1, _ = self._stats_pair(eng, d=40)
        f0 = eng.factor(s0)
        dense = np.random.default_rng(1).standard_normal((40, 40))
        f = eng.factor_update(f0, s1, dense)       # rank d > d//4 budget
        np.testing.assert_allclose(f.handle, eng.factor(s1).handle,
                                   rtol=1e-12, atol=1e-12)

    def test_pinv_fallback_not_updatable(self):
        """γ=0 rank-deficient factors refuse rank_update but factor_update
        still produces a correct (refactored) answer."""
        eng = AnalyticEngine("numpy_f64", gamma=1.0)
        rng = np.random.default_rng(2)
        d, c = 16, 3
        x = rng.standard_normal((6, d))            # n < d ⇒ singular at γ=0
        s0 = eng.client_stats(x, np.eye(c)[rng.integers(0, c, 6)])
        f0 = eng.factor(s0)
        assert not f0.updatable
        with pytest.raises(ValueError):
            f0.rank_update(x)
        xk = rng.standard_normal((3, d))
        s1 = eng.merge(s0, eng.client_stats(xk, np.eye(c)[[0, 1, 2]]))
        w = eng.factor_solve(eng.factor_update(f0, s1, xk), s1.moment)
        np.testing.assert_allclose(
            w, eng.factor_solve(eng.factor(s1), s1.moment),
            rtol=1e-12, atol=1e-12)

    def test_no_ri_factor_update_refactors(self):
        """use_ri=False systems gain a full-rank +γI per arrival — the
        low-rank update would be wrong, so factor_update must refactor."""
        eng = AnalyticEngine("numpy_f64", gamma=2.0)
        s0, s1, xk = self._stats_pair(eng)
        f0 = eng.factor(s0, use_ri=False)
        f = eng.factor_update(f0, s1, xk, use_ri=False)
        np.testing.assert_allclose(f.handle, eng.factor(s1, use_ri=False).handle,
                                   rtol=1e-12, atol=1e-12)


class TestMultiGamma:
    def test_matches_individual_solves(self):
        x, y, shards = _data(seed=8)
        eng = AnalyticEngine("numpy_f64", gamma=1.0)
        stats = _aggregate(eng, shards)
        gammas = [0.01, 0.1, 1.0, 10.0]
        ws = eng.solve_multi_gamma(stats, gammas)
        for g, w in zip(gammas, ws):
            np.testing.assert_allclose(
                w, eng.solve(stats, target_gamma=g), rtol=1e-7, atol=1e-8)

    def test_jax_backend(self):
        x, y, shards = _data(seed=9, d=24, c=4)
        eng = AnalyticEngine("jax", gamma=1.0)
        eng_ref = AnalyticEngine("numpy_f64", gamma=1.0)
        ws = eng.solve_multi_gamma(_aggregate(eng, shards), [0.1, 1.0])
        ws_ref = eng_ref.solve_multi_gamma(_aggregate(eng_ref, shards), [0.1, 1.0])
        for w, w_ref in zip(ws, ws_ref):
            np.testing.assert_allclose(np.asarray(w), w_ref, atol=3e-3)

    def test_rank_deficient_gamma_zero(self):
        """γ=0 on singular stats: eigen path == pinv semantics, stays finite."""
        rng = np.random.default_rng(10)
        x = rng.standard_normal((8, 16))  # N < d
        y = np.eye(3)[rng.integers(0, 3, 8)]
        eng = AnalyticEngine("numpy_f64")
        stats = eng.client_stats(x, y)
        (w0,) = eng.solve_multi_gamma(stats, [0.0])
        assert np.all(np.isfinite(w0))
        np.testing.assert_allclose(
            w0, np.linalg.pinv(x) @ y, rtol=1e-6, atol=1e-8)


class TestKahan:
    def test_kahan_tracks_f64_better_than_plain(self):
        """Many small batches in f32: compensated accumulation stays at least
        as close to the f64 reference as plain summation."""
        rng = np.random.default_rng(11)
        d, c, batches = 12, 3, 400
        plain = AnalyticEngine("jax", gamma=1.0)
        kahan = AnalyticEngine("jax", gamma=1.0, kahan=True)
        host = AnalyticEngine("numpy_f64", gamma=1.0)
        sp, sk, sh = plain.init(d, c), kahan.init(d, c), host.init(d, c)
        for _ in range(batches):
            x = (1.0 + rng.standard_normal((4, d)) * 1e-3).astype(np.float32)
            y = np.eye(c, dtype=np.float32)[rng.integers(0, c, 4)]
            sp = plain.update(sp, jnp.asarray(x), jnp.asarray(y))
            sk = kahan.update(sk, jnp.asarray(x), jnp.asarray(y))
            sh = host.update(sh, x, y)
        err_plain = np.abs(np.asarray(sp.gram, np.float64) - sh.gram).max()
        err_kahan = np.abs(np.asarray(sk.gram, np.float64) - sh.gram).max()
        assert err_kahan <= err_plain * 1.0 + 1e-9
        # compensation never leaks into the public 4-leaf psum layout
        assert sp.gram_c is None and sk.gram_c is not None

    def test_kahan_requires_jax(self):
        with pytest.raises(ValueError):
            AnalyticEngine("numpy_f64", kahan=True)


def test_kernel_requires_jax_backend():
    with pytest.raises(ValueError):
        AnalyticEngine("numpy_f64", use_kernel=True)


def test_streaming_wrappers_delegate(monkeypatch):
    """core.streaming stays the paper-literal device API over the engine."""
    from repro.core import streaming

    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    y = jnp.asarray(np.eye(4)[rng.integers(0, 4, 64)], jnp.float32)
    st = streaming.update_state(streaming.init_state(8, 4), x, y)
    stats = streaming.to_stats(st, clients=1.0)
    assert isinstance(stats, SuffStats)
    np.testing.assert_allclose(np.asarray(st.gram), np.asarray(x.T @ x),
                               rtol=2e-4, atol=2e-3)
    w = streaming.solve(st, gamma=0.5)
    w_ref = al.ridge_solve(np.asarray(x, np.float64), np.asarray(y, np.float64), 0.5)
    np.testing.assert_allclose(np.asarray(w), w_ref, atol=2e-3)
