"""Integration tests: partitioners, AFL end-to-end, gradient baselines."""

import numpy as np
import pytest

from repro.config import FLConfig
from repro.data import synthetic as D
from repro.fl import afl, baselines
from repro.fl.partition import dirichlet, iid, make_partition, sharding


class TestPartition:
    def setup_method(self):
        self.labels = np.repeat(np.arange(10), 100)

    def test_iid_covers_all(self):
        parts = iid(self.labels, 7, seed=0)
        allidx = np.sort(np.concatenate(parts))
        np.testing.assert_array_equal(allidx, np.arange(1000))

    def test_dirichlet_covers_all_and_heterogeneous(self):
        parts = dirichlet(self.labels, 10, alpha=0.05, seed=0)
        allidx = np.sort(np.concatenate([p for p in parts if len(p)]))
        np.testing.assert_array_equal(allidx, np.arange(1000))
        # extreme alpha → most clients see few classes
        n_classes = [len(np.unique(self.labels[p])) for p in parts if len(p) > 0]
        assert np.median(n_classes) <= 4

    def test_dirichlet_alpha_controls_heterogeneity(self):
        few = dirichlet(self.labels, 10, alpha=0.01, seed=1)
        many = dirichlet(self.labels, 10, alpha=100.0, seed=1)
        div = lambda parts: np.mean(
            [len(np.unique(self.labels[p])) for p in parts if len(p) > 0])
        assert div(few) < div(many)

    def test_sharding_classes_per_client(self):
        parts = sharding(self.labels, 50, shards_per_client=2, seed=0)
        allidx = np.sort(np.concatenate(parts))
        np.testing.assert_array_equal(allidx, np.arange(1000))
        n_classes = [len(np.unique(self.labels[p])) for p in parts]
        assert max(n_classes) <= 3  # s=2 shards → at most ~2-3 labels

    def test_make_partition_dispatch(self):
        for scheme in ("iid", "niid1", "niid2"):
            parts = make_partition(self.labels, 5, scheme)
            assert len(parts) == 5
        with pytest.raises(ValueError):
            make_partition(self.labels, 5, "nope")


class TestAFLEndToEnd:
    @pytest.fixture(scope="class")
    def data(self):
        ds = D.gaussian_mixture(n=4000, dim=64, num_classes=10, seed=0)
        return D.train_test_split(ds, 0.25, seed=0)

    def test_afl_equals_joint_any_partition(self, data):
        train, test = data
        w_joint, acc_joint = afl.joint_ridge(train, test, gamma=0.0)
        for scheme, kw in [("iid", {}), ("niid1", dict(alpha=0.01)),
                           ("niid2", dict(shards_per_client=2))]:
            fl = FLConfig(num_clients=20, gamma=1.0, partition=scheme, **kw)
            res = afl.run_afl(train, test, fl)
            assert abs(res.accuracy - acc_joint) < 1e-9, scheme
            assert np.abs(res.weight - w_joint).max() < 1e-6, scheme

    def test_client_number_invariance(self, data):
        train, test = data
        accs = set()
        for k in (5, 50, 200):
            res = afl.run_afl(train, test, FLConfig(num_clients=k, partition="iid"))
            accs.add(round(res.accuracy, 12))
        assert len(accs) == 1  # identical — zero std, like the paper

    def test_afl_beats_local_only_under_noniid(self, data):
        train, test = data
        fl = FLConfig(num_clients=20, partition="niid1", alpha=0.05)
        res = afl.run_afl(train, test, fl)
        loc_avg, loc_max = baselines.run_local_only(train, test, fl, epochs=3)
        assert res.accuracy > loc_avg + 0.05

    def test_fedavg_degrades_with_heterogeneity_afl_does_not(self):
        # Harder task than the shared fixture: at sep=1.0/C=10 every method
        # saturates at 1.0 and no degradation is observable. sep=0.4/C=50
        # reproduces the paper's qualitative Table-2 pattern.
        ds = D.gaussian_mixture(n=4000, dim=64, num_classes=50,
                                separation=0.4, seed=0)
        train, test = D.train_test_split(ds, 0.25, seed=0)
        acc_fa, acc_afl = {}, {}
        for alpha in (100.0, 0.01):
            fl = FLConfig(num_clients=20, partition="niid1", alpha=alpha)
            acc_fa[alpha] = baselines.run_gradient_fl(
                train, test, fl, rounds=10).accuracy
            acc_afl[alpha] = afl.run_afl(train, test, fl).accuracy
        assert acc_afl[100.0] == acc_afl[0.01]           # invariance
        assert acc_fa[100.0] - acc_fa[0.01] > 0.01       # FedAvg degrades
        assert acc_afl[0.01] > acc_fa[0.01]              # AFL wins when non-IID

    def test_rank_deficient_many_clients(self, data):
        """K large enough that N_k < d — needs RI (paper Table 3)."""
        train, test = data  # d=64; 3000 train / 300 clients = 10 < 64
        fl = FLConfig(num_clients=300, gamma=1.0, partition="iid")
        w_joint, acc_joint = afl.joint_ridge(train, test, gamma=0.0)
        res = afl.run_afl(train, test, fl)
        assert abs(res.accuracy - acc_joint) < 1e-9


def test_fedprox_close_to_fedavg_smoke():
    ds = D.gaussian_mixture(n=1200, dim=32, num_classes=5, seed=3)
    train, test = D.train_test_split(ds, 0.25, seed=1)
    fl = FLConfig(num_clients=10, partition="niid1", alpha=0.5)
    fa = baselines.run_gradient_fl(train, test, fl, method="fedavg", rounds=6)
    fp = baselines.run_gradient_fl(train, test, fl, method="fedprox", rounds=6)
    assert abs(fa.accuracy - fp.accuracy) < 0.15
    assert fa.accuracy > 0.3


def test_token_dataset_with_frozen_backbone():
    """AFL through a real (reduced) transformer backbone on token data."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import transformer as T

    cfg = get_config("minicpm_2b").reduced(num_classes=8)
    params = T.init_params(jax.random.key(0), cfg)
    ds = D.token_classification(n=600, seq=16, vocab=cfg.vocab_size,
                                num_classes=8, skew=4.0, seed=0)
    train, test = D.train_test_split(ds, 0.25, seed=0)

    @jax.jit
    def backbone(tokens):
        h = T.forward(params, cfg, {"tokens": jnp.asarray(tokens)})
        return T.pool(h)

    from repro.config import FLConfig
    fl = FLConfig(num_clients=12, partition="niid2", shards_per_client=2)
    res = afl.run_afl(train, test, fl, backbone_fn=backbone)
    _, acc_joint = afl.joint_ridge(train, test, gamma=0.0, backbone_fn=backbone)
    assert abs(res.accuracy - acc_joint) < 1e-9
    assert res.accuracy > 1.5 / 8  # clearly better than chance
