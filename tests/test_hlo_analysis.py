"""Unit tests for the loop-aware HLO cost analyzer (launch/hlo_analysis)."""

from repro.launch import hlo_analysis as HA

MODULE = """\
HloModule test

%inner (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  ROOT %e = f32[8,16]{1,0} exponential(%p0)
}

%body (param: (s32[], f32[8,16], f32[16,32], f32[8,32])) -> (s32[], f32[8,16], f32[16,32], f32[8,32]) {
  %param = (s32[], f32[8,16], f32[16,32], f32[8,32]) parameter(0)
  %i = s32[] get-tuple-element(%param), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%param), index=1
  %w = f32[16,32]{1,0} get-tuple-element(%param), index=2
  %acc = f32[8,32]{1,0} get-tuple-element(%param), index=3
  %d = f32[8,32]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,32]{1,0} all-reduce(%d), replica_groups={}, to_apply=%sum
  %acc2 = f32[8,32]{1,0} add(%acc, %ar)
  %copy.carry = f32[8,16]{1,0} copy(%x)
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16], f32[16,32], f32[8,32]) tuple(%i2, %copy.carry, %w, %acc2)
}

%cond (param.1: (s32[], f32[8,16], f32[16,32], f32[8,32])) -> pred[] {
  %param.1 = (s32[], f32[8,16], f32[16,32], f32[8,32]) parameter(0)
  %i.1 = s32[] get-tuple-element(%param.1), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i.1, %n), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x0: f32[8,16], w0: f32[16,32]) -> f32[8,32] {
  %x0 = f32[8,16]{1,0} parameter(0)
  %w0 = f32[16,32]{1,0} parameter(1)
  %zero = s32[] constant(0)
  %acc0 = f32[8,32]{1,0} broadcast(%zero), dimensions={}
  %init = (s32[], f32[8,16], f32[16,32], f32[8,32]) tuple(%zero, %x0, %w0, %acc0)
  %loop = (s32[], f32[8,16], f32[16,32], f32[8,32]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,32]{1,0} get-tuple-element(%loop), index=3
}
"""


def test_type_bytes_and_cap():
    assert HA.type_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert HA.type_bytes("bf16[4,4]") == 32
    assert HA.type_bytes("(f32[2], s32[3])") == 8 + 12
    assert HA.type_bytes("f32[8,16]", width_cap=2) == 8 * 16 * 2


def test_loop_multiplied_dot_flops():
    cost = HA.analyze(MODULE)
    # dot: 2*8*32*16 = 8192 flops, ×5 trips
    assert cost.flops == 5 * 2 * 8 * 32 * 16


def test_loop_multiplied_collectives_and_width_cap():
    cost = HA.analyze(MODULE)
    assert cost.collective_bytes["all-reduce"] == 5 * 8 * 32 * 4
    capped = HA.analyze(MODULE, collective_width_cap=2)
    assert capped.collective_bytes["all-reduce"] == 5 * 8 * 32 * 2


def test_carry_copy_separated():
    cost = HA.analyze(MODULE)
    # copy of the loop-carried x: 2 * 8*16*4 per iteration, not HBM traffic
    assert cost.carry_copy_bytes == 5 * 2 * 8 * 16 * 4


def test_parse_module_structure():
    comps = HA.parse_module(MODULE)
    assert "__entry__" in comps and "body" in comps and "cond" in comps
    body = comps["body"]
    ops = [i.op for i in body.instrs]
    assert "dot" in ops and "all-reduce" in ops
    whiles = [i for i in comps["__entry__"].instrs if i.op == "while"]
    assert whiles and whiles[0].trip_count() == 5
