"""Shape/dtype/mask sweep of the flash-attention Pallas kernel vs jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention


def _qkv(seed, b, hq, hkv, sq, skv, d, dtype):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, skv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, skv, d), jnp.float32).astype(dtype)
    return q, k, v


def _check(q, k, v, dtype, **kw):
    out = flash_attention(q, k, v, interpret=True, block_q=64, block_k=64, **kw)
    want = ref.mha_ref(q, k, v, **kw)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol * 20,
    )


@pytest.mark.parametrize(
    "b,hq,hkv,s,d",
    [
        (1, 4, 4, 128, 64),    # MHA
        (2, 8, 2, 128, 64),    # GQA 4:1
        (1, 4, 1, 96, 80),     # MQA, ragged seq + ragged head dim
        (1, 2, 2, 256, 128),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_causal_self_attention(b, hq, hkv, s, d, dtype):
    q, k, v = _qkv(0, b, hq, hkv, s, s, d, dtype)
    _check(q, k, v, dtype, causal=True)


def test_non_causal():
    q, k, v = _qkv(1, 1, 4, 4, 128, 128, 64, jnp.float32)
    _check(q, k, v, jnp.float32, causal=False)


@pytest.mark.parametrize("window", [32, 64, 100])
def test_sliding_window(window):
    q, k, v = _qkv(2, 1, 4, 2, 192, 192, 64, jnp.float32)
    _check(q, k, v, jnp.float32, causal=True, window=window)


def test_decode_single_query():
    """serve_step shape: Sq=1 attending to a long cache with q_offset."""
    skv = 256
    q, k, v = _qkv(3, 2, 8, 2, 1, skv, 64, jnp.float32)
    _check(q, k, v, jnp.float32, causal=True, q_offset=skv - 1)


def test_decode_windowed():
    skv = 300
    q, k, v = _qkv(4, 1, 4, 4, 1, skv, 64, jnp.float32)
    _check(q, k, v, jnp.float32, causal=True, window=128, q_offset=skv - 1)


def test_cross_attention_rectangular():
    """enc-dec: no causal mask, Sq != Skv."""
    q, k, v = _qkv(5, 1, 4, 4, 64, 200, 64, jnp.float32)
    _check(q, k, v, jnp.float32, causal=False)


def test_scale_override():
    q, k, v = _qkv(6, 1, 2, 2, 64, 64, 64, jnp.float32)
    _check(q, k, v, jnp.float32, causal=True, scale=0.25)
