"""Shape/dtype sweep of the Gram Pallas kernel vs the jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.gram import gram_update


def _data(seed, n, d, c, dtype):
    kx, ky = jax.random.split(jax.random.key(seed))
    x = jax.random.normal(kx, (n, d), jnp.float32).astype(dtype)
    y = jax.nn.one_hot(jax.random.randint(ky, (n,), 0, c), c, dtype=dtype)
    return x, y


@pytest.mark.parametrize(
    "n,d,c",
    [
        (64, 32, 10),        # tiny, everything padded
        (512, 128, 100),     # exact block multiples
        (1000, 200, 37),     # ragged everywhere
        (2048, 384, 128),    # multi-tile d
        (8, 256, 5),         # n smaller than a block
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_matches_ref(n, d, c, dtype):
    x, y = _data(0, n, d, c, dtype)
    g, q = gram_update(x, y, interpret=True)
    g_ref, q_ref = ref.gram_ref(x, y)
    # f32 tolerance covers reduction-order differences on long N sweeps.
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=tol, atol=tol * 10)
    np.testing.assert_allclose(np.asarray(q), np.asarray(q_ref), rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("block_d,block_n", [(128, 256), (256, 512)])
def test_gram_block_shapes(block_d, block_n):
    x, y = _data(1, 700, 300, 50, jnp.float32)
    g, q = gram_update(x, y, block_d=block_d, block_n=block_n, interpret=True)
    g_ref, q_ref = ref.gram_ref(x, y)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(q), np.asarray(q_ref), rtol=1e-5, atol=1e-4)


def test_gram_symmetry_and_psd():
    x, y = _data(2, 256, 64, 8, jnp.float32)
    g, _ = gram_update(x, y, interpret=True)
    g = np.asarray(g)
    np.testing.assert_allclose(g, g.T, atol=1e-5)
    assert np.linalg.eigvalsh(g).min() > -1e-3
