"""Unit tests for model substrates: SSD scan, sdpa, MoE, xLSTM, layers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MoEConfig, SSMConfig
from repro.kernels import ref as kref
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X


# --------------------------------------------------------------------- sdpa
@pytest.mark.parametrize("window", [None, 16, 48])
@pytest.mark.parametrize("sq,skv,off", [(64, 64, 0), (1, 64, 63)])
def test_sdpa_matches_oracle(window, sq, skv, off):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (2, 8, sq, 32))
    k = jax.random.normal(ks[1], (2, 2, skv, 32))
    v = jax.random.normal(ks[2], (2, 2, skv, 32))
    out = L.sdpa(q, k, v, causal=True, window=window, q_offset=off)
    want = kref.mha_ref(q, k, v, causal=True, window=window, q_offset=off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_sdpa_chunked_path_matches_direct():
    """Force the two-level online-softmax path and compare to the direct path."""
    ks = jax.random.split(jax.random.key(1), 3)
    s = 96
    q = jax.random.normal(ks[0], (1, 4, s, 16))
    k = jax.random.normal(ks[1], (1, 4, s, 16))
    v = jax.random.normal(ks[2], (1, 4, s, 16))
    direct = L.sdpa(q, k, v, causal=True)
    chunked = L.sdpa(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    # trip the chunked branch by monkeypatching threshold via large fake seq:
    big = L.sdpa(
        jnp.tile(q, (1, 1, 1, 1)), k, v, causal=True, q_chunk=32, kv_chunk=32
    )
    np.testing.assert_allclose(np.asarray(direct), np.asarray(chunked), atol=2e-5)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(big), atol=2e-5)


def test_sdpa_chunked_branch_explicit(monkeypatch):
    """Shrink the direct-path threshold so the scan path actually runs."""
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 16))
    k = jax.random.normal(ks[1], (1, 2, 128, 16))
    v = jax.random.normal(ks[2], (1, 2, 128, 16))
    want = kref.mha_ref(q, k, v, causal=True, window=40)
    import repro.models.layers as layers_mod

    src = layers_mod.sdpa.__wrapped__ if hasattr(layers_mod.sdpa, "__wrapped__") else None
    # directly call with tiny chunks after masking the threshold
    out = layers_mod.sdpa(q, k, v, causal=True, window=40, q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------- SSM
def _mamba_sequential(p, x, cfg: SSMConfig):
    """Step-by-step oracle: run mamba_decode token by token."""
    b, s, d = x.shape
    state = S.init_mamba_state(b, d, cfg)
    outs = []
    for t in range(s):
        y, state = S.mamba_decode(p, x[:, t : t + 1], state, cfg)
        outs.append(y)
    return jnp.concatenate(outs, 1), state


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_mamba_chunked_matches_sequential(chunk):
    cfg = SSMConfig(d_state=8, d_conv=4, expand=2, chunk=chunk, num_heads=4)
    d, b, s = 32, 2, 24
    p = S.init_mamba(jax.random.key(0), d, cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (b, s, d)) * 0.5
    y_par, st_par = S.mamba_apply(p, x, cfg, return_state=True)
    y_seq, st_seq = _mamba_sequential(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq), atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(st_par["ssd"]), np.asarray(st_seq["ssd"]), atol=1e-4
    )


def test_mamba_chunk_size_invariance():
    d, b, s = 32, 1, 40
    x = jax.random.normal(jax.random.key(2), (b, s, d)) * 0.5
    outs = []
    for chunk in (5, 8, 40):
        cfg = SSMConfig(d_state=8, chunk=chunk, num_heads=4)
        p = S.init_mamba(jax.random.key(3), d, cfg, jnp.float32)
        outs.append(np.asarray(S.mamba_apply(p, x, cfg)))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-4)


def test_mamba_state_continuation():
    """apply(x) == apply(x1) then apply(x2, init_state) — partition invariance
    of the recurrence (mirrors the AFL data-partition invariance at the SSM
    level)."""
    cfg = SSMConfig(d_state=8, chunk=8, num_heads=4)
    d, b = 32, 2
    p = S.init_mamba(jax.random.key(4), d, cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(5), (b, 30, d)) * 0.5
    y_full, st_full = S.mamba_apply(p, x, cfg, return_state=True)
    y1, st1 = S.mamba_apply(p, x[:, :13], cfg, return_state=True)
    y2, st2 = S.mamba_apply(p, x[:, 13:], cfg, init_state=st1, return_state=True)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), atol=1e-4
    )
    np.testing.assert_allclose(np.asarray(st2["ssd"]), np.asarray(st_full["ssd"]), atol=1e-4)


# --------------------------------------------------------------------- MoE
def test_moe_group_invariance_without_drops():
    """With capacity ≥ group size, output is independent of grouping."""
    moe_a = MoEConfig(num_experts=4, top_k=2, capacity_factor=2.0, group_size=8)
    moe_b = MoEConfig(num_experts=4, top_k=2, capacity_factor=2.0, group_size=32)
    p = M.init_moe(jax.random.key(0), 16, 32, moe_a, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 32, 16))
    out_a, _ = M.moe_apply(p, x, moe_a, "swiglu")
    out_b, _ = M.moe_apply(p, x, moe_b, "swiglu")
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b), atol=1e-5)


def test_moe_matches_dense_expert_sum():
    """Oracle: explicit per-token top-k expert mixture."""
    moe = MoEConfig(num_experts=4, top_k=2, capacity_factor=2.0, group_size=64)
    d, ff = 16, 32
    p = M.init_moe(jax.random.key(2), d, ff, moe, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.key(3), (1, 8, d))
    out, aux = M.moe_apply(p, x, moe, "swiglu")

    toks = np.asarray(x.reshape(-1, d))
    logits = toks @ np.asarray(p["router"])
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    want = np.zeros_like(toks)
    for t in range(toks.shape[0]):
        pr = np.asarray(probs[t])
        top = np.argsort(pr)[::-1][:2]
        w = pr[top] / pr[top].sum()
        for e, wi in zip(top, w):
            h = jax.nn.silu(toks[t] @ np.asarray(p["w_gate"][e])) * (
                toks[t] @ np.asarray(p["w_up"][e])
            )
            want[t] += wi * np.asarray(h @ np.asarray(p["w_down"][e]))
    np.testing.assert_allclose(np.asarray(out).reshape(-1, d), want, atol=1e-4)
    assert float(aux) > 0


def test_moe_aux_loss_balanced_router():
    """Uniform router → aux ≈ 1 (its minimum for balanced load)."""
    moe = MoEConfig(num_experts=8, top_k=2, group_size=128)
    p = M.init_moe(jax.random.key(4), 8, 16, moe, "gelu", jnp.float32)
    p["router"] = jnp.zeros_like(p["router"])  # perfectly uniform probs
    x = jax.random.normal(jax.random.key(5), (4, 64, 8))
    _, aux = M.moe_apply(p, x, moe, "gelu")
    assert abs(float(aux) - 1.0) < 0.2


# -------------------------------------------------------------------- xLSTM
def test_mlstm_state_continuation():
    d, h, b = 32, 4, 2
    p = X.init_mlstm(jax.random.key(0), d, h, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (b, 20, d)) * 0.5
    y_full = X.mlstm_apply(p, x, h)
    y1, st = X.mlstm_apply(p, x[:, :9], h, return_state=True)
    y2 = X.mlstm_apply(p, x[:, 9:], h, init_state=st)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), atol=1e-5
    )


def test_slstm_state_continuation():
    d, h, b = 32, 4, 2
    p = X.init_slstm(jax.random.key(2), d, h, jnp.float32)
    x = jax.random.normal(jax.random.key(3), (b, 20, d)) * 0.5
    y_full = X.slstm_apply(p, x, h)
    y1, st = X.slstm_apply(p, x[:, :7], h, return_state=True)
    y2 = X.slstm_apply(p, x[:, 7:], h, init_state=st)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), atol=1e-5
    )


def test_mlstm_finite_long_sequence():
    """Exp gating is stabilized — no overflow over long ranges."""
    d, h = 16, 2
    p = X.init_mlstm(jax.random.key(4), d, h, jnp.float32)
    x = jax.random.normal(jax.random.key(5), (1, 512, d)) * 3.0
    y = X.mlstm_apply(p, x, h)
    assert bool(jnp.isfinite(y).all())


# ------------------------------------------------------------------- layers
def test_rope_rotation_preserves_norm():
    x = jax.random.normal(jax.random.key(0), (1, 2, 8, 32))
    y = L.apply_rope(x, jnp.arange(8), 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_rope_relative_property():
    """q·k after rope depends only on relative distance."""
    d = 32
    q = jax.random.normal(jax.random.key(1), (1, 1, 1, d))
    k = jax.random.normal(jax.random.key(2), (1, 1, 1, d))
    def dot_at(pq, pk):
        qr = L.apply_rope(q, jnp.array([pq]), 100.0)
        kr = L.apply_rope(k, jnp.array([pk]), 100.0)
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-4
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-4


def test_norms():
    p = L.init_norm(16, jnp.float32)
    x = jax.random.normal(jax.random.key(3), (2, 4, 16)) * 10
    y = L.norm_apply(p, x, 1e-6, "rms")
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)
    pl_ = L.init_norm(16, jnp.float32, with_bias=True)
    yl = L.norm_apply(pl_, x, 1e-6, "layer")
    np.testing.assert_allclose(np.mean(np.asarray(yl), -1), 0.0, atol=1e-5)
