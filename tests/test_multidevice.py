"""Multi-device semantics tests, run in subprocesses so the forced device
count cannot leak into (or be blocked by) the main test process's jax.

Covers the two places where the distributed path must equal the host math:
  1. federated_solve (one psum over the mesh) == core.analytic host solve.
  2. shard_map MoE FFN == the single-program dense path.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_federated_solve_matches_host_analytic():
    _run("""
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.core import analytic as al, streaming
    from repro.core.distributed import make_federated_solve

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    d, c, n_per, K = 32, 8, 64, 4   # one client cohort per 'data' shard
    xs = [rng.standard_normal((n_per, d)).astype(np.float32) for _ in range(K)]
    ys = [np.eye(c, dtype=np.float32)[rng.integers(0, c, n_per)] for _ in range(K)]

    # host reference: paper Algorithm 1 (pairwise AA + RI)
    ups = [al.local_stage(x, y, gamma=1.0) for x, y in zip(xs, ys)]
    w_ref = al.afl_aggregate(ups, use_ri=True, pairwise=True)

    # device path: per-shard raw Gram stats → ONE all-reduce + solve
    states = [streaming.update_state(streaming.init_state(d, c),
                                     jnp.asarray(x), jnp.asarray(y))
              for x, y in zip(xs, ys)]
    stacked = jax.tree.map(lambda *l: jnp.stack(l), *states)
    solve = make_federated_solve(mesh, axis_names=("data",), gamma=1.0)
    w = np.asarray(solve(stacked))
    err = np.abs(w - w_ref).max()
    assert err < 5e-4, f"device/host mismatch: {err}"
    print("ok", err)
    """)


def test_shard_map_moe_matches_dense():
    _run("""
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.config import MoEConfig
    from repro.core import act
    from repro.models import moe as M

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    moe = MoEConfig(num_experts=4, top_k=2, capacity_factor=2.0, group_size=16)
    d, ff = 32, 64
    p = M.init_moe(jax.random.key(0), d, ff, moe, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 32, d), jnp.float32)

    ref, aux_ref = M.moe_apply(p, x, moe, "swiglu")           # dense path

    def run(p, x):
        with act.activation_policy(mesh, ("data",), ("model",)):
            return M.moe_apply(p, x, moe, "swiglu")

    out, aux = jax.jit(run)(p, x)                              # shard_map path
    err = float(jnp.abs(out - ref).max())
    assert err < 1e-5, err
    assert abs(float(aux) - float(aux_ref)) < 1e-6
    print("ok", err)
    """)


def test_analytic_train_step_multidevice_lowering():
    """The production train step lowers + runs on a real (tiny) mesh."""
    _run("""
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.configs.registry import get_config
    from repro.core import act, streaming
    from repro.launch import mesh as MM, sharding as SH, steps as ST
    from repro.launch.inputs import sample_batch
    from repro.models import transformer as T

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("granite_moe_3b_a800m").reduced(num_classes=8)
    params = T.init_params(jax.random.key(0), cfg)
    state = streaming.init_state(cfg.d_model, cfg.num_classes)
    batch = sample_batch(cfg, 8, 32, seed=0)

    def step(params, state, batch):
        with act.activation_policy(mesh, MM.batch_axes(mesh),
                                   MM.model_axes(mesh)):
            return ST.make_analytic_train_step(cfg)(params, state, batch)

    p_sh = SH.param_shardings(jax.eval_shape(lambda: params), mesh)
    b_sh = SH.batch_shardings(cfg, jax.eval_shape(lambda: batch), mesh)
    st_sh = SH.state_shardings(mesh)
    fn = jax.jit(step, in_shardings=(p_sh, st_sh, b_sh), out_shardings=st_sh)
    out = fn(params, state, batch)
    g = np.asarray(out.gram)
    assert out.gram.shape == (cfg.d_model, cfg.d_model)
    assert np.isfinite(g).all() and float(out.count) == 8 * 1
    # vs single-device reference
    ref = ST.make_analytic_train_step(cfg)(params, state, batch)
    err = np.abs(g - np.asarray(ref.gram)).max() / max(np.abs(g).max(), 1)
    assert err < 5e-5, err
    print("ok", err)
    """)
