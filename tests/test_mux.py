"""AFLMux transport/server tests: the parts conformance can't see.

The conformance matrix (test_coordinator_conformance.py) already drives a
RemoteCoordinator through TLS + auth mux as its fifth kind; this file locks
down the transport itself: genuine stream interleaving on one socket, frame
robustness (torn / oversized / corrupt frames answered with GOAWAY, server
survives for the next connection), graceful GOAWAY drain, the
never-replay-a-sent-submit discipline, per-stream flow control under a tiny
window, TLS handshake failure modes (pinning, mutual TLS), and bearer-token
auth leaving coordinator state untouched on every transport.
"""

import socket
import ssl
import struct
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.fl import (AFLServer, FederationService, HttpTransport,
                      MuxTransport, RemoteCoordinator, Transport,
                      generate_self_signed_cert, make_report, mux_ping,
                      probe_alive, serve_http, serve_mux, server_ssl_context)
from repro.fl import errors as E
from repro.fl.mux import (F_END_STREAM, PREFACE, T_DATA, T_GOAWAY, T_HEADERS,
                          _HDR, _U32)

DIM, C, GAMMA = 16, 4, 1.0


def _reports(n=4, rows=5, seed=0, start_id=0):
    rng = np.random.default_rng(seed)
    return [make_report(start_id + k, rng.standard_normal((rows, DIM)),
                        np.eye(C)[rng.integers(0, C, rows)], GAMMA)
            for k in range(n)]


def _service(**kw):
    return FederationService(AFLServer(DIM, C, gamma=GAMMA), **kw)


@pytest.fixture(scope="module")
def tls_files():
    with tempfile.TemporaryDirectory() as td:
        yield generate_self_signed_cert(td)


# ---------------------------------------------------------------------------
# Basics
# ---------------------------------------------------------------------------


class TestMuxBasics:
    def test_satisfies_transport_protocol(self):
        with serve_mux(_service()) as srv:
            tr = MuxTransport(srv.url)
            try:
                assert isinstance(tr, Transport)
                assert isinstance(HttpTransport("http://127.0.0.1:1"),
                                  Transport)
            finally:
                tr.close()

    def test_rejects_non_mux_scheme(self):
        with pytest.raises(ValueError):
            MuxTransport("http://127.0.0.1:8790")

    def test_ping_and_probe(self):
        with serve_mux(_service()) as srv:
            assert mux_ping(srv.url) >= 0.0
            assert probe_alive(srv.url)

    def test_probe_alive_speaks_http_too(self):
        with serve_http(_service()) as srv:
            assert probe_alive(srv.url)

    def test_probe_dead_endpoint_is_false_not_an_exception(self):
        lsock = socket.create_server(("127.0.0.1", 0))
        port = lsock.getsockname()[1]
        lsock.close()                          # nobody ever listened here
        assert not probe_alive(f"mux://127.0.0.1:{port}", timeout=2.0)
        assert not probe_alive(f"http://127.0.0.1:{port}", timeout=2.0)

    def test_full_coordinator_roundtrip_bit_for_bit(self):
        reps = _reports(6)
        oracle = AFLServer(DIM, C, gamma=GAMMA)
        oracle.submit_many(reps)
        with serve_mux(_service()) as srv:
            rc = RemoteCoordinator(srv.url)
            try:
                for r in reps:
                    rc.submit(r)
                np.testing.assert_array_equal(rc.solve(), oracle.solve())
                vw = rc.weights()
                assert rc.weights(if_etag=vw.etag).not_modified
            finally:
                rc.close()


# ---------------------------------------------------------------------------
# Interleaving: many streams, one socket
# ---------------------------------------------------------------------------


class _GatedService(FederationService):
    """handle() blocks on ``gate`` for routes in ``slow_routes`` — lets a
    test hold one stream in flight while proving others still complete."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.gate = threading.Event()
        self.slow_routes = set()
        self.entered = threading.Event()

    def handle(self, route, body=b"", federation="default", *, token=None):
        if route in self.slow_routes:
            self.entered.set()
            assert self.gate.wait(30.0), "test gate never opened"
        return super().handle(route, body, federation, token=token)


class TestInterleavedStreams:
    def test_fast_stream_completes_while_slow_stream_blocked(self):
        svc = _GatedService(AFLServer(DIM, C, gamma=GAMMA))
        svc.slow_routes = {"state"}
        with svc, serve_mux(svc) as srv:
            tr = MuxTransport(srv.url)
            try:
                results = {}

                def slow():
                    results["state"] = tr.request("state", b"", "default")

                t = threading.Thread(target=slow)
                t.start()
                assert svc.entered.wait(10.0)
                # the slow stream is parked inside handle() — a second
                # stream on the SAME socket must still round-trip
                assert tr.request("describe", b"", "default")
                svc.gate.set()
                t.join(10.0)
                assert results["state"]
            finally:
                tr.close()

    def test_eight_threads_share_one_transport(self):
        with serve_mux(_service()) as srv:
            tr = MuxTransport(srv.url)
            rc = RemoteCoordinator(tr)
            errs = []
            batches = [_reports(3, start_id=100 * (i + 1)) for i in range(8)]

            def work(i):
                try:
                    for r in batches[i]:
                        rc.submit(r)
                    rc.weights()
                except Exception as exc:               # noqa: BLE001
                    errs.append((i, repr(exc)))

            threads = [threading.Thread(target=work, args=(i,))
                       for i in range(8)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(30.0)
            assert not errs, errs
            assert rc.num_clients == 24
            assert tr.reconnects == 0          # one socket carried it all
            tr.close()


# ---------------------------------------------------------------------------
# Frame robustness: every corruption is a typed connection error, and the
# server keeps serving fresh connections afterwards
# ---------------------------------------------------------------------------


def _raw_conn(srv):
    sock = socket.create_connection((srv.host, srv.port), timeout=5.0)
    sock.sendall(PREFACE)
    return sock


def _expect_goaway(sock):
    """Read frames until GOAWAY (or EOF, which some paths race to)."""
    sock.settimeout(5.0)
    rfile = sock.makefile("rb")
    while True:
        hdr = rfile.read(_HDR.size)
        if len(hdr) < _HDR.size:
            return None                       # peer closed without GOAWAY
        length, ftype, _, _ = _HDR.unpack(hdr)
        payload = rfile.read(length)
        if ftype == T_GOAWAY:
            return payload[4:].decode("utf-8", "replace")


class TestFrameRobustness:
    def test_bad_preface_gets_goaway(self):
        with serve_mux(_service()) as srv:
            sock = socket.create_connection((srv.host, srv.port), timeout=5.0)
            sock.sendall(b"GET / HTTP/1.1\r\n")
            msg = _expect_goaway(sock)
            sock.close()
            assert msg is None or "preface" in msg
            assert probe_alive(srv.url)       # server survived

    def test_oversized_frame_is_connection_fatal(self):
        with serve_mux(_service(), max_frame_bytes=4096) as srv:
            sock = _raw_conn(srv)
            sock.sendall(_HDR.pack(1 << 30, T_HEADERS, 0, 1))
            msg = _expect_goaway(sock)
            sock.close()
            assert msg is None or "frame cap" in msg
            assert probe_alive(srv.url)

    def test_torn_frame_is_connection_fatal(self):
        with serve_mux(_service()) as srv:
            sock = _raw_conn(srv)
            # header promises 100 payload bytes; send 10 and slam the door
            sock.sendall(_HDR.pack(100, T_HEADERS, 0, 1) + b"x" * 10)
            sock.shutdown(socket.SHUT_WR)
            _expect_goaway(sock)
            sock.close()
            assert probe_alive(srv.url)

    def test_corrupt_headers_json_gets_goaway(self):
        with serve_mux(_service()) as srv:
            sock = _raw_conn(srv)
            junk = b"\xff\xfenot json"
            sock.sendall(_HDR.pack(len(junk), T_HEADERS, F_END_STREAM, 1)
                         + junk)
            msg = _expect_goaway(sock)
            sock.close()
            assert msg is None or "HEADERS" in msg
            assert probe_alive(srv.url)

    def test_even_or_stale_stream_id_rejected(self):
        with serve_mux(_service()) as srv:
            sock = _raw_conn(srv)
            hdr = b'{"route": "describe", "federation": "default"}'
            sock.sendall(_HDR.pack(len(hdr), T_HEADERS, F_END_STREAM, 2)
                         + hdr)
            msg = _expect_goaway(sock)
            sock.close()
            assert msg is None or "odd" in msg
            assert probe_alive(srv.url)

    def test_unknown_frame_type_gets_goaway(self):
        with serve_mux(_service()) as srv:
            sock = _raw_conn(srv)
            sock.sendall(_HDR.pack(0, 99, 0, 1))
            msg = _expect_goaway(sock)
            sock.close()
            assert msg is None or "frame type" in msg
            assert probe_alive(srv.url)

    def test_oversized_body_rejected_with_typed_error_not_goaway(self):
        """A too-large request BODY (well-framed) is a stream-level typed
        error — the connection and its other streams keep working."""
        svc = _service(max_report_bytes=512)
        with svc, serve_mux(svc) as srv:
            tr = MuxTransport(srv.url)
            try:
                with pytest.raises(E.OversizedReport):
                    RemoteCoordinator(tr).submit_bytes(b"\x00" * (64 << 10))
                # same connection still serves
                assert tr.request("describe", b"", "default")
                assert tr.reconnects == 0
            finally:
                tr.close()


# ---------------------------------------------------------------------------
# GOAWAY drain
# ---------------------------------------------------------------------------


class TestGoawayDrain:
    def test_close_drains_inflight_stream_to_completion(self):
        svc = _GatedService(AFLServer(DIM, C, gamma=GAMMA))
        svc.slow_routes = {"describe"}
        srv = serve_mux(svc)
        tr = MuxTransport(srv.url)
        results = {}

        def inflight():
            results["describe"] = tr.request("describe", b"", "default")

        t = threading.Thread(target=inflight)
        t.start()
        assert svc.entered.wait(10.0)

        closer = threading.Thread(
            target=lambda: srv.close(drain=True, timeout=15.0))
        closer.start()
        time.sleep(0.2)                       # GOAWAY is on the wire now
        svc.gate.set()                        # release the parked dispatch
        t.join(15.0)
        closer.join(15.0)
        assert not t.is_alive() and not closer.is_alive()
        # the in-flight stream was answered, not dropped, through shutdown
        assert results.get("describe")
        tr.close()
        svc.close()

    def test_unprocessed_stream_fails_retryable_on_goaway(self):
        """A fake server GOAWAYs with last_stream_id=0: the client's pending
        stream (id 1 > 0) must fail with retryable Unavailable — the promise
        that it was never processed."""
        lsock = socket.create_server(("127.0.0.1", 0))
        host, port = lsock.getsockname()[:2]

        def fake_server():
            sock, _ = lsock.accept()
            rfile = sock.makefile("rb")
            rfile.read(len(PREFACE))
            rfile.read(_HDR.size)             # the HEADERS frame header…
            sock.sendall(_HDR.pack(4 + 5, T_GOAWAY, 0, 0)
                         + _U32.pack(0) + b"drain")
            time.sleep(0.5)
            sock.close()

        t = threading.Thread(target=fake_server, daemon=True)
        t.start()
        tr = MuxTransport(f"mux://{host}:{port}", timeout=10.0)
        try:
            with pytest.raises(E.Unavailable) as exc:
                tr.request("describe", b"", "default")
            assert exc.value.retryable
        finally:
            tr.close()
            lsock.close()


# ---------------------------------------------------------------------------
# Replay discipline
# ---------------------------------------------------------------------------


class TestReplayDiscipline:
    def test_sent_submit_is_never_resent(self):
        """The server reads a full submit, then dies without answering. The
        client MUST surface ConnectionError and MUST NOT retry: exactly one
        connection ever carried the request."""
        lsock = socket.create_server(("127.0.0.1", 0))
        host, port = lsock.getsockname()[:2]
        connections = []

        def fake_server():
            while True:
                try:
                    sock, _ = lsock.accept()
                except OSError:
                    return
                connections.append(sock)
                rfile = sock.makefile("rb")
                rfile.read(len(PREFACE))
                while True:                   # read the whole request…
                    hdr = rfile.read(_HDR.size)
                    if len(hdr) < _HDR.size:
                        break
                    length, _, flags, _ = _HDR.unpack(hdr)
                    rfile.read(length)
                    if flags & F_END_STREAM:
                        # …then die before responding (shutdown, not just
                        # close: rfile holds the fd, so close alone would
                        # never send the FIN)
                        sock.shutdown(socket.SHUT_RDWR)
                        sock.close()
                        break

        t = threading.Thread(target=fake_server, daemon=True)
        t.start()
        tr = MuxTransport(f"mux://{host}:{port}", timeout=10.0)
        try:
            with pytest.raises(ConnectionError):
                tr.request("submit", _reports(1)[0].to_bytes(), "default")
            time.sleep(0.3)
            assert len(connections) == 1      # no silent replay
        finally:
            tr.close()
            lsock.close()

    def test_stale_connection_retries_transparently(self):
        """Requests on a connection the server already dropped (idle death)
        reconnect and succeed — HEADERS never reached a router, so the
        single retry is safe."""
        with serve_mux(_service()) as srv:
            tr = MuxTransport(srv.url)
            try:
                assert tr.request("describe", b"", "default")
                # sever every server-side socket under the client
                for conn in list(srv._conns):
                    conn.close()
                deadline = time.monotonic() + 5.0
                while tr._conn is not None and not tr._conn.dead \
                        and time.monotonic() < deadline:
                    time.sleep(0.01)
                assert tr.request("describe", b"", "default")
                assert tr.reconnects == 1
            finally:
                tr.close()


# ---------------------------------------------------------------------------
# Flow control
# ---------------------------------------------------------------------------


class TestFlowControl:
    def test_large_bodies_cross_a_tiny_window_exactly(self):
        """8 KiB windows + 2 KiB chunks force the WINDOW_UPDATE path in both
        directions; the solve must still be bit-for-bit."""
        reps = _reports(6, rows=32)
        oracle = AFLServer(DIM, C, gamma=GAMMA)
        oracle.submit_many(reps)
        with serve_mux(_service(), initial_window=8 << 10,
                       chunk_bytes=2 << 10) as srv:
            tr = MuxTransport(srv.url, initial_window=8 << 10,
                              chunk_bytes=2 << 10)
            rc = RemoteCoordinator(tr)
            try:
                for r in reps:
                    rc.submit(r)
                np.testing.assert_array_equal(rc.solve(), oracle.solve())
                # state download (the big response) crosses the window too
                state = rc.state()
                assert AFLServer.from_state(state).num_clients == len(reps)
            finally:
                tr.close()


# ---------------------------------------------------------------------------
# TLS
# ---------------------------------------------------------------------------


class TestTls:
    def test_handshake_and_pinning(self, tls_files):
        cert, key = tls_files
        with serve_mux(_service(),
                       ssl_context=server_ssl_context(cert, key)) as srv:
            assert srv.url.startswith("muxs://")
            assert mux_ping(srv.url, cafile=cert) >= 0.0

    def test_unpinned_client_fails_cleanly_server_survives(self, tls_files):
        cert, key = tls_files
        with serve_mux(_service(),
                       ssl_context=server_ssl_context(cert, key)) as srv:
            tr = MuxTransport(srv.url)        # no cafile → self-signed fails
            with pytest.raises(ssl.SSLError):
                tr.request("describe", b"", "default")
            tr.close()
            deadline = time.monotonic() + 5.0
            while not srv.errors and time.monotonic() < deadline:
                time.sleep(0.01)
            assert any(where == "tls" for where, _ in srv.errors)
            assert probe_alive(srv.url, cafile=cert)

    def test_mutual_tls_requires_client_cert(self, tls_files):
        cert, key = tls_files
        ctx = server_ssl_context(cert, key, client_ca=cert)
        with serve_mux(_service(), ssl_context=ctx) as srv:
            bare = MuxTransport(srv.url, cafile=cert)
            with pytest.raises((ssl.SSLError, ConnectionError, OSError)):
                bare.request("describe", b"", "default")
            bare.close()

            from repro.fl import client_ssl_context
            cctx = client_ssl_context(cert, certfile=cert, keyfile=key)
            tr = MuxTransport(srv.url, ssl_context=cctx)
            try:
                assert tr.request("describe", b"", "default")
            finally:
                tr.close()

    def test_https_transport_and_server(self, tls_files):
        cert, key = tls_files
        with serve_http(_service(),
                        ssl_context=server_ssl_context(cert, key)) as srv:
            assert srv.url.startswith("https://")
            rc = RemoteCoordinator(srv.url, cafile=cert)
            try:
                assert rc.describe()["kind"]
            finally:
                rc.close()


# ---------------------------------------------------------------------------
# Auth
# ---------------------------------------------------------------------------


class TestAuth:
    def test_unauthorized_is_in_the_taxonomy(self):
        exc = E.from_code("unauthorized", "nope")
        assert isinstance(exc, E.Unauthorized)
        assert exc.http_status == 401
        assert not exc.retryable

    @pytest.mark.parametrize("token", [None, "wrong"])
    def test_bad_token_rejected_state_untouched_mux(self, token):
        svc = _service(auth_token="hunter2")
        with svc, serve_mux(svc) as srv:
            tr = MuxTransport(srv.url, auth_token=token)
            try:
                with pytest.raises(E.Unauthorized):
                    RemoteCoordinator(tr)     # typed 401 through the stack
                # a raw submit attempt answers the error envelope and
                # applies nothing
                tr.request("submit", _reports(1)[0].to_bytes(), "default")
            finally:
                tr.close()
            assert svc.coordinator().num_clients == 0   # nothing applied

            good = RemoteCoordinator(srv.url, auth_token="hunter2")
            try:
                good.submit(_reports(1)[0])
                assert good.num_clients == 1
                assert good.describe()["auth_required"] is True
            finally:
                good.close()

    def test_bad_token_rejected_over_http_too(self):
        svc = _service(auth_token="hunter2")
        with svc, serve_http(svc) as srv:
            with pytest.raises(E.Unauthorized):
                RemoteCoordinator(srv.url, auth_token="wrong")
            rc = RemoteCoordinator(srv.url, auth_token="hunter2")
            try:
                rc.submit(_reports(1)[0])
                assert rc.num_clients == 1
            finally:
                rc.close()
            assert svc.coordinator().num_clients == 1

    def test_token_rotation_without_restart(self):
        svc = _service(auth_token="old")
        with svc, serve_mux(svc) as srv:
            rc = RemoteCoordinator(srv.url, auth_token="old")
            assert rc.describe()["auth_required"]
            svc.set_auth_token("new")
            with pytest.raises(E.Unauthorized):
                rc.describe()
            rc.close()
            rc2 = RemoteCoordinator(srv.url, auth_token="new")
            try:
                assert rc2.describe()["auth_required"]
            finally:
                rc2.close()

    def test_promote_is_auth_gated(self):
        """promote flips a standby to writable — exactly the call a bearer
        token must gate."""
        from repro.fl.service import promote_remote
        svc = _service(auth_token="hunter2")
        with svc, serve_mux(svc) as srv:
            with pytest.raises(E.Unauthorized):
                promote_remote(srv.url)
            # the right token clears the auth gate: the request reaches
            # routing, which (correctly) rejects promoting a non-standby
            with pytest.raises(E.BadRequest, match="standby"):
                promote_remote(srv.url, auth_token="hunter2")
