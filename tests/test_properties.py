"""Hypothesis property tests on the system's core invariants.

The AA law's whole value proposition is *invariance*: to partition boundaries,
to client order, to merge association, to the γ used locally. These hold for
ANY data, so they are properties, not examples.
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import analytic as al, streaming
from repro.core.engine import AnalyticEngine
from repro.fl.partition import make_partition

DIM, CLASSES = 12, 4


def _data(seed, n):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, DIM))
    y = np.eye(CLASSES)[rng.integers(0, CLASSES, n)]
    return x, y


@st.composite
def partitions(draw):
    n = draw(st.integers(40, 120))
    n_cuts = draw(st.integers(1, 6))
    cuts = sorted(draw(st.sets(st.integers(1, n - 1),
                               min_size=n_cuts, max_size=n_cuts)))
    return n, [0, *cuts, n]


@settings(max_examples=25, deadline=None)
@given(partitions(), st.integers(0, 10**6),
       st.sampled_from([0.1, 1.0, 10.0, 100.0]))
def test_aa_law_partition_invariance(part, seed, gamma):
    """Any split of the rows + RI restore == the joint γ→0 ridge solution."""
    n, bounds = part
    x, y = _data(seed, n)
    w_joint = al.ridge_solve(x, y, 0.0)
    ups = [al.local_stage(x[a:b], y[a:b], gamma)
           for a, b in zip(bounds, bounds[1:])]
    w = al.afl_aggregate(ups, use_ri=True)
    np.testing.assert_allclose(w, w_joint, rtol=1e-6, atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10**6), st.permutations(list(range(5))))
def test_aggregation_order_invariance(seed, order):
    """Paper §3.2: clients may be aggregated in any order."""
    x, y = _data(seed, 100)
    bounds = [0, 17, 33, 58, 79, 100]
    ups = [al.local_stage(x[a:b], y[a:b], 1.0)
           for a, b in zip(bounds, bounds[1:])]
    w_fwd = al.afl_aggregate(ups, use_ri=True, pairwise=True)
    w_perm = al.afl_aggregate([ups[i] for i in order], use_ri=True,
                              pairwise=True)
    np.testing.assert_allclose(w_perm, w_fwd, rtol=1e-7, atol=1e-8)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 5))
def test_streaming_merge_associativity(seed, n_states):
    """merge_states is associative/commutative ⇒ tree == sequential fold."""
    rng = np.random.default_rng(seed)
    states = []
    for i in range(n_states):
        x = jnp.asarray(rng.standard_normal((7, DIM)), jnp.float32)
        y = jnp.asarray(np.eye(CLASSES)[rng.integers(0, CLASSES, 7)],
                        jnp.float32)
        states.append(streaming.update_state(
            streaming.init_state(DIM, CLASSES), x, y))
    seq = states[0]
    for s in states[1:]:
        seq = streaming.merge_states(seq, s)
    rev = states[-1]
    for s in states[-2::-1]:
        rev = streaming.merge_states(rev, s)
    np.testing.assert_allclose(np.asarray(seq.gram), np.asarray(rev.gram),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(seq.moment), np.asarray(rev.moment),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 30), st.integers(0, 10**6),
       st.sampled_from(["iid", "niid1", "niid2"]))
def test_partition_is_a_partition(k, seed, scheme):
    """Every index appears exactly once, for every scheme and client count."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, 300)
    parts = make_partition(labels, k, scheme, alpha=0.1, shards_per_client=2,
                           seed=seed % 100)
    allidx = np.sort(np.concatenate([p for p in parts if len(p)]))
    np.testing.assert_array_equal(allidx, np.arange(300))


def _update_case(eng, seed, n0, ranks):
    """Base stats + a sequence of low-rank arrivals; returns the base stats
    and the chain of (post-merge stats, delta rows)."""
    rng = np.random.default_rng(seed)
    x0 = rng.standard_normal((n0, DIM))
    y0 = np.eye(CLASSES)[rng.integers(0, CLASSES, n0)]
    base = eng.client_stats(x0, y0)
    stats, chain = base, []
    for k in ranks:
        xk = rng.standard_normal((k, DIM))
        yk = np.eye(CLASSES)[rng.integers(0, CLASSES, k)]
        stats = eng.merge(stats, eng.client_stats(xk, yk))
        chain.append((stats, xk))
    return base, chain


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10**6),
       st.lists(st.integers(1, 3), min_size=1, max_size=2),
       st.sampled_from([0.0, 0.05, 1.0]),
       st.sampled_from([5, 60]))
def test_factor_update_equals_refactor_numpy(seed, ranks, target_gamma, n0):
    """Folding random low-rank deltas into a cached factor == refactoring
    from scratch, to f64 precision — including the γ=0 rank-deficient start
    (n0 < d ⇒ pinv fallback ⇒ factor_update must silently refactor; the
    chain is short enough that n0=5 stays rank-deficient throughout, so the
    well- and ill-posed regimes never blur)."""
    eng = AnalyticEngine("numpy_f64", gamma=1.0)
    base, chain = _update_case(eng, seed, n0, ranks)
    f = eng.factor(base, target_gamma=target_gamma)
    for stats, xk in chain:
        # max_rank forces the update branch at this tiny DIM (the default
        # budget d//16 is a perf crossover, not a correctness bound)
        f = eng.factor_update(f, stats, xk, target_gamma=target_gamma,
                              max_rank=4)
    stats_final = chain[-1][0]
    f_ref = eng.factor(stats_final, target_gamma=target_gamma)
    np.testing.assert_allclose(
        eng.factor_solve(f, stats_final.moment),
        eng.factor_solve(f_ref, stats_final.moment), rtol=1e-9, atol=1e-9)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 3),
       st.sampled_from([0.05, 1.0]))
def test_factor_update_equals_refactor_jax_f32(seed, k, target_gamma):
    """Same invariant on the device backend at f32 tolerance."""
    eng = AnalyticEngine("jax", gamma=1.0)
    base, [(stats, xk)] = _update_case(eng, seed, 40, [k])
    f0 = eng.factor(base, target_gamma=target_gamma)
    f_upd = eng.factor_update(f0, stats, xk, target_gamma=target_gamma,
                              max_rank=4)
    f_ref = eng.factor(stats, target_gamma=target_gamma)
    np.testing.assert_allclose(
        np.asarray(eng.factor_solve(f_upd, stats.moment)),
        np.asarray(eng.factor_solve(f_ref, stats.moment)),
        rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**6))
def test_ri_restore_inverts_regularization(seed):
    """Thm 2 as a round trip: restore(bias(W)) == W for random PD stats."""
    rng = np.random.default_rng(seed)
    k, gamma = rng.integers(2, 20), float(rng.uniform(0.1, 50))
    x, y = _data(seed + 1, 200)
    c_agg = x.T @ x
    q_agg = x.T @ y
    w_true = np.linalg.solve(c_agg + 1e-9 * np.eye(DIM), q_agg)
    c_r = c_agg + k * gamma * np.eye(DIM)
    w_r = np.linalg.solve(c_r, q_agg)
    w_restored = al.ri_restore(w_r, c_r, int(k), gamma)
    np.testing.assert_allclose(w_restored, w_true, rtol=1e-5, atol=1e-6)
