"""Replication: the durable submit ledger, warm standby, read replicas.

The AA law makes the server's state an additive sum of accepted reports, so
an append-only log of the accepted payloads is a complete, order-insensitive
replication log. This file locks down the three pieces built on that:

  * :class:`ReportLedger`: CRC framing, rotation, crash-truncated-tail
    recovery, compaction to snapshot ref + suffix, newest-record CRC lookup;
  * :class:`LedgerTailer` + :class:`WarmStandby`: incremental tailing, every
    cold-start source, and the promotion guarantee — bit-for-bit (f64,
    ``assert_array_equal``) equal to the never-crashed oracle, zero loss,
    including the kill-primary-mid-stream drill;
  * :class:`WeightsReplica`: epoch following, staleness gating (typed
    retryable ``unavailable``), instance-scoped ETag semantics, and the
    typed ``read_only`` rejection of every mutating route;

plus the service-side satellites: ledger appends fsynced before the ack,
the bounded ``applied`` map whose evictions fall back to the ledger, and
ETag lifecycles across restore / resharding / promotion / primary↔replica
for all four coordinator kinds.
"""

import struct
import time
import zlib

import numpy as np
import pytest

from repro.fl import (AFLServer, AsyncAFLServer, FederationService,
                      InProcTransport, LedgerTailer, RemoteCoordinator,
                      ReportLedger, ShardedCoordinator, WarmStandby,
                      WeightsReplica, make_report, promote_remote)
from repro.fl import errors as E
from repro.fl.replication import last_seq_on_disk
from repro.checkpoint import SnapshotDaemon

DIM, C, GAMMA = 16, 4, 1.0


def _reports(n=8, rows=10, seed=0, start_id=0):
    rng = np.random.default_rng(seed)
    return [make_report(start_id + k, rng.standard_normal((rows, DIM)),
                        np.eye(C)[rng.integers(0, C, rows)], GAMMA)
            for k in range(n)]


def _oracle(reports):
    srv = AFLServer(DIM, C, gamma=GAMMA)
    srv.submit_many(reports)
    return srv


def _drain(coord, timeout=5.0):
    deadline = time.monotonic() + timeout
    while coord.pending and time.monotonic() < deadline:
        time.sleep(0.005)
    assert coord.pending == 0


_CTOR = dict(dim=DIM, num_classes=C, gamma=GAMMA)


# ---------------------------------------------------------------------------
# The ledger
# ---------------------------------------------------------------------------


class TestReportLedger:
    def test_append_sync_replay_roundtrip(self, tmp_path):
        payloads = [r.to_bytes() for r in _reports(5)]
        with ReportLedger(tmp_path) as led:
            for cid, p in enumerate(payloads):
                assert led.append(p, cid) == cid + 1
            assert led.last_seq == 5
            led.sync()
            assert led.durable_seq == 5
        led2 = ReportLedger(tmp_path)              # fresh open, same disk
        assert led2.last_seq == 5
        got = list(led2.records())
        assert [(s, c) for s, c, _ in got] == [(k + 1, k) for k in range(5)]
        assert [p for _, _, p in got] == payloads
        assert [s for s, _, _ in led2.records(after_seq=3)] == [4, 5]
        led2.close()

    def test_rotation_seals_segments_and_replay_spans_them(self, tmp_path):
        payloads = [r.to_bytes() for r in _reports(6)]
        led = ReportLedger(tmp_path, segment_bytes=2 * len(payloads[0]))
        for cid, p in enumerate(payloads):
            led.append(p, cid)
        segs = sorted(tmp_path.glob("ledger-*.seg"))
        assert len(segs) >= 2                      # rotation happened
        assert segs[0].name == "ledger-000000000001.seg"
        assert [c for _, c, _ in led.records()] == list(range(6))
        assert last_seq_on_disk(tmp_path) == 6
        led.close()

    def test_fsync_batch_autosyncs(self, tmp_path):
        led = ReportLedger(tmp_path, fsync_batch=3)
        p = _reports(1)[0].to_bytes()
        led.append(p, 0)
        led.append(p, 1)
        assert led.durable_seq == 0                # buffered
        led.append(p, 2)
        assert led.durable_seq == 3                # batch hit the valve
        led.close()

    def test_torn_tail_garbage_is_truncated_on_open(self, tmp_path):
        led = ReportLedger(tmp_path)
        for cid, r in enumerate(_reports(3)):
            led.append(r.to_bytes(), cid)
        led.close()
        seg = sorted(tmp_path.glob("ledger-*.seg"))[-1]
        clean = seg.stat().st_size
        with seg.open("ab") as f:                  # crash mid-append
            f.write(b"\x13\x37" * 9)
        led2 = ReportLedger(tmp_path)
        assert led2.last_seq == 3                  # tear invisible
        assert seg.stat().st_size == clean         # physically truncated
        led2.append(_reports(1, start_id=9)[0].to_bytes(), 9)
        assert [s for s, _, _ in led2.records()] == [1, 2, 3, 4]
        led2.close()

    def test_torn_tail_half_record_and_torn_header(self, tmp_path):
        led = ReportLedger(tmp_path)
        payload = _reports(1)[0].to_bytes()
        led.append(payload, 0)
        led.close()
        seg = sorted(tmp_path.glob("ledger-*.seg"))[-1]
        # a half-written record: valid header, body cut short
        body = b"x" * 64
        with seg.open("ab") as f:
            f.write(struct.pack("<II", len(body), zlib.crc32(body)))
            f.write(body[:10])
        assert ReportLedger(tmp_path).last_seq == 1
        # header itself torn (fresh segment, partial magic)
        (tmp_path / "ledger-000000000099.seg").write_bytes(b"AFL")
        led3 = ReportLedger(tmp_path)
        assert led3.last_seq == 1
        led3.close()

    def test_find_crc_newest_record_wins(self, tmp_path):
        led = ReportLedger(tmp_path, segment_bytes=1)   # rotate every append
        a, b = (r.to_bytes() for r in _reports(2, seed=1))
        led.append(a, 7)
        led.append(b, 7)                           # same client, newer bytes
        assert led.find_crc(7) == zlib.crc32(b)
        assert led.find_crc(8) is None
        led.close()

    def test_compaction_keeps_suffix_and_floor(self, tmp_path):
        payloads = [r.to_bytes() for r in _reports(6)]
        led = ReportLedger(tmp_path, segment_bytes=1)   # one record/segment
        for cid, p in enumerate(payloads):
            led.append(p, cid)
        assert len(list(tmp_path.glob("ledger-*.seg"))) == 6
        deleted = led.compact("/snaps/snap-000000000004-000000", 4)
        assert len(deleted) == 4                   # sealed + covered only
        assert led.base_seq == 4
        assert led.snapshot_ref.endswith("snap-000000000004-000000")
        assert [s for s, _, _ in led.records()] == [5, 6]
        # the floor is monotone: a stale compact cannot lower it
        led.compact(None, 2)
        assert led.base_seq == 4
        led.append(payloads[0], 10)                # appends continue at seq 7
        assert led.last_seq == 7
        led.close()

    def test_checkpoint_survives_empty_segments(self, tmp_path):
        led = ReportLedger(tmp_path)
        led.append(_reports(1)[0].to_bytes(), 0)
        led.rotate()                               # active segment is empty
        led.compact(None, 1)
        led.close()
        assert last_seq_on_disk(tmp_path) == 1     # falls back to the floor
        assert ReportLedger(tmp_path).last_seq == 1


class TestLedgerTailer:
    def test_incremental_polls_across_rotation(self, tmp_path):
        led = ReportLedger(tmp_path, segment_bytes=1)
        tail = LedgerTailer(tmp_path)
        assert tail.poll() == []
        led.append(b"a", 0)
        led.append(b"b", 1)
        led.sync()
        assert [(s, c, p) for s, c, p in tail.poll()] == [(1, 0, b"a"),
                                                          (2, 1, b"b")]
        assert tail.poll() == []                   # nothing new
        led.append(b"c", 2)
        led.sync()
        assert [p for _, _, p in tail.poll()] == [b"c"]
        assert tail.position == 3 and tail.lag() == 0
        led.close()

    def test_tailer_stops_at_torn_tail_then_resumes(self, tmp_path):
        led = ReportLedger(tmp_path)
        led.append(b"ok", 0)
        led.sync()
        seg = sorted(tmp_path.glob("ledger-*.seg"))[-1]
        with seg.open("ab") as f:
            f.write(b"\xde\xad\xbe\xef")           # live/torn bytes
        tail = LedgerTailer(tmp_path)
        assert [p for _, _, p in tail.poll()] == [b"ok"]
        assert tail.poll() == []                   # parked at the tear
        led.close()

    def test_tailer_follows_past_compaction(self, tmp_path):
        led = ReportLedger(tmp_path, segment_bytes=1)
        for cid in range(4):
            led.append(bytes([cid]), cid)
        led.compact(None, 2)                       # seqs 1–2 gone from disk
        tail = LedgerTailer(tmp_path)              # cold tailer at 0
        assert [s for s, _, _ in tail.poll()] == [3, 4]
        led.close()


# ---------------------------------------------------------------------------
# Service ↔ ledger integration (durability + the bounded applied map)
# ---------------------------------------------------------------------------


class TestServiceLedger:
    def test_sync_submit_is_durable_before_the_ack(self, tmp_path):
        svc = FederationService(AFLServer(**_CTOR), ledger_dir=tmp_path)
        rc = RemoteCoordinator(svc)
        rc.submit(_reports(1)[0])
        led = svc._fed("default").ledger
        assert led.last_seq == 1 and led.durable_seq == 1
        # idempotent retry: answered from the map, NOT re-appended
        assert rc.submit(_reports(1)[0]) is True
        assert led.last_seq == 1
        svc.close()

    def test_stream_appends_on_admission_one_fsync_per_batch(self, tmp_path):
        svc = FederationService(AsyncAFLServer(**_CTOR), ledger_dir=tmp_path)
        rc = RemoteCoordinator(svc)
        payloads = [r.to_bytes() for r in _reports(5)]
        out = rc.submit_stream(payloads)
        assert out["accepted"] == 5
        led = svc._fed("default").ledger
        # appended the moment they were admitted — even if the worker has
        # not folded them yet — and durable in ONE sync
        assert led.last_seq == 5 and led.durable_seq == 5
        _drain(svc.coordinator())
        # replaying the whole batch: all duplicates, nothing re-appended
        out2 = rc.submit_stream(payloads)
        assert all(r.get("duplicate") for r in out2["results"])
        assert led.last_seq == 5
        svc.close()

    def test_bounded_applied_map_falls_back_to_the_ledger(self, tmp_path):
        svc = FederationService(AFLServer(**_CTOR), ledger_dir=tmp_path,
                                applied_cache_size=2)
        rc = RemoteCoordinator(svc)
        reports = _reports(5)
        for r in reports:
            rc.submit(r)
        fed = svc._fed("default")
        assert len(fed.applied) == 2               # LRU held the bound
        # client 0 was evicted long ago; its exact bytes replay as duplicate
        t = InProcTransport(svc)
        from repro.fl.service import _decode_response
        header, _, _ = _decode_response(
            t.request("submit", reports[0].to_bytes()))
        assert header["duplicate"] is True
        # ...and the hit was re-cached
        assert fed.applied.get(reports[0].client_id) is not None
        # DIFFERENT bytes under a known id stay a conflict, not a replay
        with pytest.raises(E.DuplicateClient):
            rc.submit_bytes(_reports(1, start_id=1, seed=42)[0].to_bytes())
        svc.close()

    def test_ledger_less_lru_floor_degrades_to_duplicate_client(self):
        svc = FederationService(AFLServer(**_CTOR), applied_cache_size=2)
        rc = RemoteCoordinator(svc)
        reports = _reports(4)
        for r in reports:
            rc.submit(r)
        # evicted + no ledger: the documented floor is the coordinator's 409
        with pytest.raises(E.DuplicateClient):
            rc.submit(reports[0])
        # a still-cached entry answers idempotently
        assert rc.submit(reports[3]) is True
        svc.close()

    def test_stream_to_async_replays_duplicates_from_disk(self, tmp_path):
        svc = FederationService(AsyncAFLServer(**_CTOR), ledger_dir=tmp_path,
                                applied_cache_size=1)
        rc = RemoteCoordinator(svc)
        payloads = [r.to_bytes() for r in _reports(4)]
        rc.submit_stream(payloads)
        _drain(svc.coordinator())
        # every map entry but one is gone; disk answers for the rest —
        # nothing is re-enqueued (the fold count proves it below)
        out = rc.submit_stream(payloads)
        assert all(r.get("duplicate") for r in out["results"])
        _drain(svc.coordinator())
        assert svc.coordinator().num_clients == 4
        assert svc._fed("default").ledger.last_seq == 4
        svc.close()


# ---------------------------------------------------------------------------
# Warm standby
# ---------------------------------------------------------------------------


class TestWarmStandby:
    def test_cold_start_sources(self, tmp_path):
        led_dir = tmp_path / "ledger"
        ReportLedger(led_dir).close()              # empty but present
        # 1. nothing to start from → typed bad_request
        with pytest.raises(E.BadRequest):
            WarmStandby(led_dir)
        # 2. empty via ctor_kw
        sb = WarmStandby(led_dir, ctor_kw=_CTOR)
        assert sb.coordinator.num_clients == 0
        # 3. explicit coordinator wins over everything
        oracle = _oracle(_reports(2))
        assert WarmStandby(led_dir, coordinator=oracle).coordinator is oracle
        # 4. snapshot dir
        snaps = tmp_path / "snaps"
        SnapshotDaemon(oracle, directory=snaps).snapshot_once()
        sb4 = WarmStandby(led_dir, snapshot_dir=snaps)
        assert sb4.coordinator.num_clients == 2
        # 5. the ledger's own compaction checkpoint names the snapshot
        led = ReportLedger(led_dir)
        snap_path = sorted(snaps.glob("snap-*"))[0]
        led.compact(snap_path, 2)
        led.close()
        sb5 = WarmStandby(led_dir)
        assert sb5.coordinator.num_clients == 2

    def test_promote_is_bitwise_the_oracle(self, tmp_path):
        reports = _reports(12)
        svc = FederationService(AFLServer(**_CTOR),
                                ledger_dir=tmp_path / "ledger")
        rc = RemoteCoordinator(svc)
        for r in reports[:7]:
            rc.submit(r)
        snaps = tmp_path / "snaps"
        SnapshotDaemon(svc, directory=snaps).snapshot_once()
        rc.submit_stream([r.to_bytes() for r in reports[7:]])
        coord = WarmStandby(tmp_path / "ledger",
                            snapshot_dir=snaps).promote()
        assert coord.num_clients == 12
        oracle = _oracle(reports)
        for g in (0.0, 0.3, 2.0):
            np.testing.assert_array_equal(coord.solve(g), oracle.solve(g))
        np.testing.assert_array_equal(
            np.asarray(coord.state()["gram"], np.float64),
            np.asarray(oracle.state()["gram"], np.float64))
        svc.close()

    def test_background_tail_follows_live_appends(self, tmp_path):
        svc = FederationService(AFLServer(**_CTOR), ledger_dir=tmp_path)
        rc = RemoteCoordinator(svc)
        with WarmStandby(tmp_path, ctor_kw=_CTOR,
                         poll_interval=0.01) as sb:
            for r in _reports(5):
                rc.submit(r)
            deadline = time.monotonic() + 5
            while sb.position < 5 and time.monotonic() < deadline:
                time.sleep(0.01)
            assert sb.position == 5 and sb.lag() == 0
            assert sb.coordinator.num_clients == 5
        svc.close()

    def test_kill_primary_mid_stream_zero_loss(self, tmp_path):
        """THE acceptance drill: the primary dies with queued-but-unapplied
        stream frames; everything a client saw acked drains into the
        standby, which promotes bit-for-bit (f64) equal to the oracle."""
        reports = _reports(16)
        primary = AsyncAFLServer(**_CTOR)
        svc = FederationService(primary, ledger_dir=tmp_path / "ledger")
        rc = RemoteCoordinator(svc)
        rc.submit_stream([r.to_bytes() for r in reports[:10]])
        _drain(primary)
        snaps = tmp_path / "snaps"
        SnapshotDaemon(svc, directory=snaps).snapshot_once()
        standby = WarmStandby(tmp_path / "ledger", snapshot_dir=snaps,
                              poll_interval=0.01).start()
        # in-flight batch is ACKED (admitted + ledgered), then the primary
        # "dies" before its worker necessarily applied any of it
        out = rc.submit_stream([r.to_bytes() for r in reports[10:]])
        assert out["accepted"] == 6
        svc.suspend_federation()
        with pytest.raises(E.Unavailable):
            rc.solve(0.25)
        promoted = standby.promote()
        oracle = _oracle(reports)                  # never-crashed run
        assert promoted.num_clients == 16          # zero reports lost
        np.testing.assert_array_equal(promoted.solve(0.25),
                                      oracle.solve(0.25))
        # the straggler retry against the restored service answers
        # duplicate, not conflict — the ledger carried the applied CRCs
        svc.restore_federation("default", promoted)
        t = InProcTransport(svc)
        from repro.fl.service import _decode_response
        header, _, _ = _decode_response(
            t.request("submit", reports[12].to_bytes()))
        assert header["duplicate"] is True
        svc.close()

    def test_hosted_standby_promotes_over_the_wire(self, tmp_path):
        reports = _reports(6)
        svc = FederationService(AFLServer(**_CTOR), ledger_dir=tmp_path)
        RemoteCoordinator(svc).submit_many(reports)
        svc.close()                                # primary box is gone

        standby_svc = FederationService()
        standby_svc.host_standby(
            "default", WarmStandby(tmp_path, ctor_kw=_CTOR))
        # suspended: every normal route answers retryable 503
        with pytest.raises(E.Unavailable) as exc:
            RemoteCoordinator(standby_svc)
        assert exc.value.retryable
        header = promote_remote(standby_svc)
        assert header["promoted"] and header["num_clients"] == 6
        rc = RemoteCoordinator(standby_svc)        # now a live primary
        np.testing.assert_array_equal(rc.solve(0.5),
                                      _oracle(reports).solve(0.5))
        # adopt_ledger: the promoted primary keeps the chain appendable
        rc.submit(_reports(1, start_id=50, seed=5)[0])
        assert standby_svc._fed("default").ledger.last_seq == 7
        standby_svc.close()


# ---------------------------------------------------------------------------
# Weights read replica
# ---------------------------------------------------------------------------


class TestWeightsReplica:
    def _primary(self, tmp_path):
        svc = FederationService(AFLServer(**_CTOR), ledger_dir=tmp_path)
        rc = RemoteCoordinator(svc)
        rc.submit_many(_reports(5))
        return svc, rc

    def test_replica_follows_the_primary_epoch(self, tmp_path):
        svc, rc = self._primary(tmp_path)
        rep = WeightsReplica(tmp_path, ctor_kw=_CTOR)
        assert rep.num_clients == 5 and rep.lag == 0
        np.testing.assert_array_equal(rep.solve(0.4),
                                      svc.coordinator().solve(0.4))
        rc.submit(_reports(1, start_id=9, seed=9)[0])
        assert rep.lag == 1                        # visible before refresh
        np.testing.assert_array_equal(rep.solve(0.4),     # auto_refresh
                                      svc.coordinator().solve(0.4))
        assert rep.version == svc.coordinator().version
        rep.close()
        svc.close()

    def test_lagging_replica_answers_typed_unavailable(self, tmp_path):
        svc, rc = self._primary(tmp_path)
        rep = WeightsReplica(tmp_path, ctor_kw=_CTOR, auto_refresh=False)
        rep.weights(0.2)                           # current: fine
        rc.submit(_reports(1, start_id=9, seed=9)[0])
        with pytest.raises(E.Unavailable) as exc:
            rep.weights(0.2)
        assert exc.value.retryable
        assert rep.refresh() == 1                  # manual catch-up
        rep.weights(0.2)
        rep.close()
        svc.close()

    def test_mutations_raise_typed_read_only(self, tmp_path):
        svc, _rc = self._primary(tmp_path)
        rep = WeightsReplica(tmp_path, ctor_kw=_CTOR)
        for call in (lambda: rep.submit(_reports(1, start_id=9)[0]),
                     lambda: rep.grow(1), lambda: rep.shrink(1)):
            with pytest.raises(E.ReadOnlyFederation) as exc:
                call()
            assert not exc.value.retryable
        rep.close()
        svc.close()

    def test_replica_over_the_wire(self, tmp_path):
        svc, rc = self._primary(tmp_path)
        rep_svc = FederationService(WeightsReplica(tmp_path, ctor_kw=_CTOR))
        rrc = RemoteCoordinator(rep_svc)
        info = rrc.describe()
        assert info["read_only"] is True and info["replica_lag"] == 0
        np.testing.assert_array_equal(rrc.solve(0.4), rc.solve(0.4))
        np.testing.assert_array_equal(
            rrc.personalized_solve(0.4), rc.personalized_solve(0.4))
        # the wire rejection is the typed 403, before dispatch
        with pytest.raises(E.ReadOnlyFederation):
            rrc.submit(_reports(1, start_id=9)[0])
        with pytest.raises(E.ReadOnlyFederation):
            rrc.grow(1)
        rep_svc.close()
        svc.close()

    def test_etag_caching_against_the_replica_itself_works(self, tmp_path):
        svc, rc = self._primary(tmp_path)
        rep = WeightsReplica(tmp_path, ctor_kw=_CTOR)
        vw = rep.weights(0.3)
        assert rep.weights(0.3, if_etag=vw.etag).not_modified
        rc.submit(_reports(1, start_id=9, seed=9)[0])   # epoch moves
        vw2 = rep.weights(0.3, if_etag=vw.etag)
        assert not vw2.not_modified and vw2.etag != vw.etag
        rep.close()
        svc.close()


# ---------------------------------------------------------------------------
# ETag lifecycles across instances — all four coordinator kinds
# ---------------------------------------------------------------------------


class _Driver:
    """Drive any coordinator kind through one synchronous surface: local
    kinds directly, the async kind through the service's federation adapter
    (its dedicated event loop), the remote kind over the wire."""

    _CLS = {"sync": AFLServer, "async": AsyncAFLServer,
            "sharded": ShardedCoordinator, "remote": AFLServer}

    def __init__(self, kind):
        self.kind = kind
        kw = {"num_shards": 2} if kind == "sharded" else {}
        self.restore_kw = kw
        self.svc = FederationService(self._CLS[kind](**_CTOR, **kw))
        self.fed = self.svc._fed("default")
        self.coord = (RemoteCoordinator(self.svc) if kind == "remote"
                      else self.svc.coordinator())

    def call(self, name, *a, **kw):
        if self.kind == "remote":
            return getattr(self.coord, name)(*a, **kw)
        return self.fed.call(name, *a, **kw)

    def restore(self):
        """Same state, NEW instance (the restore leg of the lifecycle)."""
        cls = self._CLS["sync" if self.kind == "remote" else self.kind]
        reborn = cls.from_state(self.call("state"), **self.restore_kw)
        self.svc.restore_federation("default", reborn)
        self.fed = self.svc._fed("default")
        if self.kind != "remote":
            self.coord = reborn
        return reborn

    def refresh_salt(self):
        target = self.svc.coordinator()
        target.new_etag_salt()

    def close(self):
        self.svc.close()


@pytest.mark.parametrize("kind", ["sync", "async", "sharded", "remote"])
class TestETagLifecycle:
    def test_tokens_never_revalidate_across_restore(self, kind):
        d = _Driver(kind)
        try:
            for r in _reports(4):
                d.call("submit", r)
            vw = d.call("weights", 0.5)
            assert d.call("weights", 0.5, if_etag=vw.etag).not_modified
            d.restore()
            vw2 = d.call("weights", 0.5, if_etag=vw.etag)
            assert not vw2.not_modified            # dead token: full body
            assert vw2.etag != vw.etag
            assert d.call("weights", 0.5, if_etag=vw2.etag).not_modified
        finally:
            d.close()

    def test_salt_refresh_kills_live_tokens(self, kind):
        """Promotion and resharding both go through ``new_etag_salt`` — any
        token minted before the identity change must re-download."""
        d = _Driver(kind)
        try:
            for r in _reports(3):
                d.call("submit", r)
            vw = d.call("weights", 0.1)
            d.refresh_salt()
            vw2 = d.call("weights", 0.1, if_etag=vw.etag)
            assert not vw2.not_modified and vw2.etag != vw.etag
        finally:
            d.close()


class TestETagTopology:
    def test_resharding_invalidates_tokens(self):
        coord = ShardedCoordinator(**_CTOR, num_shards=2)
        coord.submit_many(_reports(4))
        vw = coord.weights(0.2)
        assert coord.weights(0.2, if_etag=vw.etag).not_modified
        coord.grow(1)                              # _resize → new salt
        vw2 = coord.weights(0.2, if_etag=vw.etag)
        assert not vw2.not_modified and vw2.etag != vw.etag

    def test_promotion_invalidates_primary_tokens(self, tmp_path):
        svc = FederationService(AFLServer(**_CTOR), ledger_dir=tmp_path)
        rc = RemoteCoordinator(svc)
        rc.submit_many(_reports(4))
        vw = rc.weights(0.2)
        promoted = WarmStandby(tmp_path, ctor_kw=_CTOR).promote()
        vw2 = promoted.weights(0.2, if_etag=vw.etag)
        assert not vw2.not_modified and vw2.etag != vw.etag
        # ...and freshly-minted standby tokens work on the standby
        assert promoted.weights(0.2, if_etag=vw2.etag).not_modified
        svc.close()

    @pytest.mark.parametrize("kind", ["sync", "sharded"])
    def test_primary_and_replica_tokens_never_cross(self, kind, tmp_path):
        cls = AFLServer if kind == "sync" else ShardedCoordinator
        kw = {} if kind == "sync" else {"num_shards": 2}
        svc = FederationService(cls(**_CTOR, **kw), ledger_dir=tmp_path)
        rc = RemoteCoordinator(svc)
        rc.submit_many(_reports(4))
        rep = WeightsReplica(tmp_path, cls=cls, ctor_kw={**_CTOR, **kw},
                             from_state_kw=kw)
        vw_p = rc.weights(0.3)
        vw_r = rep.weights(0.3)
        assert vw_p.etag != vw_r.etag
        # primary token on the replica: full body, replica-minted token
        cross = rep.weights(0.3, if_etag=vw_p.etag)
        assert not cross.not_modified and cross.etag == vw_r.etag
        # replica token on the primary: full body too
        assert not rc.weights(0.3, if_etag=vw_r.etag).not_modified
        # each side's own token still caches
        assert rc.weights(0.3, if_etag=vw_p.etag).not_modified
        assert rep.weights(0.3, if_etag=vw_r.etag).not_modified
        rep.close()
        svc.close()


# ---------------------------------------------------------------------------
# Ledger-aware tick compaction (SnapshotDaemon + compact/compact_ledger_dir)
# ---------------------------------------------------------------------------


class TestTickCompaction:
    def test_snapshot_tick_compacts_and_standby_cold_starts(self, tmp_path):
        """A snapshot tick drops the sealed segments it covers, and a warm
        standby cold-started from (compacted ledger + snapshots) is still
        bit-for-bit the oracle — compaction loses nothing."""
        led = ReportLedger(tmp_path / "ledger", segment_bytes=2048)
        svc = FederationService()
        svc.add_federation("default", AFLServer(**_CTOR), ledger=led)
        rc = RemoteCoordinator(svc)
        reps = _reports(12)
        for r in reps[:8]:
            rc.submit(r)
        segs_before = len(list((tmp_path / "ledger").glob("ledger-*.seg")))
        assert segs_before > 1                 # rotation actually happened

        daemon = SnapshotDaemon(svc, directory=tmp_path / "snaps",
                                ledger=svc.ledger())
        assert daemon.snapshot_once() is not None
        assert not daemon.errors
        segs_after = len(list((tmp_path / "ledger").glob("ledger-*.seg")))
        assert segs_after < segs_before        # sealed prefix is gone
        ckpt_file = tmp_path / "ledger" / "ledger-checkpoint.json"
        assert ckpt_file.exists()

        # a digest-identical no-op tick still compacts (and stays a no-op)
        assert daemon.snapshot_once() is None
        assert not daemon.errors

        # post-compaction submits land in the surviving suffix…
        for r in reps[8:]:
            rc.submit(r)
        svc.close()

        # …and the standby reconstructs the full aggregate exactly
        standby = WarmStandby(tmp_path / "ledger",
                              snapshot_dir=tmp_path / "snaps")
        standby.catch_up()
        np.testing.assert_array_equal(standby.coordinator.solve(),
                                      _oracle(reps).solve())
        assert standby.coordinator.num_clients == len(reps)

    def test_out_of_process_compaction_never_touches_live_writer(
            self, tmp_path):
        """compact_ledger_dir (the daemon's path when given a directory,
        i.e. a writer in ANOTHER process) drops only sealed segments and
        never opens a ReportLedger — the live writer keeps appending and a
        replay still sees every surviving record."""
        led = ReportLedger(tmp_path / "ledger", segment_bytes=2048)
        svc = FederationService()
        svc.add_federation("default", AFLServer(**_CTOR), ledger=led)
        rc = RemoteCoordinator(svc)
        reps = _reports(10)
        for r in reps[:6]:
            rc.submit(r)

        daemon = SnapshotDaemon(svc, directory=tmp_path / "snaps",
                                ledger=str(tmp_path / "ledger"))
        assert daemon.snapshot_once() is not None
        assert not daemon.errors, daemon.errors
        active = _list_segments_for_test(tmp_path / "ledger")
        assert len(active) >= 1

        # the writer the compactor never opened keeps appending happily
        for r in reps[6:]:
            rc.submit(r)
        svc.close()

        standby = WarmStandby(tmp_path / "ledger",
                              snapshot_dir=tmp_path / "snaps")
        standby.catch_up()
        np.testing.assert_array_equal(standby.coordinator.solve(),
                                      _oracle(reps).solve())

    def test_compaction_floor_skipped_while_reports_pending(self, tmp_path):
        """An async coordinator with queued-but-unapplied reports must not
        let the tick compact past them: floor is 0 until pending drains."""
        led = ReportLedger(tmp_path / "ledger", segment_bytes=1024)

        class _Stalled:
            """state()-bearing source reporting unapplied queue depth."""
            pending = 3

            def state(self):
                return {"seen": []}

        daemon = SnapshotDaemon(_Stalled(), directory=tmp_path / "snaps",
                                ledger=led)
        led.append(b"payload", 0)
        led.sync()
        assert daemon._local_floor() == 0        # pending>0 → no floor
        _Stalled.pending = 0
        assert daemon._local_floor() == led.last_seq

    def test_tick_compaction_failure_is_advisory(self, tmp_path):
        """A compaction error lands in .errors; the snapshot still exists."""

        class _Boom:
            def compact(self, ref, base):
                raise OSError("disk says no")

            last_seq = 7

        src = AFLServer(**_CTOR)
        src.submit_many(_reports(2))
        daemon = SnapshotDaemon(src, directory=tmp_path / "snaps",
                                ledger=_Boom())
        path = daemon.snapshot_once()
        assert path is not None and path.exists()
        assert any("compact" in msg for _, msg in daemon.errors)


def _list_segments_for_test(directory):
    from repro.fl.replication import _list_segments
    return _list_segments(directory)
