"""Ring-buffer KV cache correctness: a windowed model decoding with a cache
of exactly ``window`` slots must produce the same logits as the same model
decoding with a full-length cache + window mask (the ring IS the window)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig
from repro.launch import steps as ST
from repro.launch.inputs import sample_batch
from repro.models import transformer as T

CFG = ModelConfig(
    name="ring-test", arch_type="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
    window=8, num_classes=4, source="test")


def _decode_tokens(cfg, params, prompt, total_len, cache_len):
    prefill = jax.jit(ST.make_prefill_step(cfg, cache_len))
    decode = jax.jit(ST.make_serve_step(cfg))
    logits, cache = prefill(params, {"tokens": prompt})
    outs = [np.asarray(logits)]
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for pos in range(prompt.shape[1], total_len):
        logits, cache = decode(params, cache, tok, jnp.asarray(pos, jnp.int32))
        outs.append(np.asarray(logits))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return np.stack(outs)


def test_ring_cache_equals_full_cache_beyond_window():
    """Decode well past the window: ring cache (window slots) == full cache."""
    params = T.init_params(jax.random.key(0), CFG)
    prompt = sample_batch(CFG, 2, 4, seed=1, with_labels=False)["tokens"]
    total = 24  # >> window=8: several wraps
    full = _decode_tokens(CFG, params, prompt, total, cache_len=total)
    ring = _decode_tokens(CFG, params, prompt, total, cache_len=CFG.window)
    np.testing.assert_allclose(ring, full, rtol=2e-4, atol=2e-4)


def test_ring_cache_prefill_shorter_than_window():
    """pos < window regime: causality must mask the unwritten slots."""
    params = T.init_params(jax.random.key(1), CFG)
    prompt = sample_batch(CFG, 1, 2, seed=2, with_labels=False)["tokens"]
    full = _decode_tokens(CFG, params, prompt, 7, cache_len=32)
    ring = _decode_tokens(CFG, params, prompt, 7, cache_len=CFG.window)
    np.testing.assert_allclose(ring, full, rtol=2e-4, atol=2e-4)


def test_decode_matches_forward_logits():
    """fori_loop decode path == full forward at every position (no window)."""
    cfg = dataclasses.replace(CFG, window=0)
    params = T.init_params(jax.random.key(2), cfg)
    toks = sample_batch(cfg, 2, 10, seed=3, with_labels=False)["tokens"]
    # teacher-forced decode: feed the SAME tokens, compare per-step logits
    prefill = jax.jit(ST.make_prefill_step(cfg, 16))
    decode = jax.jit(ST.make_serve_step(cfg))
    logits, cache = prefill(params, {"tokens": toks[:, :4]})
    got = [np.asarray(logits)]
    for pos in range(4, 10):
        logits, cache = decode(params, cache, toks[:, pos],
                               jnp.asarray(pos, jnp.int32))
        got.append(np.asarray(logits))
    hidden = T.forward(params, cfg, {"tokens": toks})
    ref_all = np.asarray(T.lm_logits(params, cfg, hidden))
    # decode-step logits at position p predict token p+1 ⇒ compare to
    # forward logits at positions 3..9
    for i, p in enumerate(range(3, 10)):
        np.testing.assert_allclose(got[i], ref_all[:, p], rtol=2e-4, atol=2e-4)
