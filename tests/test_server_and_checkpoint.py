"""AFLServer (incremental / stragglers / secure masking), feature maps, and
checkpoint round-trips — the beyond-paper extensions of DESIGN.md §8."""

import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.config import FLConfig
from repro.core import analytic as al
from repro.core.features import identity_map, relu_map, rff_map
from repro.data import synthetic as D
from repro.fl import AFLServer, afl, make_report, masked_reports


def _reports(n_clients=8, n=400, d=24, c=5, gamma=1.0, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d))
    y = np.eye(c)[rng.integers(0, c, n)]
    bounds = np.linspace(0, n, n_clients + 1).astype(int)
    reps = [make_report(k, x[a:b], y[a:b], gamma)
            for k, (a, b) in enumerate(zip(bounds, bounds[1:]))]
    return x, y, reps


class TestAFLServer:
    def test_incremental_equals_joint(self):
        x, y, reps = _reports()
        srv = AFLServer(dim=24, num_classes=5, gamma=1.0)
        srv.submit_many(reps)
        w_joint = al.ridge_solve(x, y, 0.0)
        np.testing.assert_allclose(srv.solve(), w_joint, rtol=1e-8, atol=1e-9)

    def test_partial_participation_is_exact_on_subset(self):
        """Paper §5 straggler concern: the aggregate over any subset is the
        exact joint solution of that subset's data — no waiting required."""
        x, y, reps = _reports(n_clients=8)
        srv = AFLServer(dim=24, num_classes=5, gamma=1.0)
        srv.submit_many(reps[:5])                     # 3 stragglers missing
        n5 = 400 * 5 // 8
        w_sub = al.ridge_solve(x[:n5], y[:n5], 0.0)
        np.testing.assert_allclose(srv.solve(), w_sub, rtol=1e-8, atol=1e-9)
        # stragglers arrive later, any order
        for r in (reps[7], reps[5], reps[6]):
            srv.submit(r)
        w_all = al.ridge_solve(x, y, 0.0)
        np.testing.assert_allclose(srv.solve(), w_all, rtol=1e-8, atol=1e-9)

    def test_duplicate_and_gamma_mismatch_rejected(self):
        _, _, reps = _reports()
        srv = AFLServer(24, 5, gamma=1.0)
        srv.submit(reps[0])
        with pytest.raises(ValueError):
            srv.submit(reps[0])
        bad = make_report(99, np.zeros((4, 24)), np.zeros((4, 5)), gamma=2.0)
        with pytest.raises(ValueError):
            srv.submit(bad)

    def test_state_roundtrip_preserves_count(self):
        """state()/from_state() used to drop the sample count — restored
        servers reported count=0.0. The full round trip must be lossless."""
        x, y, reps = _reports()
        srv = AFLServer(24, 5, gamma=1.0)
        srv.submit_many(reps[:6])
        assert float(srv._stats.count) == 300.0   # 6/8 of 400
        srv2 = AFLServer.from_state(srv.state())
        assert float(srv2._stats.count) == float(srv._stats.count)
        np.testing.assert_array_equal(srv2.state()["count"],
                                      srv.state()["count"])
        # legacy checkpoints without the field still load (count falls to 0)
        legacy = {k: v for k, v in srv.state().items() if k != "count"}
        assert float(AFLServer.from_state(legacy)._stats.count) == 0.0

    def test_low_rank_submit_updates_cached_factor(self):
        """An arrival with a low-rank root folds into the cached factor
        instead of invalidating it — and the next solve is still exact."""
        x, y, reps = _reports(n_clients=8, n=400, d=24)  # 50 rows ≥ d → dense
        srv = AFLServer(24, 5, gamma=1.0, update_rank_budget=6)
        srv.submit_many(reps[:7])
        srv.solve()
        fact = srv._factor_cache[0.0]
        assert fact.updatable
        # a straggler with a genuinely small batch: n_k=4 < d ⇒ root rides
        xs = np.random.default_rng(5).standard_normal((4, 24))
        ys = np.eye(5)[[0, 1, 2, 3]]
        late = make_report(99, xs, ys, 1.0)
        assert late.root is not None and late.root.shape == (4, 24)
        assert srv.submit(late)                   # cache survived
        assert srv._factor_cache[0.0] is not fact  # ...but was updated
        x_all = np.concatenate([x[:350], xs])
        y_all = np.concatenate([y[:350], ys])
        np.testing.assert_allclose(srv.solve(), al.ridge_solve(x_all, y_all, 0.0),
                                   rtol=1e-8, atol=1e-9)

    def test_masked_aggregation_exact_and_hiding(self):
        x, y, reps = _reports()
        masked = masked_reports(reps, seed=7)
        # individual reports are perturbed beyond recognition…
        assert np.abs(masked[0].gram - reps[0].gram).max() > 0.5
        # …but the aggregate is bit-close to the unmasked one
        srv = AFLServer(24, 5, gamma=1.0)
        srv.submit_many(masked)
        w_joint = al.ridge_solve(x, y, 0.0)
        np.testing.assert_allclose(srv.solve(), w_joint, rtol=1e-6, atol=1e-7)


class TestFeatureMaps:
    @staticmethod
    def _xor_data(n=3000, seed=0):
        """Linearly inseparable: label = sign(x0) ⊕ sign(x1)."""
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((n, 2)).astype(np.float32)
        y = ((x[:, 0] > 0) ^ (x[:, 1] > 0)).astype(int)
        return D.Dataset(x, y, 2)

    def test_rff_lifts_nonlinear_data(self):
        """Paper §5: kernel features restore accuracy where the linear head
        fails — with every AFL invariance intact in φ-space."""
        train, test = D.train_test_split(self._xor_data(), 0.25, seed=0)
        fl = FLConfig(num_clients=20, partition="niid1", alpha=0.1)
        lin = afl.run_afl(train, test, fl)
        phi = rff_map(2, 256, lengthscale=1.0, seed=1)
        nonlin = afl.run_afl(train, test, fl, feature_map=phi)
        assert lin.accuracy < 0.62          # XOR is linearly hopeless
        assert nonlin.accuracy > 0.9
        # invariance still holds in φ-space
        fl2 = FLConfig(num_clients=7, partition="niid2", shards_per_client=1)
        again = afl.run_afl(train, test, fl2, feature_map=phi)
        assert abs(again.accuracy - nonlin.accuracy) < 1e-9

    def test_relu_and_identity_maps(self):
        train, test = D.train_test_split(self._xor_data(seed=3), 0.25, seed=0)
        fl = FLConfig(num_clients=5, partition="iid")
        relu = afl.run_afl(train, test, fl, feature_map=relu_map(2, 256, seed=2))
        ident = afl.run_afl(train, test, fl, feature_map=identity_map(2))
        base = afl.run_afl(train, test, fl)
        assert abs(ident.accuracy - base.accuracy) < 1e-12
        assert relu.accuracy > base.accuracy


class TestCheckpoint:
    def test_pytree_roundtrip(self, tmp_path):
        import jax
        import jax.numpy as jnp

        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "b": {"c": np.ones((4,), np.int32), "d": jnp.zeros(())}}
        ckpt.save(tmp_path / "ck", tree, metadata={"step": 7})
        like = jax.tree.map(np.zeros_like, tree)
        back = ckpt.restore(tmp_path / "ck", like=like)
        for k, v in _leaves(tree).items():
            np.testing.assert_array_equal(_leaves(back)[k], v)

    def test_restore_validates_shapes(self, tmp_path):
        tree = {"w": np.ones((3, 3))}
        ckpt.save(tmp_path / "ck", tree)
        with pytest.raises(ValueError):
            ckpt.restore(tmp_path / "ck", like={"w": np.ones((2, 2))})

    def test_save_server_async_coroutine_state_guarded(self, tmp_path):
        """AsyncAFLServer.state() is a coroutine; the sync save_server must
        fail loudly with guidance instead of pickling a coroutine object."""
        from repro.fl import AsyncAFLServer

        srv = AsyncAFLServer(24, 5, gamma=1.0)
        with pytest.raises(TypeError, match="await server.state"):
            ckpt.save_server(tmp_path / "srv", srv)

    def test_server_roundtrip_resumes_aggregation(self, tmp_path):
        x, y, reps = _reports()
        srv = AFLServer(24, 5, gamma=1.0)
        srv.submit_many(reps[:4])
        ckpt.save_server(tmp_path / "srv", srv)
        srv2 = ckpt.load_server(tmp_path / "srv")
        assert float(srv2._stats.count) == float(srv._stats.count) == 200.0
        srv2.submit_many(reps[4:])           # resume after "restart"
        w_joint = al.ridge_solve(x, y, 0.0)
        np.testing.assert_allclose(srv2.solve(), w_joint, rtol=1e-8, atol=1e-9)
        with pytest.raises(ValueError):
            srv2.submit(reps[0])             # dedup survives the round trip


def _leaves(tree):
    import jax

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {str(p): np.asarray(v) for p, v in flat}
